//! Integration tests over the full stack: execution backend + orchestrator
//! + schedulers. They run UNCONDITIONALLY against the pure-Rust
//! `NativeBackend` — a fresh checkout with no Python artifacts and no XLA
//! native libraries exercises real multi-round train/aggregate/eval here.
//! The PJRT-artifact variants live behind the `pjrt` feature (module
//! `pjrt_artifacts` at the bottom).

use iiot_fl::config::SimConfig;
use iiot_fl::fl::{SchedulerSpec, Session};
use iiot_fl::runtime::{make_backend, Backend, NativeBackend};

fn mlp_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.exec_model = "mlp".into();
    cfg.cost_model = "vgg11".into();
    cfg.test_size = 512; // 2 eval batches
    cfg.dataset_max = 600; // small shards keep tests fast
    cfg
}

#[test]
fn make_backend_serves_both_presets_natively_without_artifacts() {
    // No artifacts/ directory exists in a fresh checkout: both executable
    // presets must still produce working backends from the layer-graph
    // engine.
    for preset in ["mlp", "cnn"] {
        let b = make_backend(std::path::Path::new("artifacts"), preset).unwrap();
        assert_eq!(b.meta().preset, preset);
        assert!(b.init_params().is_ok());
    }
    // Only unknown presets error now.
    let err = make_backend(std::path::Path::new("artifacts"), "resnet")
        .err()
        .expect("unknown presets must fail");
    assert!(err.to_string().contains("unknown preset"), "{err}");
}

#[test]
fn backend_init_train_eval_grad_roundtrip() {
    let engine = NativeBackend::mlp();
    let meta = engine.meta().clone();

    let params = engine.init_params().unwrap();
    assert_eq!(params.len(), meta.param_shapes.len());
    let total: usize = params.iter().map(|p| p.len()).sum();
    assert_eq!(total, meta.param_total);

    // init must be deterministic (seeded in the backend)
    let params2 = engine.init_params().unwrap();
    assert_eq!(params, params2);

    let dim = meta.sample_dim();
    let x = vec![0.1f32; meta.train_batch * dim];
    let y: Vec<i32> = (0..meta.train_batch as i32).map(|i| i % 10).collect();

    // lr = 0 is the identity
    let (same, loss0) = engine.train_step(&params, &x, &y, 0.0).unwrap();
    assert_eq!(same, params);
    assert!((loss0 - 10f32.ln()).abs() < 1e-4, "zero-head init loss must be ln 10");

    // a real step changes params and the gradient agrees with the step
    let (stepped, _) = engine.train_step(&params, &x, &y, 0.01).unwrap();
    assert_ne!(stepped, params);
    let g = engine.grad(&params, &x, &y).unwrap();
    assert_eq!(g.len(), meta.param_total);
    let mut manual = params.clone();
    iiot_fl::fl::vecmath::sgd_step_flat(&mut manual, &g, 0.01);
    let diff = iiot_fl::fl::vecmath::l2_diff(&manual, &stepped);
    assert!(diff < 1e-4, "grad/train_step disagree: {diff}");

    // eval on a uniform predictor: loss = ln 10, accuracy near chance
    let xe = vec![0.1f32; meta.eval_batch * dim];
    let ye: Vec<i32> = (0..meta.eval_batch as i32).map(|i| i % 10).collect();
    let (l, acc) = engine.eval_batch(&params, &xe, &ye).unwrap();
    assert!((l / meta.eval_batch as f64 - 10f64.ln()).abs() < 1e-4);
    assert!(acc <= meta.eval_batch as f64);
}

#[test]
fn session_runs_every_scheme_one_round() {
    // ONE session serves the whole scheduler menu: the DDSRA family
    // shares the cached gamma estimate, and every scheme faces identical
    // environment streams.
    let session = Session::builder(mlp_cfg()).rounds(2).eval_every(2).build().unwrap();
    let exp = session.experiment();
    for spec in SchedulerSpec::all() {
        let label = spec.label();
        let log = session.run(&spec).unwrap();
        assert_eq!(log.records.len(), 2, "{label}");
        assert!(log.records[1].cum_delay >= log.records[0].delay, "{label}");
        assert!(log.records.last().unwrap().test_acc.is_some(), "{label}");
        // J channels -> at most J gateways selected per round
        for r in &log.records {
            assert!(r.selected.count() <= exp.cfg.num_channels, "{label}");
        }
    }
}

#[test]
fn runs_are_deterministic_and_paired_across_schedulers() {
    let cfg = mlp_cfg();
    let session = Session::builder(cfg.clone()).rounds(3).eval_every(3).build().unwrap();

    // Same scheme twice through one session: identical trajectories.
    let a = session.run(&SchedulerSpec::RoundRobin).unwrap();
    let b = session.run(&SchedulerSpec::RoundRobin).unwrap();
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.delay, rb.delay);
        assert_eq!(ra.test_acc, rb.test_acc);
        assert_eq!(ra.train_loss, rb.train_loss);
    }

    // A re-built session from the same config seed reproduces the run.
    let session2 = Session::builder(cfg).rounds(3).eval_every(3).build().unwrap();
    let c = session2.run(&SchedulerSpec::RoundRobin).unwrap();
    for (ra, rc) in a.records.iter().zip(&c.records) {
        assert_eq!(ra.delay, rc.delay);
        assert_eq!(ra.test_acc, rc.test_acc);
    }
}

#[test]
fn divergence_mode_produces_per_gateway_divergence() {
    let session =
        Session::builder(mlp_cfg()).rounds(2).eval_every(0).divergence().build().unwrap();
    let log = session.run(&SchedulerSpec::RoundRobin).unwrap();
    let mean = log.mean_divergence().unwrap();
    assert_eq!(mean.len(), session.experiment().topo.num_gateways());
    assert!(mean.iter().all(|&d| d.is_finite() && d > 0.0), "{mean:?}");
}

#[test]
fn grad_stats_reflect_non_iid_structure() {
    let session = Session::builder(mlp_cfg()).build().unwrap();
    let exp = session.experiment();
    let stats = exp.estimate_grad_stats(4).unwrap();
    assert!(stats.sigma.iter().all(|&s| s.is_finite() && s >= 0.0));
    assert!(stats.delta.iter().all(|&d| d.is_finite() && d >= 0.0));
    assert!(stats.lsmooth.iter().all(|&l| l > 0.0));
    // Gateway 0's devices hold all 10 classes; their local gradient should
    // be closer to the global one than the most-skewed device's.
    let d0: f64 = exp.topo.gateways[0]
        .members
        .iter()
        .map(|&n| stats.delta[n])
        .sum::<f64>()
        / exp.topo.gateways[0].members.len() as f64;
    let worst = stats.delta.iter().cloned().fold(0.0f64, f64::max);
    assert!(d0 < worst, "gw0 delta {d0} should be below the max {worst}");
}

/// The conv acceptance test: multi-round federated training of the
/// VGG-mini `cnn` preset through the native layer-graph engine — no
/// artifacts, no pjrt. The training loss must decrease from ln 10 (the
/// zero-head init) and evaluation must handle a test set that is NOT a
/// multiple of the eval batch (a trailing partial batch).
#[test]
fn cnn_native_training_loss_decreases_from_ln10() {
    let mut cfg = SimConfig::default();
    cfg.exec_model = "cnn".into();
    cfg.cost_model = "cnn".into(); // the scheduler plans the net it trains
    cfg.num_gateways = 1;
    cfg.num_devices = 1;
    cfg.num_channels = 1;
    cfg.local_iters = 3;
    cfg.lr = 0.1; // head-driven early descent is fast and low-noise
    cfg.dataset_max = 400;
    cfg.test_size = 128; // < eval_batch 256: exercises the partial path
    cfg.rounds = 2;
    // Generous energy budgets: the baseline's fixed plan must stay
    // feasible every round, so both rounds really train.
    cfg.device_energy_max = 500.0;
    cfg.gw_energy_max = 5000.0;
    let session = Session::builder(cfg).rounds(2).eval_every(2).build().unwrap();
    let log = session.run(&SchedulerSpec::RoundRobin).unwrap();
    assert_eq!(log.records.len(), 2);
    assert!(
        log.records.iter().all(|r| !r.failed.get(0)),
        "fixed plan should stay feasible with generous energy budgets"
    );

    // Round 0's mean local loss starts at the exact zero-head ln 10.
    let first = log.records[0].train_loss.unwrap();
    let last = log.records[1].train_loss.unwrap();
    let ln10 = 10f64.ln();
    assert!(first <= ln10 + 1e-3, "first-round loss {first} must start at ln 10");
    assert!(last < first - 0.01, "cnn loss must decrease: {first} -> {last}");

    // Eval ran on the 128-sample (partial-batch) test set.
    let acc = log.records[1].test_acc.unwrap();
    assert!((0.0..=1.0).contains(&acc));
    assert!(log.records[1].test_loss.unwrap().is_finite());
}

/// The acceptance-criteria test: genuine multi-round federated training
/// through the NativeBackend — train loss must DECREASE and test accuracy
/// must beat 10-class chance, with no artifacts anywhere.
#[test]
fn ddsra_native_training_learns() {
    let session = Session::builder(mlp_cfg()).rounds(12).eval_every(12).build().unwrap();
    let log = session.run(&SchedulerSpec::ddsra()).unwrap();
    let acc = log.final_accuracy().unwrap();
    assert!(acc > 0.12, "accuracy {acc} not above chance after 12 rounds");
    // loss must decrease
    let first = log.records.iter().find_map(|r| r.train_loss).unwrap();
    let last = log.records.iter().rev().find_map(|r| r.train_loss).unwrap();
    assert!(last < first, "loss {first} -> {last}");
}

// ---------------------------------------------------------------------------
// PJRT artifact variants: identical scenarios through the XLA engine.
// Only built with `--features pjrt`; skip gracefully when `make artifacts`
// has not been run.
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt_artifacts {
    use super::*;
    use iiot_fl::runtime::Engine;
    use std::path::Path;

    fn artifacts() -> Option<&'static Path> {
        let p = Path::new("artifacts");
        if p.join("mlp.meta").exists() {
            Some(p)
        } else {
            eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
            None
        }
    }

    #[test]
    fn pjrt_engine_roundtrip() {
        let Some(dir) = artifacts() else { return };
        let engine = Engine::load(dir, "mlp").unwrap();
        let meta = engine.meta().clone();

        let params = engine.init_params().unwrap();
        assert_eq!(params.len(), meta.param_shapes.len());
        assert_eq!(engine.init_params().unwrap(), params);

        let dim = meta.sample_dim();
        let x = vec![0.1f32; meta.train_batch * dim];
        let y: Vec<i32> = (0..meta.train_batch as i32).map(|i| i % 10).collect();
        let (same, loss0) = engine.train_step(&params, &x, &y, 0.0).unwrap();
        assert_eq!(same, params);
        assert!((loss0 - 10f32.ln()).abs() < 1e-4);
        let (stepped, _) = engine.train_step(&params, &x, &y, 0.01).unwrap();
        assert_ne!(stepped, params);
    }

    #[test]
    fn pjrt_experiment_trains() {
        let Some(dir) = artifacts() else { return };
        let session = Session::builder(mlp_cfg())
            .rounds(2)
            .eval_every(2)
            .artifacts(dir)
            .build()
            .unwrap();
        let log = session.run(&SchedulerSpec::RoundRobin).unwrap();
        assert!(log.records.last().unwrap().test_acc.is_some());
    }

    #[test]
    fn cnn_engine_smoke() {
        let Some(dir) = artifacts() else { return };
        if !dir.join("cnn.meta").exists() {
            eprintln!("SKIP: cnn artifacts not built");
            return;
        }
        let engine = Engine::load(dir, "cnn").unwrap();
        let meta = engine.meta().clone();
        assert_eq!(meta.input_train, vec![64, 32, 32, 3]);
        let params = engine.init_params().unwrap();
        let x = vec![0.05f32; meta.train_batch * meta.sample_dim()];
        let y: Vec<i32> = (0..meta.train_batch as i32).map(|i| i % 10).collect();
        let (next, loss) = engine.train_step(&params, &x, &y, 0.01).unwrap();
        assert!((loss - 10f32.ln()).abs() < 1e-4);
        assert_ne!(next, params);
    }
}
