//! Allocation budget for the DDSRA scheduling hot path: one warm
//! plant-scale (M = 24, J = 8, N = 240) `schedule()` call must stay
//! within a small fixed budget. The per-gateway [`GatewayCtx`] tables,
//! the row-shared solve scratch and the incremental λ-sweep keep the
//! round to O(M) modest buffers — the pre-refactor solver allocated a
//! fresh frequency vector for every one of the ~80 bisection probes of
//! every BCD iteration of every (m, j) pair, plus a Hungarian cost
//! matrix per candidate cap (M·J of them). Measured with a
//! bytes-counting global allocator, so the whole binary holds exactly
//! ONE test — a concurrent test would pollute the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use iiot_fl::config::SimConfig;
use iiot_fl::dnn::models;
use iiot_fl::energy::EnergyArrivals;
use iiot_fl::net::ChannelModel;
use iiot_fl::rng::Rng;
use iiot_fl::sched::{Ddsra, RoundCtx, SchedPath, Scheduler};
use iiot_fl::topo::Topology;

/// Counts every allocated byte (frees are ignored: the budget is on
/// allocation traffic, which is what costs time in the hot loop).
struct CountingAlloc;

static ALLOCATED: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCATED.fetch_add(l.size() as u64, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCATED.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCATED.fetch_add(l.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn spent() -> u64 {
    ALLOCATED.load(Ordering::Relaxed)
}

#[test]
fn plant_scale_schedule_stays_within_allocation_budget() {
    let mut cfg = SimConfig::default();
    cfg.apply_scenario("plant").unwrap(); // N = 240, M = 24, J = 8
    let mut rng = Rng::new(0xa110c);
    let topo = Topology::generate(&cfg, &mut rng);
    let chan = ChannelModel::new(&cfg, &topo, &mut rng);
    let model = models::by_name(&cfg.cost_model).unwrap();

    // Serial solves: the budget targets the algorithm's own traffic, not
    // rayon's per-task bookkeeping (parity with the parallel path is
    // pinned elsewhere).
    let mut d = Ddsra::new(cfg.lyapunov_v, vec![0.5; topo.num_gateways()]);
    assert_eq!(d.sched_path, SchedPath::Incremental);

    // Warmup round: faults any lazily initialized runtime state.
    let state = chan.draw(&mut rng);
    let arr = EnergyArrivals::draw(&cfg, &mut rng);
    let warm = RoundCtx {
        cfg: &cfg,
        topo: &topo,
        model: &model,
        chan: &chan,
        state: &state,
        arrivals: &arr,
        round: 0,
    };
    let _ = d.schedule(&warm);

    // One measured round. Expected traffic: 24 GatewayCtx table sets
    // (~10 KB each), one scratch set + the per-iterate plan clones per
    // row, the edge list, and a Θ matrix per matcher EVENT (≈ J·ln(M/J),
    // not per cap) — a few hundred KB in total. The historical per-probe
    // frequency vectors alone were ~46 000 allocations per round.
    let state = chan.draw(&mut rng);
    let arr = EnergyArrivals::draw(&cfg, &mut rng);
    let round = RoundCtx {
        cfg: &cfg,
        topo: &topo,
        model: &model,
        chan: &chan,
        state: &state,
        arrivals: &arr,
        round: 1,
    };
    let t0 = spent();
    let dec = d.schedule(&round);
    let bytes = spent() - t0;
    assert!(dec.plans.len() <= cfg.num_channels);
    assert!(
        bytes < 2 << 20,
        "one plant-scale schedule() allocated {bytes} bytes (> 2 MB) — \
         per-probe or per-cap buffers are back in the hot path"
    );
}
