//! Wire-level split execution suite: a loopback tcp run through the
//! gateway service must be BYTE-identical to the in-process split
//! runtime — which partition.rs already pins against the fused engine —
//! at every legal cut, across backend calls and whole multi-round FL
//! runs alike. Plus the protocol edges: handshake skew is refused with
//! a hard error (never dropout), malformed/truncated frames are
//! rejected, and a peer that dies mid-round degrades onto the exact
//! `FaultPlan` dropout semantics instead of aborting the run.

mod common;

use std::net::TcpStream;
use std::sync::Arc;

use common::serialize;
use iiot_fl::config::{SimConfig, Transport};
use iiot_fl::dnn::models;
use iiot_fl::fl::{SchedulerSpec, Session};
use iiot_fl::net::serve::GatewayServer;
use iiot_fl::net::transport::{is_peer_lost, Conn, ConnPool};
use iiot_fl::net::wire::{self, FrameError, Msg, MAGIC, VERSION};
use iiot_fl::rng::Rng;
use iiot_fl::runtime::{Backend, KernelPath, Params, PartitionedBackend, RemoteBackend};

fn batch(seed: u64, n: usize, dim: usize) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32 * 0.5).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.below(10) as i32).collect();
    (x, y)
}

fn assert_bits_eq(a: &Params, b: &Params, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: tensor count");
    for (t, (ta, tb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ta.len(), tb.len(), "{what}: tensor {t} len");
        for (i, (va, vb)) in ta.iter().zip(tb).enumerate() {
            assert_eq!(va.to_bits(), vb.to_bits(), "{what}: tensor {t} idx {i}: {va} vs {vb}");
        }
    }
}

/// The partition.rs base config, shared by every whole-run test here:
/// split execution on, scheduler planning the net it trains.
fn split_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.exec_model = "mlp".into();
    cfg.cost_model = "mlp".into();
    cfg.execute_partition = true;
    cfg.test_size = 512;
    cfg.dataset_max = 500;
    cfg
}

// ------------------------------------------------------------ handshake

/// A client speaking a future protocol version is refused with an `Err`
/// frame that names the version — never silently served, never treated
/// as peer loss.
#[test]
fn version_skew_is_refused_with_a_named_err_frame() {
    let handle =
        GatewayServer::new("mlp", KernelPath::default()).unwrap().spawn("127.0.0.1:0").unwrap();
    let stream = TcpStream::connect(handle.addr()).unwrap();
    wire::write_msg(
        &mut (&stream),
        &Msg::Hello {
            magic: MAGIC,
            version: VERSION + 1,
            preset: "mlp".into(),
            kernel: KernelPath::default().as_str().into(),
        },
    )
    .unwrap();
    match wire::read_msg(&mut (&stream)).unwrap() {
        Msg::Err { reason } => {
            assert!(reason.contains("version"), "reason must name the skew: {reason}");
        }
        other => panic!("expected Err frame, got {}", other.name()),
    }
}

/// Bad magic: refused at the door, reason names the magic.
#[test]
fn bad_magic_is_refused() {
    let handle =
        GatewayServer::new("mlp", KernelPath::default()).unwrap().spawn("127.0.0.1:0").unwrap();
    let stream = TcpStream::connect(handle.addr()).unwrap();
    wire::write_msg(
        &mut (&stream),
        &Msg::Hello {
            magic: 0xDEAD_BEEF,
            version: VERSION,
            preset: "mlp".into(),
            kernel: KernelPath::default().as_str().into(),
        },
    )
    .unwrap();
    match wire::read_msg(&mut (&stream)).unwrap() {
        Msg::Err { reason } => assert!(reason.contains("magic"), "{reason}"),
        other => panic!("expected Err frame, got {}", other.name()),
    }
}

/// Model/kernel skew through the real dialer: a REACHABLE gateway
/// refusing the handshake is a plain error — it must NOT classify as
/// peer loss, or a misconfigured fleet would masquerade as 100% dropout.
#[test]
fn preset_and_kernel_skew_abort_instead_of_degrading_to_dropout() {
    let handle =
        GatewayServer::new("mlp", KernelPath::default()).unwrap().spawn("127.0.0.1:0").unwrap();
    let addr = handle.addr();

    let err = Conn::dial(&addr, 2000, "cnn", KernelPath::default()).unwrap_err();
    assert!(!is_peer_lost(&err), "preset skew must not be peer loss: {err:#}");
    assert!(format!("{err:#}").contains("preset"), "{err:#}");

    let err = Conn::dial(&addr, 2000, "mlp", KernelPath::Scalar).unwrap_err();
    assert!(!is_peer_lost(&err), "kernel skew must not be peer loss: {err:#}");
    assert!(format!("{err:#}").contains("kernel"), "{err:#}");

    // And the matching handshake still succeeds afterwards — refused
    // connections never poison the service.
    Conn::dial(&addr, 2000, "mlp", KernelPath::default()).unwrap();
}

// ---------------------------------------------------------------- codec

/// Frame-layer rejection: truncation at any byte is an I/O-class error
/// (the dropout path), while a length prefix past `MAX_FRAME` — or zero
/// — is a protocol violation (the abort path). The distinction is
/// load-bearing: an oversized frame must never silently become dropout.
#[test]
fn truncated_frames_are_io_and_oversized_prefixes_are_protocol() {
    let msg = Msg::SplitResp {
        loss_sum: 1.25,
        correct: 3,
        dcut: vec![0.5, -0.0, f32::MIN_POSITIVE],
        g_top: vec![],
    };
    let mut buf = Vec::new();
    wire::write_msg(&mut buf, &msg).unwrap();
    for cut in 0..buf.len() {
        match wire::read_msg(&mut &buf[..cut]) {
            Err(FrameError::Io(_)) => {}
            other => panic!("truncation at {cut}: expected Io, got {other:?}"),
        }
    }

    let oversized = (wire::MAX_FRAME as u32 + 1).to_le_bytes();
    assert!(matches!(wire::read_msg(&mut &oversized[..]), Err(FrameError::Protocol(_))));
    let zero = 0u32.to_le_bytes();
    assert!(matches!(wire::read_msg(&mut &zero[..]), Err(FrameError::Protocol(_))));
}

/// Awkward payload shapes survive the codec exactly: empty tensors,
/// lengths nowhere near a multiple of 8, sign-of-zero bit patterns, and
/// a `FoldResult` carrying `None`.
#[test]
fn awkward_shapes_roundtrip_bit_exactly() {
    let msgs = vec![
        Msg::SplitReq {
            cut: 0,
            want_grad: false,
            labels: vec![],
            top_params: vec![vec![], vec![-0.0, 0.0, f32::NAN]],
            acts: (0..13).map(|i| i as f32 * 0.1).collect(),
        },
        Msg::FoldAdd { weight: 0.0, params: vec![vec![1.0; 7], vec![], vec![-0.0]] },
        Msg::FoldResult { params: None },
        Msg::FoldResult { params: Some(vec![vec![]]) },
    ];
    for msg in msgs {
        let mut buf = Vec::new();
        wire::write_msg(&mut buf, &msg).unwrap();
        let back = wire::read_msg(&mut &buf[..]).unwrap();
        // Compare re-encoded bytes: NaN breaks PartialEq but not bits.
        assert_eq!(back.encode(), msg.encode(), "{} changed bytes", msg.name());
    }
}

// ----------------------------------------------------- per-cut parity

/// THE backend-level acceptance test: at EVERY legal mlp cut, the
/// remote backend driving a loopback gateway reproduces the in-process
/// split backend bit for bit — SGD trajectories, ragged-test-set eval
/// (full batches + a trailing partial batch over the wire), and the
/// flat minibatch gradient. partition.rs pins the in-process split to
/// the fused engine, so transitivity pins the wire to the fused engine.
#[test]
fn remote_backend_matches_inproc_split_at_every_mlp_cut() {
    let handle =
        GatewayServer::new("mlp", KernelPath::default()).unwrap().spawn("127.0.0.1:0").unwrap();
    let pool =
        Arc::new(ConnPool::new(&handle.addr(), 5000, "mlp", KernelPath::default()));
    let depth = models::by_name("mlp").unwrap().depth();

    for cut in 0..=depth {
        let local = PartitionedBackend::preset("mlp", cut).unwrap();
        let remote =
            RemoteBackend::new(PartitionedBackend::preset("mlp", cut).unwrap(), pool.clone());
        assert_eq!(remote.cut(), cut);
        let meta = local.meta().clone();
        let dim = meta.sample_dim();

        let mut wl = local.init_params().unwrap();
        let mut wr = remote.init_params().unwrap();
        assert_bits_eq(&wr, &wl, &format!("cut {cut} init"));
        for step in 0..2usize {
            let (x, y) = batch(0x71e5 ^ ((step as u64) << 8), meta.train_batch, dim);
            let (nl, ll) = local.train_step(&wl, &x, &y, 0.05).unwrap();
            let (nr, lr) = remote.train_step(&wr, &x, &y, 0.05).unwrap();
            assert_eq!(lr.to_bits(), ll.to_bits(), "cut {cut} step {step} loss");
            assert_bits_eq(&nr, &nl, &format!("cut {cut} step {step} params"));
            wl = nl;
            wr = nr;
        }

        // 300 samples: full eval batches plus a trailing partial batch.
        let (xe, ye) = batch(0xe7a1, 300, dim);
        let (el, ea) = local.eval_full(&wl, &xe, &ye).unwrap();
        let (rl, ra) = remote.eval_full(&wr, &xe, &ye).unwrap();
        assert_eq!(rl.to_bits(), el.to_bits(), "cut {cut} eval loss");
        assert_eq!(ra.to_bits(), ea.to_bits(), "cut {cut} eval acc");

        let (xg, yg) = batch(0x96ad, meta.train_batch, dim);
        let gl = local.grad(&wl, &xg, &yg).unwrap();
        let gr = remote.grad(&wr, &xg, &yg).unwrap();
        assert_eq!(gl.len(), gr.len());
        for (i, (a, b)) in gl.iter().zip(&gr).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "cut {cut} grad[{i}]");
        }
    }
}

/// cnn spot-check at the two structurally extreme cuts: the deepest cut
/// (head-only gateway — zero gateway parameters, the `g_top = []` path)
/// and a mid cut with conv layers on both sides.
#[test]
fn remote_backend_matches_inproc_split_on_cnn_extreme_cuts() {
    let handle =
        GatewayServer::new("cnn", KernelPath::default()).unwrap().spawn("127.0.0.1:0").unwrap();
    let pool =
        Arc::new(ConnPool::new(&handle.addr(), 10_000, "cnn", KernelPath::default()));
    let depth = models::by_name("cnn").unwrap().depth();

    for cut in [depth / 2, depth] {
        let local = PartitionedBackend::preset("cnn", cut).unwrap();
        let remote =
            RemoteBackend::new(PartitionedBackend::preset("cnn", cut).unwrap(), pool.clone());
        let meta = local.meta().clone();
        let dim = meta.sample_dim();
        let w = local.init_params().unwrap();

        let (x, y) = batch(0xc4, meta.train_batch, dim);
        let (nl, ll) = local.train_step(&w, &x, &y, 0.05).unwrap();
        let (nr, lr) = remote.train_step(&w, &x, &y, 0.05).unwrap();
        assert_eq!(lr.to_bits(), ll.to_bits(), "cnn cut {cut} loss");
        assert_bits_eq(&nr, &nl, &format!("cnn cut {cut} params"));

        let (xe, ye) = batch(0xe7, meta.eval_batch, dim);
        let (el, ea) = local.eval_batch(&nl, &xe, &ye).unwrap();
        let (rl, ra) = remote.eval_batch(&nr, &xe, &ye).unwrap();
        assert_eq!(rl.to_bits(), el.to_bits(), "cnn cut {cut} eval loss");
        assert_eq!(ra.to_bits(), ea.to_bits(), "cnn cut {cut} eval acc");
    }
}

// --------------------------------------------------- whole-run parity

/// THE system-level acceptance test: a full multi-round FL run over
/// loopback tcp — split local steps through the gateway service AND the
/// phase-5 FedAvg fold on the gateway's `WeightedAccum` — serializes
/// byte-identically to the in-process run, under both a fixed-plan
/// baseline and DDSRA's per-device per-round cuts.
#[test]
fn loopback_tcp_run_is_byte_identical_to_inproc() {
    let handle =
        GatewayServer::new("mlp", KernelPath::default()).unwrap().spawn("127.0.0.1:0").unwrap();
    let addr = handle.addr();

    let run = |spec: &SchedulerSpec, rounds: usize, tcp: bool| -> String {
        let mut cfg = split_cfg();
        cfg.rounds = rounds;
        if tcp {
            cfg.transport = Transport::Tcp;
            cfg.gateway_addr = addr.clone();
        }
        let session = Session::builder(cfg).rounds(rounds).eval_every(rounds).build().unwrap();
        let log = session.run(spec).unwrap();
        assert!(log.records.iter().any(|r| r.train_loss.is_some()), "must train");
        assert!(
            log.records.iter().all(|r| r.faults.is_none()),
            "a healthy loopback run must not record wire faults"
        );
        serialize(&log)
    };

    assert_eq!(
        run(&SchedulerSpec::RoundRobin, 3, false),
        run(&SchedulerSpec::RoundRobin, 3, true),
        "round-robin tcp run diverged from inproc"
    );
    assert_eq!(
        run(&SchedulerSpec::ddsra(), 2, false),
        run(&SchedulerSpec::ddsra(), 2, true),
        "DDSRA tcp run diverged from inproc"
    );
}

// ------------------------------------------------------- fault mapping

/// Mid-round peer death: the gateway severs connections after a fixed
/// split-request budget; affected devices must land on the `FaultPlan`
/// dropout path (recorded in `faults.dropped`, excluded from the fold)
/// and the run must complete every round.
#[test]
fn mid_round_disconnect_degrades_to_dropout() {
    let mut server = GatewayServer::new("mlp", KernelPath::default()).unwrap();
    server.fail_splits_after(5);
    let handle = server.spawn("127.0.0.1:0").unwrap();

    let mut cfg = split_cfg();
    cfg.transport = Transport::Tcp;
    cfg.gateway_addr = handle.addr();
    cfg.local_iters = 2;
    cfg.rounds = 2;
    let session = Session::builder(cfg).rounds(2).eval_every(2).build().unwrap();
    let log = session.run(&SchedulerSpec::RoundRobin).unwrap();

    assert_eq!(log.records.len(), 2, "the run must survive the disconnects");
    let dropped: Vec<usize> = log
        .records
        .iter()
        .filter_map(|r| r.faults.as_ref())
        .flat_map(|f| f.dropped.iter().copied())
        .collect();
    assert!(!dropped.is_empty(), "severed devices must surface as dropout");
    assert!(log.records.last().unwrap().test_acc.is_some(), "final eval must still run");
}

/// A gateway that is down from the start: every device's dial is
/// refused, every device drops, every fold is empty — so the global
/// model never changes and the final eval equals the init-parameter
/// eval bit for bit. The run still completes.
#[test]
fn dead_gateway_drops_every_device_and_leaves_the_model_unchanged() {
    // Bind an ephemeral port, then free it: a known-dead address.
    let dead = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let mut cfg = split_cfg();
    cfg.transport = Transport::Tcp;
    cfg.gateway_addr = dead;
    cfg.wire_timeout_ms = 500;
    cfg.rounds = 2;
    let session = Session::builder(cfg).rounds(2).eval_every(2).build().unwrap();
    let exp = session.experiment();
    let init = exp.engine.init_params().unwrap();
    let (init_loss, init_acc) =
        exp.engine.eval_full(&init, &exp.test_x, &exp.test_y).unwrap();

    let log = session.run(&SchedulerSpec::RoundRobin).unwrap();
    assert_eq!(log.records.len(), 2, "the run must survive a dead gateway");
    for r in &log.records {
        assert!(r.train_loss.is_none(), "round {}: no device may train", r.round);
        let f = r.faults.as_ref().expect("every round must record drops");
        assert!(!f.dropped.is_empty(), "round {}: drops must be recorded", r.round);
    }
    let last = log.records.last().unwrap();
    assert_eq!(last.test_loss.unwrap().to_bits(), init_loss.to_bits(), "model changed");
    assert_eq!(last.test_acc.unwrap().to_bits(), init_acc.to_bits(), "model changed");
}
