//! λ-sweep path parity: DDSRA's `incremental` channel assignment must
//! make BIT-identical decisions to the verbatim per-cap `sweep` oracle —
//! same (gateway, channel) selections, same Λ bits, same queue
//! trajectories. Pinned three ways:
//!
//! * a randomized property suite over synthetic Λ/queue matrices
//!   (duplicate caps, infeasible pairs, all-infeasible rounds, V = 0 and
//!   huge-V regimes, rows > cols);
//! * whole-run parity on the `paper` scenario through the real session
//!   engine (schedule-only, both paths, byte-identical logs);
//! * a nation-scale (M = 2000, J = 8) schedule smoke on the default
//!   incremental path — the scale the incremental sweep exists for.

mod common;

use common::serialize;
use iiot_fl::config::SimConfig;
use iiot_fl::dnn::models;
use iiot_fl::energy::EnergyArrivals;
use iiot_fl::fl::{SchedulerSpec, Session};
use iiot_fl::net::ChannelModel;
use iiot_fl::rng::Rng;
use iiot_fl::sched::{Ddsra, Decision, GatewayPlan, RoundCtx, SchedPath, Scheduler};
use iiot_fl::topo::Topology;

/// Decision fingerprint: selection order AND exact Λ bits.
fn key(d: &Decision) -> Vec<(usize, usize, u64)> {
    d.plans.iter().map(|p| (p.gateway, p.channel, p.lambda.to_bits())).collect()
}

fn synthetic_plan(m: usize, j: usize, lambda: f64) -> GatewayPlan {
    GatewayPlan { gateway: m, channel: j, power: 1.0, partition: vec![], freq: vec![], lambda }
}

/// Random Λ matrices with duplicate caps and infeasible holes: both paths
/// must pick the exact same assignment, for every V regime.
#[test]
fn randomized_synthetic_assignments_agree_bit_for_bit() {
    let mut rng = Rng::new(0x5eed);
    let vs = [0.0, 0.5, 100.0, 1e12];
    for case in 0..400 {
        let mm = 1 + rng.below(10);
        let jj = 1 + rng.below(mm.min(6));
        let v = vs[case % vs.len()];
        let queues: Vec<f64> = (0..mm).map(|_| rng.uniform(0.0, 20.0)).collect();

        // Λ pool with deliberate repeats so caps collide into one batch
        // exactly as `caps.dedup()` merges them on the oracle side.
        let pool: Vec<f64> = (0..4).map(|_| rng.uniform(0.1, 50.0)).collect();
        let all_infeasible = case % 50 == 49;
        let plans: Vec<Vec<Option<GatewayPlan>>> = (0..mm)
            .map(|m| {
                (0..jj)
                    .map(|j| {
                        if all_infeasible || rng.f64() < 0.35 {
                            return None;
                        }
                        let lambda = if rng.f64() < 0.4 {
                            pool[rng.below(pool.len())]
                        } else {
                            rng.uniform(0.1, 50.0)
                        };
                        Some(synthetic_plan(m, j, lambda))
                    })
                    .collect()
            })
            .collect();

        let mut sweep = Ddsra::new(v, vec![0.0; mm]);
        sweep.sched_path = SchedPath::Sweep;
        sweep.queues = queues.clone();
        let mut inc = Ddsra::new(v, vec![0.0; mm]);
        inc.queues = queues;
        assert_eq!(inc.sched_path, SchedPath::Incremental);

        let ds = sweep.assign(plans.clone());
        let di = inc.assign(plans);
        assert_eq!(key(&ds), key(&di), "case {case}: v={v} M={mm} J={jj}");
        if all_infeasible {
            assert!(ds.plans.is_empty(), "case {case}: expected an empty decision");
        }
    }
}

/// Whole-run parity through the real engine: `paper` scenario,
/// schedule-only, 8 rounds — the sweep-path and incremental-path logs
/// must serialize to the same bytes (delays, selections, queues and all).
#[test]
fn paper_scenario_runs_are_byte_identical_across_paths() {
    let run = |path: SchedPath| {
        let mut cfg = SimConfig::default();
        cfg.apply_scenario("paper").unwrap();
        cfg.sched_path = path;
        cfg.rounds = 8;
        let session =
            Session::builder(cfg).rounds(8).eval_every(8).schedule_only().build().unwrap();
        serialize(&session.run(&SchedulerSpec::ddsra()).unwrap())
    };
    assert_eq!(
        run(SchedPath::Sweep),
        run(SchedPath::Incremental),
        "sweep and incremental λ-sweep paths diverged over a full paper run"
    );
}

/// Nation-scale schedule smoke: one DDSRA round at M = 2000, J = 8 on the
/// default (incremental, rayon-parallel) production path. The generous
/// energy budgets keep the round feasible, as in the CI nation smoke.
#[test]
fn nation_scale_schedule_round_on_default_path() {
    let mut cfg = SimConfig::default();
    cfg.apply_scenario("nation").unwrap();
    cfg.device_energy_max = 500.0;
    cfg.gw_energy_max = 5000.0;
    cfg.validate().unwrap();
    let mut rng = Rng::new(99);
    let topo = Topology::generate(&cfg, &mut rng);
    let chan = ChannelModel::new(&cfg, &topo, &mut rng);
    let model = models::by_name(&cfg.cost_model).unwrap();
    let state = chan.draw(&mut rng);
    let arr = EnergyArrivals::draw(&cfg, &mut rng);
    let ctx = RoundCtx {
        cfg: &cfg,
        topo: &topo,
        model: &model,
        chan: &chan,
        state: &state,
        arrivals: &arr,
        round: 0,
    };

    let mut d = Ddsra::new(cfg.lyapunov_v, vec![0.5; topo.num_gateways()]);
    d.parallel = true;
    assert_eq!(d.sched_path, SchedPath::Incremental);
    let dec = d.schedule(&ctx);
    assert!(!dec.plans.is_empty(), "nation round scheduled nothing");
    assert!(dec.plans.len() <= cfg.num_channels);
    let mut gws: Vec<_> = dec.plans.iter().map(|p| p.gateway).collect();
    let mut chs: Vec<_> = dec.plans.iter().map(|p| p.channel).collect();
    gws.sort_unstable();
    chs.sort_unstable();
    let (gl, cl) = (gws.len(), chs.len());
    gws.dedup();
    chs.dedup();
    assert_eq!(gws.len(), gl, "gateway selected twice");
    assert_eq!(chs.len(), cl, "channel assigned twice");
    assert!(dec.round_delay().is_finite() && dec.round_delay() > 0.0);
}
