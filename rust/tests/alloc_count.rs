//! Allocation budget for the hot batch paths: after one warmup step has
//! grown every per-worker scratch buffer, `train_step` must allocate only
//! the per-call block-gradient arena + reduced gradient + stepped params
//! (a few hundred KB each for cnn), NOT a fresh gradient buffer per
//! sample (the pre-kernel engine allocated ~40 MB per cnn step that way).
//! Measured with a bytes-counting global allocator, so the whole binary
//! holds exactly ONE test — a concurrent test would pollute the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use iiot_fl::rng::Rng;
use iiot_fl::runtime::{make_backend_kernel, Backend, KernelPath};

/// Counts every allocated byte (frees are ignored: the budget is on
/// allocation traffic, which is what costs time in the hot loop).
struct CountingAlloc;

static ALLOCATED: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCATED.fetch_add(l.size() as u64, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCATED.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCATED.fetch_add(l.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn spent() -> u64 {
    ALLOCATED.load(Ordering::Relaxed)
}

#[test]
fn cnn_hot_paths_stay_within_allocation_budget() {
    // A fixed-size pool bounds how many per-worker scratch sets can ever
    // be grown, making the budget deterministic across machines.
    let pool = rayon::ThreadPoolBuilder::new().num_threads(4).build().unwrap();
    pool.install(|| {
        let be =
            make_backend_kernel(std::path::Path::new("artifacts"), "cnn", KernelPath::Vectorized)
                .unwrap();
        let meta = be.meta().clone();
        let mut rng = Rng::new(0xa110c);
        let dim = meta.sample_dim();
        let x: Vec<f32> = (0..meta.train_batch * dim).map(|_| rng.normal() as f32 * 0.5).collect();
        let y: Vec<i32> = (0..meta.train_batch).map(|_| rng.below(10) as i32).collect();

        // Warmup: two steps + one eval grow every thread-local scratch
        // (arena, ping-pong buffers, im2col patch matrices) to full size.
        let params = be.init_params().unwrap();
        let (params, _) = be.train_step(&params, &x, &y, 0.01).unwrap();
        let (params, _) = be.train_step(&params, &x, &y, 0.01).unwrap();
        be.eval_partial_batch(&params, &x, &y).unwrap().unwrap();

        // Two measured train steps. Unavoidable per-call traffic: the flat
        // block-gradient arena (8 blocks x ~624 KB for cnn), the reduced
        // gradient, the stepped parameter clone, the per-sample loss table
        // and the small per-op parameter-ref vectors — ~7 MB per step.
        // A per-sample gradient allocation would cost 64 x 624 KB per step
        // and blow straight through the bound.
        let t0 = spent();
        let (p1, _) = be.train_step(&params, &x, &y, 0.01).unwrap();
        let (p2, _) = be.train_step(&p1, &x, &y, 0.01).unwrap();
        let train_bytes = spent() - t0;
        assert!(p2.len() == params.len());
        assert!(
            train_bytes < 32 << 20,
            "2 cnn train steps allocated {} MB — per-sample buffers are back in the hot path",
            train_bytes >> 20
        );

        // Eval allocates no gradient state at all: the budget is a pair of
        // loss tables plus at most a late-woken worker's scratch set.
        let e0 = spent();
        be.eval_partial_batch(&p2, &x, &y).unwrap().unwrap();
        be.eval_partial_batch(&p2, &x, &y).unwrap().unwrap();
        let eval_bytes = spent() - e0;
        assert!(
            eval_bytes < 8 << 20,
            "2 cnn eval batches allocated {} MB — eval should reuse per-worker scratch",
            eval_bytes >> 20
        );
    });
}
