//! Deterministic-replay guarantee: two session runs built from the same
//! `SimConfig` seed must produce BYTE-identical round logs —
//! bit-for-bit equal floats, not approximately equal. This pins down the
//! `rng.rs` stateless stream keying the round engine draws from, and
//! protects the parallel paths (rayon DDSRA and the rayon device fan-out
//! must not perturb results; `rust/tests/round_engine.rs` additionally
//! pins thread-count invariance at large N).

mod common;

use common::serialize;
use iiot_fl::config::SimConfig;
use iiot_fl::fl::{SchedulerSpec, Session};
use iiot_fl::sched::Ddsra;

fn cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.exec_model = "mlp".into();
    cfg.test_size = 512;
    cfg.dataset_max = 500;
    cfg.rounds = 3;
    cfg
}

#[test]
fn same_seed_same_bytes() {
    let mut logs = Vec::new();
    for _ in 0..2 {
        let session = Session::builder(cfg()).rounds(3).eval_every(3).build().unwrap();
        logs.push(serialize(&session.run(&SchedulerSpec::ddsra()).unwrap()));
    }
    assert_eq!(logs[0], logs[1], "replay with identical SimConfig diverged");
}

#[test]
fn different_seed_different_bytes() {
    let run = |seed: u64| {
        let mut c = cfg();
        c.seed = seed;
        let session = Session::builder(c).rounds(3).eval_every(3).build().unwrap();
        serialize(&session.run(&SchedulerSpec::RoundRobin).unwrap())
    };
    assert_ne!(run(1), run(2), "seed must influence the trajectory");
}

/// The rayon-parallel batch forward/backward of the layer-graph engine
/// must not perturb results either: two cnn (VGG-mini) runs from the same
/// seed are byte-identical, conv path and partial-batch eval included.
#[test]
fn cnn_native_runs_replay_byte_identically() {
    let mut c = SimConfig::default();
    c.exec_model = "cnn".into();
    c.cost_model = "cnn".into();
    c.num_gateways = 1;
    c.num_devices = 1;
    c.num_channels = 1;
    c.local_iters = 2;
    c.dataset_max = 400;
    c.test_size = 128; // trailing partial eval batch
    c.rounds = 2;
    // Keep the baseline plan feasible so real conv training (the rayon
    // fwd/bwd path) is what gets replayed, not just scheduling.
    c.device_energy_max = 500.0;
    c.gw_energy_max = 5000.0;
    let mut logs = Vec::new();
    for _ in 0..2 {
        let session = Session::builder(c.clone()).rounds(2).eval_every(2).build().unwrap();
        let log = session.run(&SchedulerSpec::RoundRobin).unwrap();
        assert!(log.records.iter().any(|r| r.train_loss.is_some()), "cnn must train");
        logs.push(serialize(&log));
    }
    assert_eq!(logs[0], logs[1], "cnn replay with identical SimConfig diverged");
}

#[test]
fn parallel_ddsra_replays_serial_run_exactly() {
    // Custom scheduler instances (the `parallel` knob is not on the spec
    // menu) run through Session::run_scheduler.
    let run = |parallel: bool| {
        let session = Session::builder(cfg()).rounds(3).eval_every(3).build().unwrap();
        let mut sched =
            Ddsra::new(session.config().lyapunov_v, session.gamma().unwrap().to_vec());
        sched.parallel = parallel;
        serialize(&session.run_scheduler(&mut sched).unwrap())
    };
    assert_eq!(run(false), run(true), "rayon-parallel DDSRA diverged from serial");
}
