//! Deterministic-replay guarantee: two `Experiment::run` invocations built
//! from the same `SimConfig` seed must produce BYTE-identical round logs —
//! bit-for-bit equal floats, not approximately equal. This pins down the
//! `rng.rs` stateless stream keying the round engine draws from, and
//! protects the parallel paths (rayon DDSRA and the rayon device fan-out
//! must not perturb results; `rust/tests/round_engine.rs` additionally
//! pins thread-count invariance at large N).

mod common;

use common::serialize;
use iiot_fl::config::SimConfig;
use iiot_fl::fl::participation::gamma_rates;
use iiot_fl::fl::{Experiment, RunOpts};
use iiot_fl::sched::Ddsra;

fn cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.exec_model = "mlp".into();
    cfg.test_size = 512;
    cfg.dataset_max = 500;
    cfg.rounds = 3;
    cfg
}

#[test]
fn same_seed_same_bytes() {
    let opts = RunOpts { rounds: 3, eval_every: 3, track_divergence: false, train: true };
    let mut logs = Vec::new();
    for _ in 0..2 {
        let exp = Experiment::new(cfg()).unwrap();
        let mut sched = exp.make_scheduler("ddsra").unwrap();
        logs.push(serialize(&exp.run(sched.as_mut(), &opts).unwrap()));
    }
    assert_eq!(logs[0], logs[1], "replay with identical SimConfig diverged");
}

#[test]
fn different_seed_different_bytes() {
    let opts = RunOpts { rounds: 3, eval_every: 3, track_divergence: false, train: true };
    let run = |seed: u64| {
        let mut c = cfg();
        c.seed = seed;
        let exp = Experiment::new(c).unwrap();
        let mut sched = exp.make_scheduler("round_robin").unwrap();
        serialize(&exp.run(sched.as_mut(), &opts).unwrap())
    };
    assert_ne!(run(1), run(2), "seed must influence the trajectory");
}

/// The rayon-parallel batch forward/backward of the layer-graph engine
/// must not perturb results either: two cnn (VGG-mini) runs from the same
/// seed are byte-identical, conv path and partial-batch eval included.
#[test]
fn cnn_native_runs_replay_byte_identically() {
    let mut c = SimConfig::default();
    c.exec_model = "cnn".into();
    c.cost_model = "cnn".into();
    c.num_gateways = 1;
    c.num_devices = 1;
    c.num_channels = 1;
    c.local_iters = 2;
    c.dataset_max = 400;
    c.test_size = 128; // trailing partial eval batch
    c.rounds = 2;
    // Keep the baseline plan feasible so real conv training (the rayon
    // fwd/bwd path) is what gets replayed, not just scheduling.
    c.device_energy_max = 500.0;
    c.gw_energy_max = 5000.0;
    let opts = RunOpts { rounds: 2, eval_every: 2, track_divergence: false, train: true };
    let mut logs = Vec::new();
    for _ in 0..2 {
        let exp = Experiment::new(c.clone()).unwrap();
        let mut sched = exp.make_scheduler("round_robin").unwrap();
        let log = exp.run(sched.as_mut(), &opts).unwrap();
        assert!(log.records.iter().any(|r| r.train_loss.is_some()), "cnn must train");
        logs.push(serialize(&log));
    }
    assert_eq!(logs[0], logs[1], "cnn replay with identical SimConfig diverged");
}

#[test]
fn parallel_ddsra_replays_serial_run_exactly() {
    let opts = RunOpts { rounds: 3, eval_every: 3, track_divergence: false, train: true };
    let gamma_for = |exp: &Experiment| {
        let stats = exp.estimate_grad_stats(4).unwrap();
        gamma_rates(&exp.topo, &stats, exp.cfg.num_channels, exp.cfg.lr, exp.cfg.local_iters).1
    };
    let run = |parallel: bool| {
        let exp = Experiment::new(cfg()).unwrap();
        let mut sched = Ddsra::new(exp.cfg.lyapunov_v, gamma_for(&exp));
        sched.parallel = parallel;
        serialize(&exp.run(&mut sched, &opts).unwrap())
    };
    assert_eq!(run(false), run(true), "rayon-parallel DDSRA diverged from serial");
}
