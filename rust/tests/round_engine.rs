//! Round-engine guarantees: streaming aggregation is bit-identical to the
//! batch FedAvg helper, a parallel large-N run replays byte-identically
//! across rayon thread counts (the per-(round, device) RNG-stream
//! property), the §IV gradient probes are thread-count-invariant, scale
//! scenarios validate, and empty shop floors are rejected up front.

mod common;

use common::serialize;
use iiot_fl::config::SimConfig;
use iiot_fl::fl::vecmath::{weighted_average, WeightedAccum};
use iiot_fl::fl::{Experiment, SchedulerSpec, Session};
use iiot_fl::rng::Rng;
use iiot_fl::runtime::Params;
use iiot_fl::topo::Topology;

fn random_params(rng: &mut Rng, shapes: &[usize]) -> Params {
    shapes
        .iter()
        .map(|&len| (0..len).map(|_| rng.normal() as f32).collect())
        .collect()
}

/// The streaming accumulator must equal `vecmath::weighted_average`
/// BITWISE on random inputs — the O(1)-copy aggregation path and the
/// batch helper are one set of numerics.
#[test]
fn weighted_accum_is_bitwise_equal_to_weighted_average() {
    let mut rng = Rng::new(0xacc0);
    for case in 0..20usize {
        let participants = 1 + case % 9;
        let sets: Vec<(Params, f64)> = (0..participants)
            .map(|_| {
                let p = random_params(&mut rng, &[37, 5, 12]);
                let w = rng.uniform(0.5, 120.0);
                (p, w)
            })
            .collect();
        let refs: Vec<(&Params, f64)> = sets.iter().map(|(p, w)| (p, *w)).collect();
        let batch = weighted_average(&refs);
        let mut acc = WeightedAccum::new();
        for (p, w) in &sets {
            acc.add(p, *w);
        }
        assert_eq!(acc.count(), participants);
        let streamed = acc.finish().unwrap();
        assert_eq!(batch.len(), streamed.len());
        for (t, (tb, ts)) in batch.iter().zip(&streamed).enumerate() {
            for (i, (vb, vs)) in tb.iter().zip(ts).enumerate() {
                assert_eq!(
                    vb.to_bits(),
                    vs.to_bits(),
                    "case {case} tensor {t} idx {i}: {vb} vs {vs}"
                );
            }
        }
    }
}

/// THE large-N replay guarantee: a parallel 240-device run produces
/// byte-identical round logs whether rayon runs 1 worker or 8 (the
/// RAYON_NUM_THREADS=1 vs =8 property, pinned with explicit pools so one
/// test process can compare both). Per-(round, device) RNG streams make
/// training order-independent; the device-order aggregation fold makes
/// the FedAvg bytes schedule-independent.
#[test]
fn large_n_run_is_byte_identical_across_thread_counts() {
    let mut cfg = SimConfig::default();
    cfg.apply_scenario("plant").unwrap(); // N=240, M=24, J=8
    cfg.dataset_min = 16;
    cfg.dataset_max = 48; // small shards keep the test quick
    cfg.test_size = 256;
    cfg.local_iters = 1;
    cfg.rounds = 2;
    // Budgets generous enough that scheduled floors really train — the
    // replay must cover the parallel training path, not just scheduling.
    cfg.device_energy_max = 500.0;
    cfg.gw_energy_max = 5000.0;
    let run_with = |threads: usize| {
        let pool = rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
        pool.install(|| {
            let session = Session::builder(cfg.clone()).rounds(2).eval_every(2).build().unwrap();
            let log = session.run(&SchedulerSpec::RoundRobin).unwrap();
            assert!(
                log.records.iter().any(|r| r.train_loss.is_some()),
                "the large-N run must actually train"
            );
            serialize(&log)
        })
    };
    assert_eq!(run_with(1), run_with(8), "thread count changed the round bytes");
}

/// The §IV gradient probes (per-device streams, two streaming passes)
/// are deterministic and thread-count-invariant too — DDSRA's Γ_m rates
/// must not depend on the worker count.
#[test]
fn grad_stats_are_thread_count_invariant() {
    let mut cfg = SimConfig::default();
    cfg.dataset_max = 400;
    cfg.test_size = 256;
    let stats_with = |threads: usize| {
        let pool = rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
        pool.install(|| {
            let exp = Experiment::new(cfg.clone()).unwrap();
            exp.estimate_grad_stats(3).unwrap()
        })
    };
    let a = stats_with(1);
    let b = stats_with(4);
    for (x, y) in a.sigma.iter().zip(&b.sigma) {
        assert_eq!(x.to_bits(), y.to_bits(), "sigma diverged across pools");
    }
    for (x, y) in a.delta.iter().zip(&b.delta) {
        assert_eq!(x.to_bits(), y.to_bits(), "delta diverged across pools");
    }
    for (x, y) in a.lsmooth.iter().zip(&b.lsmooth) {
        assert_eq!(x.to_bits(), y.to_bits(), "lsmooth diverged across pools");
    }
    assert!(a.sigma.iter().all(|&s| s.is_finite() && s >= 0.0));
    assert!(a.lsmooth.iter().all(|&l| l > 0.0));
}

/// Divergence mode through the engine: per-gateway measurements stay
/// finite and replay exactly (the Fig. 2 path uses its own stream
/// domains).
#[test]
fn divergence_mode_replays_through_the_engine() {
    let mut cfg = SimConfig::default();
    cfg.dataset_max = 400;
    cfg.test_size = 256;
    cfg.rounds = 2;
    let run = || {
        let session =
            Session::builder(cfg.clone()).rounds(2).eval_every(0).divergence().build().unwrap();
        let log = session.run(&SchedulerSpec::RoundRobin).unwrap();
        for r in &log.records {
            let d = r.divergence.as_ref().expect("divergence recorded every round");
            assert_eq!(d.len(), session.experiment().topo.num_gateways());
            assert!(d.iter().all(|&v| v.is_finite() && v > 0.0), "{d:?}");
        }
        serialize(&log)
    };
    assert_eq!(run(), run(), "divergence-mode replay diverged");
}

/// Scale scenarios produce validating configs; unknown names fail. The
/// adversity presets inherit their base topology and arm the fault block.
#[test]
fn scale_scenarios_validate() {
    for (name, n, m) in [
        ("paper", 12, 6),
        ("plant", 240, 24),
        ("campus", 960, 48),
        ("metro", 2880, 96),
        ("nation", 100_000, 2000),
        ("nation-xl", 1_000_000, 20_000),
        ("flaky-plant", 240, 24),
        ("churn-metro", 2880, 96),
    ] {
        let mut cfg = SimConfig::default();
        cfg.apply_scenario(name).unwrap();
        assert_eq!((cfg.num_devices, cfg.num_gateways), (n, m), "{name}");
        let adversity = matches!(name, "flaky-plant" | "churn-metro");
        assert_eq!(
            cfg.fault.is_benign(),
            !adversity,
            "{name}: adversity presets (and only they) arm the fault block"
        );
        cfg.validate().unwrap();
    }
    assert!(SimConfig::default().apply_scenario("galaxy").is_err());
}

/// Empty shop floors are rejected up front — at the config level (fewer
/// devices than gateways) and at the topology level (a hand-emptied
/// member list) — instead of surfacing as NaN losses mid-run.
#[test]
fn empty_shop_floors_are_rejected_up_front() {
    let mut cfg = SimConfig::default();
    cfg.num_devices = 3;
    cfg.num_gateways = 6;
    cfg.num_channels = 3;
    let err = cfg.validate().unwrap_err().to_string();
    assert!(err.contains("shop floor"), "{err}");
    assert!(Experiment::new(cfg).is_err());

    let base = SimConfig::default();
    let mut topo = Topology::generate(&base, &mut Rng::new(1));
    topo.gateways[0].members.clear();
    let err = topo.validate().unwrap_err().to_string();
    assert!(err.contains("empty shop floor"), "{err}");
}
