//! Fault-injection guarantees: an adversity-preset run replays
//! byte-identically across rayon thread counts (faults draw from
//! per-(round, device) `STREAM_FAULT_*` streams) — with the flat AND the
//! hierarchical phase-5 fold —, an armed-but-inert
//! `FaultPlan` leaves every engine byte identical to the benign engine,
//! loss-driven schedules are identical with and without `--divergence`
//! (the probe must never leak into scheduler feedback), and the §IV
//! gradient probes weight by D̃_n — never by `dataset_size`.

mod common;

use common::{serialize, serialize_records};
use iiot_fl::config::SimConfig;
use iiot_fl::fl::{Experiment, RoundRecord, SchedulerSpec, Session};

fn cfg() -> SimConfig {
    // Paper-scale topology; small shards/test set keep real training fast.
    let mut cfg = SimConfig::default();
    cfg.exec_model = "mlp".into();
    cfg.test_size = 256;
    cfg.dataset_max = 400;
    cfg
}

/// THE adversity replay pin: a `flaky-plant` run — Dirichlet sharding,
/// stragglers, dropout, and outages all armed — produces byte-identical
/// round logs whether rayon runs 1 worker or 8. Every fault draw comes
/// from its own `(seed, round, device)` stream, so adversity is as
/// order-independent as training itself.
#[test]
fn flaky_plant_run_is_byte_identical_across_thread_counts() {
    let mut cfg = SimConfig::default();
    cfg.apply_scenario("flaky-plant").unwrap(); // N=240, M=24, J=8 + faults
    cfg.dataset_min = 16;
    cfg.dataset_max = 48; // small shards keep the test quick
    cfg.test_size = 256;
    cfg.local_iters = 1;
    cfg.rounds = 2;
    // Budgets generous enough that scheduled floors really train — the
    // replay must cover the faulted training path, not just scheduling.
    cfg.device_energy_max = 500.0;
    cfg.gw_energy_max = 5000.0;
    let run_with = |threads: usize| {
        let pool = rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
        pool.install(|| {
            let session = Session::builder(cfg.clone()).rounds(2).eval_every(2).build().unwrap();
            let log = session.run(&SchedulerSpec::RoundRobin).unwrap();
            assert!(
                log.records.iter().any(|r| r.faults.is_some()),
                "flaky-plant probabilities over 80 scheduled devices must realize \
                 at least one fault in two rounds"
            );
            assert!(
                log.records.iter().any(|r| r.train_loss.is_some()),
                "the faulted run must still train its survivors"
            );
            serialize(&log)
        })
    };
    assert_eq!(run_with(1), run_with(8), "thread count changed the faulted round bytes");
}

/// Hierarchical aggregation under adversity: the tiered fold composes
/// with the full flaky-plant fault battery (stragglers, dropout, gateway
/// outages, Dirichlet shards) without costing thread-count invariance —
/// fold order is fixed per tier, so 1 worker and 8 workers produce the
/// same bytes. A fully-outaged gateway's accumulator stays empty and its
/// cluster folds on without it (the fold-level pin lives in
/// `fl::round`'s in-file tests; this is the end-to-end run).
#[test]
fn hierarchical_flaky_plant_run_is_byte_identical_across_thread_counts() {
    let mut cfg = SimConfig::default();
    cfg.apply_scenario("flaky-plant").unwrap(); // N=240, M=24, J=8 + faults
    cfg.dataset_min = 16;
    cfg.dataset_max = 48;
    cfg.test_size = 256;
    cfg.local_iters = 1;
    cfg.rounds = 2;
    cfg.device_energy_max = 500.0;
    cfg.gw_energy_max = 5000.0;
    cfg.aggregation = iiot_fl::config::Aggregation::Hierarchical;
    cfg.num_clusters = 6; // 24 gateways -> 6 edge clusters of 4
    cfg.validate().unwrap();
    let run_with = |threads: usize| {
        let pool = rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
        pool.install(|| {
            let session = Session::builder(cfg.clone()).rounds(2).eval_every(2).build().unwrap();
            let log = session.run(&SchedulerSpec::RoundRobin).unwrap();
            assert!(
                log.records.iter().any(|r| r.faults.is_some()),
                "flaky-plant must realize at least one fault in two rounds"
            );
            assert!(
                log.records.iter().any(|r| r.train_loss.is_some()),
                "the faulted hierarchical run must still train its survivors"
            );
            serialize(&log)
        })
    };
    assert_eq!(
        run_with(1),
        run_with(8),
        "thread count changed the hierarchical faulted round bytes"
    );
}

/// THE `FaultPlan::none()` parity pin, at runtime: an ARMED fault block
/// whose probabilities are too small to ever realize walks every fault
/// seam in the engine and still produces the exact bytes of the benign
/// engine (which skips the fault machinery entirely). Arming the knobs
/// costs nothing until a fault actually fires.
#[test]
fn armed_but_inert_fault_plan_is_byte_identical_to_benign() {
    let benign = cfg();
    let mut inert = cfg();
    inert.fault.straggler_prob = 1e-300; // armed, but a draw can never land below
    inert.fault.straggler_slowdown = 1.5;
    inert.fault.dropout_prob = 1e-300;
    inert.fault.gateway_outage_prob = 1e-300;
    assert!(!inert.fault.is_benign());
    inert.validate().unwrap();
    let run = |cfg: SimConfig| {
        let session = Session::builder(cfg).rounds(3).eval_every(2).build().unwrap();
        serialize(&session.run(&SchedulerSpec::RoundRobin).unwrap())
    };
    assert_eq!(
        run(benign),
        run(inert),
        "an armed-but-inert fault plan changed the engine bytes"
    );
}

/// The scheduler-feedback bugfix pin: a loss-driven schedule is
/// IDENTICAL with and without divergence tracking. The Fig. 2 probe
/// trains every device from the round's starting model — before the fix
/// its losses overwrote the phase-4 training losses in `RoundFeedback`,
/// so turning `--divergence` on silently changed which gateways a
/// loss-driven scheduler picked.
#[test]
fn loss_driven_schedule_is_invariant_to_divergence_tracking() {
    let run = |track: bool| {
        let mut b = Session::builder(cfg()).rounds(4).eval_every(2);
        if track {
            b = b.divergence();
        }
        let log = b.build().unwrap().run(&SchedulerSpec::LossDriven).unwrap();
        assert_eq!(log.records.len(), 4);
        if track {
            assert!(log.records.iter().all(|r| r.divergence.is_some()));
        }
        // The probe's own output differs by construction; everything
        // else — selection, delays, losses, evals — must not.
        let stripped: Vec<RoundRecord> = log
            .records
            .iter()
            .map(|r| {
                let mut r = r.clone();
                r.divergence = None;
                r
            })
            .collect();
        serialize_records(&stripped)
    };
    assert_eq!(
        run(false),
        run(true),
        "divergence tracking changed a loss-driven schedule"
    );
}

/// The FedAvg-weight reconciliation pin for the §IV probes: the global
/// gradient folds by D̃_n (`Device::fedavg_weight`), so mutating every
/// device's `dataset_size` after construction — which leaves D̃_n and the
/// shards untouched — cannot move a single bit of σ/δ/L.
#[test]
fn grad_stats_weight_by_train_batch_not_dataset_size() {
    let exp = Experiment::new(cfg()).unwrap();
    let base = exp.estimate_grad_stats(3).unwrap();

    let mut warped = Experiment::new(cfg()).unwrap();
    for d in &mut warped.topo.devices {
        d.dataset_size = d.dataset_size * 13 + 1;
    }
    let stats = warped.estimate_grad_stats(3).unwrap();

    for (a, b) in base.sigma.iter().zip(&stats.sigma) {
        assert_eq!(a.to_bits(), b.to_bits(), "sigma depends on dataset_size");
    }
    for (a, b) in base.delta.iter().zip(&stats.delta) {
        assert_eq!(a.to_bits(), b.to_bits(), "delta depends on dataset_size");
    }
    for (a, b) in base.lsmooth.iter().zip(&stats.lsmooth) {
        assert_eq!(a.to_bits(), b.to_bits(), "lsmooth depends on dataset_size");
    }
}
