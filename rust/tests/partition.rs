//! Split-execution equivalence suite: the device/gateway partitioned
//! runtime must be BYTE-identical to the fused layer-graph engine at
//! every legal cut point — init stream, train-step parameters and loss,
//! eval metrics, and flat gradients alike. This extends the PR 2
//! determinism story (golden mlp pin + deterministic replay) to the
//! paper's actually-executed DNN partition, and proves that turning
//! `--execute-partition` on changes WHERE layers run, never the numbers.

mod common;

use common::serialize;
use iiot_fl::config::SimConfig;
use iiot_fl::dnn::models;
use iiot_fl::fl::{SchedulerSpec, Session};
use iiot_fl::rng::Rng;
use iiot_fl::runtime::{Backend, NativeBackend, Params, PartitionedBackend};

fn batch(seed: u64, n: usize, dim: usize) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32 * 0.5).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.below(10) as i32).collect();
    (x, y)
}

fn assert_bits_eq(a: &Params, b: &Params, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: tensor count");
    for (t, (ta, tb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ta.len(), tb.len(), "{what}: tensor {t} len");
        for (i, (va, vb)) in ta.iter().zip(tb).enumerate() {
            assert_eq!(va.to_bits(), vb.to_bits(), "{what}: tensor {t} idx {i}: {va} vs {vb}");
        }
    }
}

/// The exhaustive acceptance test: for BOTH executable presets, split
/// execution at EVERY legal partition point l ∈ 0..=L reproduces the
/// fused engine bit for bit — across several SGD steps (so errors cannot
/// hide in the update), on eval metrics over a ragged test set (full
/// batches + a trailing partial batch), and on the flat minibatch
/// gradient.
#[test]
fn split_equals_fused_at_every_cut_for_both_presets() {
    // (preset, fused backend, SGD steps to verify, eval-set size).
    let cases: Vec<(&str, NativeBackend, usize, usize)> = vec![
        ("mlp", NativeBackend::mlp(), 3, 300),
        ("cnn", NativeBackend::cnn(), 1, 96),
    ];
    for (preset, fused, steps, eval_n) in cases {
        let meta = fused.meta().clone();
        let dim = meta.sample_dim();
        let depth = models::by_name(preset).unwrap().depth();

        // Fused trajectory, computed once.
        let p0 = fused.init_params().unwrap();
        let mut fused_traj = Vec::with_capacity(steps);
        let mut p = p0.clone();
        for step in 0..steps {
            let (x, y) = batch(0x5eed ^ ((step as u64) << 8), meta.train_batch, dim);
            let (np, loss) = fused.train_step(&p, &x, &y, 0.05).unwrap();
            fused_traj.push((np.clone(), loss));
            p = np;
        }
        let (xe, ye) = batch(0xe7a1, eval_n, dim);
        let (fused_eval_loss, fused_eval_acc) = fused.eval_full(&p, &xe, &ye).unwrap();
        let (xg, yg) = batch(0x96ad, meta.train_batch, dim);
        let fused_grad = fused.grad(&p, &xg, &yg).unwrap();

        for cut in 0..=depth {
            let split = PartitionedBackend::preset(preset, cut).unwrap();
            assert_eq!(split.meta().param_shapes, meta.param_shapes, "{preset} cut {cut}");
            assert_bits_eq(&split.init_params().unwrap(), &p0, "init");

            let mut w = p0.clone();
            for (step, (fp, floss)) in fused_traj.iter().enumerate() {
                let (x, y) = batch(0x5eed ^ ((step as u64) << 8), meta.train_batch, dim);
                let (nw, loss) = split.train_step(&w, &x, &y, 0.05).unwrap();
                assert_eq!(
                    loss.to_bits(),
                    floss.to_bits(),
                    "{preset} cut {cut} step {step} loss"
                );
                assert_bits_eq(&nw, fp, &format!("{preset} cut {cut} step {step} params"));
                w = nw;
            }

            // Eval metrics (mean loss, accuracy) over the ragged test set.
            let (el, ea) = split.eval_full(&w, &xe, &ye).unwrap();
            assert_eq!(el.to_bits(), fused_eval_loss.to_bits(), "{preset} cut {cut} eval loss");
            assert_eq!(ea.to_bits(), fused_eval_acc.to_bits(), "{preset} cut {cut} eval acc");

            // Flat minibatch gradient (the §IV sigma/delta probe path).
            let g = split.grad(&w, &xg, &yg).unwrap();
            assert_eq!(g.len(), fused_grad.len());
            for (i, (va, vb)) in g.iter().zip(&fused_grad).enumerate() {
                assert_eq!(va.to_bits(), vb.to_bits(), "{preset} cut {cut} grad[{i}]");
            }
        }
    }
}

/// Finite-difference gradient check on the GATEWAY half alone: perturb
/// only gateway-side parameters and compare the split backend's analytic
/// gradient against central differences of the split loss. The device
/// half's parameters are untouched, so this isolates the top-half
/// backward pass (including the loss head and the cut exchange).
#[test]
fn gateway_half_gradient_matches_finite_differences() {
    // mlp cut 1: device = fc1(+relu), gateway = fc2 + head.
    let split = PartitionedBackend::preset("mlp", 1).unwrap();
    let meta = split.meta().clone();
    let mut p = split.init_params().unwrap();
    // The head is zero-initialised; perturb it so the loss surface is
    // curved at the probe point.
    let mut rng = Rng::new(77);
    let bt = split.device_tensor_count();
    for t in bt..p.len() {
        for v in p[t].iter_mut() {
            *v = (rng.normal() * 0.1) as f32;
        }
    }
    let (x, y) = batch(0xfd, meta.train_batch, meta.sample_dim());
    let g = split.grad(&p, &x, &y).unwrap();

    let loss_at = |params: &Params| -> f64 {
        let (_, l) = split.train_step(params, &x, &y, 0.0).unwrap();
        l as f64
    };
    // Flat offset where the gateway half's coordinates start.
    let base = split.device_param_total();
    // Probe a few coordinates of the gateway weight matrix and bias.
    let w_len = p[bt].len();
    let probes = [0usize, 7, w_len / 2, w_len - 1, w_len + 3]; // last = bias
    let eps = 1e-2f32;
    for off in probes {
        let (t, i) = if off < w_len { (bt, off) } else { (bt + 1, off - w_len) };
        let mut hi = p.clone();
        hi[t][i] += eps;
        let mut lo = p.clone();
        lo[t][i] -= eps;
        let num = (loss_at(&hi) - loss_at(&lo)) / (2.0 * eps as f64);
        let ana = g[base + off] as f64;
        assert!(
            (num - ana).abs() < 1e-3 + 0.05 * ana.abs(),
            "gateway coord {off}: numeric {num} vs analytic {ana}"
        );
    }
    // The device half's gradient is nonzero too (errors really crossed
    // the cut back to the bottom layers).
    assert!(g[..base].iter().any(|&v| v != 0.0), "no gradient crossed the cut");
}

/// Orchestrator-level parity: a full multi-round FL run with
/// `execute_partition` on — every scheduled device trains through the
/// split backend at its DDSRA-chosen cut — produces byte-identical round
/// logs to the fused run. Also asserts the runs really exercised nonzero
/// cuts (the split path was not vacuous).
#[test]
fn execute_partition_run_matches_fused_run_byte_for_byte() {
    let mut cfg = SimConfig::default();
    cfg.exec_model = "mlp".into();
    cfg.cost_model = "mlp".into(); // the scheduler plans the net it trains
    cfg.test_size = 512;
    cfg.dataset_max = 500;
    cfg.rounds = 3;

    let run = |execute_partition: bool| -> String {
        let mut c = cfg.clone();
        c.execute_partition = execute_partition;
        let session = Session::builder(c).rounds(3).eval_every(3).build().unwrap();
        let exp = session.experiment();
        assert_eq!(exp.partitioned.len(), if execute_partition { 3 } else { 0 });
        let log = session.run(&SchedulerSpec::RoundRobin).unwrap();
        assert!(log.records.iter().any(|r| r.train_loss.is_some()), "must train");
        serialize(&log)
    };
    assert_eq!(run(false), run(true), "split execution diverged from fused");

    // The baselines' fixed plan picks l = L/2 (clamped) — with the mlp
    // cost model that is cut 1, a genuine two-sided split.
    let session = Session::builder({
        let mut c = cfg.clone();
        c.execute_partition = true;
        c
    })
    .build()
    .unwrap();
    assert_eq!(session.experiment().partitioned[1].cut_activation_elems(), 64);
}

/// DDSRA + split execution: the optimiser's per-device, per-round cuts
/// (not a fixed plan) drive the split runtime, and the run still matches
/// fused execution byte for byte.
#[test]
fn ddsra_execute_partition_matches_fused() {
    let mut cfg = SimConfig::default();
    cfg.exec_model = "mlp".into();
    cfg.cost_model = "mlp".into();
    cfg.test_size = 256;
    cfg.dataset_max = 400;
    cfg.rounds = 2;
    let run = |execute_partition: bool| -> String {
        let mut c = cfg.clone();
        c.execute_partition = execute_partition;
        let session = Session::builder(c).rounds(2).eval_every(2).build().unwrap();
        serialize(&session.run(&SchedulerSpec::ddsra()).unwrap())
    };
    assert_eq!(run(false), run(true), "DDSRA split run diverged from fused");
}
