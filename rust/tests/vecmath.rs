//! Property-style suite for the FedAvg accumulators — the aggregation
//! substrate both phase-5 folds (flat and hierarchical) stand on.
//!
//! The guarantees pinned here, on exact (dyadic) inputs so float
//! association can never excuse a mismatch:
//! - ordered-fold determinism: the SEQUENCE of `add` calls alone fixes
//!   the result bytes;
//! - split-fold parity: partial accumulators merged in fold order are
//!   bitwise the single straight-line fold, at EVERY split point — the
//!   algebraic core of the flat == hierarchical parity story;
//! - degenerate folds: a single device averages to itself, a zero-weight
//!   member is invisible, an empty fold yields `None`, and an all-zero
//!   weight total is rejected loudly.

use iiot_fl::fl::vecmath::{FlatWeightedAccum, WeightedAccum};
use iiot_fl::rng::Rng;
use iiot_fl::runtime::Params;

/// Dyadic values (multiples of 1/8 in [-4, 4)): every product with a
/// small integer weight and every partial sum is exactly representable
/// in f64, so any regrouping of the fold computes the same exact sum.
fn dyadic_params(seed: u64) -> Params {
    let mut rng = Rng::new(900 + seed);
    (0..3)
        .map(|_| (0..5).map(|_| (rng.below(64) as f32 - 32.0) / 8.0).collect())
        .collect()
}

fn weights(n: usize) -> Vec<f64> {
    let mut rng = Rng::new(77);
    (0..n).map(|_| (1 + rng.below(9)) as f64).collect()
}

fn assert_params_bitwise_eq(a: &Params, b: &Params, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: tensor count");
    for (ta, tb) in a.iter().zip(b) {
        for (va, vb) in ta.iter().zip(tb) {
            assert_eq!(va.to_bits(), vb.to_bits(), "{what}");
        }
    }
}

#[test]
fn ordered_fold_is_deterministic() {
    // Same add sequence, fresh accumulators: identical bytes every time.
    let updates: Vec<(Params, f64)> =
        (0..12).map(|i| (dyadic_params(i), weights(12)[i as usize])).collect();
    let fold = || {
        let mut acc = WeightedAccum::new();
        for (p, w) in &updates {
            acc.add(p, *w);
        }
        acc.finish().unwrap()
    };
    assert_params_bitwise_eq(&fold(), &fold(), "repeated ordered fold");
}

#[test]
fn split_fold_matches_single_fold_bitwise_at_every_split_point() {
    // Split the update stream at every position k, fold the halves into
    // separate partial accumulators, merge in order — bitwise the single
    // fold. This is exactly what a gateway/cluster boundary does to the
    // hierarchical fold, so parity here is parity there.
    let n = 10;
    let ws = weights(n);
    let updates: Vec<(Params, f64)> =
        (0..n).map(|i| (dyadic_params(i as u64), ws[i])).collect();
    let mut single = WeightedAccum::new();
    for (p, w) in &updates {
        single.add(p, *w);
    }
    let expect = single.finish().unwrap();
    for k in 0..=n {
        let mut lo = WeightedAccum::new();
        for (p, w) in &updates[..k] {
            lo.add(p, *w);
        }
        let mut hi = WeightedAccum::new();
        for (p, w) in &updates[k..] {
            hi.add(p, *w);
        }
        let mut merged = WeightedAccum::new();
        merged.merge(lo);
        merged.merge(hi);
        assert_eq!(merged.count(), n);
        assert_params_bitwise_eq(&merged.finish().unwrap(), &expect, &format!("split at {k}"));
    }
}

#[test]
fn nested_three_way_split_matches_single_fold_bitwise() {
    // Two tier boundaries (gateway -> cluster -> cloud shape): partials
    // of partials merged in order still reproduce the straight fold.
    let n = 9;
    let ws = weights(n);
    let updates: Vec<(Params, f64)> =
        (0..n).map(|i| (dyadic_params(40 + i as u64), ws[i])).collect();
    let mut single = WeightedAccum::new();
    for (p, w) in &updates {
        single.add(p, *w);
    }
    let mut tiers = WeightedAccum::new();
    for chunk in updates.chunks(3) {
        let mut tier = WeightedAccum::new();
        for (p, w) in chunk {
            tier.add(p, *w);
        }
        tiers.merge(tier);
    }
    assert_params_bitwise_eq(
        &tiers.finish().unwrap(),
        &single.finish().unwrap(),
        "three-way tiered fold",
    );
}

#[test]
fn single_device_fold_averages_to_itself() {
    let p = dyadic_params(3);
    let mut acc = WeightedAccum::new();
    acc.add(&p, 7.0);
    assert_eq!(acc.count(), 1);
    assert_params_bitwise_eq(&acc.finish().unwrap(), &p, "single-device fold");
}

#[test]
fn zero_weight_member_is_invisible_to_the_fold() {
    // A scheduled-but-weightless member must not move a bit, wherever it
    // lands in the sequence. (Values here are strictly positive, so the
    // 0·v = +0.0 contributions are exact additive identities.)
    let a = vec![vec![1.5f32, 2.0, 0.25]];
    let b = vec![vec![4.0f32, 0.5, 8.0]];
    let ghost = vec![vec![3.0f32, 3.0, 3.0]];
    let mut without = WeightedAccum::new();
    without.add(&a, 2.0);
    without.add(&b, 5.0);
    let expect = without.finish().unwrap();
    for position in 0..3 {
        let mut with = WeightedAccum::new();
        for (i, (p, w)) in [(&a, 2.0), (&b, 5.0)].iter().enumerate() {
            if i == position {
                with.add(&ghost, 0.0);
            }
            with.add(p, *w);
        }
        if position == 2 {
            with.add(&ghost, 0.0);
        }
        assert_eq!(with.count(), 3, "zero-weight adds still count as folded updates");
        assert_params_bitwise_eq(
            &with.finish().unwrap(),
            &expect,
            &format!("ghost at {position}"),
        );
    }
}

#[test]
fn empty_fold_is_none_and_zero_total_is_rejected() {
    // Nothing folded: `None`, the round leaves the model unchanged.
    assert!(WeightedAccum::new().finish().is_none());
    assert!(FlatWeightedAccum::new().finish().is_none());
    // Folded-but-weightless: FedAvg is undefined, and the accumulator
    // says so loudly instead of dividing by zero.
    let mut acc = WeightedAccum::new();
    acc.add(&dyadic_params(1), 0.0);
    let bad = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| acc.finish()));
    assert!(bad.is_err(), "zero-total finish must panic");
}

#[test]
fn flat_accum_mirrors_the_params_accum_properties() {
    let mut rng = Rng::new(5);
    let vecs: Vec<Vec<f32>> = (0..8)
        .map(|_| (0..6).map(|_| (rng.below(64) as f32 - 32.0) / 8.0).collect())
        .collect();
    let ws = weights(8);
    let mut single = FlatWeightedAccum::new();
    for (v, w) in vecs.iter().zip(&ws) {
        single.add(v, *w);
    }
    let expect = single.finish().unwrap();
    for k in 0..=vecs.len() {
        let mut lo = FlatWeightedAccum::new();
        for (v, w) in vecs[..k].iter().zip(&ws[..k]) {
            lo.add(v, *w);
        }
        let mut hi = FlatWeightedAccum::new();
        for (v, w) in vecs[k..].iter().zip(&ws[k..]) {
            hi.add(v, *w);
        }
        lo.merge(hi);
        let merged = lo.finish().unwrap();
        for (x, y) in merged.iter().zip(&expect) {
            assert_eq!(x.to_bits(), y.to_bits(), "flat split at {k}");
        }
    }
}
