//! Helpers shared by the integration-test crates (pulled in via
//! `mod common;` — not a test target itself).

use iiot_fl::fl::RunLog;

/// Render every field of every round record with exact bit patterns —
/// THE definition of "byte-identical round log" the replay and
/// round-engine suites pin against.
pub fn serialize(log: &RunLog) -> String {
    let bits = |v: f64| format!("{:016x}", v.to_bits());
    let opt = |v: Option<f64>| v.map_or("-".into(), bits);
    let mut out = String::new();
    out.push_str(&log.scheme);
    out.push('\n');
    for r in &log.records {
        out.push_str(&format!(
            "{}|{}|{}|{:?}|{:?}|{}|{}|{}|{:?}\n",
            r.round,
            bits(r.delay),
            bits(r.cum_delay),
            r.selected,
            r.failed,
            opt(r.train_loss),
            opt(r.test_loss),
            opt(r.test_acc),
            r.divergence.as_ref().map(|d| d.iter().map(|&v| bits(v)).collect::<Vec<_>>()),
        ));
    }
    for p in log.participation.iter().chain(&log.effective_participation) {
        out.push_str(&bits(*p));
        out.push('\n');
    }
    out
}
