//! Helpers shared by the integration-test crates (pulled in via
//! `mod common;` — not a test target itself).

use iiot_fl::fl::{RoundRecord, RunLog};

/// Render every field of every round record with exact bit patterns.
/// `selected`/`failed` expand through [`iiot_fl::fl::GatewayMask::to_vec`]
/// so the rendered bytes are IDENTICAL to the pre-compaction `Vec<bool>`
/// representation the earlier engines logged.
pub fn serialize_records(records: &[RoundRecord]) -> String {
    let bits = |v: f64| format!("{:016x}", v.to_bits());
    let opt = |v: Option<f64>| v.map_or("-".into(), bits);
    let mut out = String::new();
    for r in records {
        out.push_str(&format!(
            "{}|{}|{}|{:?}|{:?}|{}|{}|{}|{:?}",
            r.round,
            bits(r.delay),
            bits(r.cum_delay),
            r.selected.to_vec(),
            r.failed.to_vec(),
            opt(r.train_loss),
            opt(r.test_loss),
            opt(r.test_acc),
            r.divergence.as_ref().map(|d| d.iter().map(|&v| bits(v)).collect::<Vec<_>>()),
        ));
        // Realized faults render ONLY when present, so fault-free logs
        // keep the exact historical byte layout.
        if let Some(f) = &r.faults {
            out.push_str(&format!(
                "|faults:{:?},{:?},{}",
                f.dropped,
                f.outages.to_vec(),
                bits(f.max_slowdown)
            ));
        }
        out.push('\n');
    }
    out
}

/// Render every field of a run log with exact bit patterns — THE
/// definition of "byte-identical round log" the replay, partition and
/// round-engine suites pin against.
pub fn serialize(log: &RunLog) -> String {
    let mut out = String::new();
    out.push_str(&log.scheme);
    out.push('\n');
    out.push_str(&serialize_records(&log.records));
    let bits = |v: f64| format!("{:016x}", v.to_bits());
    for p in log.participation.iter().chain(&log.effective_participation) {
        out.push_str(&bits(*p));
        out.push('\n');
    }
    out
}
