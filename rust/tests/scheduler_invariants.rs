//! Scheduler invariant tests (scheduling-only, no training):
//!
//! * the DDSRA virtual-queue update (Eq. 14) is exactly
//!   Q_m(t+1) = max(Q_m(t) − 1_m(t) + Γ_m, 0) — hence non-negative — and
//!   the queues drain under full participation (J = M);
//! * every `Decision` from all five schedulers assigns each channel to at
//!   most one gateway and each gateway at most one channel, within bounds;
//! * DDSRA only emits plans satisfying ALL memory/energy feasibility
//!   constraints; the fixed-resource baselines at least never violate the
//!   device-side memory bound their partition clamp guarantees.

use iiot_fl::config::SimConfig;
use iiot_fl::dnn::models;
use iiot_fl::dnn::ModelSpec;
use iiot_fl::energy::EnergyArrivals;
use iiot_fl::net::ChannelModel;
use iiot_fl::rng::Rng;
use iiot_fl::sched::latency::{plan_cost, Violation};
use iiot_fl::sched::{
    Ddsra, Decision, DelayDriven, LossDriven, RandomSched, RoundCtx, RoundRobin, Scheduler,
};
use iiot_fl::topo::Topology;

struct World {
    cfg: SimConfig,
    topo: Topology,
    model: ModelSpec,
    chan: ChannelModel,
}

fn world(cfg: SimConfig, seed: u64) -> (World, Rng) {
    let mut rng = Rng::new(seed);
    let topo = Topology::generate(&cfg, &mut rng);
    let chan = ChannelModel::new(&cfg, &topo, &mut rng);
    (World { cfg, topo, model: models::vgg11_cifar(), chan }, rng)
}

fn ctx<'a>(
    w: &'a World,
    state: &'a iiot_fl::net::ChannelState,
    arrivals: &'a EnergyArrivals,
    round: usize,
) -> RoundCtx<'a> {
    RoundCtx {
        cfg: &w.cfg,
        topo: &w.topo,
        model: &w.model,
        chan: &w.chan,
        state,
        arrivals,
        round,
    }
}

/// Channel-uniqueness (C2/C3) + index/resource bounds for any decision.
fn assert_decision_well_formed(w: &World, dec: &Decision) {
    let mm = w.topo.num_gateways();
    let jj = w.cfg.num_channels;
    assert!(dec.plans.len() <= jj, "more plans than channels");
    let mut gws: Vec<_> = dec.plans.iter().map(|p| p.gateway).collect();
    let mut chs: Vec<_> = dec.plans.iter().map(|p| p.channel).collect();
    gws.sort_unstable();
    chs.sort_unstable();
    let (gl, cl) = (gws.len(), chs.len());
    gws.dedup();
    chs.dedup();
    assert_eq!(gws.len(), gl, "gateway selected twice");
    assert_eq!(chs.len(), cl, "channel assigned twice");
    for p in &dec.plans {
        assert!(p.gateway < mm && p.channel < jj);
        let gw = &w.topo.gateways[p.gateway];
        assert_eq!(p.partition.len(), gw.members.len());
        assert_eq!(p.freq.len(), gw.members.len());
        assert!(p.power > 0.0 && p.power <= gw.power_max + 1e-12, "power {}", p.power);
        for (&l, &f) in p.partition.iter().zip(&p.freq) {
            assert!(l <= w.model.depth(), "partition point {l} beyond depth");
            assert!(f >= 0.0 && f.is_finite());
        }
    }
}

#[test]
fn ddsra_queue_update_is_exactly_eq14_and_nonnegative() {
    let (w, mut rng) = world(SimConfig::default(), 21);
    let gamma = vec![0.9, 0.7, 0.5, 0.4, 0.3, 0.2];
    let mut d = Ddsra::new(10.0, gamma.clone());
    for t in 0..20 {
        let before = d.queues.clone();
        let state = w.chan.draw(&mut rng);
        let arr = EnergyArrivals::draw(&w.cfg, &mut rng);
        let c = ctx(&w, &state, &arr, t);
        let dec = d.schedule(&c);
        for m in 0..w.topo.num_gateways() {
            let served = if dec.selected(m) { 1.0 } else { 0.0 };
            let expected = (before[m] - served + gamma[m]).max(0.0);
            assert!(
                (d.queues[m] - expected).abs() < 1e-12,
                "round {t} gw {m}: queue {} != Eq.14 value {expected}",
                d.queues[m]
            );
            assert!(d.queues[m] >= 0.0);
        }
    }
}

#[test]
fn ddsra_queues_drain_under_full_participation() {
    // J = M: every gateway can hold a channel every round, so with
    // Γ_m < 1 the queues must stay pinned near zero instead of growing
    // ~ t·Γ_m as they would without service.
    let mut cfg = SimConfig::default();
    cfg.num_channels = cfg.num_gateways; // J = M = 6 (C3 still holds)
    let (w, mut rng) = world(cfg, 22);
    let rounds = 30;
    let gamma = vec![0.3; 6];
    let mut d = Ddsra::new(0.0, gamma.clone());
    for t in 0..rounds {
        let state = w.chan.draw(&mut rng);
        let arr = EnergyArrivals::draw(&w.cfg, &mut rng);
        let c = ctx(&w, &state, &arr, t);
        let _ = d.schedule(&c);
        for (m, &q) in d.queues.iter().enumerate() {
            assert!(q >= 0.0 && q.is_finite());
            assert!(
                q < 2.0,
                "round {t}: queue {m} = {q} not draining under full participation"
            );
        }
    }
    let accumulated = rounds as f64 * gamma[0];
    let total: f64 = d.queues.iter().sum();
    assert!(total < accumulated / 2.0, "queues {:?} accumulated instead of draining", d.queues);
}

#[test]
fn all_five_schedulers_emit_well_formed_decisions() {
    let (w, mut rng) = world(SimConfig::default(), 23);
    let mm = w.topo.num_gateways();
    let mut scheds: Vec<Box<dyn Scheduler>> = vec![
        Box::new(Ddsra::new(SimConfig::default().lyapunov_v, vec![0.5; mm])),
        Box::new(RandomSched::new(7)),
        Box::new(RoundRobin::new()),
        Box::new(LossDriven::new(mm, 8)),
        Box::new(DelayDriven),
    ];
    for t in 0..5 {
        let state = w.chan.draw(&mut rng);
        let arr = EnergyArrivals::draw(&w.cfg, &mut rng);
        let c = ctx(&w, &state, &arr, t);
        for s in &mut scheds {
            let dec = s.schedule(&c);
            assert_decision_well_formed(&w, &dec);
            // Round delay is the max selected Λ (Eq. 10).
            let max_l = dec.plans.iter().map(|p| p.lambda).fold(0.0, f64::max);
            assert_eq!(dec.round_delay(), max_l);
        }
    }
}

#[test]
fn ddsra_plans_satisfy_all_memory_and_energy_constraints() {
    let (w, mut rng) = world(SimConfig::default(), 24);
    let mut d = Ddsra::new(100.0, vec![0.6; 6]);
    let mut seen_plans = 0usize;
    for t in 0..10 {
        let state = w.chan.draw(&mut rng);
        let arr = EnergyArrivals::draw(&w.cfg, &mut rng);
        let c = ctx(&w, &state, &arr, t);
        let dec = d.schedule(&c);
        for plan in &dec.plans {
            let cost = plan_cost(&c, plan);
            assert!(
                cost.feasible(),
                "round {t} gw {}: DDSRA plan violates {:?}",
                plan.gateway,
                cost.violations
            );
            // Spot-check the raw budgets behind the feasibility verdict.
            let gw = &w.topo.gateways[plan.gateway];
            assert!(cost.gateway_mem <= gw.mem);
            assert!(cost.gateway_energy <= arr.gateway[plan.gateway]);
            for (i, &n) in gw.members.iter().enumerate() {
                assert!(cost.device_mem[i] <= w.topo.devices[n].mem);
                assert!(cost.device_energy[i] <= arr.device[n]);
            }
            seen_plans += 1;
        }
    }
    assert!(seen_plans > 0, "DDSRA never produced a plan in 10 rounds");
}

#[test]
fn baseline_plans_never_violate_device_memory() {
    // The fixed-resource baselines may exceed ENERGY budgets (their §VII-C
    // failure mode, dropped by the orchestrator) but their partition clamp
    // guarantees the device-side memory bound always holds.
    let (w, mut rng) = world(SimConfig::default(), 25);
    let mm = w.topo.num_gateways();
    let mut scheds: Vec<Box<dyn Scheduler>> = vec![
        Box::new(RandomSched::new(9)),
        Box::new(RoundRobin::new()),
        Box::new(LossDriven::new(mm, 10)),
        Box::new(DelayDriven),
    ];
    for t in 0..5 {
        let state = w.chan.draw(&mut rng);
        let arr = EnergyArrivals::draw(&w.cfg, &mut rng);
        let c = ctx(&w, &state, &arr, t);
        for s in &mut scheds {
            for plan in &s.schedule(&c).plans {
                let cost = plan_cost(&c, plan);
                for v in &cost.violations {
                    assert!(
                        !matches!(v, Violation::DeviceMem(_)),
                        "baseline emitted device-memory violation {v:?}"
                    );
                }
            }
        }
    }
}
