//! Hierarchical-aggregation guarantees — the acceptance pins of the
//! multi-tier fold:
//!
//! - THE parity oracle: `aggregation = hierarchical` produces
//!   byte-identical round logs to the flat fold on the `paper` and
//!   `plant` scenarios, for every cluster layout tried and for every
//!   scheduler whose plans list gateways in ascending order;
//! - hierarchical runs replay byte-identically across rayon thread
//!   counts, like every other engine mode;
//! - `lazy_shards` regenerate-on-demand storage is byte-invisible: lazy
//!   and eager runs serialize identically;
//! - sampled evaluation (`eval_sample`) short-circuits to full eval at
//!   `k = 0` and `k >= test_size`, replays deterministically below it,
//!   and draws only from its own `STREAM_EVAL` domain;
//! - the nation-class presets validate (and the eager-shard memory guard
//!   rejects a nation config stripped of `lazy_shards`);
//! - a prohibitive relay Ψ prices every scheduled gateway out of its
//!   energy budget (the Hashempour-style summary-relay term).

mod common;

use common::serialize;
use iiot_fl::config::{Aggregation, SimConfig};
use iiot_fl::fl::{SchedulerSpec, Session};

/// Paper-scale config with small shards/test set for fast real training.
fn paper_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.exec_model = "mlp".into();
    cfg.test_size = 256;
    cfg.dataset_max = 400;
    cfg
}

/// Plant-scale (N=240, M=24) config shrunk for test time, budgets open
/// so scheduled floors really train.
fn plant_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.apply_scenario("plant").unwrap();
    cfg.dataset_min = 16;
    cfg.dataset_max = 48;
    cfg.test_size = 256;
    cfg.local_iters = 1;
    cfg.device_energy_max = 500.0;
    cfg.gw_energy_max = 5000.0;
    cfg
}

fn run_bytes(mut cfg: SimConfig, spec: &SchedulerSpec, rounds: usize) -> String {
    cfg.rounds = rounds;
    cfg.validate().unwrap();
    let session = Session::builder(cfg).rounds(rounds).eval_every(2).build().unwrap();
    let log = session.run(spec).unwrap();
    assert!(
        log.records.iter().any(|r| r.train_loss.is_some()),
        "the run must actually train"
    );
    serialize(&log)
}

/// THE acceptance pin: flat and hierarchical aggregation produce
/// byte-identical round logs. Both paths fold the same (update, D̃_n)
/// stream in the same within-gateway order; the tier boundaries only
/// regroup f64 partial sums whose terms are exact, so the bytes match —
/// across cluster layouts and across the ascending-plan schedulers.
#[test]
fn hierarchical_matches_flat_bytes_on_paper_scenario() {
    for clusters in [1usize, 2, 3] {
        for spec in [SchedulerSpec::RoundRobin, SchedulerSpec::DelayDriven] {
            let mut flat = paper_cfg();
            flat.num_clusters = clusters;
            let mut hier = flat.clone();
            hier.aggregation = Aggregation::Hierarchical;
            assert_eq!(
                run_bytes(flat, &spec, 4),
                run_bytes(hier, &spec, 4),
                "flat vs hierarchical diverged: paper, {clusters} clusters, {spec:?}"
            );
        }
    }
}

#[test]
fn hierarchical_matches_flat_bytes_on_plant_scenario() {
    let mut flat = plant_cfg();
    flat.num_clusters = 6; // 24 gateways -> 6 edge clusters of 4
    let mut hier = flat.clone();
    hier.aggregation = Aggregation::Hierarchical;
    assert_eq!(
        run_bytes(flat, &SchedulerSpec::RoundRobin, 2),
        run_bytes(hier, &SchedulerSpec::RoundRobin, 2),
        "flat vs hierarchical diverged on the plant scenario"
    );
}

/// Hierarchical runs keep the thread-count replay guarantee: fold order
/// is fixed per tier (members ascending within gateways, gateways
/// ascending within clusters, clusters ascending), never wall-clock.
#[test]
fn hierarchical_run_is_byte_identical_across_thread_counts() {
    let mut cfg = plant_cfg();
    cfg.num_clusters = 6;
    cfg.aggregation = Aggregation::Hierarchical;
    let run_with = |threads: usize| {
        let pool = rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
        pool.install(|| run_bytes(cfg.clone(), &SchedulerSpec::RoundRobin, 2))
    };
    assert_eq!(
        run_with(1),
        run_with(8),
        "thread count changed the hierarchical round bytes"
    );
}

/// `lazy_shards` is byte-invisible: the deferred plan consumes exactly
/// the draws eager sharding consumes and regenerates each shard from the
/// same per-device stream, so the whole run serializes identically.
#[test]
fn lazy_shards_run_is_byte_identical_to_eager() {
    let eager = paper_cfg();
    let mut lazy = paper_cfg();
    lazy.lazy_shards = true;
    assert_eq!(
        run_bytes(eager, &SchedulerSpec::RoundRobin, 3),
        run_bytes(lazy, &SchedulerSpec::RoundRobin, 3),
        "lazy shard storage changed the run bytes"
    );
}

/// Sampled evaluation: `eval_sample >= test_size` (and 0) short-circuit
/// to the full eval bytes; a genuine subsample replays deterministically
/// and actually changes the eval numbers (it IS a different estimator).
#[test]
fn eval_sample_short_circuits_and_replays() {
    let full = paper_cfg();
    let mut capped = paper_cfg();
    capped.eval_sample = capped.test_size; // >= test set: full eval
    let mut oversized = paper_cfg();
    oversized.eval_sample = 10_000;
    let full_bytes = run_bytes(full, &SchedulerSpec::RoundRobin, 3);
    assert_eq!(
        full_bytes,
        run_bytes(capped, &SchedulerSpec::RoundRobin, 3),
        "eval_sample == test_size must be the full evaluation"
    );
    assert_eq!(
        full_bytes,
        run_bytes(oversized, &SchedulerSpec::RoundRobin, 3),
        "eval_sample > test_size must be the full evaluation"
    );
    let mut sampled = paper_cfg();
    sampled.eval_sample = 64;
    let a = run_bytes(sampled.clone(), &SchedulerSpec::RoundRobin, 3);
    assert_eq!(
        a,
        run_bytes(sampled, &SchedulerSpec::RoundRobin, 3),
        "sampled evaluation must replay deterministically"
    );
    assert_ne!(
        a, full_bytes,
        "a 64-of-256 subsample estimator should not reproduce the full-eval bytes"
    );
}

/// The nation-class presets validate as shipped, and the eager-shard
/// memory guard refuses a nation config stripped of `lazy_shards`
/// instead of letting it attempt hundreds of GiB of resident shards.
#[test]
fn nation_presets_validate_and_require_lazy_shards() {
    for name in ["nation", "nation-xl"] {
        let mut cfg = SimConfig::default();
        cfg.apply_scenario(name).unwrap();
        assert!(cfg.lazy_shards, "{name} must arm lazy shard storage");
        assert_eq!(cfg.aggregation, Aggregation::Hierarchical, "{name}");
        assert!(cfg.eval_sample > 0, "{name} must arm sampled evaluation");
        cfg.validate().unwrap();
        cfg.lazy_shards = false;
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("lazy_shards"), "{name}: {err}");
    }
}

/// A prohibitive relay Ψ charges each scheduled gateway more summary-
/// relay energy than any round's arrival: every selection becomes a
/// C10 violation, so every scheduled gateway fails and nothing trains.
#[test]
fn prohibitive_relay_psi_prices_gateways_out_of_budget() {
    let mut cfg = paper_cfg();
    cfg.relay_psi = 1e3; // Ψ · Γ_bits dwarfs any harvested arrival
    cfg.aggregation = Aggregation::Hierarchical;
    cfg.num_clusters = 2;
    cfg.rounds = 2;
    cfg.validate().unwrap();
    let session = Session::builder(cfg).rounds(2).eval_every(2).build().unwrap();
    let log = session.run(&SchedulerSpec::RoundRobin).unwrap();
    for r in &log.records {
        assert!(r.selected.count() > 0, "round {} selected nobody", r.round);
        assert_eq!(
            r.failed.to_vec(),
            r.selected.to_vec(),
            "round {}: every scheduled gateway must fail its energy budget",
            r.round
        );
        assert!(r.train_loss.is_none(), "round {} trained through a violation", r.round);
    }
}
