//! Scalar ↔ vectorized kernel parity, pinned at the seams the blocked
//! kernels are most likely to get wrong: non-multiple-of-8 widths (the
//! microkernel lane count), 1-row/1-column edge shapes, and the actual
//! cnn cut-point tensor shapes the partition executes. The scalar path is
//! the bit-exactness oracle (the original naive loops, unchanged); the
//! vectorized path must agree within floating-point reassociation
//! tolerance, and each path individually must be byte-deterministic
//! across thread counts.

mod common;

use common::serialize;
use iiot_fl::config::SimConfig;
use iiot_fl::dnn::models;
use iiot_fl::fl::{SchedulerSpec, Session};
use iiot_fl::rng::Rng;
use iiot_fl::runtime::native::ops::{Conv2d, Dense, Op};
use iiot_fl::runtime::{Backend, KernelPath, NativeBackend, PartitionedBackend};

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * 0.5).collect()
}

/// Relative-L2 distance, scale-free: ||a-b|| / max(||a||, tiny).
fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut d = 0.0f64;
    let mut n = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        d += (x as f64 - y as f64).powi(2);
        n += (x as f64).powi(2);
    }
    (d / n.max(1e-30)).sqrt()
}

/// Run forward + backward on both kernel paths of `make_op` with shared
/// params/inputs; return (out_s, out_v, dx_s, dx_v, dp_s, dp_v).
#[allow(clippy::type_complexity)]
fn both_paths(
    make_op: &dyn Fn(KernelPath) -> Box<dyn Op>,
    seed: u64,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut outs = Vec::new();
    let mut dxs = Vec::new();
    let mut dps = Vec::new();
    for kernel in [KernelPath::Scalar, KernelPath::Vectorized] {
        let op = make_op(kernel);
        let mut rng = Rng::new(seed);
        let params = op.init_params(Some(&mut rng));
        let pr: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
        let x = rand_vec(&mut rng, op.in_len());
        let dy = rand_vec(&mut rng, op.out_len());
        let mut out = vec![0.0f32; op.out_len()];
        op.forward(&pr, &x, &mut out);
        let mut dx = vec![0.0f32; op.in_len()];
        let dp_len: usize = op.param_shapes().iter().map(|s| s.iter().product::<usize>()).sum();
        let mut dp = vec![0.0f32; dp_len];
        op.backward(&pr, &x, &dy, Some(&mut dx), &mut dp);
        outs.push(out);
        dxs.push(dx);
        dps.push(dp);
    }
    let (ov, os) = (outs.pop().unwrap(), outs.pop().unwrap());
    let (xv, xs) = (dxs.pop().unwrap(), dxs.pop().unwrap());
    let (pv, ps) = (dps.pop().unwrap(), dps.pop().unwrap());
    (os, ov, xs, xv, ps, pv)
}

const TOL: f64 = 1e-4;

#[test]
fn dense_parity_at_awkward_shapes() {
    // Non-multiple-of-8 widths, 1-wide edges, exact lane multiples.
    for (si, so) in [(7, 13), (1, 5), (9, 1), (8, 8), (17, 33), (64, 10)] {
        let make = |kernel| -> Box<dyn Op> { Box::new(Dense { si, so, kernel }) };
        let (os, ov, xs, xv, ps, pv) = both_paths(&make, 0x0de5e ^ (si * 131 + so) as u64);
        assert!(rel_l2(&os, &ov) < TOL, "dense {si}x{so} forward diverged");
        assert!(rel_l2(&xs, &xv) < TOL, "dense {si}x{so} dx diverged");
        assert!(rel_l2(&ps, &pv) < TOL, "dense {si}x{so} dp diverged");
    }
}

#[test]
fn conv2d_parity_at_cut_point_shapes() {
    // The first three are the exact per-sample shapes at the cnn
    // (VGG-mini) conv layers — what split execution runs at the paper's
    // cut points — plus 1x1 / 5x5 kernels and a degenerate 1x1 image.
    for (ci, co, h, w, k) in [
        (3usize, 16usize, 32usize, 32usize, 3usize),
        (16, 32, 16, 16, 3),
        (32, 64, 8, 8, 3),
        (3, 5, 7, 9, 1),
        (2, 3, 5, 5, 5),
        (1, 1, 1, 1, 3),
    ] {
        let make = |kernel| -> Box<dyn Op> {
            Box::new(Conv2d { ci, co, h, w, kh: k, kw: k, kernel })
        };
        let (os, ov, xs, xv, ps, pv) = both_paths(&make, 0xc07 ^ (ci * 7 + co * 31 + h) as u64);
        let tag = format!("conv {ci}->{co} {h}x{w} k{k}");
        assert!(rel_l2(&os, &ov) < TOL, "{tag} forward diverged");
        assert!(rel_l2(&xs, &xv) < TOL, "{tag} dx diverged");
        assert!(rel_l2(&ps, &pv) < TOL, "{tag} dp diverged");
    }
}

/// Finite differences against the VECTORIZED analytic gradients at
/// awkward shapes (the in-crate op tests cover one friendly shape per op;
/// this pins the blocked path where tails and edge lanes are exercised).
/// Loss is 0.5·||out||², so the upstream error is `out` itself.
#[test]
fn vectorized_finite_difference_at_awkward_shapes() {
    let cases: Vec<Box<dyn Op>> = vec![
        Box::new(Dense { si: 7, so: 13, kernel: KernelPath::Vectorized }),
        Box::new(Dense { si: 9, so: 1, kernel: KernelPath::Vectorized }),
        Box::new(Conv2d {
            ci: 2,
            co: 4,
            h: 5,
            w: 3,
            kh: 3,
            kw: 3,
            kernel: KernelPath::Vectorized,
        }),
    ];
    for op in cases {
        let mut rng = Rng::new(0xfd ^ op.in_len() as u64);
        let mut params = op.init_params(Some(&mut rng));
        let x = rand_vec(&mut rng, op.in_len());
        let loss = |params: &[Vec<f32>]| -> f64 {
            let pr: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
            let mut out = vec![0.0f32; op.out_len()];
            op.forward(&pr, &x, &mut out);
            out.iter().map(|&v| 0.5 * (v as f64) * (v as f64)).sum()
        };
        // Analytic: backward with dy = out.
        let pr: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
        let mut out = vec![0.0f32; op.out_len()];
        op.forward(&pr, &x, &mut out);
        let dp_len: usize = op.param_shapes().iter().map(|s| s.iter().product::<usize>()).sum();
        let mut dp = vec![0.0f32; dp_len];
        let mut dx = vec![0.0f32; op.in_len()];
        op.backward(&pr, &x, &out.clone(), Some(&mut dx), &mut dp);
        drop(pr);
        // Central differences over every parameter coordinate.
        let eps = 1e-2f32;
        let mut flat = 0usize;
        for t in 0..params.len() {
            for i in 0..params[t].len() {
                let orig = params[t][i];
                params[t][i] = orig + eps;
                let lp = loss(&params);
                params[t][i] = orig - eps;
                let lm = loss(&params);
                params[t][i] = orig;
                let fd = (lp - lm) / (2.0 * eps as f64);
                let g = dp[flat] as f64;
                assert!(
                    (fd - g).abs() <= 1e-2 + 3e-2 * fd.abs().max(g.abs()),
                    "{} param[{t}][{i}]: fd {fd} vs analytic {g}",
                    op.name()
                );
                flat += 1;
            }
        }
    }
}

#[test]
fn init_bits_identical_across_kernel_paths() {
    for spec in [models::mlp(), models::vgg_mini()] {
        let s = NativeBackend::from_spec_kernel(&spec, 77, KernelPath::Scalar).unwrap();
        let v = NativeBackend::from_spec_kernel(&spec, 77, KernelPath::Vectorized).unwrap();
        assert_eq!(
            s.init_params().unwrap(),
            v.init_params().unwrap(),
            "{}: the init stream must not depend on the kernel path",
            spec.name
        );
    }
}

/// Whole-backend agreement on the real presets: one training batch
/// through `grad`, `train_step` and `eval_batch` on each path.
#[test]
fn backend_paths_agree_on_presets_within_tolerance() {
    for preset in ["mlp", "cnn"] {
        let s = make(preset, KernelPath::Scalar);
        let v = make(preset, KernelPath::Vectorized);
        let meta = s.meta().clone();
        let mut rng = Rng::new(0xabe7);
        let x = rand_vec(&mut rng, meta.train_batch * meta.sample_dim());
        let y: Vec<i32> = (0..meta.train_batch).map(|_| rng.below(10) as i32).collect();
        // One oracle step off w(0) first: the head is zero-init, so at
        // w(0) every gradient below the head vanishes and the comparison
        // would not exercise the conv/dense backward paths.
        let (params, _) = s.train_step(&s.init_params().unwrap(), &x, &y, 0.05).unwrap();

        let gs = s.grad(&params, &x, &y).unwrap();
        let gv = v.grad(&params, &x, &y).unwrap();
        assert!(rel_l2(&gs, &gv) < 1e-3, "{preset} grad diverged: {}", rel_l2(&gs, &gv));

        let (ps, ls) = s.train_step(&params, &x, &y, 0.01).unwrap();
        let (pv, lv) = v.train_step(&params, &x, &y, 0.01).unwrap();
        assert!((ls as f64 - lv as f64).abs() < 1e-4, "{preset} loss diverged: {ls} vs {lv}");
        for (a, b) in ps.iter().zip(&pv) {
            assert!(rel_l2(a, b) < 1e-3, "{preset} stepped params diverged");
        }

        // Arbitrary-size eval goes through the partial-batch entry point.
        let (es, cs) = s.eval_partial_batch(&params, &x, &y).unwrap().unwrap();
        let (ev, cv) = v.eval_partial_batch(&params, &x, &y).unwrap().unwrap();
        assert!((es - ev).abs() < 1e-3, "{preset} eval loss diverged");
        // Argmax can legitimately flip on a near-tied logit pair under
        // reassociation; allow at most one flipped sample per batch.
        assert!((cs - cv).abs() <= 1.0, "{preset} eval correct-count diverged: {cs} vs {cv}");
    }
}

fn make(preset: &str, kernel: KernelPath) -> Box<dyn Backend> {
    iiot_fl::runtime::make_backend_kernel(std::path::Path::new("artifacts"), preset, kernel)
        .unwrap()
}

/// Each kernel path is individually byte-deterministic across rayon
/// thread counts — the blocked executor's ordered reduction at work.
#[test]
fn grad_bytes_invariant_across_thread_counts_on_both_paths() {
    for kernel in [KernelPath::Scalar, KernelPath::Vectorized] {
        for preset in ["mlp", "cnn"] {
            let be = make(preset, kernel);
            let meta = be.meta().clone();
            let mut rng = Rng::new(0x7d5);
            let x = rand_vec(&mut rng, meta.train_batch * meta.sample_dim());
            let y: Vec<i32> = (0..meta.train_batch).map(|_| rng.below(10) as i32).collect();
            let (params, _) =
                be.train_step(&be.init_params().unwrap(), &x, &y, 0.05).unwrap();
            let run = |threads: usize| {
                let pool =
                    rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
                pool.install(|| be.grad(&params, &x, &y).unwrap())
            };
            let g1 = run(1);
            let g8 = run(8);
            let bits = |g: &[f32]| -> Vec<u32> { g.iter().map(|v| v.to_bits()).collect() };
            assert_eq!(
                bits(&g1),
                bits(&g8),
                "{preset}/{kernel}: thread count changed gradient bytes"
            );
        }
    }
}

/// Split execution equals fused execution BITWISE on each kernel path
/// (the partition suite pins this for the default path; here the scalar
/// oracle path gets the same guarantee).
#[test]
fn split_equals_fused_bitwise_per_path() {
    for kernel in [KernelPath::Scalar, KernelPath::Vectorized] {
        for (preset, cuts) in [("mlp", vec![0, 1, 2]), ("cnn", vec![0, 4, 7])] {
            let fused = make(preset, kernel);
            let meta = fused.meta().clone();
            let mut rng = Rng::new(0x5417);
            let x = rand_vec(&mut rng, meta.train_batch * meta.sample_dim());
            let y: Vec<i32> = (0..meta.train_batch).map(|_| rng.below(10) as i32).collect();
            let (params, _) =
                fused.train_step(&fused.init_params().unwrap(), &x, &y, 0.05).unwrap();
            let (pf, lf) = fused.train_step(&params, &x, &y, 0.01).unwrap();
            for cut in cuts {
                let split = PartitionedBackend::preset_kernel(preset, cut, kernel).unwrap();
                assert_eq!(split.kernel(), kernel);
                let (psp, lsp) = split.train_step(&params, &x, &y, 0.01).unwrap();
                assert_eq!(lf.to_bits(), lsp.to_bits(), "{preset}/{kernel} l={cut} loss");
                assert_eq!(pf, psp, "{preset}/{kernel} l={cut} params");
            }
        }
    }
}

/// Whole-run replay on the SCALAR oracle path: the session trajectory is
/// byte-identical run to run (the numerics PR 6 shipped are still
/// reachable, unchanged, behind `kernel = scalar`), and the vectorized
/// default replays byte-identically too.
#[test]
fn scalar_and_vectorized_sessions_each_replay_byte_identically() {
    for kernel in [KernelPath::Scalar, KernelPath::Vectorized] {
        let mut cfg = SimConfig::default();
        cfg.exec_model = "mlp".into();
        cfg.test_size = 512;
        cfg.dataset_max = 500;
        cfg.rounds = 2;
        cfg.kernel = kernel;
        let mut logs = Vec::new();
        for _ in 0..2 {
            let session = Session::builder(cfg.clone()).rounds(2).eval_every(2).build().unwrap();
            logs.push(serialize(&session.run(&SchedulerSpec::RoundRobin).unwrap()));
        }
        assert_eq!(logs[0], logs[1], "{kernel} session replay diverged");
    }
}
