//! Schema guard for the committed bench emissions: `scripts/bench_compare`
//! must round-trip BOTH committed `BENCH_*.json` files (self-compare),
//! find their timed sections, and keep its report-only exit-0 contract —
//! so a bench refactor that silently breaks the JSON shape (or the
//! comparer's walker) fails here instead of in a CI log nobody reads.

use std::path::{Path, PathBuf};
use std::process::Command;

fn repo_root() -> &'static Path {
    // The workspace Cargo.toml sits at the repo root, next to the
    // committed bench files and `scripts/`.
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

fn python3_available() -> bool {
    Command::new("python3")
        .arg("--version")
        .output()
        .map(|o| o.status.success())
        .unwrap_or(false)
}

fn run_compare(base: &PathBuf, cur: &PathBuf) -> (String, String, bool) {
    let out = Command::new("python3")
        .arg(repo_root().join("scripts").join("bench_compare"))
        .arg(base)
        .arg(cur)
        .output()
        .expect("spawn scripts/bench_compare");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

#[test]
fn bench_compare_round_trips_the_committed_bench_files() {
    if !python3_available() {
        eprintln!("skipping: python3 unavailable on this machine");
        return;
    }
    for name in ["BENCH_runtime.json", "BENCH_round_engine.json"] {
        let file = repo_root().join(name);
        assert!(file.exists(), "{name} missing from the repo root");
        let (stdout, stderr, ok) = run_compare(&file, &file);
        assert!(ok, "bench_compare failed on {name}: {stderr}\n{stdout}");
        // The walker must actually find timed sections — a schema drift
        // that hides every row would otherwise pass silently.
        assert!(
            stdout.contains("== "),
            "{name}: bench_compare found no timed sections:\n{stdout}"
        );
        // A file can never regress against itself (bootstrap placeholders
        // with null timings surface as NEW rows, which is also clean).
        assert!(
            stdout.contains("no regressions beyond noise threshold"),
            "{name} self-compare reported regressions:\n{stdout}"
        );
    }
}

#[test]
fn bench_compare_reports_unreadable_input_without_failing() {
    if !python3_available() {
        eprintln!("skipping: python3 unavailable on this machine");
        return;
    }
    // Report-only contract: a missing file is diagnosed on stdout and the
    // tool still exits 0, so a CI lane wiring mistake never masquerades
    // as a perf regression.
    let good = repo_root().join("BENCH_round_engine.json");
    let missing = repo_root().join("BENCH_does_not_exist.json");
    let (stdout, stderr, ok) = run_compare(&good, &missing);
    assert!(ok, "report-only tool must exit 0: {stderr}");
    assert!(stdout.contains("cannot read"), "missing-file diagnosis absent:\n{stdout}");
}
