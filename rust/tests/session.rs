//! Session-API guarantees: sink schemas are pinned by golden files, the
//! CSV streamed DURING a run is byte-identical to the post-hoc
//! `write_run_csv` emission, the `MemorySink` log equals the compat
//! `Experiment::run` log (the pre-session engine surface) for a
//! paper-scale DDSRA run, paired runs equal sequential runs, and an
//! early-stopped run is byte-identical to the first k records of the
//! uninterrupted run.

mod common;

use std::ops::ControlFlow;
use std::path::PathBuf;

use common::{serialize, serialize_records};
use iiot_fl::config::SimConfig;
use iiot_fl::fl::{
    GatewayMask, RoundObserver, RoundRecord, RunMeta, RunOpts, RunSummary, SchedulerSpec,
    Session, StopCause,
};
use iiot_fl::metrics::{write_run_csv, CsvSink, JsonlSink, MemorySink};

fn cfg() -> SimConfig {
    // Paper-scale topology (M=6, N=12, J=3); small shards/test set keep
    // the real training fast.
    let mut cfg = SimConfig::default();
    cfg.exec_model = "mlp".into();
    cfg.test_size = 512;
    cfg.dataset_max = 500;
    cfg
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join("iiot_fl_session_test").join(name)
}

/// Hand-built two-round trajectory with exactly representable floats —
/// the fixture behind the golden-file schema pins.
fn fixture() -> (RunMeta, Vec<RoundRecord>, RunSummary) {
    let meta =
        RunMeta { scheme: "golden".into(), rounds: 2, gateways: 2, devices: 4 };
    let records = vec![
        RoundRecord {
            round: 0,
            delay: 1.5,
            cum_delay: 1.5,
            selected: GatewayMask::from_slice(&[true, false]),
            failed: GatewayMask::from_slice(&[false, false]),
            train_loss: Some(2.5),
            test_loss: None,
            test_acc: None,
            divergence: None,
            faults: None,
        },
        RoundRecord {
            round: 1,
            delay: 2.25,
            cum_delay: 3.75,
            selected: GatewayMask::from_slice(&[true, true]),
            failed: GatewayMask::from_slice(&[false, true]),
            train_loss: Some(1.25),
            test_loss: Some(0.5),
            test_acc: Some(0.75),
            divergence: Some(vec![0.5, 0.25]),
            faults: None,
        },
    ];
    let summary = RunSummary {
        scheme: "golden".into(),
        rounds_planned: 2,
        rounds_run: 2,
        stop: None,
        participation: vec![1.0, 0.5],
        effective_participation: vec![1.0, 0.0],
    };
    (meta, records, summary)
}

fn drive_sink(sink: &mut dyn RoundObserver) {
    let (meta, records, summary) = fixture();
    sink.on_start(&meta).unwrap();
    for r in &records {
        assert_eq!(sink.on_record(r).unwrap(), ControlFlow::Continue(()));
    }
    sink.on_finish(&summary).unwrap();
}

/// Golden-file schema pin: the CSV and JSONL emitted for the fixture
/// trajectory must match the checked-in files byte for byte. Changing a
/// sink's schema means deliberately regenerating the goldens.
#[test]
fn sink_output_matches_golden_files() {
    let csv_path = tmp("fixture.csv");
    let mut csv = CsvSink::create(&csv_path).unwrap();
    drive_sink(&mut csv);
    drop(csv);
    assert_eq!(
        std::fs::read_to_string(&csv_path).unwrap(),
        include_str!("golden/sink_fixture.csv"),
        "CsvSink schema drifted from rust/tests/golden/sink_fixture.csv"
    );

    let jsonl_path = tmp("fixture.jsonl");
    let mut jsonl = JsonlSink::create(&jsonl_path).unwrap();
    drive_sink(&mut jsonl);
    drop(jsonl);
    assert_eq!(
        std::fs::read_to_string(&jsonl_path).unwrap(),
        include_str!("golden/sink_fixture.jsonl"),
        "JsonlSink schema drifted from rust/tests/golden/sink_fixture.jsonl"
    );
}

/// A `MemorySink` driven by the fixture rebuilds the exact `RunLog`.
#[test]
fn memory_sink_rebuilds_the_log() {
    let mut mem = MemorySink::new();
    drive_sink(&mut mem);
    let (_, records, summary) = fixture();
    let log = mem.into_log();
    assert_eq!(log.scheme, "golden");
    assert_eq!(serialize_records(&log.records), serialize_records(&records));
    assert_eq!(log.participation, summary.participation);
    assert_eq!(log.effective_participation, summary.effective_participation);
}

/// The acceptance pin: a CSV STREAMED during a real run equals the
/// post-hoc `write_run_csv` of the buffered log, byte for byte.
#[test]
fn csv_streamed_during_run_equals_post_hoc_write() {
    let session = Session::builder(cfg()).rounds(3).eval_every(2).build().unwrap();
    let streamed_path = tmp("streamed.csv");
    let mut mem = MemorySink::new();
    let mut csv = CsvSink::create(&streamed_path).unwrap();
    {
        let mut observers: Vec<&mut dyn RoundObserver> = vec![&mut mem, &mut csv];
        session.run_with(&SchedulerSpec::RoundRobin, &mut observers).unwrap();
    }
    drop(csv);
    let log = mem.into_log();
    let post_hoc_path = tmp("post_hoc.csv");
    write_run_csv(&log, &post_hoc_path).unwrap();
    let streamed = std::fs::read_to_string(&streamed_path).unwrap();
    let post_hoc = std::fs::read_to_string(&post_hoc_path).unwrap();
    assert_eq!(streamed, post_hoc, "streamed CSV != post-hoc CSV");
    assert_eq!(streamed.lines().count(), 4, "header + one row per round");

    // The JSONL stream frames the same run: meta + rounds + summary.
    let jsonl_path = tmp("run.jsonl");
    let mut jsonl = JsonlSink::create(&jsonl_path).unwrap();
    {
        let mut observers: Vec<&mut dyn RoundObserver> = vec![&mut jsonl];
        session.run_with(&SchedulerSpec::RoundRobin, &mut observers).unwrap();
    }
    drop(jsonl);
    let text = std::fs::read_to_string(&jsonl_path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 5, "meta + 3 rounds + summary");
    assert!(lines[0].starts_with("{\"type\":\"meta\",\"scheme\":\"round_robin\""), "{}", lines[0]);
    assert!(lines[1].starts_with("{\"type\":\"round\",\"round\":0,"), "{}", lines[1]);
    assert!(lines[4].starts_with("{\"type\":\"summary\",") && lines[4].contains("\"stop\":null"));
}

/// The determinism acceptance pin at paper scale: the `MemorySink`-built
/// log of a DDSRA session run serializes byte-identically to the log
/// returned by the compat `Experiment::run` surface (the engine entry
/// that predates sessions). Both paths execute the identical per-round
/// computation — the observer layer only changes where records GO, and
/// `GatewayMask::to_vec` reproduces the pre-compaction `Vec<bool>`
/// rendering — so every byte must match.
#[test]
fn session_ddsra_log_matches_compat_run_surface() {
    let session = Session::builder(cfg()).rounds(3).eval_every(2).build().unwrap();
    let via_session = serialize(&session.run(&SchedulerSpec::ddsra()).unwrap());

    let exp = iiot_fl::fl::Experiment::new(cfg()).unwrap();
    let mut sched = exp.make_scheduler("ddsra").unwrap();
    let mut opts = RunOpts::default();
    opts.rounds = 3;
    opts.eval_every = 2;
    let via_compat = serialize(&exp.run(sched.as_mut(), &opts).unwrap());

    assert_eq!(via_session, via_compat, "session and compat logs diverged");
}

/// `run_paired` is exactly k sequential runs over one experiment: same
/// bytes, labels in spec order.
#[test]
fn paired_runs_equal_sequential_runs() {
    let session = Session::builder(cfg()).rounds(2).eval_every(2).build().unwrap();
    let specs = [SchedulerSpec::RoundRobin, SchedulerSpec::DelayDriven];
    let paired = session.run_paired(&specs).unwrap();
    assert_eq!(paired.len(), 2);
    assert_eq!(paired[0].label, "round_robin");
    assert_eq!(paired[1].label, "delay_driven");
    for (run, spec) in paired.iter().zip(&specs) {
        let solo = session.run(spec).unwrap();
        assert_eq!(serialize(&run.log), serialize(&solo), "{}", run.label);
        assert!(run.wall_secs >= 0.0);
    }
}

/// Early-stop determinism: a run stopped at round k (simulated delay
/// budget, target accuracy, or observer break) is byte-identical to the
/// first k+1 records of the uninterrupted run — except that a stopping
/// round the periodic eval gate skipped now carries a forced final eval
/// (delivered via `on_final_eval`, patched into the `MemorySink` log),
/// so those runs never end with `test_acc = None`. The eval values
/// themselves are pinned against an `eval_every = 1` run, which
/// evaluates the identical post-aggregation parameters.
#[test]
fn early_stopped_run_is_a_byte_identical_prefix() {
    let full_session = Session::builder(cfg()).rounds(6).eval_every(2).build().unwrap();
    let full = full_session.run(&SchedulerSpec::RoundRobin).unwrap();
    assert_eq!(full.records.len(), 6);

    // Reference evals for every round: eval_every = 1 evaluates the same
    // trained parameters each round (evaluation never perturbs training).
    let dense = Session::builder(cfg())
        .rounds(3)
        .eval_every(1)
        .build()
        .unwrap()
        .run(&SchedulerSpec::RoundRobin)
        .unwrap();

    // A stopped-run record whose eval fields came from the forced final
    // eval, reduced back to what the periodic gate alone would have
    // produced — so prefix comparisons stay bitwise.
    let strip_eval = |r: &RoundRecord| {
        let mut r = r.clone();
        r.test_loss = None;
        r.test_acc = None;
        r
    };

    // Delay budget: cum_delay reaches records[2].cum_delay at round 2.
    // Round 2 is not eval-aligned (eval_every = 2 evals rounds 1, 3, 5),
    // so the stopping round gets the forced final eval.
    let budget = full.records[2].cum_delay;
    let session =
        Session::builder(cfg()).rounds(6).eval_every(2).max_rounds_wall(budget).build().unwrap();
    let mut mem = MemorySink::new();
    let summary = {
        let mut observers: Vec<&mut dyn RoundObserver> = vec![&mut mem];
        session.run_with(&SchedulerSpec::RoundRobin, &mut observers).unwrap()
    };
    assert_eq!(summary.rounds_run, 3);
    assert!(
        matches!(summary.stop, Some(StopCause::DelayBudget { round: 2, .. })),
        "{:?}",
        summary.stop
    );
    let stopped = mem.into_log();
    assert_eq!(
        serialize_records(&stopped.records[..2]),
        serialize_records(&full.records[..2]),
        "delay-budget stop is not a byte-identical prefix"
    );
    assert_eq!(
        serialize_records(&[strip_eval(&stopped.records[2])]),
        serialize_records(&full.records[2..3]),
        "delay-budget stopping round diverged beyond the forced eval"
    );
    assert_eq!(
        stopped.records[2].test_acc.map(f64::to_bits),
        dense.records[2].test_acc.map(f64::to_bits),
        "forced final eval != dense-eval reference at round 2"
    );
    assert_eq!(
        stopped.records[2].test_loss.map(f64::to_bits),
        dense.records[2].test_loss.map(f64::to_bits)
    );

    // Target accuracy: any accuracy satisfies target 0.0, so the first
    // eval round (round 1 with eval_every=2) stops the run.
    let session =
        Session::builder(cfg()).rounds(6).eval_every(2).until_accuracy(0.0).build().unwrap();
    let mut mem = MemorySink::new();
    let summary = {
        let mut observers: Vec<&mut dyn RoundObserver> = vec![&mut mem];
        session.run_with(&SchedulerSpec::RoundRobin, &mut observers).unwrap()
    };
    assert_eq!(summary.rounds_run, 2);
    assert!(
        matches!(summary.stop, Some(StopCause::TargetAccuracy { round: 1, .. })),
        "{:?}",
        summary.stop
    );
    assert_eq!(
        serialize_records(&mem.into_log().records),
        serialize_records(&full.records[..2]),
        "target-accuracy stop is not a byte-identical prefix"
    );

    // Observer break after the first record.
    struct BreakAfter {
        remaining: usize,
    }
    impl RoundObserver for BreakAfter {
        fn on_record(&mut self, _r: &RoundRecord) -> anyhow::Result<ControlFlow<()>> {
            self.remaining -= 1;
            Ok(if self.remaining == 0 { ControlFlow::Break(()) } else { ControlFlow::Continue(()) })
        }
    }
    let session = Session::builder(cfg()).rounds(6).eval_every(2).build().unwrap();
    let mut mem = MemorySink::new();
    let mut brk = BreakAfter { remaining: 1 };
    let summary = {
        let mut observers: Vec<&mut dyn RoundObserver> = vec![&mut mem, &mut brk];
        session.run_with(&SchedulerSpec::RoundRobin, &mut observers).unwrap()
    };
    assert_eq!(summary.rounds_run, 1);
    assert_eq!(summary.stop, Some(StopCause::Observer { round: 0 }));
    // Round 0 is not eval-aligned, so the broken run's only record gains
    // the forced final eval — dense-eval round 0 is the reference.
    let stopped = mem.into_log();
    assert_eq!(
        serialize_records(&[strip_eval(&stopped.records[0])]),
        serialize_records(&full.records[..1]),
        "observer stop is not a byte-identical prefix"
    );
    assert_eq!(
        stopped.records[0].test_acc.map(f64::to_bits),
        dense.records[0].test_acc.map(f64::to_bits),
        "forced final eval != dense-eval reference at round 0"
    );
}
