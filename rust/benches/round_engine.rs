//! Round-engine throughput (custom harness — criterion is unavailable
//! offline): wall-clock per communication round as the device count and
//! the rayon thread count scale, plus a paired all-schedulers run at
//! N=240 — the large-N scenario exercising the streaming round engine
//! end to end. Prints human tables and emits machine-readable
//! `BENCH_round_engine.json`. Thresholds are NOT asserted (bench, not
//! test); byte-stability across thread counts IS asserted (it is the
//! engine's core guarantee and costs nothing to check here), and so is
//! flat == hierarchical trajectory parity in the aggregation section
//! (the same oracle `rust/tests/hierarchy.rs` pins, here in release
//! numerics).
//!
//! Run: `cargo bench --bench round_engine`
//! Smoke: `cargo bench --bench round_engine -- --smoke` shrinks the
//! grids to one working point per section (the CI bench-smoke lane) but
//! still emits every JSON section, including the nation-scale row.

use std::fmt::Write as _;
use std::time::Instant;

use iiot_fl::config::{Aggregation, SimConfig};
use iiot_fl::dnn::models;
use iiot_fl::energy::EnergyArrivals;
use iiot_fl::fl::{SchedulerSpec, Session};
use iiot_fl::net::ChannelModel;
use iiot_fl::rng::Rng;
use iiot_fl::runtime::KernelPath;
use iiot_fl::sched::{Ddsra, RoundCtx, SchedPath, Scheduler};
use iiot_fl::topo::Topology;

/// `git describe --always --dirty`, or "unknown" outside a git checkout —
/// tags the emitted JSON so two bench files can be attributed to commits.
fn git_describe() -> String {
    std::process::Command::new("git")
        .args(["describe", "--always", "--dirty"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

/// A scale working point with budgets generous enough that scheduled
/// floors always train — the bench measures the engine, not feasibility.
fn scale_cfg(devices: usize, gateways: usize, channels: usize) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.num_devices = devices;
    cfg.num_gateways = gateways;
    cfg.num_channels = channels;
    cfg.dataset_min = 32;
    cfg.dataset_max = 128;
    cfg.test_size = 256;
    cfg.local_iters = 2;
    cfg.device_energy_max = 500.0;
    cfg.gw_energy_max = 5000.0;
    cfg
}

/// Time the SCHEDULING phase alone: DDSRA rounds (Λ matrix + λ-sweep +
/// queue update) against a generated topology/channel world, no training
/// engine. Returns (seconds per round, a bit-exact decision digest) —
/// the digest lets the caller assert sweep/incremental parity in release
/// numerics, the same oracle `rust/tests/sched_parity.rs` pins.
fn timed_schedule(
    cfg: &SimConfig,
    path: SchedPath,
    rounds: usize,
    threads: usize,
) -> anyhow::Result<(f64, String)> {
    let pool = rayon::ThreadPoolBuilder::new().num_threads(threads).build()?;
    pool.install(|| {
        let mut rng = Rng::new(cfg.seed ^ 0x5c4ed);
        let topo = Topology::generate(cfg, &mut rng);
        let chan = ChannelModel::new(cfg, &topo, &mut rng);
        let model = models::by_name(&cfg.cost_model)
            .ok_or_else(|| anyhow::anyhow!("unknown cost model {:?}", cfg.cost_model))?;
        let mut sched = Ddsra::new(cfg.lyapunov_v, vec![0.5; topo.num_gateways()]);
        sched.parallel = true;
        sched.sched_path = path;
        let mut digest = String::new();
        let t0 = Instant::now();
        for round in 0..rounds {
            let state = chan.draw(&mut rng);
            let arrivals = EnergyArrivals::draw(cfg, &mut rng);
            let ctx = RoundCtx {
                cfg,
                topo: &topo,
                model: &model,
                chan: &chan,
                state: &state,
                arrivals: &arrivals,
                round,
            };
            let dec = sched.schedule(&ctx);
            let _ = write!(digest, "{:016x}!", dec.round_delay().to_bits());
            for p in &dec.plans {
                let _ = write!(digest, "{}:{}:{:016x};", p.gateway, p.channel, p.lambda.to_bits());
            }
        }
        let per_round = t0.elapsed().as_secs_f64() / rounds as f64;
        Ok((per_round, digest))
    })
}

/// One timed run inside a dedicated rayon pool: returns (seconds per
/// round, final train loss, a bit-exact digest of the trajectory).
fn timed_run(
    cfg: &SimConfig,
    spec: &SchedulerSpec,
    rounds: usize,
    threads: usize,
) -> anyhow::Result<(f64, Option<f64>, String)> {
    let pool = rayon::ThreadPoolBuilder::new().num_threads(threads).build()?;
    pool.install(|| {
        let session = Session::builder(cfg.clone()).rounds(rounds).eval_every(0).build()?;
        let mut sched = session.scheduler(spec)?;
        let t0 = Instant::now();
        let log = session.run_scheduler(sched.as_mut())?;
        let per_round = t0.elapsed().as_secs_f64() / rounds as f64;
        let loss = log.records.iter().rev().find_map(|r| r.train_loss);
        let mut digest = String::new();
        for r in &log.records {
            let _ = write!(
                digest,
                "{:016x}|{:016x};",
                r.delay.to_bits(),
                r.train_loss.unwrap_or(-1.0).to_bits()
            );
        }
        Ok((per_round, loss, digest))
    })
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let max_threads = std::thread::available_parallelism().map_or(4, |n| n.get());
    let mut thread_grid: Vec<usize> = if smoke {
        vec![1, max_threads]
    } else {
        [1usize, 2, 4, max_threads].into_iter().filter(|&t| t <= max_threads).collect()
    };
    thread_grid.dedup();

    let mut json = String::from("{\n  \"bench\": \"round_engine\",\n");
    // The sessions below run the config default, i.e. KernelPath::default();
    // tagging it (plus the commit) makes two bench files comparable.
    let _ = writeln!(json, "  \"kernel\": \"{}\",", KernelPath::default());
    let _ = writeln!(json, "  \"git_describe\": \"{}\",", git_describe());
    let _ = writeln!(json, "  \"max_threads\": {max_threads},");
    json.push_str("  \"device_sweep\": [\n");

    println!("== round throughput vs device count x thread count ==");
    println!(
        "{:>8} {:>9} {:>8} {:>14} {:>10}",
        "devices", "gateways", "threads", "s/round", "speedup"
    );
    let sweeps: &[(usize, usize, usize)] =
        if smoke { &[(12, 6, 3)] } else { &[(12, 6, 3), (60, 12, 6), (240, 24, 8)] };
    let rounds = if smoke { 2 } else { 3 };
    let mut first_row = true;
    for &(n, m, j) in sweeps {
        let cfg = scale_cfg(n, m, j);
        let mut serial = None;
        let mut serial_digest = None;
        for &threads in &thread_grid {
            let (per_round, _, digest) =
                timed_run(&cfg, &SchedulerSpec::RoundRobin, rounds, threads)?;
            // The engine's core guarantee, checked in passing: the
            // trajectory bytes do not depend on the thread count.
            if let Some(d) = &serial_digest {
                assert_eq!(d, &digest, "thread count changed round bytes");
            } else {
                serial_digest = Some(digest);
            }
            let base = *serial.get_or_insert(per_round);
            let speedup = base / per_round;
            println!("{n:>8} {m:>9} {threads:>8} {:>12.1}ms {speedup:>9.2}x", per_round * 1e3);
            if !first_row {
                json.push_str(",\n");
            }
            first_row = false;
            let _ = write!(
                json,
                "    {{\"devices\": {n}, \"gateways\": {m}, \"channels\": {j}, \
                 \"threads\": {threads}, \"sec_per_round\": {per_round:.6}, \
                 \"speedup_vs_1_thread\": {speedup:.3}}}"
            );
        }
    }
    json.push_str("\n  ],\n  \"schedulers_n240\": [\n");

    // The paired all-schedulers run (DDSRA's Γ estimation dominates) is
    // the slow section; the smoke lane emits an empty array instead.
    if !smoke {
        println!("\n== paired schedulers at N=240 (plant scale, {max_threads} threads) ==");
        println!("{:>16} {:>14} {:>12}", "scheme", "s/round", "train_loss");
        let cfg = scale_cfg(240, 24, 8);
        // One Session::run_paired call: every scheduler faces identical
        // environment streams over ONE experiment, the DDSRA family shares a
        // single Γ estimation, and per-run wall time comes back per entry.
        let paired = {
            let pool = rayon::ThreadPoolBuilder::new().num_threads(max_threads).build()?;
            pool.install(|| -> anyhow::Result<_> {
                let session = Session::builder(cfg.clone()).rounds(2).eval_every(0).build()?;
                session.run_paired(&SchedulerSpec::all())
            })?
        };
        for (i, run) in paired.iter().enumerate() {
            let per_round = run.wall_secs / 2.0;
            let loss = run.log.records.iter().rev().find_map(|r| r.train_loss);
            let loss_s = loss.map_or("-".into(), |l| format!("{l:.4}"));
            println!("{:>16} {:>12.1}ms {loss_s:>12}", run.label, per_round * 1e3);
            if i > 0 {
                json.push_str(",\n");
            }
            let _ = write!(
                json,
                "    {{\"scheme\": \"{}\", \"devices\": 240, \"threads\": {max_threads}, \
                 \"sec_per_round\": {per_round:.6}, \"final_train_loss\": {}}}",
                run.label,
                loss.map_or("null".into(), |l| format!("{l:.6}"))
            );
        }
    }
    json.push_str("\n  ],\n  \"fault_injection\": [\n");

    // Fault-injected rounds at plant scale: what deterministic adversity
    // costs on top of the benign engine, and — checked in passing, like
    // the sweep above — that the faulted trajectory is byte-stable across
    // thread counts too.
    println!("\n== flaky-plant (faults armed) vs plant, round_robin ==");
    println!("{:>12} {:>8} {:>14}", "scenario", "threads", "s/round");
    let mut flaky = scale_cfg(240, 24, 8);
    flaky.fault = {
        let mut probe = SimConfig::default();
        probe.apply_scenario("flaky-plant")?;
        probe.fault
    };
    // Keep the benign sweep's menu sharder so the comparison isolates the
    // per-round fault seams from the Dirichlet sharding change.
    flaky.fault.dirichlet_alpha = 0.0;
    let mut first_row = true;
    let mut flaky_digest = None;
    for &threads in &thread_grid {
        let (per_round, _, digest) = timed_run(&flaky, &SchedulerSpec::RoundRobin, rounds, threads)?;
        if let Some(d) = &flaky_digest {
            assert_eq!(d, &digest, "thread count changed faulted round bytes");
        } else {
            flaky_digest = Some(digest);
        }
        println!("{:>12} {threads:>8} {:>12.1}ms", "flaky-plant", per_round * 1e3);
        if !first_row {
            json.push_str(",\n");
        }
        first_row = false;
        let _ = write!(
            json,
            "    {{\"scenario\": \"flaky-plant\", \"devices\": 240, \"threads\": {threads}, \
             \"sec_per_round\": {per_round:.6}}}"
        );
    }
    json.push_str("\n  ],\n  \"aggregation_modes\": [\n");

    // Flat vs hierarchical phase-5 fold at plant scale — and, asserted in
    // passing in RELEASE numerics, the parity oracle itself: both modes
    // must produce byte-identical trajectories (`rust/tests/hierarchy.rs`
    // pins the same property in the test profile).
    println!("\n== aggregation: flat vs hierarchical (240 devices, 6 clusters) ==");
    println!("{:>14} {:>8} {:>14}", "aggregation", "threads", "s/round");
    let mut agg_cfg = scale_cfg(240, 24, 8);
    agg_cfg.num_clusters = 6;
    let mut first_row = true;
    let mut digests = Vec::new();
    for agg in [Aggregation::Flat, Aggregation::Hierarchical] {
        let mut cfg = agg_cfg.clone();
        cfg.aggregation = agg;
        let (per_round, _, digest) =
            timed_run(&cfg, &SchedulerSpec::RoundRobin, rounds, max_threads)?;
        digests.push(digest);
        println!("{:>14} {max_threads:>8} {:>12.1}ms", agg.to_string(), per_round * 1e3);
        if !first_row {
            json.push_str(",\n");
        }
        first_row = false;
        let _ = write!(
            json,
            "    {{\"aggregation\": \"{agg}\", \"devices\": 240, \"clusters\": 6, \
             \"threads\": {max_threads}, \"sec_per_round\": {per_round:.6}}}"
        );
    }
    assert_eq!(
        digests[0], digests[1],
        "hierarchical fold changed the flat trajectory bytes"
    );

    // Nation-scale smoke: 10^5 devices behind 2000 gateways, lazy shard
    // storage, hierarchical fold — one round end to end. Budgets opened
    // like every other bench point so the scheduled floors really train.
    let mut nation = SimConfig::default();
    nation.apply_scenario("nation")?;
    nation.device_energy_max = 500.0;
    nation.gw_energy_max = 5000.0;
    let (per_round, _, _) = timed_run(&nation, &SchedulerSpec::RoundRobin, 1, max_threads)?;
    println!(
        "{:>14} {max_threads:>8} {:>12.1}ms   (nation: 100000 devices, 1 round)",
        "nation", per_round * 1e3
    );
    json.push_str(",\n");
    let _ = write!(
        json,
        "    {{\"scenario\": \"nation\", \"aggregation\": \"hierarchical\", \
         \"devices\": 100000, \"clusters\": 40, \"threads\": {max_threads}, \
         \"sec_per_round\": {per_round:.6}}}"
    );
    json.push_str("\n  ],\n  \"schedule_phase\": [\n");

    // The scheduling phase alone (the tentpole of the incremental λ-sweep
    // work): DDSRA rounds with no training engine, per scenario and
    // sched_path. Where both paths run, their decision digests must agree
    // bit for bit — the release-numerics face of the parity oracle.
    println!("\n== schedule phase: DDSRA λ-sweep, sweep vs incremental ==");
    println!("{:>8} {:>9} {:>9} {:>13} {:>14}", "scenario", "gateways", "channels", "sched_path", "s/round");
    let grid: &[(&str, usize, bool)] = if smoke {
        // Plant pins parity; nation shows the scale the incremental
        // path exists for without paying 16 000 Hungarian solves in CI.
        &[("plant", 2, true), ("nation", 1, false)]
    } else {
        &[("plant", 3, true), ("metro", 2, true), ("nation", 1, true)]
    };
    let mut first_row = true;
    for &(name, rounds, run_sweep) in grid {
        let mut cfg = SimConfig::default();
        cfg.apply_scenario(name)?;
        cfg.device_energy_max = 500.0;
        cfg.gw_energy_max = 5000.0;
        let paths: &[SchedPath] = if run_sweep {
            &[SchedPath::Sweep, SchedPath::Incremental]
        } else {
            &[SchedPath::Incremental]
        };
        let mut digests: Vec<String> = Vec::new();
        for &path in paths {
            let (per_round, digest) = timed_schedule(&cfg, path, rounds, max_threads)?;
            digests.push(digest);
            println!(
                "{name:>8} {:>9} {:>9} {path:>13} {:>12.1}ms",
                cfg.num_gateways,
                cfg.num_channels,
                per_round * 1e3
            );
            if !first_row {
                json.push_str(",\n");
            }
            first_row = false;
            let _ = write!(
                json,
                "    {{\"scenario\": \"{name}\", \"gateways\": {}, \"channels\": {}, \
                 \"sched_path\": \"{path}\", \"threads\": {max_threads}, \
                 \"sec_per_round\": {per_round:.6}}}",
                cfg.num_gateways, cfg.num_channels
            );
        }
        if digests.len() == 2 {
            assert_eq!(
                digests[0], digests[1],
                "{name}: incremental λ-sweep diverged from the sweep oracle"
            );
        }
    }
    json.push_str("\n  ]\n}\n");

    std::fs::write("BENCH_round_engine.json", &json)?;
    println!("\nwrote BENCH_round_engine.json");
    Ok(())
}
