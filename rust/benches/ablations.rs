//! Ablation studies for the design choices DESIGN.md calls out.
//!
//! A1  Dynamic vs FIXED DNN partition point — the paper's headline novelty
//!     ("our paper is the first attempt to investigate the dynamic DNN
//!     partition in FL training"): DDSRA with the l-step disabled (l fixed
//!     at L/2, as in the prior-work baselines [19]-[21]) vs full DDSRA.
//! A2  BCD iteration count — convergence of the (l, f, P) block descent.
//! A3  Non-IID degree chi — data-heterogeneity robustness of the Γ-policy.
//!
//! Scheduling-only where possible (A1/A2 need no PJRT training); A3 trains.
//! Run: `cargo bench --bench ablations` (env ABL_ROUNDS to scale, def. 200)

use anyhow::Result;
use iiot_fl::config::SimConfig;
use iiot_fl::dnn::models;
use iiot_fl::energy::EnergyArrivals;
use iiot_fl::fl::{SchedulerSpec, Session};
use iiot_fl::metrics::print_table;
use iiot_fl::net::ChannelModel;
use iiot_fl::rng::Rng;
use iiot_fl::sched::latency::plan_cost;
use iiot_fl::sched::{Ddsra, GatewayPlan, RoundCtx};
use iiot_fl::topo::Topology;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let rounds = env_usize("ABL_ROUNDS", 200);
    a1_dynamic_vs_fixed_partition(rounds);
    a2_bcd_iterations(rounds);
    a3_non_iid_degree()?;
    Ok(())
}

/// Build a DDSRA plan but overwrite the partition with a fixed l = L/2
/// (clamped to device memory), then re-solve f and P around it by running
/// solve_gateway on a model whose only feasible l is the fixed one — here
/// approximated by taking the DDSRA plan and re-costing with fixed l.
fn fixed_partition_lambda(ctx: &RoundCtx, m: usize, j: usize) -> Option<f64> {
    let plan = Ddsra::solve_gateway(ctx, m, j, 3)?;
    let gw = &ctx.topo.gateways[m];
    let depth = ctx.model.depth();
    let partition: Vec<usize> = gw
        .members
        .iter()
        .map(|&n| {
            let dev = &ctx.topo.devices[n];
            let mut l = depth / 2;
            while l > 0 && ctx.model.bottom_mem(l, dev.train_batch as u64) > dev.mem {
                l -= 1;
            }
            l
        })
        .collect();
    // Fixed-partition prior work also fixes the frequency: even split.
    let freq = vec![gw.freq_max / gw.members.len() as f64; gw.members.len()];
    let fixed = GatewayPlan { partition, freq, ..plan };
    let cost = plan_cost(ctx, &fixed);
    cost.feasible().then(|| cost.lambda())
}

fn a1_dynamic_vs_fixed_partition(rounds: usize) {
    let cfg = SimConfig::default();
    let mut rng = Rng::new(cfg.seed);
    let topo = Topology::generate(&cfg, &mut rng);
    let chan = ChannelModel::new(&cfg, &topo, &mut rng);
    let model = models::vgg11_cifar();

    let mut sum_dyn = 0.0;
    let mut sum_fixed = 0.0;
    let (mut n_dyn, mut n_fixed) = (0usize, 0usize);
    let mut infeasible_fixed = 0usize;
    for t in 0..rounds {
        let state = chan.draw(&mut rng);
        let arrivals = EnergyArrivals::draw(&cfg, &mut rng);
        let ctx = RoundCtx {
            cfg: &cfg,
            topo: &topo,
            model: &model,
            chan: &chan,
            state: &state,
            arrivals: &arrivals,
            round: t,
        };
        for m in 0..topo.num_gateways() {
            if let Some(p) = Ddsra::solve_gateway(&ctx, m, 0, 3) {
                sum_dyn += p.lambda;
                n_dyn += 1;
            }
            match fixed_partition_lambda(&ctx, m, 0) {
                Some(l) => {
                    sum_fixed += l;
                    n_fixed += 1;
                }
                None => infeasible_fixed += 1,
            }
        }
    }
    let rows = vec![
        vec![
            "dynamic l (DDSRA)".into(),
            format!("{:.1}", sum_dyn / n_dyn.max(1) as f64),
            format!("{:.1}%", 100.0 * n_dyn as f64 / (rounds * topo.num_gateways()) as f64),
        ],
        vec![
            "fixed l = L/2 [19-21]".into(),
            format!("{:.1}", sum_fixed / n_fixed.max(1) as f64),
            format!(
                "{:.1}%",
                100.0 * n_fixed as f64 / (n_fixed + infeasible_fixed).max(1) as f64
            ),
        ],
    ];
    print_table(
        &format!("A1 — dynamic vs fixed DNN partition ({rounds} rounds, per-gateway Λ)"),
        &["policy", "mean Λ (s)", "feasible share"],
        &rows,
    );
}

fn a2_bcd_iterations(rounds: usize) {
    let cfg = SimConfig::default();
    let mut rng = Rng::new(cfg.seed ^ 0xab2);
    let topo = Topology::generate(&cfg, &mut rng);
    let chan = ChannelModel::new(&cfg, &topo, &mut rng);
    let model = models::vgg11_cifar();

    let mut rows = Vec::new();
    for iters in [1usize, 2, 3, 5, 8] {
        let mut rng2 = Rng::new(99);
        let mut sum = 0.0;
        let mut n = 0usize;
        let t0 = std::time::Instant::now();
        for t in 0..rounds.min(100) {
            let state = chan.draw(&mut rng2);
            let arrivals = EnergyArrivals::draw(&cfg, &mut rng2);
            let ctx = RoundCtx {
                cfg: &cfg,
                topo: &topo,
                model: &model,
                chan: &chan,
                state: &state,
                arrivals: &arrivals,
                round: t,
            };
            for m in 0..topo.num_gateways() {
                if let Some(p) = Ddsra::solve_gateway(&ctx, m, 0, iters) {
                    sum += p.lambda;
                    n += 1;
                }
            }
        }
        rows.push(vec![
            iters.to_string(),
            format!("{:.2}", sum / n.max(1) as f64),
            format!("{:.1}", t0.elapsed().as_secs_f64() * 1e6 / (rounds.min(100) * 6) as f64),
        ]);
    }
    print_table(
        "A2 — BCD outer iterations (l/f/P block descent)",
        &["iters", "mean Λ (s)", "µs per solve"],
        &rows,
    );
}

fn a3_non_iid_degree() -> Result<()> {
    let rounds = env_usize("ABL_TRAIN_ROUNDS", 40);
    println!("\n[A3] non-IID degree sweep ({rounds} training rounds each)...");
    let mut rows = Vec::new();
    for chi in [0.0, 0.5, 1.0] {
        let mut cfg = SimConfig::default();
        cfg.non_iid_degree = chi;
        let session = Session::builder(cfg).rounds(rounds).eval_every(rounds).build()?;
        let log = session.run(&SchedulerSpec::ddsra())?;
        rows.push(vec![
            format!("{chi}"),
            format!("{:.2}%", log.final_accuracy().unwrap_or(0.0) * 100.0),
            format!("{:.2}", log.participation[0]),
        ]);
    }
    print_table(
        "A3 — DDSRA under data heterogeneity (chi = share of q_m-class samples)",
        &["chi", "final acc", "gw0 participation"],
        &rows,
    );
    println!("expected: accuracy degrades as chi -> 1; gw0 (full-class menu) participation rises");
    Ok(())
}
