//! L3 hot-path microbenchmarks (custom harness — criterion is unavailable
//! offline). Times the per-round DDSRA solve, its components, and the
//! Hungarian substrate at growing scales. Used by the §Perf pass in
//! EXPERIMENTS.md; thresholds are NOT asserted here (bench, not test).
//!
//! Run: `cargo bench --bench scheduler`

use std::time::Instant;

use iiot_fl::config::SimConfig;
use iiot_fl::dnn::models;
use iiot_fl::energy::EnergyArrivals;
use iiot_fl::net::ChannelModel;
use iiot_fl::opt::hungarian_min;
use iiot_fl::rng::Rng;
use iiot_fl::sched::latency::plan_cost;
use iiot_fl::sched::{baselines, Ddsra, RoundCtx, Scheduler};
use iiot_fl::topo::Topology;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // warmup
    for _ in 0..iters.min(3) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let (val, unit) = if per < 1e-3 {
        (per * 1e6, "µs")
    } else if per < 1.0 {
        (per * 1e3, "ms")
    } else {
        (per, "s ")
    };
    println!("{name:<44} {val:>10.2} {unit}/iter  ({iters} iters)");
}

fn main() {
    println!("== scheduler microbenchmarks ==");
    let cfg = SimConfig::default();
    let mut rng = Rng::new(42);
    let topo = Topology::generate(&cfg, &mut rng);
    let chan = ChannelModel::new(&cfg, &topo, &mut rng);
    let model = models::vgg11_cifar();
    let state = chan.draw(&mut rng);
    let arrivals = EnergyArrivals::draw(&cfg, &mut rng);
    let ctx = RoundCtx {
        cfg: &cfg,
        topo: &topo,
        model: &model,
        chan: &chan,
        state: &state,
        arrivals: &arrivals,
        round: 0,
    };

    bench("channel draw (M x J fading + interference)", 10_000, || {
        let mut r = Rng::new(1);
        std::hint::black_box(chan.draw(&mut r));
    });

    bench("fixed_plan construction (incl. one plan_cost)", 10_000, || {
        let plan = baselines::fixed_plan(&ctx, 0, 0);
        std::hint::black_box(plan);
    });

    let fixed = baselines::fixed_plan(&ctx, 0, 0);
    bench("plan_cost (Eq.1-10 evaluation only)", 10_000, || {
        std::hint::black_box(plan_cost(&ctx, &fixed));
    });

    bench("DDSRA solve_gateway (BCD l/f/P, one pair)", 2_000, || {
        std::hint::black_box(Ddsra::solve_gateway(&ctx, 0, 0, 3));
    });

    let mut ddsra = Ddsra::new(0.01, vec![0.5; cfg.num_gateways]);
    bench("DDSRA full round (M*J solves + assignment)", 500, || {
        std::hint::black_box(ddsra.schedule(&ctx));
    });

    let mut ddsra_par = Ddsra::new(0.01, vec![0.5; cfg.num_gateways]);
    ddsra_par.parallel = true;
    bench("DDSRA full round, parallel rows", 500, || {
        std::hint::black_box(ddsra_par.schedule(&ctx));
    });

    let mut dd = iiot_fl::sched::DelayDriven;
    bench("DelayDriven full round (min-max matching)", 2_000, || {
        std::hint::black_box(dd.schedule(&ctx));
    });

    // Hungarian scaling (the §V-C complexity claim is O(M^3)).
    for n in [8usize, 32, 128, 256] {
        let mut r = Rng::new(n as u64);
        let cost: Vec<Vec<f64>> =
            (0..n).map(|_| (0..n).map(|_| r.f64()).collect()).collect();
        let iters = if n <= 32 { 2000 } else { 50 };
        bench(&format!("hungarian {n}x{n}"), iters, || {
            std::hint::black_box(hungarian_min(&cost));
        });
    }

    // Larger topologies: scalability of a full DDSRA round (§V-C).
    for (m, n) in [(12usize, 24usize), (24, 48), (48, 96)] {
        let mut cfg2 = SimConfig::default();
        cfg2.num_gateways = m;
        cfg2.num_devices = n;
        cfg2.num_channels = 3;
        let mut r = Rng::new(7);
        let topo2 = Topology::generate(&cfg2, &mut r);
        let chan2 = ChannelModel::new(&cfg2, &topo2, &mut r);
        let st2 = chan2.draw(&mut r);
        let ar2 = EnergyArrivals::draw(&cfg2, &mut r);
        let ctx2 = RoundCtx {
            cfg: &cfg2,
            topo: &topo2,
            model: &model,
            chan: &chan2,
            state: &st2,
            arrivals: &ar2,
            round: 0,
        };
        let mut d = Ddsra::new(0.01, vec![0.5; m]);
        d.parallel = true;
        bench(&format!("DDSRA round at M={m} N={n} (parallel)"), 100, || {
            std::hint::black_box(d.schedule(&ctx2));
        });
    }
}
