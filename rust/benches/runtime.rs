//! Native layer-graph engine throughput (custom harness — criterion is
//! unavailable offline): `train_step` / `eval_batch` / `grad` for the mlp
//! and cnn presets on BOTH kernel paths (`scalar` oracle loops vs the
//! `vectorized` blocked-matmul/im2col path), with GFLOP/s derived from the
//! Table II per-layer FLOP counts; a scalar-vs-vectorized speedup section;
//! PLUS fused-vs-split step time across every cut point of each preset —
//! the split-execution exchange overhead (double arena walk + cut-tensor
//! copies) made visible. Thresholds are NOT asserted (bench, not test).
//!
//! Emits machine-readable `BENCH_runtime.json` (tagged with the kernel
//! paths measured and `git describe`) next to the human tables; diff two
//! emissions with `scripts/bench_compare`.
//!
//! Run: `cargo bench --bench runtime`
//! Smoke (CI): `cargo bench --bench runtime -- --smoke` — minimum iters
//! and a truncated cut sweep, so the lane finishes in seconds.

use std::fmt::Write as _;
use std::path::Path;
use std::time::Instant;

use iiot_fl::dnn::models;
use iiot_fl::rng::Rng;
use iiot_fl::runtime::{make_backend_kernel, Backend, KernelPath, PartitionedBackend};

fn batch(rng: &mut Rng, n: usize, dim: usize) -> (Vec<f32>, Vec<i32>) {
    let x: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32 * 0.5).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.below(10) as i32).collect();
    (x, y)
}

/// `git describe --always --dirty`, or "unknown" outside a git checkout —
/// tags the emitted JSON so two bench files can be attributed to commits.
fn git_describe() -> String {
    std::process::Command::new("git")
        .args(["describe", "--always", "--dirty"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

/// Whole-model FLOPs for one batch, from the Table II cost model:
/// (forward, backward). The scheduler plans with exactly these counts, so
/// GFLOP/s here is the achieved fraction of the planned work rate.
fn model_flops(preset: &str, batch: usize) -> (f64, f64) {
    let spec = models::by_name(preset).expect("executable presets are in the model zoo");
    let mut fwd = 0.0;
    let mut bwd = 0.0;
    for l in &spec.layers {
        let c = l.cost(batch as u64, 4);
        fwd += c.fwd_flops;
        bwd += c.bwd_flops;
    }
    (fwd, bwd)
}

/// Times `f`; prints per-iter latency, samples/s, and GFLOP/s; returns the
/// per-iter seconds for the JSON emission.
fn bench<F: FnMut()>(
    name: &str,
    iters: usize,
    samples_per_iter: usize,
    flops_per_iter: f64,
    mut f: F,
) -> f64 {
    for _ in 0..iters.min(2) {
        f(); // warmup
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let (val, unit) = if per < 1e-3 {
        (per * 1e6, "µs")
    } else if per < 1.0 {
        (per * 1e3, "ms")
    } else {
        (per, "s ")
    };
    println!(
        "{name:<44} {val:>10.2} {unit}/iter  {:>12.0} samples/s  {:>8.2} GFLOP/s  ({iters} iters)",
        samples_per_iter as f64 / per,
        flops_per_iter / per / 1e9
    );
    per
}

/// One JSON object literal for a section row (no serde offline).
fn row(fields: &[(&str, String)]) -> String {
    let body: Vec<String> = fields.iter().map(|(k, v)| format!("\"{k}\": {v}")).collect();
    format!("    {{{}}}", body.join(", "))
}

fn jstr(s: &str) -> String {
    format!("\"{s}\"")
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "smoke");
    let kernels = [KernelPath::Scalar, KernelPath::Vectorized];
    let mut throughput_rows: Vec<String> = Vec::new();
    let mut speedup_rows: Vec<String> = Vec::new();
    let mut split_rows: Vec<String> = Vec::new();

    println!("== native layer-graph engine throughput (per kernel path) ==");
    let presets: &[(&str, usize)] = &[("mlp", 100), ("cnn", 5)];
    for &(name, full_iters) in presets {
        let iters = if smoke { 2 } else { full_iters };
        // (kernel, op) -> sec/iter, for the speedup section below.
        let mut secs: Vec<(KernelPath, &str, f64)> = Vec::new();
        for kernel in kernels {
            let be = make_backend_kernel(Path::new("artifacts"), name, kernel)?;
            let meta = be.meta().clone();
            println!(
                "\n-- {name}/{kernel}: {} params, train batch {}, eval batch {} --",
                meta.param_total, meta.train_batch, meta.eval_batch
            );
            let mut rng = Rng::new(0xbe0c);
            let params = be.init_params()?;
            let dim = meta.sample_dim();
            let (xt, yt) = batch(&mut rng, meta.train_batch, dim);
            let (xe, ye) = batch(&mut rng, meta.eval_batch, dim);
            let (fwd_t, bwd_t) = model_flops(name, meta.train_batch);
            let (fwd_e, _) = model_flops(name, meta.eval_batch);

            let ops: [(&str, usize, f64); 3] = [
                ("train_step", meta.train_batch, fwd_t + bwd_t),
                ("grad", meta.train_batch, fwd_t + bwd_t),
                ("eval_batch", meta.eval_batch, fwd_e),
            ];
            for (op, samples, flops) in ops {
                let label = format!("{name}/{kernel} {op}");
                let per = match op {
                    "train_step" => bench(&label, iters, samples, flops, || {
                        be.train_step(&params, &xt, &yt, 0.01).unwrap();
                    }),
                    "grad" => bench(&label, iters, samples, flops, || {
                        be.grad(&params, &xt, &yt).unwrap();
                    }),
                    _ => bench(&label, iters * 2, samples, flops, || {
                        be.eval_batch(&params, &xe, &ye).unwrap();
                    }),
                };
                secs.push((kernel, op, per));
                throughput_rows.push(row(&[
                    ("preset", jstr(name)),
                    ("kernel", jstr(kernel.as_str())),
                    ("op", jstr(op)),
                    ("sec_per_iter", format!("{per:.6}")),
                    ("samples_per_sec", format!("{:.0}", samples as f64 / per)),
                    ("gflops", format!("{:.3}", flops / per / 1e9)),
                ]));
            }
        }
        println!("\n-- {name}: scalar -> vectorized speedup --");
        for op in ["train_step", "grad", "eval_batch"] {
            let pick = |k: KernelPath| {
                secs.iter().find(|(kk, oo, _)| *kk == k && *oo == op).map(|(_, _, s)| *s)
            };
            if let (Some(s), Some(v)) = (pick(KernelPath::Scalar), pick(KernelPath::Vectorized)) {
                println!("{name} {op:<12} {:>6.2}x", s / v);
                speedup_rows.push(row(&[
                    ("preset", jstr(name)),
                    ("op", jstr(op)),
                    ("scalar_sec_per_iter", format!("{s:.6}")),
                    ("vectorized_sec_per_iter", format!("{v:.6}")),
                    ("speedup", format!("{:.3}", s / v)),
                ]));
            }
        }
    }

    println!("\n== fused vs split train_step across cut points (vectorized) ==");
    for &(name, full_iters) in presets {
        let iters = if smoke { 1 } else { full_iters };
        let kernel = KernelPath::Vectorized;
        let be = make_backend_kernel(Path::new("artifacts"), name, kernel)?;
        let meta = be.meta().clone();
        let depth = models::by_name(name).unwrap().depth();
        let mut rng = Rng::new(0x5b117);
        let params = be.init_params()?;
        let (xt, yt) = batch(&mut rng, meta.train_batch, meta.sample_dim());
        let (fwd_t, bwd_t) = model_flops(name, meta.train_batch);
        println!("\n-- {name}: L = {depth} layers --");
        let flops = fwd_t + bwd_t;
        let per = bench(&format!("{name} fused train_step"), iters, meta.train_batch, flops, || {
            be.train_step(&params, &xt, &yt, 0.01).unwrap();
        });
        split_rows.push(row(&[
            ("preset", jstr(name)),
            ("kernel", jstr(kernel.as_str())),
            ("cut", jstr("fused")),
            ("sec_per_iter", format!("{per:.6}")),
        ]));
        // Smoke keeps the endpoints and one interior cut; the full run
        // sweeps every boundary.
        let cuts: Vec<usize> = if smoke {
            let mut c = vec![0, depth / 2, depth];
            c.dedup();
            c
        } else {
            (0..=depth).collect()
        };
        for cut in cuts {
            let split = PartitionedBackend::preset_kernel(name, cut, kernel)?;
            let kib = split.cut_activation_elems() * 4 * meta.train_batch / 1024;
            let per = bench(
                &format!("{name} split train_step l={cut} (act {kib} KiB)"),
                iters,
                meta.train_batch,
                flops,
                || {
                    split.train_step(&params, &xt, &yt, 0.01).unwrap();
                },
            );
            split_rows.push(row(&[
                ("preset", jstr(name)),
                ("kernel", jstr(kernel.as_str())),
                ("cut", format!("{cut}")),
                ("sec_per_iter", format!("{per:.6}")),
            ]));
        }
    }

    let mut json = String::from("{\n  \"bench\": \"runtime\",\n");
    let _ = writeln!(json, "  \"git_describe\": \"{}\",", git_describe());
    let _ = writeln!(json, "  \"smoke\": {smoke},");
    let _ = writeln!(json, "  \"kernel_default\": \"{}\",", KernelPath::default());
    json.push_str("  \"sections\": {\n");
    for (i, (title, rows)) in [
        ("throughput", &throughput_rows),
        ("kernel_speedup", &speedup_rows),
        ("split", &split_rows),
    ]
    .into_iter()
    .enumerate()
    {
        if i > 0 {
            json.push_str(",\n");
        }
        let _ = write!(json, "  \"{title}\": [\n{}\n  ]", rows.join(",\n"));
    }
    json.push_str("\n  }\n}\n");
    std::fs::write("BENCH_runtime.json", &json)?;
    println!("\nwrote BENCH_runtime.json");
    Ok(())
}
