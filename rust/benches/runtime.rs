//! Native layer-graph engine throughput (custom harness — criterion is
//! unavailable offline): `train_step` / `eval_batch` / `grad` for the mlp
//! and cnn presets, seeding the perf trajectory of the rayon fwd/bwd path,
//! PLUS fused-vs-split step time across every cut point of each preset —
//! the split-execution exchange overhead (double arena walk + cut-tensor
//! copies) made visible. Thresholds are NOT asserted (bench, not test).
//!
//! Run: `cargo bench --bench runtime`

use std::time::Instant;

use iiot_fl::dnn::models;
use iiot_fl::rng::Rng;
use iiot_fl::runtime::{Backend, NativeBackend, PartitionedBackend};

fn batch(rng: &mut Rng, n: usize, dim: usize) -> (Vec<f32>, Vec<i32>) {
    let x: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32 * 0.5).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.below(10) as i32).collect();
    (x, y)
}

/// Times `f` and prints per-iter latency plus samples/s throughput.
fn bench<F: FnMut()>(name: &str, iters: usize, samples_per_iter: usize, mut f: F) {
    for _ in 0..iters.min(2) {
        f(); // warmup
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let (val, unit) = if per < 1e-3 {
        (per * 1e6, "µs")
    } else if per < 1.0 {
        (per * 1e3, "ms")
    } else {
        (per, "s ")
    };
    println!(
        "{name:<40} {val:>10.2} {unit}/iter  {:>12.0} samples/s  ({iters} iters)",
        samples_per_iter as f64 / per
    );
}

fn main() {
    println!("== native layer-graph engine throughput ==");
    let presets: Vec<(&str, NativeBackend, usize)> =
        vec![("mlp", NativeBackend::mlp(), 100), ("cnn", NativeBackend::cnn(), 5)];
    for (name, be, iters) in &presets {
        let iters = *iters;
        let meta = be.meta().clone();
        println!(
            "\n-- {name}: {} params, train batch {}, eval batch {} --",
            meta.param_total, meta.train_batch, meta.eval_batch
        );
        let mut rng = Rng::new(0xbe0c);
        let params = be.init_params().unwrap();
        let dim = meta.sample_dim();
        let (xt, yt) = batch(&mut rng, meta.train_batch, dim);
        let (xe, ye) = batch(&mut rng, meta.eval_batch, dim);

        bench(&format!("{name} train_step (fwd+bwd+sgd)"), iters, meta.train_batch, || {
            be.train_step(&params, &xt, &yt, 0.01).unwrap();
        });
        bench(&format!("{name} grad (fwd+bwd)"), iters, meta.train_batch, || {
            be.grad(&params, &xt, &yt).unwrap();
        });
        bench(&format!("{name} eval_batch (fwd)"), iters * 2, meta.eval_batch, || {
            be.eval_batch(&params, &xe, &ye).unwrap();
        });
    }

    println!("\n== fused vs split train_step across cut points ==");
    for (name, be, iters) in &presets {
        let iters = *iters;
        let meta = be.meta().clone();
        let depth = models::by_name(name).unwrap().depth();
        let mut rng = Rng::new(0x5b117);
        let params = be.init_params().unwrap();
        let (xt, yt) = batch(&mut rng, meta.train_batch, meta.sample_dim());
        println!("\n-- {name}: L = {depth} layers --");
        bench(&format!("{name} fused train_step"), iters, meta.train_batch, || {
            be.train_step(&params, &xt, &yt, 0.01).unwrap();
        });
        for cut in 0..=depth {
            let split = PartitionedBackend::preset(name, cut).unwrap();
            let kib = split.cut_activation_elems() * 4 * meta.train_batch / 1024;
            bench(
                &format!("{name} split train_step l={cut} (act {kib} KiB)"),
                iters,
                meta.train_batch,
                || {
                    split.train_step(&params, &xt, &yt, 0.01).unwrap();
                },
            );
        }
    }
}
