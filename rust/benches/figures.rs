//! Figure harness: regenerates the data behind every figure in §VII
//! (Fig. 2–6) plus the Table II view, writing CSVs under results/ and
//! printing the paper-shaped summaries.
//!
//! This is a bench target (custom harness) because it is a long-running
//! measurement program, not a pass/fail test. Scale knobs via env:
//!   FIG_ROUNDS      rounds per training run        (default 80)
//!   FIG_DIV_ROUNDS  rounds for the Fig. 2 divergence runs (default 25)
//!   FIG_DATASETS    comma list: svhn,cifar         (default both)
//!   FIG_ONLY        fig2|fig3|fig4|fig5|fig6|table2|all (default all)
//!
//! Run: `make artifacts && cargo bench --bench figures`

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::Result;
use iiot_fl::config::SimConfig;
use iiot_fl::dnn::models;
use iiot_fl::fl::participation::{gamma_from_phi, gamma_rates};
use iiot_fl::fl::{RunLog, SchedulerSpec, Session};
use iiot_fl::metrics::{print_table, write_run_csv, Csv};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_str(key: &str, default: &str) -> String {
    std::env::var(key).unwrap_or_else(|_| default.to_string())
}

fn out(name: &str) -> PathBuf {
    PathBuf::from("results").join(name)
}

fn main() -> Result<()> {
    let rounds = env_usize("FIG_ROUNDS", 80);
    let div_rounds = env_usize("FIG_DIV_ROUNDS", 25);
    let datasets: Vec<String> =
        env_str("FIG_DATASETS", "svhn,cifar").split(',').map(|s| s.to_string()).collect();
    let only = env_str("FIG_ONLY", "all");
    let want = |f: &str| only == "all" || only == f;

    if want("table2") {
        table2();
    }
    for ds in &datasets {
        if want("fig2") {
            fig2(ds, div_rounds)?;
        }
        if want("fig3") || want("fig4") || want("fig5") || want("fig6") {
            fig3_to_6(ds, rounds)?;
        }
    }
    println!("\nfigure data written under results/");
    Ok(())
}

/// Table II: the layer-level cost model, printed for VGG-11.
fn table2() {
    let model = models::vgg11_cifar();
    let rows: Vec<Vec<String>> = model
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let c = l.cost(100, 4);
            vec![
                (i + 1).to_string(),
                l.short_name().into(),
                format!("{:.3e}", c.fwd_flops),
                format!("{:.3e}", c.bwd_flops),
                format!("{:.1}", c.mem_bytes / 1e6),
                c.params.to_string(),
            ]
        })
        .collect();
    print_table(
        "Table II — VGG-11 layer costs at batch 100 (FLOPs fwd/bwd, memory MB, params)",
        &["l", "kind", "fwd", "bwd", "mem_MB", "params"],
        &rows,
    );
}

/// Fig. 2: derived Γ_m (Eq. 13 from the Theorem-1 bound) vs the
/// experimental participation rate (Eq. 13 applied to the MEASURED
/// divergence ||ŵ_m − v^{K,t}||).
fn fig2(dataset: &str, rounds: usize) -> Result<()> {
    println!("\n[fig2] {dataset}: divergence-tracked run ({rounds} rounds)...");
    let mut cfg = SimConfig::default();
    cfg.dataset = dataset.into();
    let session = Session::builder(cfg).rounds(rounds).eval_every(0).divergence().build()?;
    let exp = session.experiment();

    let stats = exp.estimate_grad_stats(4)?;
    let (phis, derived) =
        gamma_rates(&exp.topo, &stats, exp.cfg.num_channels, exp.cfg.lr, exp.cfg.local_iters);

    // Any scheduler works — divergence is measured for ALL gateways.
    let log = session.run(&SchedulerSpec::RoundRobin)?;
    let measured = log.mean_divergence().expect("divergence mode");
    let experimental = gamma_from_phi(&measured, exp.cfg.num_channels);

    let mut csv = Csv::create(
        &out(&format!("fig2_{dataset}.csv")),
        &["gateway", "phi_derived", "gamma_derived", "divergence_measured", "gamma_experimental"],
    )?;
    let mut rows = Vec::new();
    for m in 0..exp.topo.num_gateways() {
        csv.rowf(&[m as f64, phis[m], derived[m], measured[m], experimental[m]])?;
        rows.push(vec![
            format!("gw{m}"),
            format!("{:.4}", derived[m]),
            format!("{:.4}", experimental[m]),
            exp.topo.gateways[m]
                .members
                .iter()
                .map(|&n| exp.shard_class_count(n).to_string())
                .collect::<Vec<_>>()
                .join("/"),
        ]);
    }
    print_table(
        &format!("Fig.2 ({dataset}) — derived vs experimental participation rate"),
        &["gateway", "derived", "experimental", "classes/device"],
        &rows,
    );
    Ok(())
}

/// Figures 3–6 share training runs: one per scheme per dataset.
/// Fig. 3: participation-rate policy (DDSRA V=0) vs Random vs Round Robin.
/// Fig. 4: test accuracy, DDSRA (V = 0.01 / 1000 / 10000) vs 4 baselines.
/// Fig. 5: cumulative training delay for the same schemes.
/// Fig. 6: per-gateway participation rates for the same schemes.
fn fig3_to_6(dataset: &str, rounds: usize) -> Result<()> {
    println!("\n[fig3-6] {dataset}: {rounds} rounds per scheme...");
    let mut cfg = SimConfig::default();
    cfg.dataset = dataset.into();
    let session = Session::builder(cfg).rounds(rounds).eval_every(5).build()?;
    let exp = session.experiment();

    // The paper's paired comparison as one call: every scheme faces the
    // same environment streams, the DDSRA family shares one Γ estimation.
    let specs = vec![
        SchedulerSpec::Participation,
        SchedulerSpec::ddsra_with_v(0.01),
        SchedulerSpec::ddsra_with_v(1000.0),
        SchedulerSpec::ddsra_with_v(10000.0),
        SchedulerSpec::Random,
        SchedulerSpec::RoundRobin,
        SchedulerSpec::LossDriven,
        SchedulerSpec::DelayDriven,
    ];
    let mut logs: BTreeMap<String, RunLog> = BTreeMap::new();
    for run in session.run_paired(&specs)? {
        println!(
            "  {:<14} final_acc={:>6.2}%  total_delay={:>8.0}s  wall={:.0}s",
            run.label,
            run.log.final_accuracy().unwrap_or(0.0) * 100.0,
            run.log.total_delay(),
            run.wall_secs
        );
        write_run_csv(&run.log, &out(&format!("run_{dataset}_{}.csv", run.label)))?;
        logs.insert(run.label, run.log);
    }

    // Fig. 3 summary: accuracy of the Γ-policy vs fairness baselines.
    let acc_rows = |labels: &[&str]| -> Vec<Vec<String>> {
        labels
            .iter()
            .map(|l| {
                let log = &logs[*l];
                vec![
                    l.to_string(),
                    format!("{:.2}%", log.final_accuracy().unwrap_or(0.0) * 100.0),
                    rounds_to_acc(log, 0.5).map_or("-".into(), |r| r.to_string()),
                ]
            })
            .collect()
    };
    print_table(
        &format!("Fig.3 ({dataset}) — device-specific participation policy vs fairness baselines"),
        &["scheme", "final acc", "rounds to 50%"],
        &acc_rows(&["participation", "random", "round_robin"]),
    );

    let fig4 = [
        "ddsra_v0.01",
        "ddsra_v1000",
        "ddsra_v10000",
        "random",
        "round_robin",
        "loss_driven",
        "delay_driven",
    ];
    print_table(
        &format!("Fig.4 ({dataset}) — test accuracy"),
        &["scheme", "final acc", "rounds to 50%"],
        &acc_rows(&fig4),
    );

    // Fig. 5: cumulative delay.
    let rows5: Vec<Vec<String>> = fig4
        .iter()
        .map(|l| {
            let log = &logs[*l];
            vec![
                l.to_string(),
                format!("{:.0}", log.total_delay()),
                format!("{:.1}", log.total_delay() / rounds as f64),
            ]
        })
        .collect();
    print_table(
        &format!("Fig.5 ({dataset}) — training delay over {rounds} rounds"),
        &["scheme", "total delay (s)", "avg per round"],
        &rows5,
    );

    // Fig. 6: per-gateway participation.
    let mut csv = Csv::create(
        &out(&format!("fig6_{dataset}.csv")),
        &["scheme", "gateway", "selected_rate", "effective_rate"],
    )?;
    let mut rows6 = Vec::new();
    for l in fig4.iter().chain(["participation"].iter()) {
        let log = &logs[*l];
        for m in 0..exp.topo.num_gateways() {
            csv.row(&[
                l.to_string(),
                m.to_string(),
                format!("{:.4}", log.participation[m]),
                format!("{:.4}", log.effective_participation[m]),
            ])?;
        }
        rows6.push(
            std::iter::once(l.to_string())
                .chain(log.participation.iter().map(|p| format!("{p:.2}")))
                .collect::<Vec<_>>(),
        );
    }
    print_table(
        &format!("Fig.6 ({dataset}) — participation rate per gateway"),
        &["scheme", "gw0", "gw1", "gw2", "gw3", "gw4", "gw5"],
        &rows6,
    );
    Ok(())
}

fn rounds_to_acc(log: &RunLog, target: f64) -> Option<usize> {
    log.records
        .iter()
        .find(|r| r.test_acc.is_some_and(|a| a >= target))
        .map(|r| r.round)
}
