//! Deterministic RNG substrate (no `rand` crate available offline).
//!
//! SplitMix64 for seeding, xoshiro256** as the main generator, plus the
//! distributions the simulator needs: uniform, exponential (small-scale
//! Rayleigh-power fading is Exp(1) in the paper), and normal (Box–Muller,
//! for the Gaussian co-channel interference and the synthetic datasets).
//!
//! Everything in the simulation is seeded, so every figure is exactly
//! reproducible from the config seed.

/// SplitMix64: used to expand one u64 seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality, deterministic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    /// Derive an independent stream (for per-subsystem RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// A generator determined ONLY by its key path `(seed, path[0],
    /// path[1], ...)` — unlike [`Rng::fork`], no shared generator state is
    /// consumed, so any worker can reconstruct any stream independently,
    /// in any order, and as often as it likes (replaying a stream is
    /// free). The round engine keys its streams as `[DOMAIN, round,
    /// device]` (see `fl::round`), which is what makes parallel local
    /// training order-independent and byte-identical across thread counts.
    pub fn stream(seed: u64, path: &[u64]) -> Rng {
        let mut s = seed;
        for &k in path {
            // Absorb each key through a full SplitMix64 round so adjacent
            // keys (round t vs t+1, device n vs n+1) land in unrelated
            // states.
            s = SplitMix64(s ^ k.wrapping_mul(0x9E3779B97F4A7C15)).next_u64();
        }
        Rng::new(s)
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes.
        (self.next_u64() % n as u64) as usize
    }

    /// Exponential with unit mean (small-scale fading power gain).
    pub fn exp1(&mut self) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        let (u1, u2) = (1.0 - self.f64(), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample k distinct indices from 0..n (k <= n).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..20000 {
            let x = r.uniform(2.0, 4.0);
            assert!((2.0..4.0).contains(&x));
            sum += x;
        }
        assert!((sum / 20000.0 - 3.0).abs() < 0.05);
    }

    #[test]
    fn exp1_mean_is_one() {
        let mut r = Rng::new(8);
        let m: f64 = (0..40000).map(|_| r.exp1()).sum::<f64>() / 40000.0;
        assert!((m - 1.0).abs() < 0.05, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let xs: Vec<f64> = (0..40000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(10);
        for _ in 0..50 {
            let mut v = r.choose_k(10, 4);
            v.sort_unstable();
            v.dedup();
            assert_eq!(v.len(), 4);
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Rng::new(1);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn stream_is_stateless_and_replayable() {
        // Same key path -> same stream, no matter how often or when it is
        // derived (nothing is consumed from a shared generator).
        let mut a = Rng::stream(2022, &[7, 3, 11]);
        let _burn = Rng::stream(2022, &[1, 1, 1]).next_u64();
        let mut b = Rng::stream(2022, &[7, 3, 11]);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn stream_keys_are_order_and_value_sensitive() {
        let draw = |path: &[u64]| Rng::stream(42, path).next_u64();
        // Adjacent (round, device) keys diverge.
        assert_ne!(draw(&[1, 0, 0]), draw(&[1, 0, 1]));
        assert_ne!(draw(&[1, 0, 0]), draw(&[1, 1, 0]));
        // The path is ordered: (a, b) != (b, a).
        assert_ne!(draw(&[2, 5]), draw(&[5, 2]));
        // Distinct seeds give distinct streams for the same path.
        assert_ne!(Rng::stream(1, &[3, 4]).next_u64(), Rng::stream(2, &[3, 4]).next_u64());
        // The empty path is the plain seeded generator.
        assert_eq!(Rng::stream(9, &[]).next_u64(), Rng::new(9).next_u64());
    }
}
