//! Block-fading channel model and the Eq. 6–8 delay/energy formulas.
//!
//! Channel power gain: h = h0 * rho * (d0 / d_m)^nu with rho ~ Exp(1)
//! redrawn each communication round (IID block fading: static within a
//! round, independent across rounds). Co-channel interference is the
//! squared amplitude of a zero-mean Gaussian whose per-channel std-dev is
//! drawn once per experiment ("different variances" in §VII-A).

use crate::config::SimConfig;
use crate::rng::Rng;
use crate::topo::Topology;

/// Static channel model (distances + constants), draws per-round states.
#[derive(Clone, Debug)]
pub struct ChannelModel {
    /// Large-scale gain per gateway: h0 * (d0/d_m)^nu.
    large_scale: Vec<f64>,
    /// Per-channel interference amplitude std-dev (uplink, downlink).
    intf_amp_up: Vec<f64>,
    intf_amp_down: Vec<f64>,
    pub bw_up: f64,
    pub bw_down: f64,
    pub noise_psd: f64,
    pub bs_power: f64,
}

/// One round's realisation: gains and interference for every (m, j).
#[derive(Clone, Debug)]
pub struct ChannelState {
    /// `up_gain[m][j]` = h^u_{m,j}(t).
    pub up_gain: Vec<Vec<f64>>,
    pub down_gain: Vec<Vec<f64>>,
    /// Interference POWER i^u_{m,j}(t), i^d_{m,j}(t) (W).
    pub up_intf: Vec<Vec<f64>>,
    pub down_intf: Vec<Vec<f64>>,
}

impl ChannelModel {
    pub fn new(cfg: &SimConfig, topo: &Topology, rng: &mut Rng) -> Self {
        let large_scale = topo
            .gateways
            .iter()
            .map(|g| cfg.h0_lin() * (cfg.ref_dist / g.distance).powf(cfg.path_loss_exp))
            .collect();
        let draw_amp = |rng: &mut Rng| {
            (0..cfg.num_channels)
                .map(|_| rng.uniform(cfg.interference_amp_min, cfg.interference_amp_max))
                .collect::<Vec<_>>()
        };
        ChannelModel {
            large_scale,
            intf_amp_up: draw_amp(rng),
            intf_amp_down: draw_amp(rng),
            bw_up: cfg.bw_up,
            bw_down: cfg.bw_down,
            noise_psd: cfg.noise_psd,
            bs_power: cfg.bs_power,
        }
    }

    /// Draw the block-fading state for one communication round.
    pub fn draw(&self, rng: &mut Rng) -> ChannelState {
        let m = self.large_scale.len();
        let j = self.intf_amp_up.len();
        let mut mk = |amps: &[f64], fade: bool| -> Vec<Vec<f64>> {
            (0..m)
                .map(|mi| {
                    (0..j)
                        .map(|ji| {
                            if fade {
                                self.large_scale[mi] * rng.exp1()
                            } else {
                                let a = amps[ji] * rng.normal();
                                a * a
                            }
                        })
                        .collect()
                })
                .collect()
        };
        ChannelState {
            up_gain: mk(&[], true),
            down_gain: mk(&[], true),
            up_intf: mk(&self.intf_amp_up, false),
            down_intf: mk(&self.intf_amp_down, false),
        }
    }

    /// Uplink rate (bits/s) for gateway m on channel j at transmit power p:
    /// B^u log2(1 + p h / (B^u N0 + i)).
    pub fn rate_up(&self, st: &ChannelState, m: usize, j: usize, p: f64) -> f64 {
        let snr = p * st.up_gain[m][j] / (self.bw_up * self.noise_psd + st.up_intf[m][j]);
        self.bw_up * (1.0 + snr).log2()
    }

    /// Downlink rate (bits/s) — the BS transmits at P^B (Eq. 6).
    pub fn rate_down(&self, st: &ChannelState, m: usize, j: usize) -> f64 {
        let snr = self.bs_power * st.down_gain[m][j]
            / (self.bw_down * self.noise_psd + st.down_intf[m][j]);
        self.bw_down * (1.0 + snr).log2()
    }

    /// tau^down_m (Eq. 6) for model size gamma_bits.
    pub fn tau_down(&self, st: &ChannelState, m: usize, j: usize, gamma_bits: f64) -> f64 {
        gamma_bits / self.rate_down(st, m, j)
    }

    /// tau^up_m (Eq. 7).
    pub fn tau_up(
        &self,
        st: &ChannelState,
        m: usize,
        j: usize,
        p: f64,
        gamma_bits: f64,
    ) -> f64 {
        gamma_bits / self.rate_up(st, m, j, p)
    }

    /// e^up_m (Eq. 8): transmit power x transmission time.
    pub fn energy_up(
        &self,
        st: &ChannelState,
        m: usize,
        j: usize,
        p: f64,
        gamma_bits: f64,
    ) -> f64 {
        p * self.tau_up(st, m, j, p, gamma_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (ChannelModel, ChannelState) {
        let cfg = SimConfig::default();
        let mut rng = Rng::new(3);
        let topo = Topology::generate(&cfg, &mut rng);
        let model = ChannelModel::new(&cfg, &topo, &mut rng);
        let st = model.draw(&mut rng);
        (model, st)
    }

    #[test]
    fn rates_positive_and_increasing_in_power() {
        let (m, st) = setup();
        for gw in 0..6 {
            for ch in 0..3 {
                let r1 = m.rate_up(&st, gw, ch, 0.05);
                let r2 = m.rate_up(&st, gw, ch, 0.2);
                assert!(r1 > 0.0 && r2 > r1, "{r1} {r2}");
            }
        }
    }

    #[test]
    fn uplink_rate_plausible_magnitude() {
        // §VII-A numbers should give ~Mb/s uplink rates at P^max.
        let (m, st) = setup();
        let r = m.rate_up(&st, 0, 0, 0.2);
        assert!(r > 1e5 && r < 1e9, "rate {r}");
    }

    #[test]
    fn tau_and_energy_consistent() {
        let (m, st) = setup();
        let gamma = 1e8;
        let p = 0.1;
        let tau = m.tau_up(&st, 2, 1, p, gamma);
        let e = m.energy_up(&st, 2, 1, p, gamma);
        assert!((e - p * tau).abs() < 1e-12 * e.max(1.0));
    }

    #[test]
    fn tau_down_faster_than_up() {
        // 20 MHz downlink at 1 W vs 1 MHz uplink at 200 mW.
        let (m, st) = setup();
        let gamma = 1e8;
        let mut down = 0.0;
        let mut up = 0.0;
        for gw in 0..6 {
            down += m.tau_down(&st, gw, 0, gamma);
            up += m.tau_up(&st, gw, 0, 0.2, gamma);
        }
        assert!(down < up);
    }

    #[test]
    fn block_fading_varies_across_rounds() {
        let (m, _) = setup();
        let mut rng = Rng::new(9);
        let a = m.draw(&mut rng);
        let b = m.draw(&mut rng);
        assert_ne!(a.up_gain[0][0], b.up_gain[0][0]);
    }

    #[test]
    fn interference_nonnegative() {
        let (m, st) = setup();
        let _ = m;
        for row in &st.up_intf {
            for &v in row {
                assert!(v >= 0.0);
            }
        }
    }
}
