//! Transport layer over the wire protocol: dialing + handshake,
//! connection pooling, fault classification, and the client half of the
//! gateway-hosted FedAvg fold.
//!
//! # Fault policy (the `FaultPlan` dropout mapping)
//!
//! Every I/O-class failure — connection refused, dial/read/write
//! timeout, a stream severed mid-frame — carries a [`PeerLost`] marker
//! in its error chain. The round engine tests for it with
//! [`is_peer_lost`] and maps an affected DEVICE onto the exact dropout
//! semantics of [`crate::fl::fault`]: the device contributes nothing to
//! the round's fold, the fault is recorded on the round's
//! `RoundFaults`, and the run continues. Anything else — version or
//! preset skew at the handshake, a malformed frame, an `Err` frame from
//! the gateway — is a plain error and aborts the run: silent numeric
//! divergence is worse than a crash, and a refused handshake would
//! otherwise masquerade as 100% dropout.
//!
//! Connections are fail-stop: [`ConnPool::with_conn`] returns a healthy
//! connection to the idle pool and DROPS one whose operation failed, so
//! the next use redials lazily. A gateway that comes back between
//! rounds is picked up automatically; one that stays dead keeps
//! resolving to dropout.

use std::fmt;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::{KernelPath, Params};

use super::wire::{self, FrameError, Msg, MAGIC, VERSION};

/// Marker error: the remote peer is gone (refused, timed out, or went
/// away mid-conversation). See the module docs for how the round engine
/// maps this onto the `FaultPlan` dropout path.
#[derive(Debug)]
pub struct PeerLost(pub String);

impl fmt::Display for PeerLost {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "peer lost: {}", self.0)
    }
}

impl std::error::Error for PeerLost {}

/// Does `err`'s chain contain a [`PeerLost`]? (`context(..)` wrapping
/// keeps the marker reachable through `err.chain()`.)
pub fn is_peer_lost(err: &anyhow::Error) -> bool {
    err.chain().any(|c| c.downcast_ref::<PeerLost>().is_some())
}

fn lost(what: String) -> anyhow::Error {
    anyhow::Error::new(PeerLost(what))
}

/// One handshaken connection to a gateway service.
pub struct Conn {
    stream: TcpStream,
}

impl Conn {
    /// Connect to `addr` and complete the version/preset/kernel
    /// handshake. Failures meaning "nobody is (responsively) there"
    /// carry [`PeerLost`]; a REACHABLE gateway refusing the handshake
    /// (protocol or model skew) is a plain error — skew must abort the
    /// run, not degrade into dropout.
    pub fn dial(addr: &str, timeout_ms: u64, preset: &str, kernel: KernelPath) -> Result<Conn> {
        let timeout = Duration::from_millis(timeout_ms.max(1));
        let sa = addr
            .to_socket_addrs()
            .with_context(|| format!("cannot resolve gateway address {addr:?}"))?
            .next()
            .ok_or_else(|| anyhow!("gateway address {addr:?} resolves to nothing"))?;
        let stream = TcpStream::connect_timeout(&sa, timeout)
            .map_err(|e| lost(format!("connect {addr}: {e}")))?;
        // Frames are whole request/response units; never Nagle-delay them.
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(timeout)).map_err(|e| lost(format!("{addr}: {e}")))?;
        stream.set_write_timeout(Some(timeout)).map_err(|e| lost(format!("{addr}: {e}")))?;
        let mut conn = Conn { stream };
        conn.send(&Msg::Hello {
            magic: MAGIC,
            version: VERSION,
            preset: preset.to_string(),
            kernel: kernel.as_str().to_string(),
        })?;
        match conn.recv().with_context(|| format!("gateway {addr} handshake"))? {
            Msg::HelloOk => Ok(conn),
            other => bail!("gateway {addr} handshake: unexpected {}", other.name()),
        }
    }

    /// Send one message. I/O failures carry [`PeerLost`].
    pub fn send(&mut self, msg: &Msg) -> Result<()> {
        wire::write_msg(&mut (&self.stream), msg)
            .map_err(|e| lost(format!("sending {}: {e}", msg.name())))
    }

    /// Read one message. I/O failures carry [`PeerLost`]; an [`Msg::Err`]
    /// frame or malformed bytes are plain (fatal) errors.
    pub fn recv(&mut self) -> Result<Msg> {
        match wire::read_msg(&mut (&self.stream)) {
            Ok(Msg::Err { reason }) => bail!("gateway error: {reason}"),
            Ok(msg) => Ok(msg),
            Err(FrameError::Io(e)) => Err(lost(format!("receiving: {e}"))),
            Err(FrameError::Protocol(p)) => bail!("wire protocol violation: {p}"),
        }
    }

    /// One request/response exchange.
    pub fn request(&mut self, msg: &Msg) -> Result<Msg> {
        self.send(msg)?;
        self.recv()
    }
}

/// A pool of handshaken connections to ONE gateway address. The round
/// engine fans train steps over rayon, so several connections may be
/// checked out at once; each worker's exchange is a self-contained
/// request/response pair, so any idle connection serves any step.
pub struct ConnPool {
    addr: String,
    timeout_ms: u64,
    preset: String,
    kernel: KernelPath,
    idle: Mutex<Vec<Conn>>,
}

impl ConnPool {
    pub fn new(addr: &str, timeout_ms: u64, preset: &str, kernel: KernelPath) -> Self {
        ConnPool {
            addr: addr.to_string(),
            timeout_ms,
            preset: preset.to_string(),
            kernel,
            idle: Mutex::new(Vec::new()),
        }
    }

    /// The gateway address this pool dials.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    fn checkout(&self) -> Result<Conn> {
        if let Some(c) = self.idle.lock().expect("pool lock").pop() {
            return Ok(c);
        }
        Conn::dial(&self.addr, self.timeout_ms, &self.preset, self.kernel)
    }

    /// Run `f` with a pooled connection (dialing lazily when none is
    /// idle). The connection returns to the pool on success and is
    /// dropped on failure — fail-stop, lazy reconnect on next use.
    pub fn with_conn<T>(&self, f: impl FnOnce(&mut Conn) -> Result<T>) -> Result<T> {
        let mut conn = self.checkout()?;
        let out = f(&mut conn);
        if out.is_ok() {
            self.idle.lock().expect("pool lock").push(conn);
        }
        out
    }
}

/// Client half of the gateway-hosted FedAvg fold (§III-A step 3 over
/// the wire). `FoldBegin` is sent lazily on the first [`FoldSession::add`];
/// each add is a synchronous acknowledged `FoldAdd`, so the caller's
/// add ORDER is the gateway's fold order — the gateway folds with the
/// same order-sensitive f64 `WeightedAccum` the in-process flat path
/// uses, which is what keeps tcp and inproc rounds byte-identical.
///
/// A session with zero adds never touches the network and finishes
/// `None`, exactly like the empty in-process fold — so a gateway whose
/// every device already dropped still lets the round complete with the
/// global model unchanged.
pub struct FoldSession {
    pool: Arc<ConnPool>,
    conn: Option<Conn>,
    count: usize,
}

impl FoldSession {
    pub fn new(pool: Arc<ConnPool>) -> Self {
        FoldSession { pool, conn: None, count: 0 }
    }

    /// Updates folded in so far.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Fold one weighted parameter set in (order-sensitive).
    pub fn add(&mut self, p: &Params, w: f64) -> Result<()> {
        if self.conn.is_none() {
            let mut c = self.pool.checkout().context("opening the FedAvg fold")?;
            match c.request(&Msg::FoldBegin)? {
                Msg::FoldOk => {}
                other => bail!("FoldBegin: unexpected {}", other.name()),
            }
            self.conn = Some(c);
        }
        let c = self.conn.as_mut().expect("fold connection just opened");
        match c.request(&Msg::FoldAdd { weight: w, params: p.clone() })? {
            Msg::FoldOk => {
                self.count += 1;
                Ok(())
            }
            other => bail!("FoldAdd: unexpected {}", other.name()),
        }
    }

    /// Close the fold and fetch the aggregate (`None` when nothing was
    /// added). Returns the connection to the pool on success.
    pub fn finish(mut self) -> Result<Option<Params>> {
        let Some(mut c) = self.conn.take() else { return Ok(None) };
        match c.request(&Msg::FoldFinish)? {
            Msg::FoldResult { params } => {
                self.pool.idle.lock().expect("pool lock").push(c);
                Ok(params)
            }
            other => bail!("FoldFinish: unexpected {}", other.name()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peer_lost_survives_context_wrapping() {
        let e = lost("connect 127.0.0.1:1: refused".into()).context("during local step");
        assert!(is_peer_lost(&e));
        let plain = anyhow!("version skew").context("during handshake");
        assert!(!is_peer_lost(&plain));
    }

    #[test]
    fn dialing_a_dead_port_is_peer_lost_not_fatal() {
        // Bind an ephemeral port, then drop the listener so the port is
        // known-dead; the dial must classify as PeerLost (the dropout
        // path), not as a hard protocol error.
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let err = Conn::dial(&dead, 300, "mlp", KernelPath::Vectorized).unwrap_err();
        assert!(is_peer_lost(&err), "got: {err:#}");
    }
}
