//! Networking: the modelled radio layer and the real wire layer.
//!
//! * [`channel`] — wireless communication model (§III-C): IID
//!   block-fading channels between the BS and the gateways, OFDM with J
//!   orthogonal channels, co-channel interference from neighbouring
//!   deployments.
//! * [`wire`] — versioned, length-prefixed binary message protocol for
//!   split execution (smashed activations ⇡, cut gradients ⇣, FedAvg
//!   folds, round control) with an explicit little-endian codec.
//! * [`transport`] — dialing/handshake, connection pooling, and the
//!   `PeerLost` fault classification that maps wire failures onto
//!   `FaultPlan` dropout semantics.
//! * [`serve`] — the threaded TCP gateway service hosting the gateway
//!   half of the split plus the FedAvg fold.

pub mod channel;
pub mod serve;
pub mod transport;
pub mod wire;

pub use channel::{ChannelModel, ChannelState};
