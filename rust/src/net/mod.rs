//! Wireless communication model (§III-C): IID block-fading channels
//! between the BS and the gateways, OFDM with J orthogonal channels,
//! co-channel interference from neighbouring deployments.

pub mod channel;

pub use channel::{ChannelModel, ChannelState};
