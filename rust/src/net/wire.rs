//! The wire protocol of the distributed split runtime: versioned,
//! length-prefixed binary frames with an explicit little-endian codec.
//!
//! Everything the device ↔ gateway boundary of §II-B exchanges travels
//! in one frame grammar:
//!
//! ```text
//!   frame   := len:u32  payload          (len = payload bytes, LE)
//!   payload := tag:u8   fields…          (tag = message type)
//! ```
//!
//! Numbers are little-endian. A tensor is `count:u32` followed by raw
//! LE f32 words; a parameter set is `tensors:u32` followed by that many
//! tensors in ABI order. The codec is spelled out by hand — no serde,
//! no derive — because the byte layout IS the compatibility contract:
//! LE f32/f64 round-trips are exact, which is one link in the chain
//! that pins a loopback tcp run byte-identical to the in-process oracle
//! (`rust/tests/wire.rs`).
//!
//! A session opens with [`Msg::Hello`] carrying magic, protocol
//! version, preset and kernel path; the gateway answers [`Msg::HelloOk`]
//! or an [`Msg::Err`] naming the mismatch. After the handshake the
//! client drives request/response pairs: [`Msg::SplitReq`] (smashed
//! activations ⇡) answered by [`Msg::SplitResp`] (loss, top gradients
//! and per-sample cut gradients ⇣), and the FedAvg fold sequence
//! `FoldBegin`, `FoldAdd`*, `FoldFinish` answered by `FoldOk`s and a
//! final `FoldResult`.
//!
//! Decoding is fail-closed: every declared length is validated against
//! the bytes actually present BEFORE anything is allocated, frames are
//! capped at [`MAX_FRAME`], and trailing payload bytes are an error.
//! Classifying failures (which ones mean "peer lost" — the dropout
//! path — vs a protocol bug that must abort) is the transport layer's
//! job ([`crate::net::transport`]); this module only distinguishes
//! [`FrameError::Io`] from [`FrameError::Protocol`].

use std::fmt;
use std::io::{self, Read, Write};

use anyhow::{bail, Result};

use crate::runtime::Params;

/// Handshake magic: the bytes `IIFL` read as a little-endian u32.
pub const MAGIC: u32 = 0x4C46_4949;

/// Protocol version this build speaks. Bump on ANY frame-layout change;
/// the gateway refuses mismatched [`Msg::Hello`]s rather than guessing.
pub const VERSION: u16 = 1;

/// Hard cap on one frame's payload (bytes). Large enough for a full
/// cnn parameter set or a train batch of smashed activations with an
/// order of magnitude to spare; small enough that a corrupt length
/// prefix cannot balloon into an absurd allocation.
pub const MAX_FRAME: usize = 1 << 28;

const TAG_HELLO: u8 = 1;
const TAG_HELLO_OK: u8 = 2;
const TAG_ERR: u8 = 3;
const TAG_SPLIT_REQ: u8 = 4;
const TAG_SPLIT_RESP: u8 = 5;
const TAG_FOLD_BEGIN: u8 = 6;
const TAG_FOLD_ADD: u8 = 7;
const TAG_FOLD_OK: u8 = 8;
const TAG_FOLD_FINISH: u8 = 9;
const TAG_FOLD_RESULT: u8 = 10;
const TAG_SHUTDOWN: u8 = 11;

/// One wire message. See the module docs for the session grammar.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Client → gateway session opener: magic + version + the model
    /// preset and kernel path the client executes. The gateway half and
    /// the device half MUST agree on all four for split execution to be
    /// byte-meaningful, so skew is refused at the door.
    Hello { magic: u32, version: u16, preset: String, kernel: String },
    /// Gateway → client: handshake accepted.
    HelloOk,
    /// Gateway → client: the request (or handshake) was refused. Always
    /// a hard error on the client — genuine peer loss never produces a
    /// well-formed frame.
    Err { reason: String },
    /// Device → gateway: one batch of smashed activations at `cut`,
    /// with labels and the gateway half's parameter tensors. When
    /// `want_grad`, the gateway also runs its half backward.
    SplitReq { cut: u32, want_grad: bool, labels: Vec<i32>, top_params: Params, acts: Vec<f32> },
    /// Gateway → device: summed batch loss + correct count (the same
    /// sequential fold as the in-process executor), the per-sample cut
    /// gradients (`batch · cut width`; empty when not applicable) and
    /// the gateway half's flat gradient (empty unless `want_grad`).
    SplitResp { loss_sum: f64, correct: u64, dcut: Vec<f32>, g_top: Vec<f32> },
    /// Device → gateway: open a FedAvg fold on this connection.
    FoldBegin,
    /// Device → gateway: fold one weighted parameter set in. Adds are
    /// acknowledged one by one so the caller controls the exact fold
    /// order — `WeightedAccum` is order-sensitive f64 accumulation.
    FoldAdd { weight: f64, params: Params },
    /// Gateway → device: fold step accepted.
    FoldOk,
    /// Device → gateway: close the fold and return the aggregate.
    FoldFinish,
    /// Gateway → device: the folded parameters (`None` when nothing was
    /// added — the round then leaves the global model unchanged).
    FoldResult { params: Option<Params> },
    /// Device → gateway: clean goodbye; the gateway closes this
    /// connection and keeps serving others.
    Shutdown,
}

impl Msg {
    /// Message name for error messages and logs.
    pub fn name(&self) -> &'static str {
        match self {
            Msg::Hello { .. } => "Hello",
            Msg::HelloOk => "HelloOk",
            Msg::Err { .. } => "Err",
            Msg::SplitReq { .. } => "SplitReq",
            Msg::SplitResp { .. } => "SplitResp",
            Msg::FoldBegin => "FoldBegin",
            Msg::FoldAdd { .. } => "FoldAdd",
            Msg::FoldOk => "FoldOk",
            Msg::FoldFinish => "FoldFinish",
            Msg::FoldResult { .. } => "FoldResult",
            Msg::Shutdown => "Shutdown",
        }
    }

    /// Serialize into one frame payload (tag byte + fields).
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::new();
        match self {
            Msg::Hello { magic, version, preset, kernel } => {
                b.push(TAG_HELLO);
                put_u32(&mut b, *magic);
                put_u16(&mut b, *version);
                put_str(&mut b, preset);
                put_str(&mut b, kernel);
            }
            Msg::HelloOk => b.push(TAG_HELLO_OK),
            Msg::Err { reason } => {
                b.push(TAG_ERR);
                put_str(&mut b, reason);
            }
            Msg::SplitReq { cut, want_grad, labels, top_params, acts } => {
                b.push(TAG_SPLIT_REQ);
                put_u32(&mut b, *cut);
                b.push(*want_grad as u8);
                put_i32s(&mut b, labels);
                put_params(&mut b, top_params);
                put_f32s(&mut b, acts);
            }
            Msg::SplitResp { loss_sum, correct, dcut, g_top } => {
                b.push(TAG_SPLIT_RESP);
                put_f64(&mut b, *loss_sum);
                put_u64(&mut b, *correct);
                put_f32s(&mut b, dcut);
                put_f32s(&mut b, g_top);
            }
            Msg::FoldBegin => b.push(TAG_FOLD_BEGIN),
            Msg::FoldAdd { weight, params } => {
                b.push(TAG_FOLD_ADD);
                put_f64(&mut b, *weight);
                put_params(&mut b, params);
            }
            Msg::FoldOk => b.push(TAG_FOLD_OK),
            Msg::FoldFinish => b.push(TAG_FOLD_FINISH),
            Msg::FoldResult { params } => {
                b.push(TAG_FOLD_RESULT);
                match params {
                    Some(p) => {
                        b.push(1);
                        put_params(&mut b, p);
                    }
                    None => b.push(0),
                }
            }
            Msg::Shutdown => b.push(TAG_SHUTDOWN),
        }
        b
    }

    /// Parse one frame payload. Rejects unknown tags, truncated fields,
    /// lengths that overrun the payload, and trailing bytes.
    pub fn decode(payload: &[u8]) -> Result<Msg> {
        let mut r = Reader::new(payload);
        let tag = r.u8()?;
        let msg = match tag {
            TAG_HELLO => Msg::Hello {
                magic: r.u32()?,
                version: r.u16()?,
                preset: r.string()?,
                kernel: r.string()?,
            },
            TAG_HELLO_OK => Msg::HelloOk,
            TAG_ERR => Msg::Err { reason: r.string()? },
            TAG_SPLIT_REQ => Msg::SplitReq {
                cut: r.u32()?,
                want_grad: r.flag()?,
                labels: r.i32s()?,
                top_params: r.params()?,
                acts: r.f32s()?,
            },
            TAG_SPLIT_RESP => Msg::SplitResp {
                loss_sum: r.f64()?,
                correct: r.u64()?,
                dcut: r.f32s()?,
                g_top: r.f32s()?,
            },
            TAG_FOLD_BEGIN => Msg::FoldBegin,
            TAG_FOLD_ADD => Msg::FoldAdd { weight: r.f64()?, params: r.params()? },
            TAG_FOLD_OK => Msg::FoldOk,
            TAG_FOLD_FINISH => Msg::FoldFinish,
            TAG_FOLD_RESULT => {
                let params = if r.flag()? { Some(r.params()?) } else { None };
                Msg::FoldResult { params }
            }
            TAG_SHUTDOWN => Msg::Shutdown,
            other => bail!("unknown message tag {other}"),
        };
        r.finish()?;
        Ok(msg)
    }
}

// ------------------------------------------------------------- LE writers

fn put_u16(b: &mut Vec<u8>, v: u16) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(b: &mut Vec<u8>, v: f64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_str(b: &mut Vec<u8>, s: &str) {
    put_u32(b, s.len() as u32);
    b.extend_from_slice(s.as_bytes());
}

fn put_f32s(b: &mut Vec<u8>, xs: &[f32]) {
    put_u32(b, xs.len() as u32);
    b.reserve(xs.len() * 4);
    for &v in xs {
        b.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_i32s(b: &mut Vec<u8>, xs: &[i32]) {
    put_u32(b, xs.len() as u32);
    b.reserve(xs.len() * 4);
    for &v in xs {
        b.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_params(b: &mut Vec<u8>, p: &Params) {
    put_u32(b, p.len() as u32);
    for t in p {
        put_f32s(b, t);
    }
}

// ------------------------------------------------------------- LE reader

/// Bounds-checked payload cursor: every read validates against the bytes
/// remaining, and declared element counts are checked (with overflow-safe
/// multiplication) BEFORE any buffer is allocated.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!("truncated payload: need {n} bytes, {} left", self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn flag(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => bail!("bad flag byte {other} (expected 0 or 1)"),
        }
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().expect("2-byte slice")))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4-byte slice")))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8-byte slice")))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().expect("8-byte slice")))
    }

    /// Read a `u32` element count and validate that `count·elem_bytes`
    /// fits in the remaining payload.
    fn len32(&mut self, elem_bytes: usize, what: &str) -> Result<usize> {
        let n = self.u32()? as usize;
        match n.checked_mul(elem_bytes) {
            Some(bytes) if bytes <= self.remaining() => Ok(n),
            _ => bail!(
                "{what} declares {n} elements ({elem_bytes} B each) but only {} payload bytes remain",
                self.remaining()
            ),
        }
    }

    fn string(&mut self) -> Result<String> {
        let n = self.len32(1, "string")?;
        Ok(std::str::from_utf8(self.take(n)?)?.to_string())
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len32(4, "f32 tensor")?;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().expect("4-byte chunk"))).collect())
    }

    fn i32s(&mut self) -> Result<Vec<i32>> {
        let n = self.len32(4, "i32 tensor")?;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().expect("4-byte chunk"))).collect())
    }

    fn params(&mut self) -> Result<Params> {
        // Each tensor costs at least its own 4-byte count header, so the
        // tensor count itself is bounded by the remaining bytes.
        let n = self.len32(4, "param set")?;
        (0..n).map(|_| self.f32s()).collect()
    }

    fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("{} trailing bytes after message", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

// -------------------------------------------------------------- frame I/O

/// Why reading a frame failed: an I/O-class failure (the peer is gone —
/// the transport layer maps this onto the dropout path) vs a protocol
/// violation (malformed bytes or an oversized length — a bug or version
/// skew, which must surface as a hard error instead).
#[derive(Debug)]
pub enum FrameError {
    Io(io::Error),
    Protocol(String),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "i/o: {e}"),
            FrameError::Protocol(p) => write!(f, "protocol: {p}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<io::Error> for FrameError {
    fn from(e: io::Error) -> Self {
        FrameError::Io(e)
    }
}

/// Write one message as a length-prefixed frame and flush it.
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg) -> io::Result<()> {
    let payload = msg.encode();
    debug_assert!(payload.len() <= MAX_FRAME, "oversized outbound frame");
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&payload)?;
    w.flush()
}

/// Read one length-prefixed frame and decode its message. A zero-length
/// or over-[`MAX_FRAME`] length prefix is rejected before any payload
/// allocation; a stream that ends mid-frame surfaces as
/// [`FrameError::Io`] (`UnexpectedEof`).
pub fn read_msg<R: Read>(r: &mut R) -> Result<Msg, FrameError> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    if len == 0 {
        return Err(FrameError::Protocol("zero-length frame".into()));
    }
    if len > MAX_FRAME {
        return Err(FrameError::Protocol(format!(
            "frame of {len} bytes exceeds the {MAX_FRAME}-byte cap"
        )));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Msg::decode(&payload).map_err(|e| FrameError::Protocol(format!("{e:#}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: &Msg) {
        let payload = msg.encode();
        let back = Msg::decode(&payload).expect("decode");
        assert_eq!(&back, msg);
        // And through the frame layer.
        let mut buf = Vec::new();
        write_msg(&mut buf, msg).unwrap();
        assert_eq!(buf.len(), payload.len() + 4);
        let framed = read_msg(&mut &buf[..]).expect("framed decode");
        assert_eq!(&framed, msg);
    }

    #[test]
    fn every_message_roundtrips() {
        let msgs = vec![
            Msg::Hello {
                magic: MAGIC,
                version: VERSION,
                preset: "mlp".into(),
                kernel: "vectorized".into(),
            },
            Msg::HelloOk,
            Msg::Err { reason: "no".into() },
            Msg::SplitReq {
                cut: 2,
                want_grad: true,
                labels: vec![0, 9, 3],
                top_params: vec![vec![1.0, -2.5], vec![], vec![f32::MIN_POSITIVE]],
                acts: vec![0.25; 7], // deliberately not a multiple of 8
            },
            Msg::SplitResp {
                loss_sum: 12.75,
                correct: 3,
                dcut: vec![-1.0; 13],
                g_top: vec![],
            },
            Msg::FoldBegin,
            Msg::FoldAdd { weight: 0.125, params: vec![vec![3.0; 5]] },
            Msg::FoldOk,
            Msg::FoldFinish,
            Msg::FoldResult { params: Some(vec![vec![], vec![1.0]]) },
            Msg::FoldResult { params: None },
            Msg::Shutdown,
        ];
        for msg in &msgs {
            roundtrip(msg);
        }
    }

    #[test]
    fn awkward_tensor_sizes_roundtrip_exactly() {
        // Empty tensors, 1-element, non-multiple-of-8 lengths, and a
        // large frame; bit patterns (incl. -0.0, inf, NaN payloads via
        // bits) must survive the LE round trip untouched.
        for n in [0usize, 1, 7, 9, 63, 100_003] {
            let t: Vec<f32> = (0..n).map(|i| f32::from_bits(0x3f00_0000 ^ i as u32)).collect();
            let msg = Msg::SplitResp { loss_sum: -0.0, correct: u64::MAX, dcut: t, g_top: vec![-0.0] };
            let back = Msg::decode(&msg.encode()).unwrap();
            let (Msg::SplitResp { dcut: a, loss_sum: ls, .. }, Msg::SplitResp { dcut: b, .. }) =
                (&msg, &back)
            else {
                panic!("variant changed");
            };
            assert_eq!(ls.to_bits(), (-0.0f64).to_bits());
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn truncated_frames_and_payloads_are_rejected() {
        let msg = Msg::FoldAdd { weight: 1.0, params: vec![vec![1.0, 2.0, 3.0]] };
        let mut buf = Vec::new();
        write_msg(&mut buf, &msg).unwrap();
        // Cutting the stream anywhere before the end must error, never
        // panic and never yield a message.
        for k in 0..buf.len() {
            let r = read_msg(&mut &buf[..k]);
            match r {
                Err(FrameError::Io(e)) => {
                    assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof, "cut at {k}")
                }
                Err(FrameError::Protocol(_)) => panic!("cut at {k}: truncation is an I/O error"),
                Ok(m) => panic!("cut at {k} decoded {}", m.name()),
            }
        }
        // Payload-level truncation (a length that overruns the frame) is
        // a protocol error and must not allocate the declared size.
        let mut payload = msg.encode();
        payload.truncate(payload.len() - 2);
        assert!(Msg::decode(&payload).is_err());
        let huge = [TAG_SPLIT_RESP].iter().copied()
            .chain(0u64.to_le_bytes())
            .chain(0u64.to_le_bytes())
            .chain(u32::MAX.to_le_bytes()) // dcut claims 4 billion floats
            .collect::<Vec<u8>>();
        assert!(Msg::decode(&huge).is_err());
    }

    #[test]
    fn oversized_zero_and_trailing_frames_are_rejected() {
        // Length prefix over the cap: rejected before allocation.
        let mut buf = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        buf.extend_from_slice(&[0u8; 16]);
        assert!(matches!(read_msg(&mut &buf[..]), Err(FrameError::Protocol(_))));
        // Zero-length frame: protocol error, not EOF.
        let zero = 0u32.to_le_bytes();
        assert!(matches!(read_msg(&mut &zero[..]), Err(FrameError::Protocol(_))));
        // Trailing bytes after a complete message: rejected.
        let mut payload = Msg::HelloOk.encode();
        payload.push(0);
        assert!(Msg::decode(&payload).is_err());
        // Unknown tag: rejected.
        assert!(Msg::decode(&[0xEE]).is_err());
        // Bad bool byte: rejected.
        let mut req = Msg::SplitReq {
            cut: 0,
            want_grad: false,
            labels: vec![],
            top_params: vec![],
            acts: vec![],
        }
        .encode();
        req[5] = 7; // the want_grad flag byte
        assert!(Msg::decode(&req).is_err());
    }
}
