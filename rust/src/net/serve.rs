//! The gateway service: a threaded TCP server hosting the gateway half
//! of split execution (§II-B) and the FedAvg fold (§III-A step 3).
//!
//! Pure `std::net` — the crate's zero-heavy-deps policy rules out an
//! async runtime. One OS thread per accepted connection runs the frame
//! loop; the actual math inside `PartitionedBackend::gateway_split_batch`
//! (crate-private) rides a DEDICATED rayon pool through the SAME blocked
//! executors the in-process path uses, which is why a loopback tcp run
//! is byte-identical to the in-process oracle (`rust/tests/wire.rs`) —
//! and why one never deadlocks: see the `compute` field.
//!
//! Per-connection state is exactly one optional in-progress
//! `WeightedAccum` fold; split requests are stateless. A protocol
//! violation tears down its own connection (after a best-effort
//! [`Msg::Err`] frame) and the service keeps accepting; a client that
//! disappears mid-fold takes its partial fold with it.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;

use anyhow::{bail, Context, Result};

use crate::fl::vecmath::WeightedAccum;
use crate::runtime::native::check_params_against;
use crate::runtime::{make_partitioned_stack_kernel, Backend, KernelPath, PartitionedBackend};

use super::wire::{self, FrameError, Msg, MAGIC, VERSION};

/// A gateway service for one preset/kernel pair: hosts the full split
/// stack (one gateway half per legal cut), so clients may request any
/// partition point the scheduler assigns.
pub struct GatewayServer {
    preset: String,
    kernel: KernelPath,
    stack: Arc<Vec<PartitionedBackend>>,
    /// Service-wide budget of SplitReq frames to serve before SEVERING
    /// the connection of every later one — the deterministic fault
    /// injection hook behind the mid-round-disconnect test (the client
    /// must degrade to the `FaultPlan` dropout path, not abort). Fold
    /// frames are unaffected, so surviving devices still aggregate.
    /// `usize::MAX` (the default) never fires.
    split_budget: Arc<AtomicUsize>,
    /// The service's OWN rayon pool for the gateway math. In a loopback
    /// run the CLIENT parks global-pool workers on frame I/O while they
    /// await replies; if the gateway math also queued on the global pool
    /// (handler threads are plain OS threads — their `par_*` calls
    /// inject into it), a single-process loopback run would deadlock
    /// until the read timeout fired and every device "dropped". A
    /// dedicated pool changes scheduling only, never bytes: the blocked
    /// executors' fold order is worker-count independent.
    compute: Arc<rayon::ThreadPool>,
}

impl GatewayServer {
    /// Compile the split stack for `preset` on `kernel`.
    pub fn new(preset: &str, kernel: KernelPath) -> Result<Self> {
        let stack = make_partitioned_stack_kernel(preset, kernel)?;
        let compute = rayon::ThreadPoolBuilder::new()
            .build()
            .context("building the gateway compute pool")?;
        Ok(GatewayServer {
            preset: preset.to_string(),
            kernel,
            stack: Arc::new(stack),
            split_budget: Arc::new(AtomicUsize::new(usize::MAX)),
            compute: Arc::new(compute),
        })
    }

    /// Test hook (see `split_budget`): serve only `served` split
    /// requests, then drop the connection of every subsequent one.
    pub fn fail_splits_after(&mut self, served: usize) {
        self.split_budget = Arc::new(AtomicUsize::new(served));
    }

    /// Bind `addr` (`:0` picks an ephemeral port — how the tests run
    /// client and service in one process) and serve on a background
    /// accept thread until the returned handle stops or is dropped.
    pub fn spawn(self, addr: &str) -> Result<GatewayHandle> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding gateway on {addr}"))?;
        let local = listener.local_addr().context("gateway local_addr")?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept_stop = stop.clone();
        let join = thread::spawn(move || {
            for conn in listener.incoming() {
                if accept_stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let stack = self.stack.clone();
                let preset = self.preset.clone();
                let kernel = self.kernel;
                let budget = self.split_budget.clone();
                let compute = self.compute.clone();
                thread::spawn(move || {
                    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
                    if let Err(e) = handle_conn(&stream, &stack, &preset, kernel, &budget, &compute)
                    {
                        eprintln!("[gateway] connection {peer}: {e:#}");
                    }
                });
            }
        });
        Ok(GatewayHandle { addr: local, stop, join: Some(join) })
    }
}

/// Handle on a spawned [`GatewayServer`]: the bound address plus stop /
/// join control. Dropping the handle stops the service.
pub struct GatewayHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<thread::JoinHandle<()>>,
}

impl GatewayHandle {
    /// The bound address (with `:0` binds resolved to the real port).
    pub fn addr(&self) -> String {
        self.addr.to_string()
    }

    /// Block until the accept loop exits — a `serve-gateway` process
    /// serves until killed.
    pub fn join(mut self) {
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }

    /// Stop accepting and join the accept loop. Handler threads finish
    /// their current connection on their own.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        let Some(join) = self.join.take() else { return };
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        let _ = join.join();
    }
}

impl Drop for GatewayHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn reply(stream: &TcpStream, msg: &Msg) -> Result<()> {
    wire::write_msg(&mut (&*stream), msg)
        .with_context(|| format!("replying {}", msg.name()))
}

/// Best-effort `Err` frame; the connection is about to close anyway.
fn refuse(stream: &TcpStream, reason: &str) {
    let _ = reply(stream, &Msg::Err { reason: reason.to_string() });
}

fn handle_conn(
    stream: &TcpStream,
    stack: &[PartitionedBackend],
    preset: &str,
    kernel: KernelPath,
    split_budget: &AtomicUsize,
    compute: &rayon::ThreadPool,
) -> Result<()> {
    let _ = stream.set_nodelay(true);
    let mut reader = stream;
    // ---- handshake: magic, version, preset, kernel must all agree.
    let hello = match wire::read_msg(&mut reader) {
        Ok(m) => m,
        // Connect-and-close probes (incl. the stop() wakeup) are normal.
        Err(FrameError::Io(_)) => return Ok(()),
        Err(FrameError::Protocol(p)) => {
            refuse(stream, &p);
            bail!("handshake: {p}");
        }
    };
    let Msg::Hello { magic, version, preset: their_preset, kernel: their_kernel } = hello else {
        refuse(stream, "expected Hello");
        bail!("handshake: got {} before Hello", hello.name());
    };
    if magic != MAGIC {
        refuse(stream, &format!("bad magic {magic:#010x}"));
        bail!("handshake: bad magic {magic:#010x}");
    }
    if version != VERSION {
        let why = format!("protocol version {version} not supported (gateway speaks {VERSION})");
        refuse(stream, &why);
        bail!("handshake: {why}");
    }
    if their_preset != preset {
        let why = format!("gateway serves preset {preset:?}, client runs {their_preset:?}");
        refuse(stream, &why);
        bail!("handshake: {why}");
    }
    if their_kernel != kernel.as_str() {
        let why =
            format!("gateway runs kernel {:?}, client runs {their_kernel:?}", kernel.as_str());
        refuse(stream, &why);
        bail!("handshake: {why}");
    }
    reply(stream, &Msg::HelloOk)?;

    // ---- frame loop: split requests + at most one in-progress fold.
    let mut fold: Option<WeightedAccum> = None;
    loop {
        let msg = match wire::read_msg(&mut reader) {
            Ok(m) => m,
            // The client went away; its partial fold (if any) dies here.
            Err(FrameError::Io(_)) => return Ok(()),
            Err(FrameError::Protocol(p)) => {
                refuse(stream, &p);
                bail!("{p}");
            }
        };
        match msg {
            Msg::SplitReq { cut, want_grad, labels, top_params, acts } => {
                // Fault-injection hook: budget exhausted → sever the
                // connection mid-round, exactly like a dying peer.
                let alive = split_budget
                    .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1));
                if alive.is_err() {
                    return Ok(());
                }
                let Some(backend) = stack.get(cut as usize) else {
                    let why = format!(
                        "partition point {cut} outside the served model's 0..={}",
                        stack.len() - 1
                    );
                    refuse(stream, &why);
                    bail!("SplitReq: {why}");
                };
                match compute
                    .install(|| backend.gateway_split_batch(&top_params, &acts, &labels, want_grad))
                {
                    Ok((loss_sum, correct, g_top, dcut)) => reply(
                        stream,
                        &Msg::SplitResp { loss_sum, correct: correct as u64, dcut, g_top },
                    )?,
                    Err(e) => {
                        refuse(stream, &format!("{e:#}"));
                        bail!("SplitReq: {e:#}");
                    }
                }
            }
            Msg::FoldBegin => {
                fold = Some(WeightedAccum::new());
                reply(stream, &Msg::FoldOk)?;
            }
            Msg::FoldAdd { weight, params } => {
                let Some(acc) = fold.as_mut() else {
                    refuse(stream, "FoldAdd before FoldBegin");
                    bail!("FoldAdd before FoldBegin");
                };
                // Validate BEFORE WeightedAccum::add — its layout checks
                // are assertions, and a skewed client must not panic a
                // handler thread.
                if let Err(e) = check_fold_add(stack, &params, weight) {
                    refuse(stream, &format!("{e:#}"));
                    bail!("FoldAdd: {e:#}");
                }
                acc.add(&params, weight);
                reply(stream, &Msg::FoldOk)?;
            }
            Msg::FoldFinish => {
                let Some(acc) = fold.take() else {
                    refuse(stream, "FoldFinish before FoldBegin");
                    bail!("FoldFinish before FoldBegin");
                };
                reply(stream, &Msg::FoldResult { params: acc.finish() })?;
            }
            Msg::Shutdown => return Ok(()),
            other => {
                let why = format!("unexpected {}", other.name());
                refuse(stream, &why);
                bail!("{why}");
            }
        }
    }
}

/// A `FoldAdd` must carry the served model's exact tensor layout and a
/// finite non-negative FedAvg weight.
fn check_fold_add(stack: &[PartitionedBackend], params: &crate::runtime::Params, w: f64) -> Result<()> {
    if !(w.is_finite() && w >= 0.0) {
        bail!("bad FedAvg weight {w}");
    }
    // Every preset has at least the cut-0 backend, and all cuts share
    // the fused parameter ABI.
    let meta = stack.first().expect("non-empty split stack").meta();
    check_params_against(meta, params)
}
