//! iiot-fl — launcher for the DDSRA federated-learning system.
//!
//! Subcommands:
//!   train          run one scheduler for T rounds with real training
//!                  (pure-Rust NativeBackend; PJRT with --features pjrt)
//!   serve-gateway  host the gateway half of split execution + the
//!                  FedAvg fold as a TCP service (for --transport tcp)
//!   participation  estimate Γ_m (Eq. 13) for the current config
//!   info           print the cost-model layer table (Table II view)
//!
//! Examples:
//!   iiot-fl train --scheme ddsra --v 0.01 --rounds 100 --dataset svhn
//!   iiot-fl train --scheme round_robin --rounds 50 --out results/rr.csv
//!   iiot-fl train --scheme ddsra --until-acc 0.5 --jsonl results/run.jsonl
//!   iiot-fl train --scenario metro --progress 10 --max-delay 3600
//!   iiot-fl serve-gateway --listen 127.0.0.1:7700 --preset mlp
//!   iiot-fl train --transport tcp --execute-partition --cost-model mlp
//!   iiot-fl participation --dataset cifar
//!   iiot-fl info --cost-model vgg11

use std::path::Path;

use anyhow::Result;
use iiot_fl::cli::Args;
use iiot_fl::dnn::models;
use iiot_fl::fl::{RoundObserver, SchedulerSpec, Session};
use iiot_fl::metrics::{print_table, CsvSink, JsonlSink, MemorySink, ProgressSink};

/// Flags every subcommand understands (config assembly).
const COMMON_FLAGS: &[&str] = &[
    "config",
    "scenario",
    "set",
    "rounds",
    "v",
    "seed",
    "dataset",
    "preset",
    "cost-model",
    "kernel",
    "sched-path",
    "aggregation",
    "transport",
    "gateway-addr",
    "execute-partition",
];

/// Flags only `train` understands (session knobs + sinks).
const TRAIN_FLAGS: &[&str] = &[
    "scheme",
    "eval-every",
    "no-train",
    "divergence",
    "until-acc",
    "max-delay",
    "out",
    "jsonl",
    "progress",
];

fn allowed(extra: &[&'static str]) -> Vec<&'static str> {
    let mut v = COMMON_FLAGS.to_vec();
    v.extend_from_slice(extra);
    v
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    match args.command.as_str() {
        "train" => {
            args.expect_known(&allowed(TRAIN_FLAGS))?;
            cmd_train(&args)
        }
        "serve-gateway" => {
            args.expect_known(&allowed(&["listen"]))?;
            cmd_serve_gateway(&args)
        }
        "participation" => {
            args.expect_known(&allowed(&[]))?;
            cmd_participation(&args)
        }
        "info" => {
            args.expect_known(&allowed(&[]))?;
            cmd_info(&args)
        }
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            anyhow::bail!("unknown command {other:?}")
        }
    }
}

fn print_help() {
    println!(
        "iiot-fl — Low-latency FL with DNN Partition (DDSRA)\n\
         commands: train | serve-gateway | participation | info\n\
         common flags: --rounds N --v V --seed S --dataset svhn|cifar\n\
         \u{20}                --preset mlp|cnn --cost-model vgg11|cnn|mlp\n\
         \u{20}                --kernel vectorized|scalar (native compute path;\n\
         \u{20}                scalar = the bit-exact oracle loops)\n\
         \u{20}                --sched-path incremental|sweep (DDSRA λ-sweep:\n\
         \u{20}                sweep = the per-cap Hungarian re-solve oracle)\n\
         \u{20}                --aggregation flat|hierarchical (phase-5 fold:\n\
         \u{20}                flat = one cloud accumulator, hierarchical =\n\
         \u{20}                gateway -> edge cluster -> cloud tier folds)\n\
         \u{20}                --scenario paper|plant|campus|metro|nation|\n\
         \u{20}                nation-xl|flaky-plant|churn-metro (scale/adversity\n\
         \u{20}                preset, applied before --set overrides)\n\
         \u{20}                --set key=value (any config key) --config file\n\
         train flags:  --scheme ddsra|participation|random|round_robin|\n\
         \u{20}                loss_driven|delay_driven\n\
         \u{20}                --eval-every N --no-train --divergence\n\
         \u{20}                --until-acc A (stop at test accuracy >= A)\n\
         \u{20}                --max-delay S (stop at simulated delay budget S)\n\
         \u{20}                --out results/run.csv (stream CSV during the run)\n\
         \u{20}                --jsonl results/run.jsonl (stream JSONL)\n\
         \u{20}                --progress N (stderr heartbeat every N rounds)\n\
         \u{20}                --execute-partition (run each device's local step\n\
         \u{20}                SPLIT at the scheduler's chosen cut; needs\n\
         \u{20}                --cost-model == --preset)\n\
         \u{20}                --transport inproc|tcp (tcp drives the split over\n\
         \u{20}                the wire to a serve-gateway process; needs\n\
         \u{20}                --execute-partition) --gateway-addr HOST:PORT\n\
         serve-gateway flags: --listen HOST:PORT (default: gateway_addr)\n\
         unknown flags are rejected with a \"did you mean\" hint"
    );
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = args.sim_config()?;
    let spec: SchedulerSpec = args.get_or("scheme", "ddsra").parse()?;

    let mut builder =
        Session::builder(cfg).eval_every(args.parse_num::<usize>("eval-every")?.unwrap_or(5));
    if args.has("no-train") {
        builder = builder.schedule_only();
    }
    if args.has("divergence") {
        builder = builder.divergence();
    }
    if let Some(target) = args.parse_num::<f64>("until-acc")? {
        builder = builder.until_accuracy(target);
    }
    if let Some(budget) = args.parse_num::<f64>("max-delay")? {
        builder = builder.max_rounds_wall(budget);
    }
    let session = builder.build()?;
    let exp = session.experiment();
    eprintln!(
        "[train] scheme={} rounds={} dataset={} exec={} cost={}{}{}",
        spec.label(),
        session.opts().rounds,
        exp.cfg.dataset,
        exp.cfg.exec_model,
        exp.cfg.cost_model,
        if exp.cfg.execute_partition { " split-execution=on" } else { "" },
        if exp.cfg.transport == iiot_fl::config::Transport::Tcp {
            format!(" transport=tcp gateway={}", exp.cfg.gateway_addr)
        } else {
            String::new()
        }
    );

    // Sinks: records stream to every requested emitter DURING the run;
    // the memory sink rebuilds the log for the closing tables.
    let mut mem = MemorySink::new();
    let mut csv = match args.get("out") {
        Some(path) => Some(CsvSink::create(Path::new(path))?),
        None => None,
    };
    let mut jsonl = match args.get("jsonl") {
        Some(path) => Some(JsonlSink::create(Path::new(path))?),
        None => None,
    };
    let mut progress = args.parse_num::<usize>("progress")?.map(ProgressSink::every);

    let summary = {
        let mut observers: Vec<&mut dyn RoundObserver> = vec![&mut mem];
        if let Some(sink) = csv.as_mut() {
            observers.push(sink);
        }
        if let Some(sink) = jsonl.as_mut() {
            observers.push(sink);
        }
        if let Some(sink) = progress.as_mut() {
            observers.push(sink);
        }
        session.run_with(&spec, &mut observers)?
    };
    if let Some(cause) = &summary.stop {
        eprintln!("[train] stopped early: {cause}");
    }
    if let Some(path) = args.get("out") {
        eprintln!("[train] wrote {path}");
    }
    if let Some(path) = args.get("jsonl") {
        eprintln!("[train] wrote {path}");
    }

    let log = mem.into_log();
    let rows: Vec<Vec<String>> = log
        .records
        .iter()
        .filter(|r| r.test_acc.is_some() || r.round + 1 == log.records.len())
        .map(|r| {
            vec![
                r.round.to_string(),
                format!("{:.2}", r.cum_delay),
                r.train_loss.map_or("-".into(), |v| format!("{v:.4}")),
                r.test_acc.map_or("-".into(), |v| format!("{:.2}%", v * 100.0)),
            ]
        })
        .collect();
    print_table(
        &format!("{} on {}", log.scheme, exp.cfg.dataset),
        &["round", "cum_delay_s", "train_loss", "test_acc"],
        &rows,
    );
    // Per-gateway rows stop being a table anyone reads past metro scale
    // (nation has thousands of gateways) — summarize instead.
    let m_total = exp.topo.num_gateways();
    if m_total <= 128 {
        let prow: Vec<Vec<String>> = (0..m_total)
            .map(|m| {
                vec![
                    format!("gw{m}"),
                    format!("{:.3}", log.participation[m]),
                    format!("{:.3}", log.effective_participation[m]),
                ]
            })
            .collect();
        print_table("participation", &["gateway", "selected", "effective"], &prow);
    } else {
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        println!(
            "participation: {m_total} gateways — mean selected {:.4}, mean effective {:.4} \
             (per-gateway table suppressed beyond 128 gateways)",
            mean(&log.participation),
            mean(&log.effective_participation)
        );
    }
    Ok(())
}

/// Host the gateway half of split execution (plus the FedAvg fold) as a
/// TCP service; `train --transport tcp` processes dial it. Serves until
/// killed.
fn cmd_serve_gateway(args: &Args) -> Result<()> {
    let cfg = args.sim_config()?;
    let listen = args.get_or("listen", &cfg.gateway_addr);
    let server = iiot_fl::net::serve::GatewayServer::new(&cfg.exec_model, cfg.kernel)?;
    let handle = server.spawn(listen)?;
    eprintln!(
        "[serve-gateway] preset={} kernel={} listening on {}",
        cfg.exec_model,
        cfg.kernel,
        handle.addr()
    );
    handle.join();
    Ok(())
}

fn cmd_participation(args: &Args) -> Result<()> {
    let cfg = args.sim_config()?;
    let session = Session::builder(cfg).build()?;
    let exp = session.experiment();
    let stats = exp.estimate_grad_stats(4)?;
    let (phis, gammas) = iiot_fl::fl::gamma_rates(
        &exp.topo,
        &stats,
        exp.cfg.num_channels,
        exp.cfg.lr,
        exp.cfg.local_iters,
    );
    let rows: Vec<Vec<String>> = (0..exp.topo.num_gateways())
        .map(|m| {
            let members = &exp.topo.gateways[m].members;
            vec![
                format!("gw{m}"),
                format!("{:.4}", phis[m]),
                format!("{:.4}", gammas[m]),
                members
                    .iter()
                    .map(|&n| exp.shard_class_count(n).to_string())
                    .collect::<Vec<_>>()
                    .join("/"),
            ]
        })
        .collect();
    print_table(
        &format!("device-specific participation rates ({})", exp.cfg.dataset),
        &["gateway", "phi_m", "gamma_m", "classes"],
        &rows,
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = args.sim_config()?;
    let model = models::by_name(&cfg.cost_model)
        .ok_or_else(|| anyhow::anyhow!("unknown cost model {:?}", cfg.cost_model))?;
    let rows: Vec<Vec<String>> = model
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            vec![
                (i + 1).to_string(),
                l.short_name().to_string(),
                format!("{:.3e}", l.o()),
                format!("{:.3e}", l.o_prime()),
                format!("{:.1}", l.cost(100, 4).mem_bytes / 1e6),
            ]
        })
        .collect();
    print_table(
        &format!(
            "{} — Table II per-layer costs (batch 100); {} params, gamma = {:.0} Mbit",
            model.name,
            model.params,
            model.gamma_bits() / 1e6
        ),
        &["layer", "kind", "o_l (FLOPs)", "o'_l (FLOPs)", "mem (MB)"],
        &rows,
    );
    Ok(())
}
