//! Optimization substrates used by the DDSRA solver (§V-B):
//! Hungarian assignment for the channel-assignment subproblem and scalar
//! bisection / root finding for the frequency- and power-allocation
//! subproblems.

pub mod hungarian;
pub mod scalar;

pub use hungarian::{hungarian_min, IncrementalMatcher};
pub use scalar::{bisect_decreasing, bisect_root};
