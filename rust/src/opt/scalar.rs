//! Scalar bisection substrates for the DDSRA inner loops (§V-B).
//!
//! The paper solves the partition-point and frequency-allocation
//! subproblems (Eq. 21, 22) by bisecting on the min-max objective value and
//! the transmit-power subproblem (Eq. 23–24) by finding the root of a
//! monotone energy-balance equation. Both primitives live here.

/// Bisect for the smallest `eta` in `[lo, hi]` such that `feasible(eta)`,
/// assuming feasibility is monotone non-decreasing in `eta` (infeasible
/// below some threshold, feasible above). Returns `None` if `feasible(hi)`
/// is false.
pub fn bisect_decreasing(
    mut lo: f64,
    mut hi: f64,
    tol: f64,
    max_iter: usize,
    mut feasible: impl FnMut(f64) -> bool,
) -> Option<f64> {
    if !feasible(hi) {
        return None;
    }
    if feasible(lo) {
        return Some(lo);
    }
    for _ in 0..max_iter {
        if hi - lo <= tol * (1.0 + hi.abs()) {
            break;
        }
        let mid = 0.5 * (lo + hi);
        if feasible(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(hi)
}

/// Find a root of a continuous function `f` on `[lo, hi]` with
/// `f(lo) <= 0 <= f(hi)` or `f(lo) >= 0 >= f(hi)` by bisection.
/// Returns `None` if the signs do not bracket a root.
pub fn bisect_root(
    mut lo: f64,
    mut hi: f64,
    tol: f64,
    max_iter: usize,
    mut f: impl FnMut(f64) -> f64,
) -> Option<f64> {
    let (flo, fhi) = (f(lo), f(hi));
    if flo == 0.0 {
        return Some(lo);
    }
    if fhi == 0.0 {
        return Some(hi);
    }
    if flo.signum() == fhi.signum() {
        return None;
    }
    let rising = flo < 0.0;
    for _ in 0..max_iter {
        if hi - lo <= tol * (1.0 + hi.abs()) {
            break;
        }
        let mid = 0.5 * (lo + hi);
        let fm = f(mid);
        if (fm > 0.0) == rising {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(0.5 * (lo + hi))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn bisect_decreasing_finds_threshold() {
        // feasible iff eta >= 3.7
        let got = bisect_decreasing(0.0, 10.0, 1e-9, 200, |e| e >= 3.7).unwrap();
        assert!((got - 3.7).abs() < 1e-6, "{got}");
    }

    #[test]
    fn bisect_decreasing_infeasible() {
        assert!(bisect_decreasing(0.0, 1.0, 1e-9, 100, |_| false).is_none());
    }

    #[test]
    fn bisect_decreasing_trivially_feasible() {
        assert_eq!(bisect_decreasing(2.0, 9.0, 1e-9, 100, |_| true), Some(2.0));
    }

    #[test]
    fn bisect_root_quadratic() {
        let r = bisect_root(0.0, 10.0, 1e-12, 200, |x| x * x - 2.0).unwrap();
        assert!((r - 2f64.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn bisect_root_decreasing_fn() {
        let r = bisect_root(0.0, 10.0, 1e-12, 200, |x| 5.0 - x).unwrap();
        assert!((r - 5.0).abs() < 1e-6);
    }

    #[test]
    fn bisect_root_no_bracket() {
        assert!(bisect_root(0.0, 1.0, 1e-9, 100, |x| x + 1.0).is_none());
    }

    #[test]
    fn bisect_root_exact_at_endpoints() {
        // f(lo) == 0 and f(hi) == 0 short-circuit without iterating.
        assert_eq!(bisect_root(2.0, 9.0, 1e-9, 100, |x| x - 2.0), Some(2.0));
        assert_eq!(bisect_root(0.0, 4.0, 1e-9, 100, |x| x - 4.0), Some(4.0));
    }

    #[test]
    fn bisect_root_degenerate_bracket() {
        // lo == hi with a sign: no bracket, must refuse rather than loop.
        assert!(bisect_root(1.0, 1.0, 1e-9, 100, |x| x - 0.5).is_none());
        // Same-sign negative bracket is also rejected.
        assert!(bisect_root(0.0, 1.0, 1e-9, 100, |x| -x - 1.0).is_none());
    }

    #[test]
    fn bisect_root_respects_iteration_cap() {
        // One iteration still returns a point inside the bracket.
        let r = bisect_root(0.0, 8.0, 0.0, 1, |x| x - 3.0).unwrap();
        assert!((0.0..=8.0).contains(&r));
    }

    #[test]
    fn bisect_root_energy_balance_shape() {
        // The Eq. 23–24 P-step solves g(P) = c1·log2(1 + c2·P) − P = 0 with
        // g(0+) > 0 and g(Pmax) < 0; the recovered root must satisfy g ≈ 0.
        let (c1, c2) = (0.05, 400.0);
        let g = |p: f64| c1 * (1.0 + c2 * p).log2() - p;
        assert!(g(1e-12) > 0.0 && g(1.0) < 0.0);
        let p = bisect_root(1e-12, 1.0, 1e-12, 200, g).unwrap();
        assert!(g(p).abs() < 1e-6, "g({p}) = {}", g(p));
        assert!(p > 0.0 && p < 1.0);
    }

    #[test]
    fn bisect_decreasing_threshold_at_bounds() {
        // Threshold exactly at hi: feasible(hi) holds, answer near hi.
        let got = bisect_decreasing(0.0, 5.0, 1e-9, 200, |e| e >= 5.0).unwrap();
        assert!((got - 5.0).abs() < 1e-6, "{got}");
        // Degenerate interval, feasible: returns lo immediately.
        assert_eq!(bisect_decreasing(3.0, 3.0, 1e-9, 100, |e| e >= 1.0), Some(3.0));
        // Degenerate interval, infeasible: None.
        assert!(bisect_decreasing(3.0, 3.0, 1e-9, 100, |_| false).is_none());
    }

    #[test]
    fn bisect_decreasing_result_is_always_feasible() {
        // The returned eta itself must satisfy the predicate (the f-step
        // allocates frequencies AT the returned θ, so feasibility of the
        // answer — not just proximity to the threshold — is load-bearing).
        let mut rng = Rng::new(4242);
        for _ in 0..100 {
            let t = rng.uniform(0.5, 9.5);
            let got = bisect_decreasing(0.0, 10.0, 1e-6, 100, |e| e >= t).unwrap();
            assert!(got >= t, "returned infeasible eta {got} for threshold {t}");
        }
    }

    /// Property: for random monotone thresholds, bisection recovers them.
    #[test]
    fn property_random_thresholds() {
        let mut rng = Rng::new(99);
        for _ in 0..200 {
            let t = rng.uniform(0.1, 9.9);
            let got = bisect_decreasing(0.0, 10.0, 1e-10, 200, |e| e >= t).unwrap();
            assert!((got - t).abs() < 1e-5, "t={t} got={got}");
        }
    }
}
