//! Kuhn–Munkres (Hungarian) algorithm, O(n³), for min-cost assignment.
//!
//! Used by DDSRA's channel-assignment subproblem (Eq. 26–29): the paper
//! builds a composite cost Θ_{m,j} (−Q_m for admissible pairs, a huge Ψ for
//! pairs violating the latency cap VΛ_{m,j} ≤ λ) and assigns each of the J
//! channels to exactly one gateway.
//!
//! This implementation is the classic potentials + augmenting-path variant
//! over a rows×cols matrix with rows <= cols (we transpose internally when
//! needed). `hungarian_min` returns, for each row, the assigned column (or
//! None when rows > cols and the row is left unassigned).

/// Solve min-cost assignment. `cost[r][c]`, rectangular allowed.
/// Returns (assignment per row, total cost). When rows > cols, exactly
/// `cols` rows get a column and the rest get `None`.
pub fn hungarian_min(cost: &[Vec<f64>]) -> (Vec<Option<usize>>, f64) {
    let rows = cost.len();
    if rows == 0 {
        return (vec![], 0.0);
    }
    let cols = cost[0].len();
    debug_assert!(cost.iter().all(|r| r.len() == cols));

    if rows <= cols {
        let (a, c) = kuhn_munkres(cost, rows, cols);
        (a.into_iter().map(Some).collect(), c)
    } else {
        // Transpose, solve, invert the mapping.
        let t: Vec<Vec<f64>> = (0..cols)
            .map(|j| (0..rows).map(|i| cost[i][j]).collect())
            .collect();
        let (a, c) = kuhn_munkres(&t, cols, rows);
        let mut out = vec![None; rows];
        for (j, i) in a.into_iter().enumerate() {
            out[i] = Some(j);
        }
        (out, c)
    }
}

/// Classic O(n²m) potentials algorithm; requires n <= m.
/// Returns assignment: for each row, its column; plus total cost.
fn kuhn_munkres(cost: &[Vec<f64>], n: usize, m: usize) -> (Vec<usize>, f64) {
    const INF: f64 = f64::INFINITY;
    // 1-indexed potentials as in the standard e-maxx formulation.
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; m + 1];
    let mut p = vec![0usize; m + 1]; // p[j] = row matched to column j (1-indexed)
    let mut way = vec![0usize; m + 1];

    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![INF; m + 1];
        let mut used = vec![false; m + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = INF;
            let mut j1 = 0usize;
            for j in 1..=m {
                if !used[j] {
                    let cur = cost[i0 - 1][j - 1] - u[i0] - v[j];
                    if cur < minv[j] {
                        minv[j] = cur;
                        way[j] = j0;
                    }
                    if minv[j] < delta {
                        delta = minv[j];
                        j1 = j;
                    }
                }
            }
            for j in 0..=m {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        // Augment along the path.
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }

    let mut assign = vec![usize::MAX; n];
    let mut total = 0.0;
    for j in 1..=m {
        if p[j] != 0 {
            assign[p[j] - 1] = j - 1;
            total += cost[p[j] - 1][j - 1];
        }
    }
    (assign, total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Brute-force optimal assignment for validation.
    fn brute(cost: &[Vec<f64>]) -> f64 {
        let rows = cost.len();
        let cols = cost[0].len();
        let (small, _large, transposed) = if rows <= cols {
            (rows, cols, false)
        } else {
            (cols, rows, true)
        };
        let big = if transposed { rows } else { cols };
        let mut idx: Vec<usize> = (0..big).collect();
        let mut best = f64::INFINITY;
        permute(&mut idx, 0, small, &mut |perm| {
            let mut c = 0.0;
            for (r, &cc) in perm.iter().take(small).enumerate() {
                c += if transposed { cost[cc][r] } else { cost[r][cc] };
            }
            if c < best {
                best = c;
            }
        });
        best
    }

    fn permute(idx: &mut Vec<usize>, k: usize, depth: usize, f: &mut impl FnMut(&[usize])) {
        if k == depth {
            f(idx);
            return;
        }
        for i in k..idx.len() {
            idx.swap(k, i);
            permute(idx, k + 1, depth, f);
            idx.swap(k, i);
        }
    }

    #[test]
    fn square_known() {
        let cost = vec![
            vec![4.0, 1.0, 3.0],
            vec![2.0, 0.0, 5.0],
            vec![3.0, 2.0, 2.0],
        ];
        let (a, c) = hungarian_min(&cost);
        assert_eq!(c, 5.0);
        let mut cols: Vec<_> = a.iter().map(|x| x.unwrap()).collect();
        cols.sort_unstable();
        assert_eq!(cols, vec![0, 1, 2]);
    }

    #[test]
    fn rectangular_rows_lt_cols() {
        let cost = vec![vec![10.0, 1.0, 7.0], vec![3.0, 9.0, 4.0]];
        let (a, c) = hungarian_min(&cost);
        assert_eq!(c, 4.0);
        assert_eq!(a[0], Some(1));
        assert_eq!(a[1], Some(0));
    }

    #[test]
    fn rectangular_rows_gt_cols_leaves_rows_unassigned() {
        // 6 gateways, 3 channels — the paper's shape. Exactly 3 assigned.
        let cost = vec![
            vec![5.0, 5.0, 5.0],
            vec![1.0, 9.0, 9.0],
            vec![9.0, 1.0, 9.0],
            vec![9.0, 9.0, 1.0],
            vec![5.0, 5.0, 5.0],
            vec![5.0, 5.0, 5.0],
        ];
        let (a, c) = hungarian_min(&cost);
        assert_eq!(c, 3.0);
        assert_eq!(a.iter().filter(|x| x.is_some()).count(), 3);
        assert_eq!(a[1], Some(0));
        assert_eq!(a[2], Some(1));
        assert_eq!(a[3], Some(2));
    }

    /// Property test: matches brute force on random instances.
    #[test]
    fn matches_brute_force_random() {
        let mut rng = Rng::new(1234);
        for case in 0..200 {
            let rows = 1 + rng.below(5);
            let cols = 1 + rng.below(5);
            let cost: Vec<Vec<f64>> = (0..rows)
                .map(|_| (0..cols).map(|_| (rng.below(100)) as f64).collect())
                .collect();
            let (a, c) = hungarian_min(&cost);
            let b = brute(&cost);
            assert!(
                (c - b).abs() < 1e-9,
                "case {case}: hungarian {c} != brute {b} for {cost:?} ({a:?})"
            );
            // Assignment must be a partial injection.
            let mut used = vec![false; cols];
            for col in a.iter().flatten() {
                assert!(!used[*col]);
                used[*col] = true;
            }
        }
    }

    /// Eq. 28–29 shape: composite cost Θ with −Q_m for admissible pairs and
    /// a huge Ψ penalty for inadmissible ones. When an admissible perfect
    /// matching of the channels exists, the solver must find one without
    /// paying Ψ, and it must serve the longest queues.
    #[test]
    fn psi_penalty_composite_assignment() {
        const PSI: f64 = 1e15;
        let queues = [10.0, 2.0, 8.0, 1.0, 9.0, 0.5];
        // gw1 admissible only on channel 2; gw3 admissible nowhere.
        let admissible = [
            [true, true, true],
            [false, false, true],
            [true, true, false],
            [false, false, false],
            [true, false, true],
            [true, true, true],
        ];
        let cost: Vec<Vec<f64>> = (0..6)
            .map(|m| {
                (0..3)
                    .map(|j| if admissible[m][j] { -queues[m] } else { PSI })
                    .collect()
            })
            .collect();
        let (assign, total) = hungarian_min(&cost);
        assert!(total < PSI / 2.0, "admissible matching exists but Ψ was paid");
        // Exactly J = 3 rows assigned, channels distinct, admissible only.
        let picks: Vec<(usize, usize)> = assign
            .iter()
            .enumerate()
            .filter_map(|(m, a)| a.map(|j| (m, j)))
            .collect();
        assert_eq!(picks.len(), 3);
        let mut chs: Vec<_> = picks.iter().map(|&(_, j)| j).collect();
        chs.sort_unstable();
        assert_eq!(chs, vec![0, 1, 2]);
        for &(m, j) in &picks {
            assert!(admissible[m][j], "inadmissible pair ({m},{j}) selected");
        }
        // Optimal total is serving the three longest admissible queues:
        // gw0 (10), gw4 (9), gw2 (8) — fits: gw2 on ch1, gw4 on ch2|0, gw0 rest.
        assert!((total - (-27.0)).abs() < 1e-9, "total {total}");
        assert_eq!(assign[3], None, "fully-inadmissible gateway must stay unassigned");
    }

    /// When no admissible perfect matching exists, the minimum cost must
    /// include at least one Ψ — the DDSRA λ-sweep uses `total >= Ψ/2` as
    /// its rejection test.
    #[test]
    fn psi_penalty_reports_no_admissible_matching() {
        const PSI: f64 = 1e15;
        // Channel 1 is inadmissible for every gateway.
        let cost: Vec<Vec<f64>> = (0..4)
            .map(|m| vec![-(m as f64), PSI, -(m as f64)])
            .collect();
        let (_, total) = hungarian_min(&cost);
        assert!(total >= PSI / 2.0);
    }

    #[test]
    fn one_by_one_and_single_column() {
        let (a, c) = hungarian_min(&[vec![3.5]]);
        assert_eq!(a, vec![Some(0)]);
        assert_eq!(c, 3.5);
        // 3 rows, 1 column: only the cheapest row is assigned.
        let (a, c) = hungarian_min(&[vec![5.0], vec![1.0], vec![2.0]]);
        assert_eq!(c, 1.0);
        assert_eq!(a, vec![None, Some(0), None]);
    }

    #[test]
    fn negative_costs_supported() {
        // Queue-composite costs are negative; optimum picks most-negative.
        let cost = vec![vec![-5.0, -1.0], vec![-2.0, -4.0]];
        let (a, c) = hungarian_min(&cost);
        assert_eq!(c, -9.0);
        assert_eq!(a, vec![Some(0), Some(1)]);
    }

    #[test]
    fn large_instance_runs() {
        let mut rng = Rng::new(5);
        let n = 256;
        let cost: Vec<Vec<f64>> =
            (0..n).map(|_| (0..n).map(|_| rng.f64()).collect()).collect();
        let (a, c) = hungarian_min(&cost);
        assert_eq!(a.iter().filter(|x| x.is_some()).count(), n);
        assert!(c >= 0.0 && c < n as f64);
    }
}
