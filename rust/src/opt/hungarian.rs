//! Kuhn–Munkres (Hungarian) algorithm, O(n³), for min-cost assignment.
//!
//! Used by DDSRA's channel-assignment subproblem (Eq. 26–29): the paper
//! builds a composite cost Θ_{m,j} (−Q_m for admissible pairs, a huge Ψ for
//! pairs violating the latency cap VΛ_{m,j} ≤ λ) and assigns each of the J
//! channels to exactly one gateway.
//!
//! This implementation is the classic potentials + augmenting-path variant
//! over a rows×cols matrix with rows <= cols (we transpose internally when
//! needed). `hungarian_min` returns, for each row, the assigned column (or
//! None when rows > cols and the row is left unassigned).

/// Solve min-cost assignment. `cost[r][c]`, rectangular allowed.
/// Returns (assignment per row, total cost). When rows > cols, exactly
/// `cols` rows get a column and the rest get `None`.
pub fn hungarian_min(cost: &[Vec<f64>]) -> (Vec<Option<usize>>, f64) {
    let rows = cost.len();
    if rows == 0 {
        return (vec![], 0.0);
    }
    let cols = cost[0].len();
    debug_assert!(cost.iter().all(|r| r.len() == cols));

    if rows <= cols {
        let (a, c) = kuhn_munkres(cost, rows, cols);
        (a.into_iter().map(Some).collect(), c)
    } else {
        // Transpose, solve, invert the mapping.
        let t: Vec<Vec<f64>> = (0..cols)
            .map(|j| (0..rows).map(|i| cost[i][j]).collect())
            .collect();
        let (a, c) = kuhn_munkres(&t, cols, rows);
        let mut out = vec![None; rows];
        for (j, i) in a.into_iter().enumerate() {
            out[i] = Some(j);
        }
        (out, c)
    }
}

/// Classic O(n²m) potentials algorithm; requires n <= m.
/// Returns assignment: for each row, its column; plus total cost.
fn kuhn_munkres(cost: &[Vec<f64>], n: usize, m: usize) -> (Vec<usize>, f64) {
    const INF: f64 = f64::INFINITY;
    // 1-indexed potentials as in the standard e-maxx formulation.
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; m + 1];
    let mut p = vec![0usize; m + 1]; // p[j] = row matched to column j (1-indexed)
    let mut way = vec![0usize; m + 1];

    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![INF; m + 1];
        let mut used = vec![false; m + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = INF;
            let mut j1 = 0usize;
            for j in 1..=m {
                if !used[j] {
                    let cur = cost[i0 - 1][j - 1] - u[i0] - v[j];
                    if cur < minv[j] {
                        minv[j] = cur;
                        way[j] = j0;
                    }
                    if minv[j] < delta {
                        delta = minv[j];
                        j1 = j;
                    }
                }
            }
            for j in 0..=m {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        // Augment along the path.
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }

    let mut assign = vec![usize::MAX; n];
    let mut total = 0.0;
    for j in 1..=m {
        if p[j] != 0 {
            assign[p[j] - 1] = j - 1;
            total += cost[p[j] - 1][j - 1];
        }
    }
    (assign, total)
}

/// Incremental max-weight bipartite matching over a *growing* edge set —
/// the augmenting-path core of DDSRA's incremental λ-sweep
/// (`sched_path = incremental`).
///
/// The λ-sweep's per-cap assignment has special structure: every
/// admissible (gateway, channel) edge of row `r` carries the same value
/// (the queue weight `Q_r`), and raising the cap only ever ADDS edges.
/// The optimal per-cap objective can therefore change only at caps where
/// (a) a perfect matching of all columns first exists, or (b) the
/// maximum total row weight over perfect matchings strictly increases —
/// and this matcher reports exactly those caps. Feed it the edges in
/// ascending cap order, one batch per distinct cap value; `add_edges`
/// returns `true` precisely when one of those two events occurs, which
/// is the caller's cue to run the verbatim per-cap evaluation.
///
/// Matched rows form a base of the transversal matroid induced by the
/// edge set. The base is kept maximum-weight by exact single exchanges:
/// an unmatched row `p` displaces the minimum-weight matched row
/// reachable from `p` via an alternating path whenever `p` is strictly
/// heavier (the matroid exchange theorem makes "no improving single
/// exchange" equivalent to global optimality). Columns live in a `u64`
/// bitmask, so at most 64 columns — J ≤ 16 in every scenario.
pub struct IncrementalMatcher {
    /// Per row: bitmask of admissible columns seen so far.
    adj: Vec<u64>,
    /// Per row: its exchange weight (DDSRA: the virtual queue Q_m).
    weight: Vec<f64>,
    /// Per column: the row currently holding it.
    col_row: Vec<Option<usize>>,
    /// Per row: the column it currently holds.
    row_col: Vec<Option<usize>>,
    /// Unmatched rows with at least one edge that may still enter the
    /// matching (not yet pruned).
    pending: Vec<usize>,
    in_pending: Vec<bool>,
    /// Permanently out: once all columns are matched, a row no heavier
    /// than the lightest matched row can never displace anyone (the
    /// minimum matched weight is non-decreasing from that point on).
    pruned: Vec<bool>,
    matched: usize,
    cols: usize,
    /// Latch: has the matching ever been perfect? The perfection event
    /// fires exactly once, on the batch that completes the matching.
    was_perfect: bool,
}

impl IncrementalMatcher {
    /// `weights[r]` is row r's exchange weight; `cols` ≤ 64.
    pub fn new(weights: &[f64], cols: usize) -> Self {
        assert!(cols <= 64, "IncrementalMatcher supports at most 64 columns, got {cols}");
        IncrementalMatcher {
            adj: vec![0; weights.len()],
            weight: weights.to_vec(),
            col_row: vec![None; cols],
            row_col: vec![None; weights.len()],
            pending: Vec::new(),
            in_pending: vec![false; weights.len()],
            pruned: vec![false; weights.len()],
            matched: 0,
            cols,
            was_perfect: false,
        }
    }

    /// All columns matched?
    pub fn is_perfect(&self) -> bool {
        self.matched == self.cols
    }

    /// Row currently matched to column `c` (test/diagnostic accessor).
    pub fn holder(&self, c: usize) -> Option<usize> {
        self.col_row[c]
    }

    /// Add one batch of edges that become admissible simultaneously (all
    /// edges of one cap value). Returns `true` when the matching crossed
    /// an objective-relevant boundary: the matching first became perfect,
    /// or a strictly heavier row displaced a matched one while perfect.
    pub fn add_edges(&mut self, batch: &[(usize, usize)]) -> bool {
        for &(r, c) in batch {
            debug_assert!(c < self.cols);
            self.adj[r] |= 1 << c;
            if self.row_col[r].is_none() && !self.in_pending[r] && !self.pruned[r] {
                self.in_pending[r] = true;
                self.pending.push(r);
            }
        }

        let mut event = false;

        // Cardinality phase: grow the matching by plain augmenting paths
        // until no pending row can be matched. New edges on already
        // matched rows can unlock paths for older pending rows, so sweep
        // the whole pending list until a full pass makes no progress.
        while self.matched < self.cols {
            let mut progress = false;
            let mut i = 0;
            while i < self.pending.len() {
                let p = self.pending[i];
                let mut visited = 0u64;
                if self.try_augment(p, &mut visited) {
                    self.matched += 1;
                    self.in_pending[p] = false;
                    self.pending.swap_remove(i);
                    progress = true;
                } else {
                    i += 1;
                }
            }
            if !progress {
                break;
            }
        }
        if self.is_perfect() && !self.was_perfect {
            self.was_perfect = true;
            event = true;
        }

        // Weight phase: with every column held, pending rows can only
        // enter by displacing a strictly lighter reachable row. Repeat
        // until no improving exchange remains — the base is then the
        // maximum-weight one, so an exchange here means the optimal
        // weight strictly increased at exactly this cap.
        if self.is_perfect() {
            loop {
                let mut improved = false;
                let mut i = 0;
                while i < self.pending.len() {
                    let p = self.pending[i];
                    if let Some(q) = self.min_reachable(p) {
                        if self.weight[p] > self.weight[q] {
                            self.exchange(p, q);
                            self.in_pending[p] = false;
                            self.pending.swap_remove(i);
                            if !self.in_pending[q] && !self.pruned[q] {
                                self.in_pending[q] = true;
                                self.pending.push(q);
                            }
                            improved = true;
                            event = true;
                            continue;
                        }
                    }
                    i += 1;
                }
                if !improved {
                    break;
                }
            }
            self.prune_pending();
        }
        event
    }

    /// Standard Kuhn augmenting DFS from `root` over `visited` columns.
    fn try_augment(&mut self, root: usize, visited: &mut u64) -> bool {
        let mut cands = self.adj[root] & !*visited;
        while cands != 0 {
            let c = cands.trailing_zeros() as usize;
            cands &= cands - 1;
            *visited |= 1 << c;
            match self.col_row[c] {
                None => {
                    self.col_row[c] = Some(root);
                    self.row_col[root] = Some(c);
                    return true;
                }
                Some(q) => {
                    if self.try_augment(q, visited) {
                        self.col_row[c] = Some(root);
                        self.row_col[root] = Some(c);
                        return true;
                    }
                }
            }
        }
        false
    }

    /// Minimum-weight matched row reachable from unmatched row `p` via
    /// an alternating path (edge to a column, then that column's holder,
    /// and so on). With every column matched, these are exactly the rows
    /// `q` for which base − q + p is again a base.
    fn min_reachable(&self, p: usize) -> Option<usize> {
        let mut seen = 0u64;
        let mut frontier = self.adj[p];
        let mut best: Option<usize> = None;
        while frontier != 0 {
            seen |= frontier;
            let mut next = 0u64;
            let mut bits = frontier;
            while bits != 0 {
                let c = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                if let Some(q) = self.col_row[c] {
                    if best.is_none_or(|b| self.weight[q] < self.weight[b]) {
                        best = Some(q);
                    }
                    next |= self.adj[q];
                }
            }
            frontier = next & !seen;
        }
        best
    }

    /// Evict `q` and re-match `p`: the column `q` held is reachable from
    /// `p`, so the augmentation is guaranteed to succeed (restored
    /// defensively if it somehow does not).
    fn exchange(&mut self, p: usize, q: usize) {
        let freed = self.row_col[q].expect("exchange target must be matched");
        self.col_row[freed] = None;
        self.row_col[q] = None;
        let mut visited = 0u64;
        if !self.try_augment(p, &mut visited) {
            self.col_row[freed] = Some(q);
            self.row_col[q] = Some(freed);
            debug_assert!(false, "reachable eviction must re-augment");
        }
    }

    /// Drop pending rows that can never displace anyone again: the
    /// minimum matched weight only rises from here on.
    fn prune_pending(&mut self) {
        let min_w = self
            .col_row
            .iter()
            .filter_map(|h| h.map(|q| self.weight[q]))
            .fold(f64::INFINITY, f64::min);
        let mut i = 0;
        while i < self.pending.len() {
            let p = self.pending[i];
            if self.weight[p] <= min_w {
                self.pruned[p] = true;
                self.in_pending[p] = false;
                self.pending.swap_remove(i);
            } else {
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Brute-force optimal assignment for validation.
    fn brute(cost: &[Vec<f64>]) -> f64 {
        let rows = cost.len();
        let cols = cost[0].len();
        let (small, _large, transposed) = if rows <= cols {
            (rows, cols, false)
        } else {
            (cols, rows, true)
        };
        let big = if transposed { rows } else { cols };
        let mut idx: Vec<usize> = (0..big).collect();
        let mut best = f64::INFINITY;
        permute(&mut idx, 0, small, &mut |perm| {
            let mut c = 0.0;
            for (r, &cc) in perm.iter().take(small).enumerate() {
                c += if transposed { cost[cc][r] } else { cost[r][cc] };
            }
            if c < best {
                best = c;
            }
        });
        best
    }

    fn permute(idx: &mut Vec<usize>, k: usize, depth: usize, f: &mut impl FnMut(&[usize])) {
        if k == depth {
            f(idx);
            return;
        }
        for i in k..idx.len() {
            idx.swap(k, i);
            permute(idx, k + 1, depth, f);
            idx.swap(k, i);
        }
    }

    #[test]
    fn square_known() {
        let cost = vec![
            vec![4.0, 1.0, 3.0],
            vec![2.0, 0.0, 5.0],
            vec![3.0, 2.0, 2.0],
        ];
        let (a, c) = hungarian_min(&cost);
        assert_eq!(c, 5.0);
        let mut cols: Vec<_> = a.iter().map(|x| x.unwrap()).collect();
        cols.sort_unstable();
        assert_eq!(cols, vec![0, 1, 2]);
    }

    #[test]
    fn rectangular_rows_lt_cols() {
        let cost = vec![vec![10.0, 1.0, 7.0], vec![3.0, 9.0, 4.0]];
        let (a, c) = hungarian_min(&cost);
        assert_eq!(c, 4.0);
        assert_eq!(a[0], Some(1));
        assert_eq!(a[1], Some(0));
    }

    #[test]
    fn rectangular_rows_gt_cols_leaves_rows_unassigned() {
        // 6 gateways, 3 channels — the paper's shape. Exactly 3 assigned.
        let cost = vec![
            vec![5.0, 5.0, 5.0],
            vec![1.0, 9.0, 9.0],
            vec![9.0, 1.0, 9.0],
            vec![9.0, 9.0, 1.0],
            vec![5.0, 5.0, 5.0],
            vec![5.0, 5.0, 5.0],
        ];
        let (a, c) = hungarian_min(&cost);
        assert_eq!(c, 3.0);
        assert_eq!(a.iter().filter(|x| x.is_some()).count(), 3);
        assert_eq!(a[1], Some(0));
        assert_eq!(a[2], Some(1));
        assert_eq!(a[3], Some(2));
    }

    /// Property test: matches brute force on random instances.
    #[test]
    fn matches_brute_force_random() {
        let mut rng = Rng::new(1234);
        for case in 0..200 {
            let rows = 1 + rng.below(5);
            let cols = 1 + rng.below(5);
            let cost: Vec<Vec<f64>> = (0..rows)
                .map(|_| (0..cols).map(|_| (rng.below(100)) as f64).collect())
                .collect();
            let (a, c) = hungarian_min(&cost);
            let b = brute(&cost);
            assert!(
                (c - b).abs() < 1e-9,
                "case {case}: hungarian {c} != brute {b} for {cost:?} ({a:?})"
            );
            // Assignment must be a partial injection.
            let mut used = vec![false; cols];
            for col in a.iter().flatten() {
                assert!(!used[*col]);
                used[*col] = true;
            }
        }
    }

    /// Eq. 28–29 shape: composite cost Θ with −Q_m for admissible pairs and
    /// a huge Ψ penalty for inadmissible ones. When an admissible perfect
    /// matching of the channels exists, the solver must find one without
    /// paying Ψ, and it must serve the longest queues.
    #[test]
    fn psi_penalty_composite_assignment() {
        const PSI: f64 = 1e15;
        let queues = [10.0, 2.0, 8.0, 1.0, 9.0, 0.5];
        // gw1 admissible only on channel 2; gw3 admissible nowhere.
        let admissible = [
            [true, true, true],
            [false, false, true],
            [true, true, false],
            [false, false, false],
            [true, false, true],
            [true, true, true],
        ];
        let cost: Vec<Vec<f64>> = (0..6)
            .map(|m| {
                (0..3)
                    .map(|j| if admissible[m][j] { -queues[m] } else { PSI })
                    .collect()
            })
            .collect();
        let (assign, total) = hungarian_min(&cost);
        assert!(total < PSI / 2.0, "admissible matching exists but Ψ was paid");
        // Exactly J = 3 rows assigned, channels distinct, admissible only.
        let picks: Vec<(usize, usize)> = assign
            .iter()
            .enumerate()
            .filter_map(|(m, a)| a.map(|j| (m, j)))
            .collect();
        assert_eq!(picks.len(), 3);
        let mut chs: Vec<_> = picks.iter().map(|&(_, j)| j).collect();
        chs.sort_unstable();
        assert_eq!(chs, vec![0, 1, 2]);
        for &(m, j) in &picks {
            assert!(admissible[m][j], "inadmissible pair ({m},{j}) selected");
        }
        // Optimal total is serving the three longest admissible queues:
        // gw0 (10), gw4 (9), gw2 (8) — fits: gw2 on ch1, gw4 on ch2|0, gw0 rest.
        assert!((total - (-27.0)).abs() < 1e-9, "total {total}");
        assert_eq!(assign[3], None, "fully-inadmissible gateway must stay unassigned");
    }

    /// When no admissible perfect matching exists, the minimum cost must
    /// include at least one Ψ — the DDSRA λ-sweep uses `total >= Ψ/2` as
    /// its rejection test.
    #[test]
    fn psi_penalty_reports_no_admissible_matching() {
        const PSI: f64 = 1e15;
        // Channel 1 is inadmissible for every gateway.
        let cost: Vec<Vec<f64>> = (0..4)
            .map(|m| vec![-(m as f64), PSI, -(m as f64)])
            .collect();
        let (_, total) = hungarian_min(&cost);
        assert!(total >= PSI / 2.0);
    }

    #[test]
    fn one_by_one_and_single_column() {
        let (a, c) = hungarian_min(&[vec![3.5]]);
        assert_eq!(a, vec![Some(0)]);
        assert_eq!(c, 3.5);
        // 3 rows, 1 column: only the cheapest row is assigned.
        let (a, c) = hungarian_min(&[vec![5.0], vec![1.0], vec![2.0]]);
        assert_eq!(c, 1.0);
        assert_eq!(a, vec![None, Some(0), None]);
    }

    #[test]
    fn negative_costs_supported() {
        // Queue-composite costs are negative; optimum picks most-negative.
        let cost = vec![vec![-5.0, -1.0], vec![-2.0, -4.0]];
        let (a, c) = hungarian_min(&cost);
        assert_eq!(c, -9.0);
        assert_eq!(a, vec![Some(0), Some(1)]);
    }

    /// Reference for the incremental matcher: per-batch from-scratch
    /// Hungarian over the edges seen so far (−w admissible, Ψ otherwise).
    /// Returns (perfect matching exists, max total weight when it does).
    fn hungarian_reference(adj: &[u64], weights: &[f64], cols: usize) -> (bool, f64) {
        const PSI: f64 = 1e15;
        let cost: Vec<Vec<f64>> = adj
            .iter()
            .enumerate()
            .map(|(r, &mask)| {
                (0..cols)
                    .map(|c| if mask & (1 << c) != 0 { -weights[r] } else { PSI })
                    .collect()
            })
            .collect();
        let (_, total) = hungarian_min(&cost);
        if total >= PSI / 2.0 {
            (false, 0.0)
        } else {
            (true, -total)
        }
    }

    /// The matcher's contract, against brute force: `add_edges` returns
    /// true exactly when a perfect matching first exists or the optimal
    /// perfect-matching weight strictly increases. Integer weights keep
    /// every total exact, so equality comparisons are safe.
    #[test]
    fn incremental_matcher_events_match_hungarian_reference() {
        let mut rng = Rng::new(77);
        for case in 0..300 {
            let rows = 1 + rng.below(9);
            let cols = 1 + rng.below(6);
            let weights: Vec<f64> = (0..rows).map(|_| rng.below(40) as f64).collect();
            let mut m = IncrementalMatcher::new(&weights, cols);
            let mut adj = vec![0u64; rows];
            let (mut was_perfect, mut best_w) = (false, 0.0);
            for _batch in 0..12 {
                let n_edges = 1 + rng.below(3);
                let batch: Vec<(usize, usize)> = (0..n_edges)
                    .map(|_| (rng.below(rows), rng.below(cols)))
                    .collect();
                let event = m.add_edges(&batch);
                for &(r, c) in &batch {
                    adj[r] |= 1 << c;
                }
                let (perfect, w) = hungarian_reference(&adj, &weights, cols);
                let expect = perfect && (!was_perfect || w > best_w);
                assert_eq!(
                    event, expect,
                    "case {case}: event {event} vs expected {expect} \
                     (perfect {perfect}, w {w}, prev {best_w}, adj {adj:?}, weights {weights:?})"
                );
                assert_eq!(m.is_perfect(), perfect, "case {case}");
                if perfect {
                    // The matched base must itself be maximum-weight.
                    let got: f64 = (0..cols).map(|c| weights[m.holder(c).unwrap()]).sum();
                    assert_eq!(got, w, "case {case}: base weight {got} != optimal {w}");
                    was_perfect = true;
                    best_w = w;
                }
            }
        }
    }

    #[test]
    fn incremental_matcher_known_sequence() {
        // 4 rows (weights 10, 2, 8, 5), 2 columns.
        let mut m = IncrementalMatcher::new(&[10.0, 2.0, 8.0, 5.0], 2);
        // Row 1 on col 0: not perfect yet — no event.
        assert!(!m.add_edges(&[(1, 0)]));
        // Row 3 on col 1: perfect for the first time — event.
        assert!(m.add_edges(&[(3, 1)]));
        assert!(m.is_perfect());
        // Row 2 can take col 0 from row 1 (8 > 2) — weight rose, event.
        assert!(m.add_edges(&[(2, 0)]));
        assert_eq!(m.holder(0), Some(2));
        // A lighter row gains an edge: no displacement, no event.
        assert!(!m.add_edges(&[(1, 1)]));
        // Row 0 reaches col 1 only; evicts row 3 (10 > 5) — event. Row 3
        // has no other column, and the displaced chain stops there.
        assert!(m.add_edges(&[(0, 1)]));
        assert_eq!(m.holder(1), Some(0));
        // Duplicate edges change nothing.
        assert!(!m.add_edges(&[(0, 1), (2, 0)]));
    }

    #[test]
    fn incremental_matcher_eviction_cascades_via_alternating_path() {
        // Base {5 on c0, 3 on c1}; row of weight 10 sees only c0. The
        // exchange must evict the reachable minimum (the 5 — the 3 is
        // NOT reachable), and the evicted row must return to pending so
        // a later edge lets it displace the 3.
        let mut m = IncrementalMatcher::new(&[5.0, 3.0, 10.0], 2);
        assert!(m.add_edges(&[(0, 0), (1, 1)]));
        assert!(m.add_edges(&[(2, 0)]));
        assert_eq!(m.holder(0), Some(2));
        // Evicted row 0 (weight 5) later reaches c1: displaces the 3.
        assert!(m.add_edges(&[(0, 1)]));
        assert_eq!(m.holder(1), Some(0));
    }

    #[test]
    fn incremental_matcher_never_perfect_when_columns_unreachable() {
        // Column 1 never gains an edge: no event, ever.
        let mut m = IncrementalMatcher::new(&[4.0, 7.0, 1.0], 2);
        assert!(!m.add_edges(&[(0, 0)]));
        assert!(!m.add_edges(&[(1, 0), (2, 0)]));
        assert!(!m.is_perfect());
    }

    #[test]
    fn large_instance_runs() {
        let mut rng = Rng::new(5);
        let n = 256;
        let cost: Vec<Vec<f64>> =
            (0..n).map(|_| (0..n).map(|_| rng.f64()).collect()).collect();
        let (a, c) = hungarian_min(&cost);
        assert_eq!(a.iter().filter(|x| x.is_some()).count(), n);
        assert!(c >= 0.0 && c < n as f64);
    }
}
