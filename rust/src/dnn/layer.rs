//! Table II: layer-level memory usage and FLOPs for forward + backward
//! propagation, per layer category (convolution / pooling / fully
//! connected).
//!
//! Notation follows the paper: `B_s` batch size, `S_f` precision bytes,
//! conv/pool tensors are `H x W x C` with `i` input, `o` output, `f`
//! filter; FC has input size `S_i`, output size `S_o`.

/// Activation applied after a layer. Executable hyperparameter only: the
/// Table II cost model ignores it (elementwise FLOPs are negligible), but
/// the native layer-graph engine needs it to build the runnable network
/// from the same description the scheduler plans with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Relu,
    /// No activation (the logits head).
    Linear,
}

/// Pooling flavour. Table II costs max and average pooling identically;
/// the executable engine implements max pooling.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolKind {
    Max,
    Avg,
}

/// One DNN layer: the hyper-parameters Table II needs, plus the
/// executable ones (activation, pool flavour) the runtime needs to build
/// the same network it costs.
#[derive(Clone, Debug, PartialEq)]
pub enum Layer {
    /// Convolution with SAME-style geometry (the model zoo fills the
    /// concrete output sizes, so stride/padding are already resolved).
    Conv {
        ci: u64,
        hi: u64,
        wi: u64,
        co: u64,
        ho: u64,
        wo: u64,
        hf: u64,
        wf: u64,
        act: Activation,
    },
    /// Pooling.
    Pool {
        ci: u64,
        hi: u64,
        wi: u64,
        co: u64,
        ho: u64,
        wo: u64,
        kind: PoolKind,
    },
    /// Fully connected.
    Fc { si: u64, so: u64, act: Activation },
}

/// Per-layer cost summary for a given batch size and precision.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LayerCost {
    /// Forward FLOPs for the WHOLE batch (Table II "Forward Propagation").
    pub fwd_flops: f64,
    /// Backward FLOPs for the whole batch (error + gradient calculation).
    pub bwd_flops: f64,
    /// Memory bytes for parameters + intermediate data (weight, forward
    /// output, backward error, gradient rows of Table II).
    pub mem_bytes: f64,
    /// Parameter count (weights only; used for the model size gamma).
    pub params: u64,
}

impl Layer {
    /// Table II applied to this layer.
    pub fn cost(&self, batch: u64, sf_bytes: u64) -> LayerCost {
        let b = batch as f64;
        let sf = sf_bytes as f64;
        match *self {
            Layer::Conv { ci, hi, wi, co, ho, wo, hf, wf, .. } => {
                let (cif, hif, wif) = (ci as f64, hi as f64, wi as f64);
                let (cof, hof, wof) = (co as f64, ho as f64, wo as f64);
                let (hff, wff) = (hf as f64, wf as f64);
                let fwd = 2.0 * b * cif * hff * wff * cof * hof * wof;
                // Error calculation (Table II row 2): full-correlation cost
                // of propagating the error through the filter.
                let err = 2.0 * b * (2.0 * wff + wff * wof - 2.0)
                    * (2.0 * hff + hff * hof - 2.0);
                // Gradient calculation (Table II row 3).
                let grad = 2.0 * b * cif * hff * wff * cof * hof * wof;
                let params = ci * hf * wf * co;
                let mem = sf * (ci * hf * wf * co) as f64      // weight
                    + sf * b * cof * hof * wof                  // forward output
                    + sf * b * cif * hif * wif                  // backward error
                    + sf * (ci * hf * wf * co) as f64; // gradient
                LayerCost { fwd_flops: fwd, bwd_flops: err + grad, mem_bytes: mem, params }
            }
            Layer::Pool { ci, hi, wi, co, ho, wo, .. } => {
                let (cif, hif, wif) = (ci as f64, hi as f64, wi as f64);
                let (cof, hof, wof) = (co as f64, ho as f64, wo as f64);
                let fwd = b * cif * hif * wif;
                let err = b * cif * hif * wif;
                let mem = sf * b * cof * hof * wof + sf * b * cif * hif * wif;
                LayerCost { fwd_flops: fwd, bwd_flops: err, mem_bytes: mem, params: 0 }
            }
            Layer::Fc { si, so, .. } => {
                let (sif, sof) = (si as f64, so as f64);
                let fwd = 2.0 * b * sif * sof;
                let err = 2.0 * b * sif * sof;
                let grad = b * sif * sof;
                let params = si * so;
                let mem = sf * (si * so) as f64  // weight
                    + sf * b * sof               // forward output
                    + sf * b * sif               // backward error
                    + sf * (si * so) as f64; // gradient
                LayerCost { fwd_flops: fwd, bwd_flops: err + grad, mem_bytes: mem, params }
            }
        }
    }

    /// `o_l`: forward FLOPs for ONE sample (paper divides by batch).
    pub fn o(&self) -> f64 {
        self.cost(1, 4).fwd_flops
    }

    /// `o'_l`: backward FLOPs for one sample.
    pub fn o_prime(&self) -> f64 {
        self.cost(1, 4).bwd_flops
    }

    pub fn short_name(&self) -> &'static str {
        match self {
            Layer::Conv { .. } => "conv",
            Layer::Pool { .. } => "pool",
            Layer::Fc { .. } => "fc",
        }
    }

    /// Per-sample input element count when this layer is executed
    /// (H·W·C for spatial layers, S_i for fully connected).
    pub fn in_len(&self) -> usize {
        match *self {
            Layer::Conv { ci, hi, wi, .. } | Layer::Pool { ci, hi, wi, .. } => {
                (ci * hi * wi) as usize
            }
            Layer::Fc { si, .. } => si as usize,
        }
    }

    /// Per-sample output element count when this layer is executed.
    pub fn out_len(&self) -> usize {
        match *self {
            Layer::Conv { co, ho, wo, .. } | Layer::Pool { co, ho, wo, .. } => {
                (co * ho * wo) as usize
            }
            Layer::Fc { so, .. } => so as usize,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_fwd_flops_table2() {
        // 2 * Bs * Ci * Hf * Wf * Co * Ho * Wo
        let l = Layer::Conv { ci: 3, hi: 32, wi: 32, co: 16, ho: 32, wo: 32, hf: 3, wf: 3, act: Activation::Relu };
        let c = l.cost(64, 4);
        assert_eq!(c.fwd_flops, 2.0 * 64.0 * 3.0 * 3.0 * 3.0 * 16.0 * 32.0 * 32.0);
        assert_eq!(c.params, 3 * 3 * 3 * 16);
    }

    #[test]
    fn conv_bwd_is_error_plus_gradient() {
        let l = Layer::Conv { ci: 3, hi: 8, wi: 8, co: 4, ho: 8, wo: 8, hf: 3, wf: 3, act: Activation::Relu };
        let b = 2.0;
        let err = 2.0 * b * (2.0 * 3.0 + 3.0 * 8.0 - 2.0) * (2.0 * 3.0 + 3.0 * 8.0 - 2.0);
        let grad = 2.0 * b * 3.0 * 3.0 * 3.0 * 4.0 * 8.0 * 8.0;
        assert_eq!(l.cost(2, 4).bwd_flops, err + grad);
    }

    #[test]
    fn conv_memory_table2() {
        let l = Layer::Conv { ci: 3, hi: 32, wi: 32, co: 16, ho: 32, wo: 32, hf: 3, wf: 3, act: Activation::Relu };
        let c = l.cost(64, 4);
        let w = 4.0 * (3 * 3 * 3 * 16) as f64;
        let out = 4.0 * 64.0 * 16.0 * 32.0 * 32.0;
        let err = 4.0 * 64.0 * 3.0 * 32.0 * 32.0;
        assert_eq!(c.mem_bytes, w + out + err + w);
    }

    #[test]
    fn pool_costs_table2() {
        let l = Layer::Pool { ci: 16, hi: 32, wi: 32, co: 16, ho: 16, wo: 16, kind: PoolKind::Max };
        let c = l.cost(8, 4);
        assert_eq!(c.fwd_flops, 8.0 * 16.0 * 32.0 * 32.0);
        assert_eq!(c.bwd_flops, 8.0 * 16.0 * 32.0 * 32.0);
        assert_eq!(c.params, 0);
        assert_eq!(
            c.mem_bytes,
            4.0 * 8.0 * 16.0 * 16.0 * 16.0 + 4.0 * 8.0 * 16.0 * 32.0 * 32.0
        );
    }

    #[test]
    fn fc_costs_table2() {
        let l = Layer::Fc { si: 1024, so: 128, act: Activation::Relu };
        let c = l.cost(64, 4);
        assert_eq!(c.fwd_flops, 2.0 * 64.0 * 1024.0 * 128.0);
        assert_eq!(c.bwd_flops, 2.0 * 64.0 * 1024.0 * 128.0 + 64.0 * 1024.0 * 128.0);
        assert_eq!(c.params, 1024 * 128);
    }

    #[test]
    fn executable_element_counts() {
        let conv =
            Layer::Conv { ci: 3, hi: 32, wi: 32, co: 16, ho: 32, wo: 32, hf: 3, wf: 3, act: Activation::Relu };
        assert_eq!(conv.in_len(), 3 * 32 * 32);
        assert_eq!(conv.out_len(), 16 * 32 * 32);
        let pool = Layer::Pool { ci: 16, hi: 32, wi: 32, co: 16, ho: 16, wo: 16, kind: PoolKind::Max };
        assert_eq!(pool.in_len(), 16 * 32 * 32);
        assert_eq!(pool.out_len(), 16 * 16 * 16);
        let fc = Layer::Fc { si: 1024, so: 128, act: Activation::Linear };
        assert_eq!((fc.in_len(), fc.out_len()), (1024, 128));
    }

    #[test]
    fn per_sample_o_scales_linearly_with_batch() {
        let l = Layer::Fc { si: 100, so: 10, act: Activation::Linear };
        assert_eq!(l.o() * 32.0, l.cost(32, 4).fwd_flops);
        assert_eq!(l.o_prime() * 32.0, l.cost(32, 4).bwd_flops);
    }
}
