//! Layer-level DNN cost model — the paper's Table II made executable.
//!
//! DDSRA never sees tensors: it sees per-layer forward/backward FLOPs
//! (`o_l`, `o'_l`) and memory footprints (`g_{n,l}`), computed from the
//! hyper-parameters of each layer exactly as Table II specifies. These
//! numbers drive the latency (Eq. 1), energy (Eq. 2–3) and memory (Eq. 4–5)
//! models and hence every scheduling decision.

pub mod layer;
pub mod models;

pub use layer::{Activation, Layer, LayerCost, PoolKind};
pub use models::ModelSpec;
