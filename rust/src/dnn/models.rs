//! Model zoo for the scheduler's cost model.
//!
//! `vgg11_cifar` is the paper-scale objective DNN (§VII trains VGG-11 on
//! 32x32 datasets); `vgg_mini` / `mlp` mirror the *executable* presets in
//! python/compile/model.py so that, in end-to-end runs, the latency/energy
//! the scheduler simulates corresponds to the network actually trained via
//! the PJRT artifacts.

use super::layer::{Activation, Layer, PoolKind};

/// A DNN as the scheduler sees it: an ordered layer list + derived
/// prefix-sum cost tables. Partition point `l ∈ 0..=L` means the bottom
/// `l` layers train on the device and the top `L-l` on the gateway (C5).
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub layers: Vec<Layer>,
    /// Per-sample forward+backward FLOPs, cumulative over layers 1..=l.
    flops_prefix: Vec<f64>,
    /// Memory bytes per layer for batch=1 (scaled by batch at query time is
    /// wrong for weights — so we keep both weight and activation parts).
    weight_bytes: Vec<f64>,
    act_bytes_per_sample: Vec<f64>,
    /// Total parameter count.
    pub params: u64,
}

impl ModelSpec {
    pub fn new(name: &str, layers: Vec<Layer>) -> Self {
        let mut flops_prefix = Vec::with_capacity(layers.len() + 1);
        flops_prefix.push(0.0);
        let mut weight_bytes = Vec::with_capacity(layers.len());
        let mut act_bytes_per_sample = Vec::with_capacity(layers.len());
        let mut params = 0u64;
        for l in &layers {
            let c1 = l.cost(1, 4);
            flops_prefix.push(flops_prefix.last().unwrap() + c1.fwd_flops + c1.bwd_flops);
            // Split Table II memory into batch-independent (weight+gradient)
            // and per-sample (forward output + backward error) parts.
            let w = 2.0 * 4.0 * c1.params as f64;
            weight_bytes.push(w);
            act_bytes_per_sample.push(c1.mem_bytes - w);
            params += c1.params;
        }
        ModelSpec {
            name: name.to_string(),
            layers,
            flops_prefix,
            weight_bytes,
            act_bytes_per_sample,
            params,
        }
    }

    /// Number of layers `L`.
    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    /// Model size gamma in BITS (f32 parameters) — Eq. 6–8 transmit this.
    pub fn gamma_bits(&self) -> f64 {
        self.params as f64 * 32.0
    }

    /// Per-sample fwd+bwd FLOPs of the bottom `l` layers: Σ_{i<=l}(o_i+o'_i).
    pub fn bottom_flops(&self, l: usize) -> f64 {
        self.flops_prefix[l]
    }

    /// Per-sample fwd+bwd FLOPs of the top `L-l` layers.
    pub fn top_flops(&self, l: usize) -> f64 {
        self.flops_prefix[self.depth()] - self.flops_prefix[l]
    }

    /// Memory bytes `G^D` of the bottom `l` layers at training batch `b`
    /// (Eq. 4 with Table II entries).
    pub fn bottom_mem(&self, l: usize, batch: u64) -> f64 {
        (0..l)
            .map(|i| self.weight_bytes[i] + self.act_bytes_per_sample[i] * batch as f64)
            .sum()
    }

    /// Memory bytes `G^G` of the top `L-l` layers at training batch `b`
    /// (Eq. 5).
    pub fn top_mem(&self, l: usize, batch: u64) -> f64 {
        (l..self.depth())
            .map(|i| self.weight_bytes[i] + self.act_bytes_per_sample[i] * batch as f64)
            .sum()
    }

    /// Per-sample input tensor shape when this model is executed
    /// (`[H, W, C]` channels-last for conv-front models, `[S_i]` for flat
    /// ones) — what the native layer-graph engine and the artifact ABI
    /// both consume.
    pub fn exec_input_shape(&self) -> Vec<usize> {
        match self.layers.first() {
            Some(&Layer::Conv { ci, hi, wi, .. }) | Some(&Layer::Pool { ci, hi, wi, .. }) => {
                vec![hi as usize, wi as usize, ci as usize]
            }
            Some(&Layer::Fc { si, .. }) => vec![si as usize],
            None => Vec::new(),
        }
    }
}

fn conv(c_in: u64, c_out: u64, hw: u64) -> Layer {
    Layer::Conv {
        ci: c_in,
        hi: hw,
        wi: hw,
        co: c_out,
        ho: hw,
        wo: hw,
        hf: 3,
        wf: 3,
        act: Activation::Relu,
    }
}

fn pool(c: u64, hw_in: u64) -> Layer {
    Layer::Pool {
        ci: c,
        hi: hw_in,
        wi: hw_in,
        co: c,
        ho: hw_in / 2,
        wo: hw_in / 2,
        kind: PoolKind::Max,
    }
}

fn fc(si: u64, so: u64, act: Activation) -> Layer {
    Layer::Fc { si, so, act }
}

/// VGG-11 for 32x32 inputs (the paper's objective DNN): 8 conv + 5 pool +
/// 3 FC = 16 partitionable layers, ~28M parameters.
pub fn vgg11_cifar() -> ModelSpec {
    ModelSpec::new(
        "vgg11",
        vec![
            conv(3, 64, 32),
            pool(64, 32),
            conv(64, 128, 16),
            pool(128, 16),
            conv(128, 256, 8),
            conv(256, 256, 8),
            pool(256, 8),
            conv(256, 512, 4),
            conv(512, 512, 4),
            pool(512, 4),
            conv(512, 512, 2),
            conv(512, 512, 2),
            pool(512, 2),
            fc(512, 4096, Activation::Relu),
            fc(4096, 4096, Activation::Relu),
            fc(4096, 10, Activation::Linear),
        ],
    )
}

/// VGG-mini — the executable `cnn` preset (python/compile/model.py).
pub fn vgg_mini() -> ModelSpec {
    ModelSpec::new(
        "cnn",
        vec![
            conv(3, 16, 32),
            pool(16, 32),
            conv(16, 32, 16),
            pool(32, 16),
            conv(32, 64, 8),
            pool(64, 8),
            fc(1024, 128, Activation::Relu),
            fc(128, 10, Activation::Linear),
        ],
    )
}

/// MLP — the executable `mlp` preset.
pub fn mlp() -> ModelSpec {
    ModelSpec::new(
        "mlp",
        vec![fc(3072, 64, Activation::Relu), fc(64, 10, Activation::Linear)],
    )
}

/// Look up a model spec by preset name.
pub fn by_name(name: &str) -> Option<ModelSpec> {
    match name {
        "vgg11" => Some(vgg11_cifar()),
        "cnn" => Some(vgg_mini()),
        "mlp" => Some(mlp()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg11_param_count_plausible() {
        let m = vgg11_cifar();
        // conv ~9.2M + fc ~19M
        assert!(m.params > 27_000_000 && m.params < 30_000_000, "{}", m.params);
        assert_eq!(m.depth(), 16);
    }

    #[test]
    fn cnn_matches_python_preset_params() {
        // python param_count('cnn'): conv 432+16? weights only here.
        let m = vgg_mini();
        let expect = 3 * 3 * 3 * 16 + 3 * 3 * 16 * 32 + 3 * 3 * 32 * 64
            + 1024 * 128 + 128 * 10;
        assert_eq!(m.params, expect as u64);
    }

    #[test]
    fn prefix_sums_consistent() {
        let m = vgg11_cifar();
        let total = m.bottom_flops(m.depth());
        for l in 0..=m.depth() {
            let (b, t) = (m.bottom_flops(l), m.top_flops(l));
            assert!((b + t - total).abs() < 1e-6 * total);
            assert!(b >= 0.0 && t >= 0.0);
        }
        assert_eq!(m.bottom_flops(0), 0.0);
        assert_eq!(m.top_flops(m.depth()), 0.0);
    }

    #[test]
    fn memory_monotone_in_partition_point() {
        let m = vgg11_cifar();
        for l in 1..=m.depth() {
            assert!(m.bottom_mem(l, 100) >= m.bottom_mem(l - 1, 100));
            assert!(m.top_mem(l, 100) <= m.top_mem(l - 1, 100));
        }
    }

    #[test]
    fn memory_scales_with_batch_for_activations_only() {
        let m = vgg_mini();
        let l = m.depth();
        let small = m.bottom_mem(l, 1);
        let big = m.bottom_mem(l, 101);
        // activations grow linearly, weights constant
        assert!(big > small);
        let weights = 2.0 * 4.0 * m.params as f64;
        assert!((big - small) > 0.0 && small > weights);
    }

    #[test]
    fn vgg11_device_memory_fits_2gb_at_small_partition() {
        // Sanity of §VII-A numbers: a 2 GB device can hold the first layers
        // at batch 100 but not the whole network's activations.
        let m = vgg11_cifar();
        assert!(m.bottom_mem(2, 100) < 2.0e9);
    }

    #[test]
    fn gamma_bits_is_32x_params() {
        let m = mlp();
        assert_eq!(m.gamma_bits(), m.params as f64 * 32.0);
    }

    #[test]
    fn executable_presets_have_relu_bodies_and_linear_heads() {
        for m in [vgg11_cifar(), vgg_mini(), mlp()] {
            let fcs: Vec<&Layer> =
                m.layers.iter().filter(|l| matches!(l, Layer::Fc { .. })).collect();
            assert!(!fcs.is_empty(), "{}", m.name);
            // Every FC except the last is ReLU; the head is linear.
            for (i, l) in fcs.iter().enumerate() {
                let Layer::Fc { act, .. } = l else { unreachable!() };
                let expect =
                    if i + 1 == fcs.len() { Activation::Linear } else { Activation::Relu };
                assert_eq!(*act, expect, "{} fc {i}", m.name);
            }
            for l in &m.layers {
                match l {
                    Layer::Conv { act, .. } => assert_eq!(*act, Activation::Relu),
                    Layer::Pool { kind, .. } => assert_eq!(*kind, PoolKind::Max),
                    Layer::Fc { .. } => {}
                }
            }
        }
    }

    #[test]
    fn exec_input_shapes() {
        assert_eq!(vgg_mini().exec_input_shape(), vec![32, 32, 3]);
        assert_eq!(vgg11_cifar().exec_input_shape(), vec![32, 32, 3]);
        assert_eq!(mlp().exec_input_shape(), vec![3072]);
    }
}
