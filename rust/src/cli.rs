//! Tiny CLI argument parser (no clap offline): subcommand + `--key value`
//! flags + repeated `--set cfg_key=value` config overrides, plus
//! per-subcommand unknown-flag rejection with "did you mean"
//! suggestions (a typo like `--eval-evry 2` fails loudly instead of
//! silently running with the default).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::config::SimConfig;

/// Edit distance for the "did you mean" suggestions (full Levenshtein —
/// flag names are short, so the O(|a|·|b|) table is trivial).
fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for i in 1..=a.len() {
        let mut cur = Vec::with_capacity(b.len() + 1);
        cur.push(i);
        for j in 1..=b.len() {
            let sub = prev[j - 1] + usize::from(a[i - 1] != b[j - 1]);
            cur.push(sub.min(prev[j] + 1).min(cur[j - 1] + 1));
        }
        prev = cur;
    }
    prev[b.len()]
}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, Vec<String>>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Self> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(cmd) = it.peek() {
            if !cmd.starts_with("--") {
                args.command = it.next().unwrap().clone();
            }
        }
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected positional argument {a:?}");
            };
            let val = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                _ => "true".to_string(),
            };
            args.flags.entry(key.to_string()).or_default().push(val);
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, key: &str) -> &[String] {
        self.flags.get(key).map_or(&[], |v| v.as_slice())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn parse_num<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Reject any parsed flag not in `allowed`, suggesting the nearest
    /// known flags ("did you mean") and listing the full menu. Callers
    /// pass the union of common and subcommand-specific flags.
    pub fn expect_known(&self, allowed: &[&str]) -> Result<()> {
        for key in self.flags.keys() {
            if allowed.contains(&key.as_str()) {
                continue;
            }
            let mut near: Vec<(usize, &str)> = allowed
                .iter()
                .map(|&cand| (levenshtein(key, cand), cand))
                .filter(|&(d, _)| d <= 3)
                .collect();
            near.sort_unstable();
            let suggestion = if near.is_empty() {
                String::new()
            } else {
                let menu: Vec<String> =
                    near.iter().take(3).map(|(_, c)| format!("--{c}")).collect();
                format!(" — did you mean {}?", menu.join(" or "))
            };
            bail!(
                "unknown flag --{key}{suggestion}\n  known flags here: {}",
                allowed.iter().map(|f| format!("--{f}")).collect::<Vec<_>>().join(" ")
            );
        }
        Ok(())
    }

    /// Build a SimConfig: optional `--config file`, then `--scenario name`
    /// (a named scale preset, applied BEFORE the overrides so individual
    /// knobs can be tuned on top), then `--set k=v` overrides, then
    /// well-known direct flags (--rounds, --v, --seed, ...).
    pub fn sim_config(&self) -> Result<SimConfig> {
        let mut cfg = match self.get("config") {
            Some(path) => SimConfig::from_file(std::path::Path::new(path))?,
            None => SimConfig::default(),
        };
        if let Some(name) = self.get("scenario") {
            cfg.apply_scenario(name)?;
        }
        for kv in self.get_all("set") {
            let Some((k, v)) = kv.split_once('=') else {
                bail!("--set expects key=value, got {kv:?}");
            };
            cfg.set(k.trim(), v.trim())?;
        }
        if let Some(r) = self.parse_num::<usize>("rounds")? {
            cfg.rounds = r;
        }
        if let Some(v) = self.parse_num::<f64>("v")? {
            cfg.lyapunov_v = v;
        }
        if let Some(s) = self.parse_num::<u64>("seed")? {
            cfg.seed = s;
        }
        if let Some(d) = self.get("dataset") {
            cfg.dataset = d.to_string();
        }
        if let Some(p) = self.get("preset") {
            cfg.exec_model = p.to_string();
        }
        if let Some(c) = self.get("cost-model") {
            cfg.cost_model = c.to_string();
        }
        if let Some(k) = self.get("kernel") {
            cfg.kernel = k.parse()?;
        }
        if let Some(s) = self.get("sched-path") {
            cfg.sched_path = s.parse()?;
        }
        if let Some(a) = self.get("aggregation") {
            cfg.aggregation = a.parse()?;
        }
        if let Some(t) = self.get("transport") {
            cfg.transport = t.parse()?;
        }
        if let Some(a) = self.get("gateway-addr") {
            cfg.gateway_addr = a.to_string();
        }
        if self.has("execute-partition") {
            cfg.execute_partition = true;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = Args::parse(&sv(&["train", "--rounds", "10", "--verbose"])).unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get("rounds"), Some("10"));
        assert_eq!(a.get("verbose"), Some("true"));
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn set_overrides_config() {
        let a = Args::parse(&sv(&["train", "--set", "rounds=7", "--set", "lr=0.1"])).unwrap();
        let cfg = a.sim_config().unwrap();
        assert_eq!(cfg.rounds, 7);
        assert_eq!(cfg.lr, 0.1);
    }

    #[test]
    fn direct_flags_override() {
        let a = Args::parse(&sv(&["train", "--v", "1000", "--dataset", "cifar"])).unwrap();
        let cfg = a.sim_config().unwrap();
        assert_eq!(cfg.lyapunov_v, 1000.0);
        assert_eq!(cfg.dataset, "cifar");
    }

    #[test]
    fn execute_partition_flag_flips_the_config() {
        let a = Args::parse(&sv(&[
            "train",
            "--execute-partition",
            "--preset",
            "mlp",
            "--cost-model",
            "mlp",
        ]))
        .unwrap();
        let cfg = a.sim_config().unwrap();
        assert!(cfg.execute_partition);
        // Mismatched cost/exec models are rejected at validation.
        let bad = Args::parse(&sv(&["train", "--execute-partition"])).unwrap();
        assert!(bad.sim_config().is_err());
    }

    #[test]
    fn scenario_scales_then_overrides_apply_on_top() {
        let a = Args::parse(&sv(&["train", "--scenario", "plant"])).unwrap();
        let cfg = a.sim_config().unwrap();
        assert_eq!((cfg.num_devices, cfg.num_gateways), (240, 24));
        // --set lands after the scenario, tuning a single knob on top.
        let b = Args::parse(&sv(&[
            "train",
            "--scenario",
            "plant",
            "--set",
            "num_devices=480",
        ]))
        .unwrap();
        assert_eq!(b.sim_config().unwrap().num_devices, 480);
        let bad = Args::parse(&sv(&["train", "--scenario", "galaxy"])).unwrap();
        assert!(bad.sim_config().is_err());
    }

    #[test]
    fn fault_knobs_flow_through_scenario_and_set() {
        // An adversity preset arms the fault block from the CLI...
        let a = Args::parse(&sv(&["train", "--scenario", "flaky-plant"])).unwrap();
        let cfg = a.sim_config().unwrap();
        assert_eq!((cfg.num_devices, cfg.num_gateways), (240, 24));
        assert_eq!(cfg.fault.dropout_prob, 0.10);
        assert!(!cfg.fault.is_benign());
        // ...and --set tunes (or disarms) individual fault.* keys on top.
        let b = Args::parse(&sv(&[
            "train",
            "--scenario",
            "flaky-plant",
            "--set",
            "fault.dropout_prob=0",
            "--set",
            "fault.straggler_prob=0.5",
        ]))
        .unwrap();
        let cfg = b.sim_config().unwrap();
        assert_eq!(cfg.fault.dropout_prob, 0.0);
        assert_eq!(cfg.fault.straggler_prob, 0.5);
        let plain = Args::parse(&sv(&["train", "--set", "fault.dropout_prob=0.1"])).unwrap();
        assert_eq!(plain.sim_config().unwrap().fault.dropout_prob, 0.1);
    }

    #[test]
    fn kernel_flag_and_set_key_flow_through() {
        use crate::runtime::KernelPath;
        let a = Args::parse(&sv(&["train", "--kernel", "scalar"])).unwrap();
        assert_eq!(a.sim_config().unwrap().kernel, KernelPath::Scalar);
        let b = Args::parse(&sv(&["train", "--set", "kernel=scalar"])).unwrap();
        assert_eq!(b.sim_config().unwrap().kernel, KernelPath::Scalar);
        // The direct flag lands after --set, like every other direct flag.
        let c = Args::parse(&sv(&[
            "train",
            "--set",
            "kernel=scalar",
            "--kernel",
            "vectorized",
        ]))
        .unwrap();
        assert_eq!(c.sim_config().unwrap().kernel, KernelPath::Vectorized);
        // An unknown path name is a loud parse error, not a default.
        let bad = Args::parse(&sv(&["train", "--kernel", "avx512"])).unwrap();
        assert!(bad.sim_config().is_err());
    }

    #[test]
    fn sched_path_flag_and_set_key_flow_through() {
        use crate::sched::SchedPath;
        let a = Args::parse(&sv(&["train", "--sched-path", "sweep"])).unwrap();
        assert_eq!(a.sim_config().unwrap().sched_path, SchedPath::Sweep);
        let b = Args::parse(&sv(&["train", "--set", "sched_path=sweep"])).unwrap();
        assert_eq!(b.sim_config().unwrap().sched_path, SchedPath::Sweep);
        // The direct flag lands after --set, like every other direct flag.
        let c = Args::parse(&sv(&[
            "train",
            "--set",
            "sched_path=sweep",
            "--sched-path",
            "incremental",
        ]))
        .unwrap();
        assert_eq!(c.sim_config().unwrap().sched_path, SchedPath::Incremental);
        // An unknown path name is a loud parse error, not a default.
        let bad = Args::parse(&sv(&["train", "--sched-path", "hungarian"])).unwrap();
        assert!(bad.sim_config().is_err());
    }

    #[test]
    fn aggregation_flag_and_set_key_flow_through() {
        use crate::config::Aggregation;
        let a = Args::parse(&sv(&["train", "--aggregation", "hierarchical"])).unwrap();
        assert_eq!(a.sim_config().unwrap().aggregation, Aggregation::Hierarchical);
        let b = Args::parse(&sv(&["train", "--set", "aggregation=hierarchical"])).unwrap();
        assert_eq!(b.sim_config().unwrap().aggregation, Aggregation::Hierarchical);
        // The direct flag lands after --set, like every other direct flag.
        let c = Args::parse(&sv(&[
            "train",
            "--set",
            "aggregation=hierarchical",
            "--aggregation",
            "flat",
        ]))
        .unwrap();
        assert_eq!(c.sim_config().unwrap().aggregation, Aggregation::Flat);
        let bad = Args::parse(&sv(&["train", "--aggregation", "pyramidal"])).unwrap();
        assert!(bad.sim_config().is_err());
    }

    #[test]
    fn transport_flag_and_set_key_flow_through() {
        use crate::config::Transport;
        // tcp needs an executed partition with matching models to validate.
        let a = Args::parse(&sv(&[
            "train",
            "--transport",
            "tcp",
            "--gateway-addr",
            "127.0.0.1:9901",
            "--execute-partition",
            "--preset",
            "mlp",
            "--cost-model",
            "mlp",
        ]))
        .unwrap();
        let cfg = a.sim_config().unwrap();
        assert_eq!(cfg.transport, Transport::Tcp);
        assert_eq!(cfg.gateway_addr, "127.0.0.1:9901");
        let b = Args::parse(&sv(&[
            "train",
            "--set",
            "transport=tcp",
            "--set",
            "execute_partition=1",
            "--set",
            "cost_model=mlp",
            "--set",
            "wire_timeout_ms=750",
        ]))
        .unwrap();
        let cfg = b.sim_config().unwrap();
        assert_eq!(cfg.transport, Transport::Tcp);
        assert_eq!(cfg.wire_timeout_ms, 750);
        // tcp without --execute-partition is rejected at validation...
        let bad = Args::parse(&sv(&["train", "--transport", "tcp"])).unwrap();
        assert!(bad.sim_config().is_err());
        // ...and an unknown transport is a loud parse error.
        let bad = Args::parse(&sv(&["train", "--transport", "udp"])).unwrap();
        assert!(bad.sim_config().is_err());
    }

    #[test]
    fn rejects_positional_after_flags() {
        assert!(Args::parse(&sv(&["train", "oops"])).is_err());
        assert!(Args::parse(&sv(&["train", "--set", "nokey"])).unwrap().sim_config().is_err());
    }

    #[test]
    fn levenshtein_basics() {
        assert_eq!(levenshtein("", ""), 0);
        assert_eq!(levenshtein("abc", "abc"), 0);
        assert_eq!(levenshtein("abc", ""), 3);
        assert_eq!(levenshtein("eval-evry", "eval-every"), 1);
        assert_eq!(levenshtein("kitten", "sitting"), 3);
    }

    #[test]
    fn unknown_flags_are_rejected_with_suggestions() {
        let allowed = &["rounds", "eval-every", "scheme", "out"];
        // The motivating bug: a typo'd flag used to be silently ignored.
        let a = Args::parse(&sv(&["train", "--eval-evry", "2"])).unwrap();
        let err = a.expect_known(allowed).unwrap_err().to_string();
        assert!(err.contains("unknown flag --eval-evry"), "{err}");
        assert!(err.contains("did you mean --eval-every"), "{err}");
        assert!(err.contains("--scheme"), "list all known flags: {err}");

        // Nothing near: no suggestion, but the menu still prints.
        let b = Args::parse(&sv(&["train", "--zzzzzzzzzz", "1"])).unwrap();
        let err = b.expect_known(allowed).unwrap_err().to_string();
        assert!(err.contains("unknown flag --zzzzzzzzzz"), "{err}");
        assert!(!err.contains("did you mean"), "{err}");

        // All-known parses clean.
        let c = Args::parse(&sv(&["train", "--rounds", "5", "--out", "x.csv"])).unwrap();
        assert!(c.expect_known(allowed).is_ok());
    }
}
