//! Two-tier IIoT topology (§III-A): N end devices deployed across M shop
//! floors, one edge gateway per floor, a base station on top.
//!
//! The deployment matrix `a_{n,m}` is realised as `Device::gateway` plus
//! the per-gateway member lists — both views the paper uses.
//!
//! On top of the paper's two tiers sits an optional **edge-cluster
//! layer** (`Topology::clusters`): gateways are grouped into
//! `cfg.num_clusters` contiguous clusters purely arithmetically — the
//! partition consumes NO random draws, so adding clusters never shifts
//! any existing stream and a `num_clusters = 1` topology is byte-for-byte
//! the old one. The hierarchical aggregation path (`fl::hierarchy`) folds
//! gateway summaries per cluster and cluster summaries at the cloud.

use crate::config::SimConfig;
use crate::rng::Rng;

/// Static attributes of one end device (drawn once per experiment).
#[derive(Clone, Debug)]
pub struct Device {
    pub id: usize,
    /// Shop floor / gateway index m with a_{n,m} = 1.
    pub gateway: usize,
    /// Local dataset size D_n.
    pub dataset_size: usize,
    /// Training batch size \tilde{D}_n = ceil(alpha * D_n).
    pub train_batch: usize,
    /// CPU frequency f_n^D (Hz) — fixed per the paper (devices do not DVFS;
    /// only the gateway frequency f^G_{m,n} is optimized).
    pub freq: f64,
    /// FLOPs per clock cycle phi_n^D.
    pub flops_per_cycle: f64,
    /// Effective switched capacitance v_n^D.
    pub kappa: f64,
    /// Memory size G_n^{D,max} bytes.
    pub mem: f64,
    /// Energy-arrival cap E_n^{D,max} (J); arrivals ~ U[0, cap] per round.
    pub energy_max: f64,
}

impl Device {
    /// THE FedAvg weight of this device: D̃_n = ceil(alpha · D_n), the
    /// per-iteration training batch (§III-A step 3 / Eq. 7). Every
    /// `WeightedAccum` feed — phase-5 aggregation, the centralized-GD
    /// shadow, and the §IV gradient probes — weights by this one
    /// definition, so the realized averages match the paper's D̃_n
    /// weighting everywhere (not `dataset_size`, which only D̃_n is
    /// derived from).
    pub fn fedavg_weight(&self) -> f64 {
        self.train_batch as f64
    }
}

/// Static attributes of one edge gateway.
#[derive(Clone, Debug)]
pub struct Gateway {
    pub id: usize,
    /// Devices on this shop floor (indices into `Topology::devices`).
    pub members: Vec<usize>,
    /// Distance to the BS d_m (m).
    pub distance: f64,
    pub freq_max: f64,
    pub freq_min: f64,
    pub flops_per_cycle: f64,
    pub kappa: f64,
    pub mem: f64,
    pub energy_max: f64,
    pub power_max: f64,
}

/// One edge cluster: a contiguous run of gateway indices whose partial
/// aggregates are folded together before moving up to the cloud.
#[derive(Clone, Debug)]
pub struct Cluster {
    pub id: usize,
    /// Gateways in this cluster (ascending indices into
    /// `Topology::gateways`; contiguous by construction).
    pub gateways: Vec<usize>,
}

/// The full deployment: two paper tiers plus the edge-cluster layer.
#[derive(Clone, Debug)]
pub struct Topology {
    pub devices: Vec<Device>,
    pub gateways: Vec<Gateway>,
    /// Edge clusters over the gateways; always non-empty (a single
    /// cluster when `num_clusters = 1`, the default).
    pub clusters: Vec<Cluster>,
}

impl Topology {
    /// Draw a deployment from the config's distributions (§VII-A).
    pub fn generate(cfg: &SimConfig, rng: &mut Rng) -> Self {
        let per = cfg.devices_per_gateway();
        let mut devices = Vec::with_capacity(cfg.num_devices);
        let mut gateways = Vec::with_capacity(cfg.num_gateways);
        for m in 0..cfg.num_gateways {
            let members = (0..per).map(|i| m * per + i).collect::<Vec<_>>();
            gateways.push(Gateway {
                id: m,
                members: members.clone(),
                distance: rng.uniform(cfg.gw_dist_min, cfg.gw_dist_max),
                freq_max: cfg.gw_freq_max,
                freq_min: cfg.gw_freq_min,
                flops_per_cycle: cfg.gw_flops_per_cycle,
                kappa: cfg.gw_kappa,
                mem: cfg.gw_mem,
                energy_max: cfg.gw_energy_max,
                power_max: cfg.gw_power_max,
            });
            for n in members {
                let d = cfg.dataset_min
                    + rng.below(cfg.dataset_max - cfg.dataset_min + 1);
                devices.push(Device {
                    id: n,
                    gateway: m,
                    dataset_size: d,
                    train_batch: ((cfg.sample_ratio * d as f64).ceil() as usize).max(1),
                    freq: rng.uniform(cfg.device_freq_min, cfg.device_freq_max),
                    flops_per_cycle: cfg.device_flops_per_cycle,
                    kappa: cfg.device_kappa,
                    mem: cfg.device_mem,
                    energy_max: cfg.device_energy_max,
                });
            }
        }
        // The cluster layer is derived arithmetically (balanced contiguous
        // partition), never drawn: the RNG state after `generate` is
        // independent of `num_clusters`, so every downstream stream keeps
        // its bytes.
        let clusters = Self::partition_clusters(cfg.num_gateways, cfg.num_clusters);
        Topology { devices, gateways, clusters }
    }

    /// Balanced contiguous partition of `m` gateways into `c` clusters:
    /// cluster `k` owns gateways `[k*m/c, (k+1)*m/c)`. Draw-free and
    /// deterministic in `(m, c)` alone.
    fn partition_clusters(m: usize, c: usize) -> Vec<Cluster> {
        let c = c.clamp(1, m.max(1));
        (0..c)
            .map(|k| Cluster { id: k, gateways: (k * m / c..(k + 1) * m / c).collect() })
            .collect()
    }

    /// Structural invariants the round engine divides by: every gateway
    /// owns at least one device (an empty shop floor would turn the
    /// per-floor loss/FedAvg denominators into NaN), every member list
    /// points back at its gateway, and every device is deployed exactly
    /// once. `Experiment` construction runs this once up front, so the
    /// round loop never re-checks.
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.devices.is_empty() || self.gateways.is_empty() {
            anyhow::bail!("topology must contain at least one device and one gateway");
        }
        let mut deployed = vec![false; self.devices.len()];
        for g in &self.gateways {
            if g.members.is_empty() {
                anyhow::bail!(
                    "gateway {} has no member devices (empty shop floor): \
                     FedAvg and the per-floor loss are undefined there",
                    g.id
                );
            }
            for &n in &g.members {
                if n >= self.devices.len() {
                    anyhow::bail!("gateway {} lists unknown device {n}", g.id);
                }
                let dev = &self.devices[n];
                if dev.gateway != g.id {
                    anyhow::bail!(
                        "device {n} is deployed on gateway {} but listed by gateway {}",
                        dev.gateway,
                        g.id
                    );
                }
                if deployed[n] {
                    anyhow::bail!("device {n} is listed by two gateways");
                }
                deployed[n] = true;
            }
        }
        if let Some(n) = deployed.iter().position(|&d| !d) {
            anyhow::bail!("device {n} belongs to no gateway");
        }
        // Cluster layer: every gateway in exactly one cluster, clusters
        // non-empty and in ascending gateway order — the fixed fold order
        // the hierarchical aggregation's byte-determinism leans on.
        if self.clusters.is_empty() {
            anyhow::bail!("topology must contain at least one edge cluster");
        }
        let mut next_gateway = 0usize;
        for (k, c) in self.clusters.iter().enumerate() {
            if c.id != k {
                anyhow::bail!("cluster ids must be sequential (cluster {k} has id {})", c.id);
            }
            if c.gateways.is_empty() {
                anyhow::bail!("cluster {k} has no member gateways");
            }
            for &m in &c.gateways {
                if m != next_gateway {
                    anyhow::bail!(
                        "cluster layer must cover gateways contiguously in ascending \
                         order (cluster {k} lists gateway {m}, expected {next_gateway})"
                    );
                }
                next_gateway += 1;
            }
        }
        if next_gateway != self.gateways.len() {
            anyhow::bail!(
                "cluster layer covers {next_gateway} of {} gateways",
                self.gateways.len()
            );
        }
        Ok(())
    }

    pub fn num_devices(&self) -> usize {
        self.devices.len()
    }

    pub fn num_gateways(&self) -> usize {
        self.gateways.len()
    }

    /// a_{n,m} as a predicate.
    pub fn deployed(&self, n: usize, m: usize) -> bool {
        self.devices[n].gateway == m
    }

    /// Total training-data weight of a shop floor: D_m = Σ_n a_{n,m} D̃_n.
    pub fn floor_batch_weight(&self, m: usize) -> f64 {
        self.gateways[m]
            .members
            .iter()
            .map(|&n| self.devices[n].fedavg_weight())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        let cfg = SimConfig::default();
        Topology::generate(&cfg, &mut Rng::new(1))
    }

    #[test]
    fn deployment_matrix_rows_sum_to_one() {
        let t = topo();
        // every device deployed with exactly one gateway
        for d in &t.devices {
            assert_eq!(
                (0..t.num_gateways()).filter(|&m| t.deployed(d.id, m)).count(),
                1
            );
        }
    }

    #[test]
    fn paper_shape_6_gateways_2_devices_each() {
        let t = topo();
        assert_eq!(t.num_gateways(), 6);
        assert_eq!(t.num_devices(), 12);
        for g in &t.gateways {
            assert_eq!(g.members.len(), 2);
            for &n in &g.members {
                assert_eq!(t.devices[n].gateway, g.id);
            }
        }
    }

    #[test]
    fn attribute_ranges_match_config() {
        let cfg = SimConfig::default();
        let t = topo();
        for d in &t.devices {
            assert!(d.dataset_size >= cfg.dataset_min && d.dataset_size <= cfg.dataset_max);
            assert!(d.freq >= cfg.device_freq_min && d.freq <= cfg.device_freq_max);
            assert_eq!(
                d.train_batch,
                ((cfg.sample_ratio * d.dataset_size as f64).ceil() as usize).max(1)
            );
            // The one FedAvg weight definition: D̃_n, never D_n.
            assert_eq!(d.fedavg_weight(), d.train_batch as f64);
        }
        for g in &t.gateways {
            assert!(g.distance >= cfg.gw_dist_min && g.distance <= cfg.gw_dist_max);
        }
    }

    #[test]
    fn validate_accepts_generated_and_rejects_broken_topologies() {
        let t = topo();
        t.validate().unwrap();

        // An emptied shop floor is caught.
        let mut empty = topo();
        empty.gateways[0].members.clear();
        let err = empty.validate().unwrap_err().to_string();
        assert!(err.contains("empty shop floor"), "{err}");

        // A member list pointing at a foreign device is caught.
        let mut cross = topo();
        let stolen = cross.gateways[1].members[0];
        cross.gateways[0].members.push(stolen);
        assert!(cross.validate().is_err());

        // Scales: a hundreds-of-devices generation still validates.
        let mut cfg = SimConfig::default();
        cfg.num_gateways = 24;
        cfg.num_devices = 240;
        let big = Topology::generate(&cfg, &mut Rng::new(5));
        assert_eq!(big.num_devices(), 240);
        assert_eq!(big.num_gateways(), 24);
        big.validate().unwrap();
    }

    #[test]
    fn cluster_layer_partitions_gateways_contiguously_and_draw_free() {
        // num_clusters = 1 (default): one cluster owning every gateway.
        let t = topo();
        assert_eq!(t.clusters.len(), 1);
        assert_eq!(t.clusters[0].gateways, (0..6).collect::<Vec<_>>());

        // A non-dividing partition stays balanced (sizes differ by <= 1)
        // and contiguous.
        let mut cfg = SimConfig::default();
        cfg.num_clusters = 4;
        let t4 = Topology::generate(&cfg, &mut Rng::new(1));
        assert_eq!(t4.clusters.len(), 4);
        let sizes: Vec<usize> = t4.clusters.iter().map(|c| c.gateways.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 6);
        assert!(sizes.iter().all(|&s| s == 1 || s == 2), "{sizes:?}");
        t4.validate().unwrap();

        // Draw-free: the device/gateway draws are byte-identical no
        // matter how many clusters partition them.
        for (a, b) in t.devices.iter().zip(&t4.devices) {
            assert_eq!(a.dataset_size, b.dataset_size);
            assert_eq!(a.freq.to_bits(), b.freq.to_bits());
        }
        for (a, b) in t.gateways.iter().zip(&t4.gateways) {
            assert_eq!(a.distance.to_bits(), b.distance.to_bits());
        }
    }

    #[test]
    fn validate_rejects_broken_cluster_layers() {
        let mut gap = topo();
        gap.clusters[0].gateways.remove(2);
        let err = gap.validate().unwrap_err().to_string();
        assert!(err.contains("contiguously"), "{err}");

        let mut missing = topo();
        missing.clusters[0].gateways.pop();
        let err = missing.validate().unwrap_err().to_string();
        assert!(err.contains("covers"), "{err}");

        let mut none = topo();
        none.clusters.clear();
        assert!(none.validate().is_err());
    }

    #[test]
    fn generation_is_deterministic_in_seed() {
        let cfg = SimConfig::default();
        let a = Topology::generate(&cfg, &mut Rng::new(7));
        let b = Topology::generate(&cfg, &mut Rng::new(7));
        for (x, y) in a.devices.iter().zip(&b.devices) {
            assert_eq!(x.dataset_size, y.dataset_size);
            assert_eq!(x.freq, y.freq);
        }
    }
}
