//! # iiot-fl — Low-latency Federated Learning with DNN Partition (DDSRA)
//!
//! Full-system reproduction of Deng et al., "Low-latency Federated Learning
//! with DNN Partition in Distributed Industrial IoT Networks" (2022).
//!
//! Layer 3 of the three-layer stack: the rust coordinator owns the FL round
//! loop, the DDSRA scheduler (Lyapunov drift-plus-penalty + block coordinate
//! descent + bisection + Hungarian), the wireless/energy/memory simulators,
//! and a pluggable execution backend that runs the actual training.
//! Python never runs on the request path.
//!
//! # Execution backends
//!
//! Training/evaluation go through the [`runtime::Backend`] trait:
//! - default build: [`runtime::NativeBackend`], a pure-Rust layer-graph
//!   engine (composable dense/conv/pool/relu ops, rayon-parallel batches)
//!   compiled from the scheduler's own [`dnn::ModelSpec`] descriptions —
//!   the `mlp` AND `cnn` (VGG-mini) presets build, train and are tested
//!   with **zero native dependencies**;
//! - split execution: [`runtime::PartitionedBackend`] runs the same
//!   presets cut into a device half and a gateway half at the partition
//!   point the DDSRA scheduler selects (byte-identical to fused
//!   execution) — enable with `--execute-partition`;
//! - wire-level split: [`runtime::RemoteBackend`] drives the same split
//!   over TCP to a `serve-gateway` process speaking the length-prefixed
//!   [`net::wire`] protocol (byte-identical to the in-process split at
//!   every cut) — enable with `--transport tcp`;
//! - feature `pjrt`: `runtime::Engine` executes the AOT-compiled
//!   JAX/Pallas HLO artifacts on the PJRT CPU client (requires the `xla`
//!   crate to be supplied — see Cargo.toml — plus `make artifacts`).
//!
//! Module map (see DESIGN.md for the full system inventory):
//! - [`dnn`] — layer-level FLOPs/memory model (paper Table II) + model zoo
//! - [`topo`] — devices / gateways / shop floors / deployment matrix
//! - [`net`] — block-fading wireless channels (Eq. 6–8) + the wire
//!   protocol / transport / gateway service of `--transport tcp`
//! - [`energy`] — energy-harvesting arrivals + consumption (Eq. 2, 3, 9)
//! - [`opt`] — Hungarian assignment + scalar bisection substrates
//! - [`sched`] — DDSRA (§V) and the four baseline schedulers
//! - [`fl`] — FL orchestration, the parallel streaming round engine
//!   ([`fl::round`]: rayon device fan-out, stateless per-(round, device)
//!   RNG streams, O(1)-copy FedAvg), participation rates (§IV), and the
//!   [`fl::Session`] API ([`fl::session`]: typed run builder,
//!   [`fl::SchedulerSpec`], streaming observer/sink telemetry, engine-
//!   owned early stopping, one-call paired multi-scheduler runs)
//! - [`data`] — synthetic SVHN/CIFAR-like datasets + non-IID sharding
//! - [`runtime`] — the [`runtime::Backend`] trait + native/PJRT engines
//! - [`rng`], [`config`], [`metrics`] (streaming CSV/JSONL/progress
//!   sinks), [`cli`] — infrastructure

pub mod cli;
pub mod config;
pub mod data;
pub mod dnn;
pub mod energy;
pub mod fl;
pub mod metrics;
pub mod net;
pub mod opt;
pub mod rng;
pub mod runtime;
pub mod sched;
pub mod topo;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
