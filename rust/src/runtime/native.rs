//! Pure-Rust execution backend for the `mlp` preset: dense forward/backward
//! + SGD over flat `Vec<f32>` buffers, mirroring python/compile/model.py
//! (3072 -> 64 ReLU -> 10, He-normal hidden init, zero-init head, mean
//! softmax cross-entropy). No PJRT, no artifacts, no native libraries —
//! `Experiment` trains end-to-end on a fresh checkout with this backend.
//!
//! The ABI matches the artifact family exactly: parameters travel in the
//! order [w1 (3072x64, row-major), b1 (64), w2 (64x10, row-major), b2 (10)],
//! `train_step` returns the loss at the *pre-step* parameters (like
//! `jax.value_and_grad`), `eval_batch` returns (sum loss, num correct), and
//! `grad` returns the flat concatenated minibatch gradient.

use anyhow::{bail, Result};

use super::backend::{Backend, Params};
use super::meta::ModelMeta;
use crate::rng::Rng;

const INPUT_DIM: usize = 3072; // 32·32·3, matches data::synth::IMG_DIM
const HIDDEN: usize = 64;
const CLASSES: usize = 10;

// Offsets of each tensor inside the flat gradient vector.
const O_W1: usize = 0;
const O_B1: usize = INPUT_DIM * HIDDEN;
const O_W2: usize = O_B1 + HIDDEN;
const O_B2: usize = O_W2 + HIDDEN * CLASSES;
const PARAM_TOTAL: usize = O_B2 + CLASSES;

/// Dependency-free MLP runtime.
pub struct NativeBackend {
    meta: ModelMeta,
    init_seed: u64,
}

impl NativeBackend {
    /// The `mlp` preset with the default deterministic init seed.
    pub fn mlp() -> Self {
        Self::mlp_seeded(0x6d6c70) // "mlp"
    }

    /// Same preset, custom init seed (distinct seeds give distinct inits,
    /// each individually deterministic).
    pub fn mlp_seeded(init_seed: u64) -> Self {
        NativeBackend {
            meta: ModelMeta {
                preset: "mlp".into(),
                train_batch: 64,
                eval_batch: 256,
                num_classes: CLASSES,
                input_train: vec![64, INPUT_DIM],
                input_eval: vec![256, INPUT_DIM],
                param_total: PARAM_TOTAL,
                train_k: 0,
                param_shapes: vec![
                    vec![INPUT_DIM, HIDDEN],
                    vec![HIDDEN],
                    vec![HIDDEN, CLASSES],
                    vec![CLASSES],
                ],
            },
            init_seed,
        }
    }

    fn check_params(&self, params: &Params) -> Result<()> {
        if params.len() != self.meta.param_shapes.len() {
            bail!("expected {} param tensors, got {}", self.meta.param_shapes.len(), params.len());
        }
        for (buf, shape) in params.iter().zip(&self.meta.param_shapes) {
            let expect: usize = shape.iter().product();
            if buf.len() != expect {
                bail!("param tensor size {} != shape {shape:?}", buf.len());
            }
        }
        Ok(())
    }

    fn check_batch(&self, x: &[f32], y: &[i32], batch: usize) -> Result<()> {
        if y.len() != batch {
            bail!("label batch {} != expected {batch}", y.len());
        }
        if x.len() != batch * INPUT_DIM {
            bail!("input size {} != {batch}x{INPUT_DIM}", x.len());
        }
        for &c in y {
            if !(0..CLASSES as i32).contains(&c) {
                bail!("label {c} outside 0..{CLASSES}");
            }
        }
        Ok(())
    }

    /// Batched forward (+ optional backward): returns the summed per-sample
    /// loss, the number of argmax-correct predictions, and — when requested
    /// — the flat gradient of the MEAN loss (matching `jax.grad` of
    /// `_xent`, which averages over the batch).
    fn fwd_bwd(
        &self,
        params: &Params,
        x: &[f32],
        y: &[i32],
        want_grad: bool,
    ) -> Result<(f64, usize, Option<Vec<f32>>)> {
        self.check_params(params)?;
        let b = y.len();
        let (w1, b1, w2, b2) = (&params[0], &params[1], &params[2], &params[3]);
        let inv_b = 1.0f32 / b as f32;
        let mut grad = if want_grad { Some(vec![0.0f32; PARAM_TOTAL]) } else { None };

        let mut pre = vec![0.0f32; HIDDEN]; // hidden pre-activation
        let mut act = vec![0.0f32; HIDDEN]; // relu(pre)
        let mut z = vec![0.0f32; CLASSES]; // logits
        let mut dz = vec![0.0f32; CLASSES];
        let mut dh = vec![0.0f32; HIDDEN];
        let mut loss_sum = 0.0f64;
        let mut correct = 0usize;

        for s in 0..b {
            let xs = &x[s * INPUT_DIM..(s + 1) * INPUT_DIM];

            // Hidden layer: pre = x·W1 + b1, act = relu(pre).
            pre.copy_from_slice(b1);
            for i in 0..INPUT_DIM {
                let xi = xs[i];
                if xi != 0.0 {
                    let row = &w1[i * HIDDEN..(i + 1) * HIDDEN];
                    for j in 0..HIDDEN {
                        pre[j] += xi * row[j];
                    }
                }
            }
            for j in 0..HIDDEN {
                act[j] = pre[j].max(0.0);
            }

            // Output layer: z = act·W2 + b2.
            z.copy_from_slice(b2);
            for j in 0..HIDDEN {
                let aj = act[j];
                if aj != 0.0 {
                    let row = &w2[j * CLASSES..(j + 1) * CLASSES];
                    for k in 0..CLASSES {
                        z[k] += aj * row[k];
                    }
                }
            }

            // Stable log-softmax cross-entropy.
            let label = y[s] as usize;
            let zmax = z.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let mut expsum = 0.0f32;
            for k in 0..CLASSES {
                dz[k] = (z[k] - zmax).exp();
                expsum += dz[k];
            }
            loss_sum += (expsum.ln() + zmax - z[label]) as f64;

            let mut best = 0usize;
            for k in 1..CLASSES {
                if z[k] > z[best] {
                    best = k;
                }
            }
            if best == label {
                correct += 1;
            }

            if let Some(g) = grad.as_mut() {
                // dL/dz = (softmax - onehot) / B.
                for k in 0..CLASSES {
                    dz[k] *= inv_b / expsum;
                }
                dz[label] -= inv_b;

                // dW2 += act ⊗ dz, db2 += dz, dh = W2·dz (through relu).
                for j in 0..HIDDEN {
                    let aj = act[j];
                    let row = &w2[j * CLASSES..(j + 1) * CLASSES];
                    let mut acc = 0.0f32;
                    for k in 0..CLASSES {
                        acc += row[k] * dz[k];
                        g[O_W2 + j * CLASSES + k] += aj * dz[k];
                    }
                    dh[j] = if pre[j] > 0.0 { acc } else { 0.0 };
                }
                for k in 0..CLASSES {
                    g[O_B2 + k] += dz[k];
                }

                // dW1 += x ⊗ dh, db1 += dh.
                for i in 0..INPUT_DIM {
                    let xi = xs[i];
                    if xi != 0.0 {
                        let row = &mut g[O_W1 + i * HIDDEN..O_W1 + (i + 1) * HIDDEN];
                        for j in 0..HIDDEN {
                            row[j] += xi * dh[j];
                        }
                    }
                }
                for j in 0..HIDDEN {
                    g[O_B1 + j] += dh[j];
                }
            }
        }
        Ok((loss_sum, correct, grad))
    }
}

impl Backend for NativeBackend {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn init_params(&self) -> Result<Params> {
        // He-normal hidden weights, zero biases, ZERO-init head — initial
        // logits are all zero, so the initial loss is exactly ln 10
        // (matching the artifact contract the integration tests assert).
        let mut rng = Rng::new(self.init_seed);
        let scale = (2.0 / INPUT_DIM as f64).sqrt();
        let w1: Vec<f32> =
            (0..INPUT_DIM * HIDDEN).map(|_| (rng.normal() * scale) as f32).collect();
        Ok(vec![
            w1,
            vec![0.0; HIDDEN],
            vec![0.0; HIDDEN * CLASSES],
            vec![0.0; CLASSES],
        ])
    }

    fn train_step(
        &self,
        params: &Params,
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<(Params, f32)> {
        self.check_batch(x, y, self.meta.train_batch)?;
        let (loss_sum, _, grad) = self.fwd_bwd(params, x, y, true)?;
        let g = grad.expect("gradient requested");
        let mut new = params.clone();
        let mut off = 0usize;
        for t in new.iter_mut() {
            for v in t.iter_mut() {
                *v -= lr * g[off];
                off += 1;
            }
        }
        Ok((new, (loss_sum / y.len() as f64) as f32))
    }

    fn eval_batch(&self, params: &Params, x: &[f32], y: &[i32]) -> Result<(f64, f64)> {
        self.check_batch(x, y, self.meta.eval_batch)?;
        let (loss_sum, correct, _) = self.fwd_bwd(params, x, y, false)?;
        Ok((loss_sum, correct as f64))
    }

    fn grad(&self, params: &Params, x: &[f32], y: &[i32]) -> Result<Vec<f32>> {
        self.check_batch(x, y, self.meta.train_batch)?;
        let (_, _, grad) = self.fwd_bwd(params, x, y, true)?;
        Ok(grad.expect("gradient requested"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(seed: u64, n: usize) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n * INPUT_DIM).map(|_| rng.normal() as f32 * 0.5).collect();
        let y: Vec<i32> = (0..n).map(|_| rng.below(CLASSES) as i32).collect();
        (x, y)
    }

    #[test]
    fn meta_matches_python_preset() {
        let b = NativeBackend::mlp();
        let m = b.meta();
        assert_eq!(m.preset, "mlp");
        assert_eq!((m.train_batch, m.eval_batch, m.num_classes), (64, 256, 10));
        assert_eq!(m.param_total, 3072 * 64 + 64 + 64 * 10 + 10);
        assert_eq!(m.sample_dim(), 3072);
    }

    #[test]
    fn init_is_deterministic_and_zero_headed() {
        let b = NativeBackend::mlp();
        let p1 = b.init_params().unwrap();
        let p2 = b.init_params().unwrap();
        assert_eq!(p1, p2);
        assert!(p1[2].iter().all(|&v| v == 0.0));
        assert!(p1[3].iter().all(|&v| v == 0.0));
        assert!(p1[0].iter().any(|&v| v != 0.0));
        // Different seeds give different hidden features.
        let p3 = NativeBackend::mlp_seeded(99).init_params().unwrap();
        assert_ne!(p1[0], p3[0]);
    }

    #[test]
    fn initial_loss_is_ln10_and_zero_lr_is_identity() {
        let b = NativeBackend::mlp();
        let p = b.init_params().unwrap();
        let (x, y) = batch(1, 64);
        let (same, loss) = b.train_step(&p, &x, &y, 0.0).unwrap();
        assert_eq!(same, p);
        assert!((loss - 10f32.ln()).abs() < 1e-5, "loss {loss}");
    }

    #[test]
    fn grad_matches_finite_differences() {
        let b = NativeBackend::mlp();
        let mut p = b.init_params().unwrap();
        // Perturb the head so gradients flow through both layers.
        let mut rng = Rng::new(7);
        for v in p[2].iter_mut().chain(p[3].iter_mut()) {
            *v = (rng.normal() * 0.1) as f32;
        }
        let (x, y) = batch(2, 64);
        let g = b.grad(&p, &x, &y).unwrap();
        assert_eq!(g.len(), PARAM_TOTAL);

        let loss_at = |params: &Params| -> f64 {
            let (_, l) = b.train_step(params, &x, &y, 0.0).unwrap();
            l as f64
        };
        // Probe a few coordinates in every tensor.
        let probes = [
            (0usize, 0usize),      // w1[0,0]
            (0, 5 * HIDDEN + 3),   // w1[5,3]
            (1, 2),                // b1[2]
            (2, 7),                // w2[0,7]
            (2, 4 * CLASSES + 1),  // w2[4,1]
            (3, 6),                // b2[6]
        ];
        let offsets = [O_W1, O_B1, O_W2, O_B2];
        let eps = 1e-2f32;
        for (t, i) in probes {
            let mut hi = p.clone();
            hi[t][i] += eps;
            let mut lo = p.clone();
            lo[t][i] -= eps;
            let num = (loss_at(&hi) - loss_at(&lo)) / (2.0 * eps as f64);
            let ana = g[offsets[t] + i] as f64;
            assert!(
                (num - ana).abs() < 1e-3 + 0.05 * ana.abs(),
                "tensor {t} idx {i}: numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn train_step_equals_manual_sgd_on_grad() {
        let b = NativeBackend::mlp();
        let p = b.init_params().unwrap();
        let (x, y) = batch(3, 64);
        let (stepped, _) = b.train_step(&p, &x, &y, 0.01).unwrap();
        let g = b.grad(&p, &x, &y).unwrap();
        let mut manual = p.clone();
        let mut off = 0;
        for t in manual.iter_mut() {
            for v in t.iter_mut() {
                *v -= 0.01 * g[off];
                off += 1;
            }
        }
        assert_eq!(manual, stepped);
    }

    #[test]
    fn sgd_reduces_loss_on_separable_batch() {
        let b = NativeBackend::mlp();
        let mut p = b.init_params().unwrap();
        // One fixed batch: repeated steps must drive its loss down fast.
        let (x, y) = batch(4, 64);
        let (_, first) = b.train_step(&p, &x, &y, 0.0).unwrap();
        for _ in 0..30 {
            let (np, _) = b.train_step(&p, &x, &y, 0.1).unwrap();
            p = np;
        }
        let (_, last) = b.train_step(&p, &x, &y, 0.0).unwrap();
        assert!(
            last < first - 0.5,
            "memorising one batch should cut the loss: {first} -> {last}"
        );
    }

    #[test]
    fn eval_batch_sums_and_counts() {
        let b = NativeBackend::mlp();
        let p = b.init_params().unwrap();
        let (x, y) = batch(5, 256);
        let (loss_sum, correct) = b.eval_batch(&p, &x, &y).unwrap();
        // Zero head: per-sample loss is exactly ln 10.
        assert!((loss_sum / 256.0 - 10f64.ln()).abs() < 1e-5);
        assert!((0.0..=256.0).contains(&correct));
    }

    #[test]
    fn eval_full_chunks_consistently() {
        let b = NativeBackend::mlp();
        let p = b.init_params().unwrap();
        let (x, y) = batch(6, 512);
        let (mean_loss, acc) = b.eval_full(&p, &x, &y).unwrap();
        assert!((mean_loss - 10f64.ln()).abs() < 1e-5);
        assert!((0.0..=1.0).contains(&acc));
        // Ragged sizes are rejected.
        assert!(b.eval_full(&p, &x[..100 * INPUT_DIM], &y[..100]).is_err());
    }

    #[test]
    fn rejects_malformed_inputs() {
        let b = NativeBackend::mlp();
        let p = b.init_params().unwrap();
        let (x, y) = batch(8, 64);
        assert!(b.train_step(&p, &x[..10], &y, 0.1).is_err());
        assert!(b.train_step(&p, &x, &y[..10], 0.1).is_err());
        let bad_y: Vec<i32> = vec![11; 64];
        assert!(b.train_step(&p, &x, &bad_y, 0.1).is_err());
        let mut bad_p = p.clone();
        bad_p[0].pop();
        assert!(b.train_step(&bad_p, &x, &y, 0.1).is_err());
    }
}
