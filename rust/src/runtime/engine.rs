//! The PJRT execution engine (feature `pjrt`): one compiled executable per
//! artifact, implementing [`Backend`] over the AOT HLO artifacts.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax >= 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects in proto
//! form; the text parser reassigns ids (see DESIGN.md and aot.py).
//!
//! NOTE: the `xla` crate is not on crates.io; enabling this feature
//! requires adding a vendored checkout of xla-rs under `[dependencies]`
//! in Cargo.toml (e.g. `xla = { path = "../xla-rs" }`).
//!
//! NOTE: [`Backend`] is `Send + Sync` (the round engine fans device
//! training out over rayon), so the vendored xla-rs types backing
//! [`Engine`] must be `Send + Sync` too. XLA's underlying `PjRtClient` /
//! `PjRtLoadedExecutable` are thread-safe C++ objects; if the vendored
//! binding does not mark its wrappers accordingly, patch the vendored
//! crate rather than weakening the trait bound.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::backend::{Backend, Params};
use super::meta::ModelMeta;

/// Loads and runs one preset's artifact family.
pub struct Engine {
    client: PjRtClient,
    pub meta: ModelMeta,
    dir: PathBuf,
    init: PjRtLoadedExecutable,
    train: PjRtLoadedExecutable,
    /// Fused K-step local-training artifact (§Perf): one call per local
    /// training instead of K, eliminating K−1 parameter round-trips.
    train_k: Option<PjRtLoadedExecutable>,
    eval: PjRtLoadedExecutable,
    grad: PjRtLoadedExecutable,
}

impl Engine {
    /// Compile the init/train/eval/grad artifacts for `preset`.
    pub fn load(artifacts_dir: &Path, preset: &str) -> Result<Self> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let meta = ModelMeta::load(&artifacts_dir.join(format!("{preset}.meta")))?;
        let compile = |name: &str| -> Result<PjRtLoadedExecutable> {
            compile_artifact(&client, &artifacts_dir.join(format!("{preset}_{name}.hlo.txt")))
        };
        let train_k = if meta.train_k > 0
            && artifacts_dir
                .join(format!("{preset}_train_k{}.hlo.txt", meta.train_k))
                .exists()
        {
            Some(compile(&format!("train_k{}", meta.train_k))?)
        } else {
            None
        };
        Ok(Engine {
            init: compile("init")?,
            train: compile("train_step")?,
            train_k,
            eval: compile("eval")?,
            grad: compile("grad")?,
            dir: artifacts_dir.to_path_buf(),
            client,
            meta,
        })
    }

    /// Compile an arbitrary extra artifact from the same directory (e.g.
    /// the cnn_bottom_fwd / cnn_top_step / cnn_bottom_bwd partition
    /// artifacts; the native split runtime — `PartitionedBackend` — has
    /// since superseded them as the proof of split/fused equivalence).
    pub fn compile_extra(&self, name: &str) -> Result<PjRtLoadedExecutable> {
        compile_artifact(&self.client, &self.dir.join(format!("{name}.hlo.txt")))
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    // ------------------------------------------------------------ marshal

    fn param_literals(&self, params: &Params) -> Result<Vec<Literal>> {
        if params.len() != self.meta.param_shapes.len() {
            bail!("expected {} param tensors, got {}", self.meta.param_shapes.len(), params.len());
        }
        params
            .iter()
            .zip(&self.meta.param_shapes)
            .map(|(buf, shape)| lit_f32(buf, shape))
            .collect()
    }

    fn unpack_params(&self, lits: &[Literal]) -> Result<Params> {
        lits.iter().map(|l| l.to_vec::<f32>().map_err(Into::into)).collect()
    }
}

impl Backend for Engine {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// K of the fused local-training artifact, if loaded.
    fn fused_k(&self) -> Option<usize> {
        self.train_k.as_ref().map(|_| self.meta.train_k)
    }

    /// Seeded parameter initialisation (runs the `init` artifact).
    fn init_params(&self) -> Result<Params> {
        let out = run_tuple(&self.init, &[])?;
        self.unpack_params(&out)
    }

    /// One SGD step: (params, `x[train_batch]`, y, lr) -> (params', loss).
    fn train_step(
        &self,
        params: &Params,
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<(Params, f32)> {
        let mut args = self.param_literals(params)?;
        args.push(lit_f32(x, &self.meta.input_train)?);
        args.push(lit_i32(y, self.meta.train_batch)?);
        args.push(Literal::scalar(lr));
        let out = run_tuple(&self.train, &args)?;
        let (loss_lit, param_lits) = out.split_last().context("empty train output")?;
        let loss = loss_lit.get_first_element::<f32>()?;
        Ok((self.unpack_params(param_lits)?, loss))
    }

    /// K fused SGD steps: (params, xs[K·train_batch·dim], ys[K·train_batch],
    /// lr) -> (params', mean loss). Requires the fused artifact.
    fn train_k_steps(
        &self,
        params: &Params,
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> Result<(Params, f32)> {
        let exe = self.train_k.as_ref().context("fused train_k artifact not loaded")?;
        let k = self.meta.train_k;
        let mut xshape = vec![k];
        xshape.extend_from_slice(&self.meta.input_train);
        let mut args = self.param_literals(params)?;
        args.push(lit_f32(xs, &xshape)?);
        if ys.len() != k * self.meta.train_batch {
            bail!("train_k labels: {} != {}", ys.len(), k * self.meta.train_batch);
        }
        args.push(Literal::vec1(ys).reshape(&[k as i64, self.meta.train_batch as i64])?);
        args.push(Literal::scalar(lr));
        let out = run_tuple(exe, &args)?;
        let (loss_lit, param_lits) = out.split_last().context("empty train_k output")?;
        Ok((self.unpack_params(param_lits)?, loss_lit.get_first_element::<f32>()?))
    }

    /// One eval batch: -> (sum_loss, num_correct).
    fn eval_batch(&self, params: &Params, x: &[f32], y: &[i32]) -> Result<(f64, f64)> {
        let mut args = self.param_literals(params)?;
        args.push(lit_f32(x, &self.meta.input_eval)?);
        args.push(lit_i32(y, self.meta.eval_batch)?);
        let out = run_tuple(&self.eval, &args)?;
        Ok((
            out[0].get_first_element::<f32>()? as f64,
            out[1].get_first_element::<f32>()? as f64,
        ))
    }

    /// Evaluate over a whole test set (len divisible by eval_batch);
    /// returns (mean loss, accuracy).
    ///
    /// §Perf: parameters are uploaded to device buffers ONCE and reused
    /// across all chunks via `execute_b` (the test set spans several
    /// batches, and the 0.8 MB parameter upload dominated per-chunk cost).
    fn eval_full(&self, params: &Params, x: &[f32], y: &[i32]) -> Result<(f64, f64)> {
        let b = self.meta.eval_batch;
        let dim = self.meta.sample_dim();
        if y.len() % b != 0 || x.len() != y.len() * dim {
            bail!("test set size {} not divisible by eval batch {b}", y.len());
        }
        if params.len() != self.meta.param_shapes.len() {
            bail!("expected {} param tensors", self.meta.param_shapes.len());
        }
        let pbufs: Vec<xla::PjRtBuffer> = params
            .iter()
            .zip(&self.meta.param_shapes)
            .map(|(buf, shape)| self.client.buffer_from_host_buffer::<f32>(buf, shape, None))
            .collect::<xla::Result<_>>()?;
        let (mut loss, mut correct) = (0.0, 0.0);
        for c in 0..y.len() / b {
            let xb = self.client.buffer_from_host_buffer::<f32>(
                &x[c * b * dim..(c + 1) * b * dim],
                &self.meta.input_eval,
                None,
            )?;
            let yb = self
                .client
                .buffer_from_host_buffer::<i32>(&y[c * b..(c + 1) * b], &[b], None)?;
            let mut args: Vec<&xla::PjRtBuffer> = pbufs.iter().collect();
            args.push(&xb);
            args.push(&yb);
            let out = self.eval.execute_b(&args)?[0][0].to_literal_sync()?.to_tuple()?;
            loss += out[0].get_first_element::<f32>()? as f64;
            correct += out[1].get_first_element::<f32>()? as f64;
        }
        let n = y.len() as f64;
        Ok((loss / n, correct / n))
    }

    /// Flat minibatch gradient (sigma/delta probes for §IV).
    fn grad(&self, params: &Params, x: &[f32], y: &[i32]) -> Result<Vec<f32>> {
        let mut args = self.param_literals(params)?;
        args.push(lit_f32(x, &self.meta.input_train)?);
        args.push(lit_i32(y, self.meta.train_batch)?);
        let out = run_tuple(&self.grad, &args)?;
        Ok(out[0].to_vec::<f32>()?)
    }
}

/// Compile one HLO-text artifact.
pub fn compile_artifact(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable> {
    let proto = HloModuleProto::from_text_file(path)
        .with_context(|| format!("parsing HLO text {path:?} (run `make artifacts`?)"))?;
    let comp = XlaComputation::from_proto(&proto);
    client.compile(&comp).with_context(|| format!("compiling {path:?}"))
}

/// Execute and unpack the 1-element-replica tuple output.
///
/// NOTE: arguments are uploaded to Rust-owned `PjRtBuffer`s and passed via
/// `execute_b`. The crate's `execute::<Literal>` path leaks its internal
/// input buffers (~1.6 MB per train step, enough to OOM a long figure
/// run); buffers created here are freed on drop.
pub fn run_tuple(exe: &PjRtLoadedExecutable, args: &[Literal]) -> Result<Vec<Literal>> {
    let client = exe.client();
    let bufs: Vec<xla::PjRtBuffer> = args
        .iter()
        .map(|lit| client.buffer_from_host_literal(None, lit))
        .collect::<xla::Result<_>>()?;
    let result = exe.execute_b(&bufs)?[0][0].to_literal_sync()?;
    Ok(result.to_tuple()?)
}

/// f32 literal with the given dims.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let expect: usize = shape.iter().product();
    if data.len() != expect {
        bail!("literal size {} != shape {:?}", data.len(), shape);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

/// rank-1 i32 literal.
pub fn lit_i32(data: &[i32], len: usize) -> Result<Literal> {
    if data.len() != len {
        bail!("label literal size {} != {len}", data.len());
    }
    Ok(Literal::vec1(data))
}
