//! Parser for the line-oriented `<preset>.meta` files emitted by
//! python/compile/aot.py (we have no JSON dependency offline).

use std::path::Path;

use anyhow::{bail, Context};

/// Shapes and sizes of one model preset's artifact family.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub preset: String,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub num_classes: usize,
    /// Training input shape, e.g. [64, 3072] or [64, 32, 32, 3].
    pub input_train: Vec<usize>,
    pub input_eval: Vec<usize>,
    pub param_total: usize,
    /// K baked into the fused `train_k` artifact (0 = artifact absent).
    pub train_k: usize,
    /// Per-parameter tensor shapes, in artifact ABI order.
    pub param_shapes: Vec<Vec<usize>>,
}

impl ModelMeta {
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let mut preset = String::new();
        let (mut train_batch, mut eval_batch, mut num_classes, mut param_total) =
            (0usize, 0usize, 0usize, 0usize);
        let mut train_k = 0usize;
        let mut input_train = Vec::new();
        let mut input_eval = Vec::new();
        let mut param_shapes = Vec::new();

        let parse_shape = |v: &str| -> anyhow::Result<Vec<usize>> {
            v.split('x')
                .map(|d| d.parse::<usize>().context("shape dim"))
                .collect()
        };

        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("bad meta line: {line:?}");
            };
            match k {
                "preset" => preset = v.to_string(),
                "train_batch" => train_batch = v.parse()?,
                "eval_batch" => eval_batch = v.parse()?,
                "num_classes" => num_classes = v.parse()?,
                "input_train" => input_train = parse_shape(v)?,
                "input_eval" => input_eval = parse_shape(v)?,
                "param_total" => param_total = v.parse()?,
                "train_k" => train_k = v.parse()?,
                "param" => param_shapes.push(parse_shape(v)?),
                other => bail!("unknown meta key {other:?}"),
            }
        }
        if preset.is_empty() || param_shapes.is_empty() || train_batch == 0 {
            bail!("incomplete meta file");
        }
        let meta = ModelMeta {
            preset,
            train_batch,
            eval_batch,
            num_classes,
            input_train,
            input_eval,
            param_total,
            train_k,
            param_shapes,
        };
        let sum: usize = meta.param_shapes.iter().map(|s| s.iter().product::<usize>()).sum();
        if sum != meta.param_total {
            bail!("param_total {} != sum of shapes {}", meta.param_total, sum);
        }
        Ok(meta)
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?}"))?;
        Self::parse(&text)
    }

    /// Per-sample feature count of the training input.
    pub fn sample_dim(&self) -> usize {
        self.input_train[1..].iter().product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "preset=mlp\ntrain_batch=64\neval_batch=256\nnum_classes=10\n\
input_train=64x3072\ninput_eval=256x3072\nparam_total=197322\n\
param=3072x64\nparam=64\nparam=64x10\nparam=10\n";

    #[test]
    fn parses_sample() {
        let m = ModelMeta::parse(SAMPLE).unwrap();
        assert_eq!(m.preset, "mlp");
        assert_eq!(m.train_batch, 64);
        assert_eq!(m.param_shapes.len(), 4);
        assert_eq!(m.param_shapes[0], vec![3072, 64]);
        assert_eq!(m.sample_dim(), 3072);
        assert_eq!(m.param_total, 3072 * 64 + 64 + 64 * 10 + 10);
    }

    #[test]
    fn rejects_inconsistent_total() {
        let bad = SAMPLE.replace("197322", "5");
        assert!(ModelMeta::parse(&bad).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(ModelMeta::parse("nonsense").is_err());
        assert!(ModelMeta::parse("").is_err());
    }
}
