//! [`RemoteBackend`]: the device side of wire-level split execution.
//!
//! Implements the existing [`Backend`] trait over a [`ConnPool`] to a
//! gateway service (`net::serve`), so the orchestrator and `Session`
//! layers are untouched — a device's K local steps dispatch through
//! `Box<dyn Backend>`/`&dyn Backend` exactly as before, but each step's
//! gateway half now crosses a real network boundary:
//!
//! ```text
//!   device (this process)                    gateway (net::serve)
//!   ─────────────────────                    ────────────────────
//!   bottom forward ── SplitReq{acts ⇡} ────▶ top fwd + head + bwd
//!   bottom backward ◀─ SplitResp{dcut ⇣, g_top}
//!   SGD on the fused gradient
//! ```
//!
//! Every method wraps the in-process [`PartitionedBackend`] for the
//! device half, metadata, input validation, and `init_params` — w(0)
//! never crosses the wire, both ends derive it from the same
//! `Rng::stream` draws. The numerics are bit-identical to the
//! in-process split step (pinned by `rust/tests/wire.rs`): the gateway
//! runs the same blocked executors with the same block size, and the
//! device folds the returned per-sample cut gradients through the same
//! ordered reduction the fused gradient uses.
//!
//! I/O failures surface as [`PeerLost`]-marked errors from the
//! transport layer; the round engine maps them onto `FaultPlan` dropout
//! (see `net::transport` module docs).
//!
//! [`PeerLost`]: crate::net::transport::PeerLost

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::net::transport::ConnPool;
use crate::net::wire::Msg;

use super::backend::{Backend, Params};
use super::meta::ModelMeta;
use super::native::{
    apply_sgd, check_batch_against, check_params_against, check_samples_against,
    PartitionedBackend,
};

/// A device/gateway split where the gateway half lives behind a wire.
pub struct RemoteBackend {
    /// The in-process split at the same cut: device-half math + ABI
    /// metadata. Its gateway half is only used for `init_params`.
    local: PartitionedBackend,
    pool: Arc<ConnPool>,
}

impl RemoteBackend {
    pub fn new(local: PartitionedBackend, pool: Arc<ConnPool>) -> Self {
        RemoteBackend { local, pool }
    }

    /// The partition point this backend executes.
    pub fn cut(&self) -> usize {
        self.local.cut()
    }

    /// One split exchange: bottom forward locally, ship the smashed
    /// activations, receive loss/accuracy (+ gradients when requested),
    /// finish backward locally. Returns `(loss_sum, correct, grad)` with
    /// `grad` in the fused ABI (device coordinates then gateway's).
    fn split_round_trip(
        &self,
        params: &Params,
        x: &[f32],
        y: &[i32],
        want_grad: bool,
    ) -> Result<(f64, usize, Option<Vec<f32>>)> {
        let b = y.len();
        let n_cut = self.local.cut_activation_elems();
        let (bottom, top) = params.split_at(self.local.device_tensor_count());
        let mut acts = vec![0.0f32; b * n_cut];
        self.local.device_forward_batch(bottom, x, &mut acts);
        let req = Msg::SplitReq {
            cut: self.local.cut() as u32,
            want_grad,
            labels: y.to_vec(),
            top_params: top.to_vec(),
            acts,
        };
        let resp = self.pool.with_conn(|c| c.request(&req))?;
        let Msg::SplitResp { loss_sum, correct, dcut, g_top } = resp else {
            bail!("unexpected {} in reply to SplitReq", resp.name())
        };
        if !want_grad {
            if !dcut.is_empty() || !g_top.is_empty() {
                bail!("unsolicited gradients in SplitResp");
            }
            return Ok((loss_sum, correct as usize, None));
        }
        let gw_total = self.local.meta().param_total - self.local.device_param_total();
        if g_top.len() != gw_total {
            bail!("gateway gradient {} != expected {gw_total}", g_top.len());
        }
        let mut g = if self.local.device_num_ops() > 0 {
            if dcut.len() != b * n_cut {
                bail!("cut gradient {} != batch {b} x cut width {n_cut}", dcut.len());
            }
            self.local.device_backward_batch(bottom, x, &dcut, b)
        } else {
            // Cut 0: the device half is empty; its (zero-length) gradient
            // block still leads the fused ABI.
            if !dcut.is_empty() {
                bail!("unsolicited cut gradient for an op-less device half");
            }
            vec![0.0f32; self.local.device_param_total()]
        };
        g.extend_from_slice(&g_top);
        Ok((loss_sum, correct as usize, Some(g)))
    }
}

impl Backend for RemoteBackend {
    fn meta(&self) -> &ModelMeta {
        self.local.meta()
    }

    /// Deterministic and LOCAL: both ends derive w(0) from the preset's
    /// seed, so initial parameters never cross the wire.
    fn init_params(&self) -> Result<Params> {
        self.local.init_params()
    }

    fn train_step(&self, params: &Params, x: &[f32], y: &[i32], lr: f32) -> Result<(Params, f32)> {
        let meta = self.local.meta();
        check_params_against(meta, params)?;
        check_batch_against(meta, meta.sample_dim(), x, y, meta.train_batch)?;
        let (loss_sum, _, grad) = self.split_round_trip(params, x, y, true)?;
        let g = grad.expect("gradient requested");
        Ok((apply_sgd(params, &g, lr), (loss_sum / y.len() as f64) as f32))
    }

    fn eval_batch(&self, params: &Params, x: &[f32], y: &[i32]) -> Result<(f64, f64)> {
        let meta = self.local.meta();
        check_params_against(meta, params)?;
        check_batch_against(meta, meta.sample_dim(), x, y, meta.eval_batch)?;
        let (loss_sum, correct, _) = self.split_round_trip(params, x, y, false)?;
        Ok((loss_sum, correct as f64))
    }

    fn eval_partial_batch(
        &self,
        params: &Params,
        x: &[f32],
        y: &[i32],
    ) -> Result<Option<(f64, f64)>> {
        let meta = self.local.meta();
        check_params_against(meta, params)?;
        check_samples_against(meta, meta.sample_dim(), x, y)?;
        let (loss_sum, correct, _) = self.split_round_trip(params, x, y, false)?;
        Ok(Some((loss_sum, correct as f64)))
    }

    fn grad(&self, params: &Params, x: &[f32], y: &[i32]) -> Result<Vec<f32>> {
        let meta = self.local.meta();
        check_params_against(meta, params)?;
        check_batch_against(meta, meta.sample_dim(), x, y, meta.train_batch)?;
        let (_, _, grad) = self.split_round_trip(params, x, y, true)?;
        Ok(grad.expect("gradient requested"))
    }
}
