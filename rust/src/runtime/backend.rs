//! The pluggable execution backend: everything the FL orchestrator needs
//! from a training runtime (the K local SGD iterations of §III-A step 2,
//! the §IV gradient probes, and test-set evaluation), abstracted over
//! *how* the numerics run.
//!
//! Three implementations:
//! * [`crate::runtime::NativeBackend`] — the pure-Rust layer-graph engine
//!   (rayon-parallel forward/backward + SGD) for the `mlp` and `cnn`
//!   presets. Zero native dependencies; the default.
//! * [`crate::runtime::PartitionedBackend`] — the same presets executed
//!   SPLIT at a device/gateway partition point (the paper's §II-B training
//!   flow), byte-identical to the fused engine at every cut.
//! * `crate::runtime::Engine` (feature `pjrt`) — the PJRT CPU client over
//!   the AOT HLO artifacts compiled by python/compile/aot.py.
//!
//! Parameters live in the coordinator as `Params = Vec<Vec<f32>>` (one flat
//! buffer per tensor, in artifact ABI order) so that FedAvg (§III-A step 3),
//! divergence norms (Fig. 2) and the centralized-GD shadow run are plain
//! vector arithmetic regardless of backend.
//!
//! ```
//! use iiot_fl::runtime::{make_backend, Backend};
//! let backend = make_backend(std::path::Path::new("artifacts"), "mlp").unwrap();
//! assert_eq!(backend.meta().preset, "mlp");
//! // Seeded deterministic init: same backend, same bytes.
//! assert_eq!(backend.init_params().unwrap(), backend.init_params().unwrap());
//! ```

use std::path::Path;

use anyhow::Result;

use super::meta::ModelMeta;

/// Model parameters as flat per-tensor buffers (artifact ABI order):
/// the w-vectors the paper's aggregation steps (§III-A) average.
pub type Params = Vec<Vec<f32>>;

/// One model preset's training/evaluation runtime.
///
/// `Send + Sync` is part of the contract: the round engine fans
/// `local_train` calls out over rayon, so every backend must be safely
/// shareable across worker threads (the native layer-graph backends are
/// stateless per call; a PJRT engine must wrap a thread-safe client).
pub trait Backend: Send + Sync {
    /// Shapes and sizes of the preset this backend executes.
    fn meta(&self) -> &ModelMeta;

    /// K of the fused local-training entry point, if one is available
    /// (the paper's K local iterations batched into one backend call).
    fn fused_k(&self) -> Option<usize> {
        None
    }

    /// Seeded, deterministic parameter initialisation (the shared global
    /// model w(0) every device starts from).
    fn init_params(&self) -> Result<Params>;

    /// One local SGD iteration of §III-A step 2, w ← w − β·∇F̃ on one
    /// minibatch: (params, `x[train_batch·dim]`, `y[train_batch]`, lr = β)
    /// -> (params', mean batch loss). The loss is evaluated at the
    /// PRE-step parameters (like `jax.value_and_grad`).
    fn train_step(&self, params: &Params, x: &[f32], y: &[i32], lr: f32)
        -> Result<(Params, f32)>;

    /// K fused SGD steps: (params, xs[K·train_batch·dim], ys[K·train_batch],
    /// lr) -> (params', mean loss). Only when [`Backend::fused_k`] is Some.
    fn train_k_steps(
        &self,
        _params: &Params,
        _xs: &[f32],
        _ys: &[i32],
        _lr: f32,
    ) -> Result<(Params, f32)> {
        anyhow::bail!("backend for {:?} has no fused train_k entry point", self.meta().preset)
    }

    /// One eval batch: -> (sum of per-sample losses, number correct).
    fn eval_batch(&self, params: &Params, x: &[f32], y: &[i32]) -> Result<(f64, f64)>;

    /// One eval batch of ARBITRARY size (the trailing remainder of a test
    /// set not divisible by `eval_batch`). Backends with shape-flexible
    /// numerics (the native layer-graph engine) return `Some`; backends
    /// whose shapes are baked in at compile time (the AOT PJRT artifacts)
    /// keep the default `None`, and `eval_full` then rejects ragged sets.
    fn eval_partial_batch(
        &self,
        _params: &Params,
        _x: &[f32],
        _y: &[i32],
    ) -> Result<Option<(f64, f64)>> {
        Ok(None)
    }

    /// Evaluate a whole test set; returns (mean loss, accuracy). Runs
    /// `eval_batch`-sized chunks, then a final partial batch for any
    /// remainder via [`Backend::eval_partial_batch`] — so test sets need
    /// not be divisible by `eval_batch` on backends that support it.
    fn eval_full(&self, params: &Params, x: &[f32], y: &[i32]) -> Result<(f64, f64)> {
        let b = self.meta().eval_batch;
        let dim = self.meta().sample_dim();
        if y.is_empty() {
            anyhow::bail!("empty test set");
        }
        if x.len() != y.len() * dim {
            anyhow::bail!("test inputs {} != {} labels x dim {dim}", x.len(), y.len());
        }
        let (mut loss, mut correct) = (0.0, 0.0);
        let full = y.len() / b;
        for c in 0..full {
            let (l, n_ok) =
                self.eval_batch(params, &x[c * b * dim..(c + 1) * b * dim], &y[c * b..(c + 1) * b])?;
            loss += l;
            correct += n_ok;
        }
        if y.len() % b != 0 {
            match self.eval_partial_batch(params, &x[full * b * dim..], &y[full * b..])? {
                Some((l, n_ok)) => {
                    loss += l;
                    correct += n_ok;
                }
                None => anyhow::bail!(
                    "test set size {} not divisible by eval batch {b}, and the {:?} \
                     backend cannot run partial batches",
                    y.len(),
                    self.meta().preset
                ),
            }
        }
        let n = y.len() as f64;
        Ok((loss / n, correct / n))
    }

    /// Flat minibatch gradient ∇F̃_n(w), length `meta().param_total` —
    /// the estimator behind the §IV Assumption 1–2 probes (σ_n, δ_n) and
    /// the L_n smoothness estimate that feed Theorem 1's Φ_m.
    fn grad(&self, params: &Params, x: &[f32], y: &[i32]) -> Result<Vec<f32>>;
}

/// Construct the best available backend for `preset`.
///
/// With the `pjrt` feature enabled AND compiled artifacts present under
/// `artifacts_dir`, the PJRT engine is used; otherwise the pure-Rust
/// [`crate::runtime::NativeBackend`] layer-graph engine serves the preset.
/// Both executable presets — `mlp` AND `cnn` (VGG-mini) — run natively
/// from a fresh checkout; only unknown presets fail.
pub fn make_backend(artifacts_dir: &Path, preset: &str) -> Result<Box<dyn Backend>> {
    make_backend_kernel(artifacts_dir, preset, super::native::KernelPath::default())
}

/// [`make_backend`] with an explicit native [`crate::runtime::KernelPath`]
/// (`Scalar` = the bit-exact oracle loops, `Vectorized` = the blocked
/// fast path — the default). The kernel choice only applies to the
/// native layer-graph engine; a PJRT engine, when selected, runs its
/// compiled artifacts regardless.
pub fn make_backend_kernel(
    artifacts_dir: &Path,
    preset: &str,
    kernel: super::native::KernelPath,
) -> Result<Box<dyn Backend>> {
    #[cfg(feature = "pjrt")]
    {
        if artifacts_dir.join(format!("{preset}.meta")).exists() {
            return Ok(Box::new(super::engine::Engine::load(artifacts_dir, preset)?));
        }
    }
    let _ = artifacts_dir;
    let (spec, seed) = super::native::preset_spec_and_seed(preset)?;
    let native = super::native::NativeBackend::from_spec_kernel(&spec, seed, kernel)?;
    // A pjrt build reaching this point means the artifacts are missing —
    // say so instead of silently swapping the numerics.
    #[cfg(feature = "pjrt")]
    eprintln!(
        "[runtime] no compiled artifacts under {artifacts_dir:?} — \
         falling back to the pure-Rust native {preset:?} backend"
    );
    Ok(Box::new(native))
}
