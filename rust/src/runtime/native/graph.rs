//! `LayerGraph`: composes the op library into one executable network — or
//! into one *half* of a partitioned network.
//!
//! The graph is compiled from the same `dnn::ModelSpec` the scheduler's
//! cost model plans with — one source of truth for both the FLOPs/memory
//! the scheduler simulates and the tensors the runtime actually trains.
//! The graph owns every offset: the per-sample activation arena, each
//! op's block inside the flat gradient vector, and the ABI parameter
//! tensor order (weights-then-bias per parameterized op, ops in layer
//! order — exactly the artifact family's ABI).
//!
//! [`LayerGraph::from_spec`] compiles the whole model with its
//! softmax-cross-entropy loss head; [`LayerGraph::from_spec_range`]
//! compiles any contiguous run of spec layers into a *segment* — a
//! headless device subgraph (the paper's bottom `l` layers, §II-B) or a
//! head-owning gateway subgraph (the top `L − l` layers). The
//! `runtime::native::partition` module composes two such halves into the
//! split-execution `PartitionedBackend`, exchanging the smashed activation
//! forward and the cut gradient backward. Segment execution reuses the
//! exact per-op call sequence of the fused pass, so split results are
//! byte-identical to fused ones.
//!
//! The batch dimension of [`LayerGraph::fwd_bwd`] fans out over rayon in
//! fixed SAMPLE BLOCKS (the crate-private `run_blocked` executor): each
//! block accumulates its samples' gradients (in sample order) into one
//! per-block buffer, and the blocks then reduce coordinate-wise in block
//! order — so results depend only on the kernel path and the batch,
//! never on the worker count. On the scalar path the block size is 1,
//! which makes the whole executor arithmetic-identical to the original
//! per-sample fan-out — the pre-refactor replay bytes are preserved
//! exactly. All per-sample working memory (activation arenas, backward
//! ping-pong buffers, the softmax `dz`) lives in a per-worker
//! thread-local scratch (`GraphScratch`), so the hot batch path performs
//! no per-sample heap allocation.

use anyhow::{bail, Result};
use rayon::prelude::*;

use crate::dnn::layer::{Activation, Layer, PoolKind};
use crate::dnn::ModelSpec;
use crate::rng::Rng;

use super::super::backend::Params;
use super::kernels::{self, KernelPath};
use super::ops::{Conv2d, Dense, Flatten, MaxPool2d, Op, Relu, SoftmaxXent};

/// Chunk width of the rayon ordered gradient reduction (coordinates per
/// task; the sum over blocks inside a chunk runs in block order).
const GRAD_CHUNK: usize = 8192;

/// Samples per gradient-accumulation block on the vectorized path. The
/// scalar path uses block size 1 (bit-compatibility with the original
/// per-sample reduction); the vectorized path amortizes the per-block
/// gradient buffer over this many samples.
const SAMPLE_BLOCK: usize = 8;

/// Per-sample tensor shape flowing between layers.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Shape {
    /// (h, w, c) channels-last.
    Spatial(usize, usize, usize),
    Flat(usize),
}

impl Shape {
    fn len(self) -> usize {
        match self {
            Shape::Spatial(h, w, c) => h * w * c,
            Shape::Flat(n) => n,
        }
    }

    fn as_dims(self) -> Vec<usize> {
        match self {
            Shape::Spatial(h, w, c) => vec![h, w, c],
            Shape::Flat(n) => vec![n],
        }
    }
}

/// The per-sample input shape a layer declares.
fn layer_input_shape(layer: &Layer) -> Shape {
    match *layer {
        Layer::Conv { ci, hi, wi, .. } | Layer::Pool { ci, hi, wi, .. } => {
            Shape::Spatial(hi as usize, wi as usize, ci as usize)
        }
        Layer::Fc { si, .. } => Shape::Flat(si as usize),
    }
}

/// Per-worker reusable working memory for graph execution: the forward
/// activation arenas (two, so a partitioned device+gateway pass fits),
/// the backward ping-pong error buffers, the softmax-xent `dz`, and the
/// cut-gradient staging buffer of the split backend. All buffers are
/// grow-only ([`kernels::ensure`]) and carry stale data between samples —
/// safe because every op fully writes its outputs (see `ops` docs).
#[derive(Default)]
pub(crate) struct GraphScratch {
    pub acts: Vec<f32>,
    pub acts2: Vec<f32>,
    pub dy: Vec<f32>,
    pub dx: Vec<f32>,
    pub dz: Vec<f32>,
    pub dcut: Vec<f32>,
}

thread_local! {
    static SCRATCH: std::cell::RefCell<GraphScratch> =
        std::cell::RefCell::new(GraphScratch::default());
}

/// Run `f` with this worker's [`GraphScratch`]. Not reentrant (the graph
/// never nests sample executions); conv ops use a separate thread-local
/// ([`kernels::with_conv_scratch`]), so an op running inside `f` is fine.
pub(crate) fn with_scratch<R>(f: impl FnOnce(&mut GraphScratch) -> R) -> R {
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Deterministic blocked batch executor shared by the fused graph and the
/// partitioned backend. Samples are grouped into fixed blocks of `block`
/// consecutive samples; rayon fans out over BLOCKS. Within a block,
/// `per_sample(s, Some(g_block))` runs in sample order, accumulating the
/// sample gradients directly into the block's zeroed gradient buffer;
/// the per-block buffers then reduce coordinate-wise in block order
/// (rayon over `GRAD_CHUNK`-wide coordinate chunks). Both reductions
/// depend only on `block` and the batch — never on the worker count.
/// With `block == 1` this is arithmetic-identical to the original
/// per-sample fan-out + sample-order reduction.
pub(crate) fn run_blocked<F>(
    b: usize,
    block: usize,
    param_total: usize,
    want_grad: bool,
    per_sample: F,
) -> (f64, usize, Option<Vec<f32>>)
where
    F: Fn(usize, Option<&mut [f32]>) -> (f64, bool) + Sync,
{
    let nblocks = b.div_ceil(block);
    let mut results: Vec<(f64, bool)> = vec![(0.0, false); b];
    let grad = if want_grad && param_total > 0 {
        // ONE flat allocation holds every block's gradient buffer.
        let mut block_gs = vec![0.0f32; nblocks * param_total];
        results
            .par_chunks_mut(block)
            .zip(block_gs.par_chunks_mut(param_total))
            .enumerate()
            .for_each(|(bi, (chunk, g))| {
                for (k, slot) in chunk.iter_mut().enumerate() {
                    *slot = per_sample(bi * block + k, Some(&mut *g));
                }
            });
        Some(reduce_blocks(&block_gs, nblocks, param_total))
    } else {
        results.par_chunks_mut(block).enumerate().for_each(|(bi, chunk)| {
            for (k, slot) in chunk.iter_mut().enumerate() {
                *slot = per_sample(bi * block + k, None);
            }
        });
        want_grad.then(Vec::new)
    };
    let mut loss_sum = 0.0f64;
    let mut correct = 0usize;
    for &(l, ok) in &results {
        loss_sum += l;
        correct += ok as usize;
    }
    (loss_sum, correct, grad)
}

/// [`run_blocked`] with a per-sample output sink: `per_sample(s, g, out)`
/// additionally fills `out` — the sample's `out_stride`-wide slice of
/// `out` (the gateway uses this to collect per-sample cut gradients ⇣
/// for the wire). The sink slices are disjoint per-block partitions
/// zipped into the same rayon fan-out, so this stays safe Rust and the
/// loss/gradient arithmetic is EXACTLY `run_blocked`'s: same block
/// boundaries, same sample order within a block, same coordinate-wise
/// block-order reduction. Gradients are always requested; with
/// `param_total == 0` (a head-only gateway at the deepest cut) the
/// returned gradient is empty, mirroring `run_blocked`'s no-grad branch.
pub(crate) fn run_blocked_sink<F>(
    b: usize,
    block: usize,
    param_total: usize,
    out_stride: usize,
    out: &mut [f32],
    per_sample: F,
) -> (f64, usize, Vec<f32>)
where
    F: Fn(usize, Option<&mut [f32]>, &mut [f32]) -> (f64, bool) + Sync,
{
    debug_assert!(out_stride > 0);
    debug_assert_eq!(out.len(), b * out_stride);
    let nblocks = b.div_ceil(block);
    let mut results: Vec<(f64, bool)> = vec![(0.0, false); b];
    let grad = if param_total > 0 {
        let mut block_gs = vec![0.0f32; nblocks * param_total];
        results
            .par_chunks_mut(block)
            .zip(block_gs.par_chunks_mut(param_total))
            .zip(out.par_chunks_mut(block * out_stride))
            .enumerate()
            .for_each(|(bi, ((chunk, g), o))| {
                for (k, slot) in chunk.iter_mut().enumerate() {
                    let sink = &mut o[k * out_stride..(k + 1) * out_stride];
                    *slot = per_sample(bi * block + k, Some(&mut *g), sink);
                }
            });
        reduce_blocks(&block_gs, nblocks, param_total)
    } else {
        results
            .par_chunks_mut(block)
            .zip(out.par_chunks_mut(block * out_stride))
            .enumerate()
            .for_each(|(bi, (chunk, o))| {
                for (k, slot) in chunk.iter_mut().enumerate() {
                    let sink = &mut o[k * out_stride..(k + 1) * out_stride];
                    *slot = per_sample(bi * block + k, None, sink);
                }
            });
        Vec::new()
    };
    let mut loss_sum = 0.0f64;
    let mut correct = 0usize;
    for &(l, ok) in &results {
        loss_sum += l;
        correct += ok as usize;
    }
    (loss_sum, correct, grad)
}

/// Coordinate-wise ordered reduction of the per-block gradient buffers:
/// each coordinate sums its block contributions in block order, fanned
/// out over `GRAD_CHUNK`-wide coordinate chunks — chunk boundaries are
/// fixed, so the result is independent of the worker count.
fn reduce_blocks(block_gs: &[f32], nblocks: usize, param_total: usize) -> Vec<f32> {
    let mut g = vec![0.0f32; param_total];
    g.par_chunks_mut(GRAD_CHUNK).enumerate().for_each(|(ci, chunk)| {
        let base = ci * GRAD_CHUNK;
        for bi in 0..nblocks {
            let src = &block_gs[bi * param_total + base..][..chunk.len()];
            for (dst, s) in chunk.iter_mut().zip(src) {
                *dst += *s;
            }
        }
    });
    g
}

/// An executable DNN (or DNN segment): ops + offset bookkeeping + an
/// optional softmax-xent head.
pub struct LayerGraph {
    ops: Vec<Box<dyn Op>>,
    /// (start, len) of each op's parameter block in the flat gradient.
    param_off: Vec<(usize, usize)>,
    /// (first ABI tensor index, tensor count) per op.
    tensor_off: Vec<(usize, usize)>,
    /// ABI parameter tensor shapes (concatenated op `param_shapes`).
    param_shapes: Vec<Vec<usize>>,
    param_total: usize,
    /// Activation-arena offset of each op's output.
    act_off: Vec<usize>,
    act_total: usize,
    /// Largest activation length (backward scratch size).
    max_act: usize,
    in_len: usize,
    /// Per-sample output element count (= `in_len` for an empty segment).
    out_len: usize,
    /// Per-sample input tensor shape (`[H, W, C]` or `[S]`).
    input_shape: Vec<usize>,
    classes: usize,
    /// The loss head — `Some` for full graphs and gateway (top) segments,
    /// `None` for device (bottom) segments.
    head: Option<SoftmaxXent>,
    /// Which kernel implementation every op of this graph dispatches to.
    kernel: KernelPath,
}

impl LayerGraph {
    /// Compile the whole of `spec` into an executable graph with a
    /// `classes`-way softmax cross-entropy head. Fails when a layer's
    /// geometry is not natively executable: only SAME stride-1 odd-kernel
    /// convolutions, non-overlapping max pools, and dense layers are
    /// implemented.
    pub fn from_spec(spec: &ModelSpec, classes: usize) -> Result<Self> {
        Self::from_spec_kernel(spec, classes, KernelPath::default())
    }

    /// [`Self::from_spec`] with an explicit [`KernelPath`].
    pub fn from_spec_kernel(
        spec: &ModelSpec,
        classes: usize,
        kernel: KernelPath,
    ) -> Result<Self> {
        if spec.layers.is_empty() {
            bail!("model {:?} has no layers", spec.name);
        }
        let g = Self::from_spec_range_kernel(spec, classes, 0, spec.depth(), true, kernel)?;
        if g.param_total == 0 {
            bail!("{}: no parameterized layers", spec.name);
        }
        Ok(g)
    }

    /// Compile spec layers `lo..hi` into a segment graph — the unit the
    /// split-execution runtime is built from (paper §II-B: the bottom `l`
    /// layers train on the device, the top `L − l` on the gateway).
    ///
    /// `with_head = true` attaches the softmax-xent head and requires the
    /// segment to end in `classes` logits (a gateway/top half or a full
    /// graph); `with_head = false` compiles a headless device/bottom half
    /// whose output is the smashed activation at the cut. Either half may
    /// be empty (`lo == hi`): an empty bottom half forwards the raw input,
    /// an empty top half (`lo == hi == depth`) is the bare loss head.
    pub fn from_spec_range(
        spec: &ModelSpec,
        classes: usize,
        lo: usize,
        hi: usize,
        with_head: bool,
    ) -> Result<Self> {
        Self::from_spec_range_kernel(spec, classes, lo, hi, with_head, KernelPath::default())
    }

    /// [`Self::from_spec_range`] with an explicit [`KernelPath`] — the
    /// partitioned backend compiles BOTH halves with the same path, so a
    /// split run's numerics match the equally-configured fused run.
    pub fn from_spec_range_kernel(
        spec: &ModelSpec,
        classes: usize,
        lo: usize,
        hi: usize,
        with_head: bool,
        kernel: KernelPath,
    ) -> Result<Self> {
        let depth = spec.depth();
        if lo > hi || hi > depth {
            bail!("{}: layer range {lo}..{hi} outside 0..={depth}", spec.name);
        }
        // The segment's input shape: declared by its first layer; an empty
        // top segment at the very end consumes the logits directly.
        let mut cur = match spec.layers.get(lo) {
            Some(layer) => layer_input_shape(layer),
            None => Shape::Flat(classes),
        };
        let in_len = cur.len();
        let input_shape = cur.as_dims();

        let mut ops: Vec<Box<dyn Op>> = Vec::new();
        for (li, layer) in spec.layers[lo..hi].iter().enumerate() {
            let li = lo + li;
            match *layer {
                Layer::Conv { ci, hi, wi, co, ho, wo, hf, wf, act } => {
                    let (ci, hi, wi) = (ci as usize, hi as usize, wi as usize);
                    let (co, ho, wo) = (co as usize, ho as usize, wo as usize);
                    let (hf, wf) = (hf as usize, wf as usize);
                    if cur != Shape::Spatial(hi, wi, ci) {
                        bail!(
                            "{} layer {li}: conv input {hi}x{wi}x{ci} does not chain \
                             (previous output is {cur:?})",
                            spec.name
                        );
                    }
                    if ho != hi || wo != wi {
                        bail!(
                            "{} layer {li}: only SAME stride-1 convolutions run natively \
                             ({hi}x{wi} -> {ho}x{wo})",
                            spec.name
                        );
                    }
                    if hf % 2 == 0 || wf % 2 == 0 {
                        bail!(
                            "{} layer {li}: SAME padding needs odd kernels, got {hf}x{wf}",
                            spec.name
                        );
                    }
                    ops.push(Box::new(Conv2d { ci, co, h: hi, w: wi, kh: hf, kw: wf, kernel }));
                    if act == Activation::Relu {
                        ops.push(Box::new(Relu { n: ho * wo * co }));
                    }
                    cur = Shape::Spatial(ho, wo, co);
                }
                Layer::Pool { ci, hi, wi, co, ho, wo, kind } => {
                    let (ci, hi, wi) = (ci as usize, hi as usize, wi as usize);
                    let (co, ho, wo) = (co as usize, ho as usize, wo as usize);
                    if cur != Shape::Spatial(hi, wi, ci) {
                        bail!(
                            "{} layer {li}: pool input {hi}x{wi}x{ci} does not chain \
                             (previous output is {cur:?})",
                            spec.name
                        );
                    }
                    if co != ci {
                        bail!("{} layer {li}: pooling must preserve channels", spec.name);
                    }
                    if kind != PoolKind::Max {
                        bail!("{} layer {li}: only max pooling runs natively", spec.name);
                    }
                    if ho == 0 || wo == 0 || hi % ho != 0 || wi % wo != 0 {
                        bail!(
                            "{} layer {li}: pool {hi}x{wi} -> {ho}x{wo} is not an \
                             integer non-overlapping window",
                            spec.name
                        );
                    }
                    ops.push(Box::new(MaxPool2d {
                        c: ci,
                        hi,
                        wi,
                        kh: hi / ho,
                        kw: wi / wo,
                    }));
                    cur = Shape::Spatial(ho, wo, co);
                }
                Layer::Fc { si, so, act } => {
                    let (si, so) = (si as usize, so as usize);
                    if let Shape::Spatial(h, w, c) = cur {
                        ops.push(Box::new(Flatten { n: h * w * c }));
                        cur = Shape::Flat(h * w * c);
                    }
                    if cur != Shape::Flat(si) {
                        bail!(
                            "{} layer {li}: fc input {si} does not chain \
                             (previous output is {cur:?})",
                            spec.name
                        );
                    }
                    ops.push(Box::new(Dense { si, so, kernel }));
                    if act == Activation::Relu {
                        ops.push(Box::new(Relu { n: so }));
                    }
                    cur = Shape::Flat(so);
                }
            }
        }
        if with_head && cur != Shape::Flat(classes) {
            bail!(
                "{}: the final layer must emit {classes} logits, got {cur:?}",
                spec.name
            );
        }

        let mut param_off = Vec::with_capacity(ops.len());
        let mut tensor_off = Vec::with_capacity(ops.len());
        let mut param_shapes: Vec<Vec<usize>> = Vec::new();
        let mut act_off = Vec::with_capacity(ops.len());
        let (mut ptot, mut atot) = (0usize, 0usize);
        let mut max_act = in_len;
        for op in ops.iter() {
            let shapes = op.param_shapes();
            let len: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
            param_off.push((ptot, len));
            tensor_off.push((param_shapes.len(), shapes.len()));
            param_shapes.extend(shapes);
            ptot += len;
            act_off.push(atot);
            atot += op.out_len();
            max_act = max_act.max(op.out_len());
        }
        Ok(LayerGraph {
            ops,
            param_off,
            tensor_off,
            param_shapes,
            param_total: ptot,
            act_off,
            act_total: atot,
            max_act,
            in_len,
            out_len: cur.len(),
            input_shape,
            classes,
            head: with_head.then_some(SoftmaxXent { classes }),
            kernel,
        })
    }

    /// The kernel path this graph's ops run on.
    pub fn kernel(&self) -> KernelPath {
        self.kernel
    }

    /// Gradient-accumulation block size of the batch executor for this
    /// graph's kernel path (see `run_blocked`).
    pub(crate) fn sample_block(&self) -> usize {
        match self.kernel {
            KernelPath::Scalar => 1,
            KernelPath::Vectorized => SAMPLE_BLOCK,
        }
    }

    pub fn param_total(&self) -> usize {
        self.param_total
    }

    pub fn param_shapes(&self) -> &[Vec<usize>] {
        &self.param_shapes
    }

    pub fn in_len(&self) -> usize {
        self.in_len
    }

    /// Per-sample output element count of the segment — at a partition
    /// point this is the size of the smashed activation the device uploads
    /// (and of the cut gradient the gateway returns).
    pub fn out_len(&self) -> usize {
        self.out_len
    }

    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    pub fn num_ops(&self) -> usize {
        self.ops.len()
    }

    /// Whether this graph carries the softmax-xent loss head.
    pub fn has_head(&self) -> bool {
        self.head.is_some()
    }

    /// Deterministic init: ONE RNG stream walks the ops in ABI order —
    /// He-normal weights, zero biases, and a zero-init head (the last
    /// parameterized op), so the initial loss is exactly ln C.
    pub fn init_params(&self, seed: u64) -> Params {
        let mut rng = Rng::new(seed);
        self.init_params_with(&mut rng, true)
    }

    /// Init from a caller-supplied RNG stream: He-normal weights, zero
    /// biases; when `zero_last`, the LAST parameterized op of this segment
    /// is zero-initialised instead (the logits head of the overall model).
    /// Splitting a model and initialising bottom-then-top with one shared
    /// stream (zeroing only the half that holds the model head) reproduces
    /// the fused init stream byte for byte.
    pub fn init_params_with(&self, rng: &mut Rng, zero_last: bool) -> Params {
        let last_param = self
            .ops
            .iter()
            .rposition(|op| !op.param_shapes().is_empty());
        let mut out: Params = Vec::with_capacity(self.param_shapes.len());
        for (i, op) in self.ops.iter().enumerate() {
            let tensors = if zero_last && Some(i) == last_param {
                op.init_params(None)
            } else {
                op.init_params(Some(&mut *rng))
            };
            out.extend(tensors);
        }
        out
    }

    /// This op's parameter tensors as slices (ABI order).
    fn op_params<'a>(&self, params: &'a [Vec<f32>], i: usize) -> Vec<&'a [f32]> {
        let (t0, tn) = self.tensor_off[i];
        params[t0..t0 + tn].iter().map(|t| t.as_slice()).collect()
    }

    /// Per-sample forward through every op (no loss head) into a reusable
    /// arena buffer (grown, never shrunk — no per-sample allocation after
    /// warm-up); returns the filled `[..act_total]` prefix. An empty
    /// segment returns an empty arena — its output is the input itself
    /// (see [`Self::output_slice`]).
    pub(crate) fn forward_arena_into<'a>(
        &self,
        params: &[Vec<f32>],
        xs: &[f32],
        acts: &'a mut Vec<f32>,
    ) -> &'a mut [f32] {
        kernels::ensure(acts, self.act_total);
        let acts = &mut acts[..self.act_total];
        for (i, op) in self.ops.iter().enumerate() {
            let (prev, cur) = acts.split_at_mut(self.act_off[i]);
            let input: &[f32] = if i == 0 { xs } else { &prev[self.act_off[i - 1]..] };
            let pv = self.op_params(params, i);
            op.forward(&pv, input, &mut cur[..op.out_len()]);
        }
        acts
    }

    /// The segment's per-sample output inside (`xs`, `acts`): the last
    /// op's activation, or `xs` itself when the segment has no ops.
    pub(crate) fn output_slice<'a>(&self, xs: &'a [f32], acts: &'a [f32]) -> &'a [f32] {
        match self.ops.last() {
            None => xs,
            Some(op) => {
                let off = self.act_off[self.ops.len() - 1];
                &acts[off..off + op.out_len()]
            }
        }
    }

    /// Loss head on a logits slice (gateway/full graphs only): returns
    /// (per-sample loss, argmax == label) and — when `grad_scale` is
    /// `Some(1/B)` — writes dL/dz of the mean batch loss into `dz`.
    pub(crate) fn head_loss_grad(
        &self,
        logits: &[f32],
        label: usize,
        grad_scale: Option<f32>,
        dz: &mut [f32],
    ) -> (f64, bool) {
        self.head
            .as_ref()
            .expect("loss head requested on a headless segment")
            .loss_grad(logits, label, grad_scale, dz)
    }

    /// Per-sample backward from the error `dy` at the segment output:
    /// accumulates every op's parameter gradient into `g` (length
    /// [`Self::param_total`]) and, when `want_dx`, leaves the error at
    /// the segment *input* — the cut gradient a gateway half sends back
    /// to its device half — in `dx_buf[..in_len]`, returning `true`.
    /// An empty segment echoes `dy` into `dx_buf` (identity).
    ///
    /// `dy_buf`/`dx_buf` are reusable per-worker scratch (the backward
    /// ping-pong pair); their `Vec` allocations may be swapped with each
    /// other, but when the result is `true` it is ALWAYS readable from
    /// the `dx_buf` binding the caller passed.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn backward_arena(
        &self,
        params: &[Vec<f32>],
        xs: &[f32],
        acts: &[f32],
        dy: &[f32],
        g: &mut [f32],
        dy_buf: &mut Vec<f32>,
        dx_buf: &mut Vec<f32>,
        want_dx: bool,
    ) -> bool {
        let nops = self.ops.len();
        kernels::ensure(dx_buf, self.max_act.max(dy.len()));
        if nops == 0 {
            if want_dx {
                dx_buf[..dy.len()].copy_from_slice(dy);
            }
            return want_dx;
        }
        kernels::ensure(dy_buf, self.max_act);
        dy_buf[..dy.len()].copy_from_slice(dy);
        for i in (0..nops).rev() {
            let op = &self.ops[i];
            let pv = self.op_params(params, i);
            let (po, pl) = self.param_off[i];
            let dp = &mut g[po..po + pl];
            if i == 0 {
                if want_dx {
                    op.backward(
                        &pv,
                        xs,
                        &dy_buf[..op.out_len()],
                        Some(&mut dx_buf[..op.in_len()]),
                        dp,
                    );
                } else {
                    op.backward(&pv, xs, &dy_buf[..op.out_len()], None, dp);
                }
                return want_dx;
            }
            let off = self.act_off[i - 1];
            let input = &acts[off..off + op.in_len()];
            op.backward(
                &pv,
                input,
                &dy_buf[..op.out_len()],
                Some(&mut dx_buf[..op.in_len()]),
                dp,
            );
            std::mem::swap(dy_buf, dx_buf);
        }
        unreachable!("loop returns at i == 0")
    }

    /// One sample on this worker's scratch: forward through the arena,
    /// loss head, and — when `g` is set — backward, ACCUMULATING the
    /// sample's parameter gradient into `g` (`grad_scale` must then be
    /// `Some(1/B)`). No heap allocation after scratch warm-up.
    fn fwd_bwd_sample(
        &self,
        params: &Params,
        xs: &[f32],
        label: usize,
        grad_scale: Option<f32>,
        g: Option<&mut [f32]>,
    ) -> (f64, bool) {
        with_scratch(|s| {
            let GraphScratch { acts, dy, dx, dz, .. } = s;
            let acts = self.forward_arena_into(params, xs, acts);
            let logits = self.output_slice(xs, acts);
            kernels::ensure(dz, self.classes);
            let dz = &mut dz[..self.classes];
            let (loss, ok) = self.head_loss_grad(logits, label, grad_scale, dz);
            if let Some(g) = g {
                self.backward_arena(params, xs, acts, dz, g, dy, dx, false);
            }
            (loss, ok)
        })
    }

    /// Batched forward (+ optional backward): returns the summed
    /// per-sample loss, the argmax-correct count, and — when requested —
    /// the flat gradient of the MEAN loss. Sample blocks fan out over
    /// rayon through the blocked executor; both reductions are ordered,
    /// so the result is independent of the worker count — byte-identical
    /// across pool sizes on either kernel path.
    pub fn fwd_bwd(
        &self,
        params: &Params,
        x: &[f32],
        y: &[i32],
        want_grad: bool,
    ) -> (f64, usize, Option<Vec<f32>>) {
        let b = y.len();
        let grad_scale = want_grad.then_some(1.0f32 / b as f32);
        run_blocked(b, self.sample_block(), self.param_total, want_grad, |s, g| {
            self.fwd_bwd_sample(
                params,
                &x[s * self.in_len..(s + 1) * self.in_len],
                y[s] as usize,
                grad_scale,
                g,
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::models;

    /// A small conv net whose cost-model description doubles as the
    /// executable description — the single-source-of-truth property.
    fn tiny_cnn_spec() -> ModelSpec {
        ModelSpec::new(
            "tiny",
            vec![
                Layer::Conv {
                    ci: 2,
                    hi: 6,
                    wi: 6,
                    co: 3,
                    ho: 6,
                    wo: 6,
                    hf: 3,
                    wf: 3,
                    act: Activation::Relu,
                },
                Layer::Pool {
                    ci: 3,
                    hi: 6,
                    wi: 6,
                    co: 3,
                    ho: 3,
                    wo: 3,
                    kind: PoolKind::Max,
                },
                Layer::Fc { si: 27, so: 10, act: Activation::Linear },
            ],
        )
    }

    #[test]
    fn builds_executable_presets_from_the_model_zoo() {
        let mlp = LayerGraph::from_spec(&models::mlp(), 10).unwrap();
        assert_eq!(mlp.param_total(), 3072 * 64 + 64 + 64 * 10 + 10);
        assert_eq!(mlp.in_len(), 3072);
        assert_eq!(mlp.input_shape(), &[3072]);
        assert_eq!(mlp.out_len(), 10);
        assert!(mlp.has_head());
        // dense, relu, dense
        assert_eq!(mlp.num_ops(), 3);

        let cnn = LayerGraph::from_spec(&models::vgg_mini(), 10).unwrap();
        assert_eq!(cnn.in_len(), 32 * 32 * 3);
        assert_eq!(cnn.input_shape(), &[32, 32, 3]);
        // 3x (conv, relu, pool) + flatten + dense + relu + dense
        assert_eq!(cnn.num_ops(), 13);
        // The ABI order and totals match python/compile/model.py.
        assert_eq!(
            cnn.param_shapes(),
            &[
                vec![3, 3, 3, 16],
                vec![16],
                vec![3, 3, 16, 32],
                vec![32],
                vec![3, 3, 32, 64],
                vec![64],
                vec![1024, 128],
                vec![128],
                vec![128, 10],
                vec![10],
            ]
        );
        assert_eq!(cnn.param_total(), models::vgg_mini().params as usize + 16 + 32 + 64 + 128 + 10);
    }

    #[test]
    fn vgg11_compiles_too() {
        // The paper-scale objective DNN is also executable in principle.
        let g = LayerGraph::from_spec(&models::vgg11_cifar(), 10).unwrap();
        assert_eq!(g.param_total(), {
            let m = models::vgg11_cifar();
            // weights + biases (one bias per conv/fc output channel)
            m.params as usize
                + (64 + 128 + 256 + 256 + 512 + 512 + 512 + 512)
                + (4096 + 4096 + 10)
        });
    }

    #[test]
    fn segment_compilation_covers_every_cut_point() {
        // Each half compiles at every spec-layer boundary; the halves chain
        // (bottom output length == top input length) and their ABI tensor
        // lists concatenate to the fused graph's.
        for spec in [models::mlp(), models::vgg_mini(), tiny_cnn_spec()] {
            let full = LayerGraph::from_spec(&spec, 10).unwrap();
            for cut in 0..=spec.depth() {
                let bottom =
                    LayerGraph::from_spec_range(&spec, 10, 0, cut, false).unwrap();
                let top =
                    LayerGraph::from_spec_range(&spec, 10, cut, spec.depth(), true).unwrap();
                assert!(!bottom.has_head());
                assert!(top.has_head());
                assert_eq!(bottom.out_len(), top.in_len(), "{} cut {cut}", spec.name);
                assert_eq!(bottom.in_len(), full.in_len());
                assert_eq!(top.out_len(), 10);
                let mut shapes = bottom.param_shapes().to_vec();
                shapes.extend(top.param_shapes().iter().cloned());
                assert_eq!(shapes, full.param_shapes(), "{} cut {cut}", spec.name);
                assert_eq!(
                    bottom.param_total() + top.param_total(),
                    full.param_total()
                );
            }
        }
    }

    #[test]
    fn split_init_with_shared_stream_matches_fused_init() {
        for spec in [models::mlp(), models::vgg_mini()] {
            let full = LayerGraph::from_spec(&spec, 10).unwrap();
            for cut in 0..=spec.depth() {
                let bottom =
                    LayerGraph::from_spec_range(&spec, 10, 0, cut, false).unwrap();
                let top =
                    LayerGraph::from_spec_range(&spec, 10, cut, spec.depth(), true).unwrap();
                let mut rng = Rng::new(42);
                let top_has_params = top.param_total() > 0;
                let mut split = bottom.init_params_with(&mut rng, !top_has_params);
                split.extend(top.init_params_with(&mut rng, top_has_params));
                assert_eq!(split, full.init_params(42), "{} cut {cut}", spec.name);
            }
        }
    }

    #[test]
    fn rejects_unchainable_and_inexecutable_specs() {
        // Mismatched fc width.
        let bad = ModelSpec::new(
            "bad",
            vec![
                Layer::Fc { si: 10, so: 5, act: Activation::Relu },
                Layer::Fc { si: 6, so: 10, act: Activation::Linear },
            ],
        );
        assert!(LayerGraph::from_spec(&bad, 10).is_err());
        // Wrong head width.
        let bad2 = ModelSpec::new(
            "bad2",
            vec![Layer::Fc { si: 10, so: 7, act: Activation::Linear }],
        );
        assert!(LayerGraph::from_spec(&bad2, 10).is_err());
        // Average pooling is cost-model-only.
        let bad3 = ModelSpec::new(
            "bad3",
            vec![
                Layer::Pool { ci: 1, hi: 4, wi: 4, co: 1, ho: 2, wo: 2, kind: PoolKind::Avg },
                Layer::Fc { si: 4, so: 10, act: Activation::Linear },
            ],
        );
        assert!(LayerGraph::from_spec(&bad3, 10).is_err());
        // Strided conv is not executable.
        let bad4 = ModelSpec::new(
            "bad4",
            vec![
                Layer::Conv {
                    ci: 1,
                    hi: 8,
                    wi: 8,
                    co: 1,
                    ho: 4,
                    wo: 4,
                    hf: 3,
                    wf: 3,
                    act: Activation::Relu,
                },
                Layer::Fc { si: 16, so: 10, act: Activation::Linear },
            ],
        );
        assert!(LayerGraph::from_spec(&bad4, 10).is_err());
        // A segment range outside the model is rejected too.
        let m = models::mlp();
        assert!(LayerGraph::from_spec_range(&m, 10, 0, 3, false).is_err());
        assert!(LayerGraph::from_spec_range(&m, 10, 2, 1, false).is_err());
    }

    #[test]
    fn init_is_deterministic_with_zero_head() {
        let g = LayerGraph::from_spec(&tiny_cnn_spec(), 10).unwrap();
        let p1 = g.init_params(42);
        let p2 = g.init_params(42);
        assert_eq!(p1, p2);
        assert_ne!(p1[0], g.init_params(43)[0]);
        // Head (last dense) is zero-initialised, conv weights are not.
        assert!(p1[0].iter().any(|&v| v != 0.0));
        assert!(p1[2].iter().all(|&v| v == 0.0));
        assert!(p1[3].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn zero_head_loss_is_ln10_and_grad_checks_through_the_whole_graph() {
        let g = LayerGraph::from_spec(&tiny_cnn_spec(), 10).unwrap();
        let mut p = g.init_params(7);
        // Perturb the head so gradients flow through every layer.
        let mut rng = Rng::new(8);
        let b = 4usize;
        let (loss0, _, _) = {
            let x: Vec<f32> =
                (0..b * g.in_len()).map(|_| (rng.normal() * 0.5) as f32).collect();
            let y: Vec<i32> = (0..b).map(|_| (rng.below(10)) as i32).collect();
            g.fwd_bwd(&p, &x, &y, false)
        };
        assert!((loss0 / b as f64 - 10f64.ln()).abs() < 1e-6);

        // Perturb the head (dense w/b, tensors 2 and 3) so gradients flow
        // through conv and pool as well.
        for v in p[2].iter_mut().chain(p[3].iter_mut()) {
            *v = (rng.normal() * 0.2) as f32;
        }
        let x: Vec<f32> =
            (0..b * g.in_len()).map(|_| (rng.normal() * 0.8) as f32).collect();
        let y: Vec<i32> = (0..b).map(|i| (i % 10) as i32).collect();
        let (_, _, grad) = g.fwd_bwd(&p, &x, &y, true);
        let grad = grad.unwrap();
        assert_eq!(grad.len(), g.param_total());

        let mean_loss = |p: &Params| -> f64 {
            let (l, _, _) = g.fwd_bwd(p, &x, &y, false);
            l / b as f64
        };
        // Probe a few coordinates in every tensor (conv w/b, fc w/b).
        let mut flat_base = vec![0usize; p.len()];
        for t in 1..p.len() {
            flat_base[t] = flat_base[t - 1] + p[t - 1].len();
        }
        let probes = [(0usize, 1usize), (0, 17), (1, 2), (2, 5), (2, 40), (3, 1)];
        let eps = 1e-2f32;
        for (t, i) in probes {
            let mut hi = p.clone();
            hi[t][i] += eps;
            let mut lo = p.clone();
            lo[t][i] -= eps;
            let num = (mean_loss(&hi) - mean_loss(&lo)) / (2.0 * eps as f64);
            let ana = grad[flat_base[t] + i] as f64;
            assert!(
                (num - ana).abs() < 2e-3 + 0.05 * ana.abs(),
                "tensor {t} idx {i}: numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn kernel_paths_share_init_bits_and_agree_within_tolerance() {
        let spec = tiny_cnn_spec();
        let gv = LayerGraph::from_spec(&spec, 10).unwrap();
        assert_eq!(gv.kernel(), KernelPath::Vectorized);
        let gs = LayerGraph::from_spec_kernel(&spec, 10, KernelPath::Scalar).unwrap();
        // Init touches no kernel arithmetic: identical bits on both paths.
        let mut p = gs.init_params(12);
        assert_eq!(p, gv.init_params(12));
        let mut rng = Rng::new(13);
        for v in p[2].iter_mut().chain(p[3].iter_mut()) {
            *v = (rng.normal() * 0.2) as f32;
        }
        // Batch size deliberately NOT a multiple of the vectorized
        // sample block, so the ragged tail block is exercised.
        let b = 6usize;
        let x: Vec<f32> =
            (0..b * gs.in_len()).map(|_| (rng.normal() * 0.6) as f32).collect();
        let y: Vec<i32> = (0..b).map(|i| (i % 10) as i32).collect();
        let (ls, cs, grs) = gs.fwd_bwd(&p, &x, &y, true);
        let (lv, cv, grv) = gv.fwd_bwd(&p, &x, &y, true);
        assert!((ls - lv).abs() < 1e-4 * (1.0 + ls.abs()), "loss {ls} vs {lv}");
        assert_eq!(cs, cv);
        let (grs, grv) = (grs.unwrap(), grv.unwrap());
        assert_eq!(grs.len(), grv.len());
        for (i, (a, v)) in grs.iter().zip(&grv).enumerate() {
            assert!((a - v).abs() < 1e-4 + 2e-3 * a.abs(), "grad[{i}]: {a} vs {v}");
        }
    }

    #[test]
    fn batch_reduction_is_independent_of_worker_count() {
        // Run the same batch through differently-sized rayon pools: the
        // ordered reduction must make the results bit-identical.
        let g = LayerGraph::from_spec(&tiny_cnn_spec(), 10).unwrap();
        let mut p = g.init_params(3);
        let mut rng = Rng::new(4);
        for v in p[2].iter_mut().chain(p[3].iter_mut()) {
            *v = (rng.normal() * 0.2) as f32;
        }
        let b = 16usize;
        let x: Vec<f32> = (0..b * g.in_len()).map(|_| (rng.normal() * 0.7) as f32).collect();
        let y: Vec<i32> = (0..b).map(|i| (i % 10) as i32).collect();

        let (l0, c0, g0) = g.fwd_bwd(&p, &x, &y, true);
        for threads in [1usize, 3] {
            let pool = rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
            let (l, c, gg) = pool.install(|| g.fwd_bwd(&p, &x, &y, true));
            assert_eq!(l.to_bits(), l0.to_bits(), "{threads} threads");
            assert_eq!(c, c0);
            let (a, b2) = (gg.unwrap(), g0.clone().unwrap());
            assert_eq!(a.len(), b2.len());
            for (i, (va, vb)) in a.iter().zip(&b2).enumerate() {
                assert_eq!(va.to_bits(), vb.to_bits(), "grad[{i}] differs");
            }
        }
    }

    #[test]
    fn training_the_tiny_graph_reduces_loss() {
        let g = LayerGraph::from_spec(&tiny_cnn_spec(), 10).unwrap();
        let mut p = g.init_params(5);
        let mut rng = Rng::new(6);
        let b = 8usize;
        let x: Vec<f32> = (0..b * g.in_len()).map(|_| (rng.normal() * 0.8) as f32).collect();
        let y: Vec<i32> = (0..b).map(|_| rng.below(10) as i32).collect();
        let first = g.fwd_bwd(&p, &x, &y, false).0 / b as f64;
        for _ in 0..30 {
            let (_, _, grad) = g.fwd_bwd(&p, &x, &y, true);
            let grad = grad.unwrap();
            let mut off = 0usize;
            for t in p.iter_mut() {
                for v in t.iter_mut() {
                    *v -= 0.5 * grad[off];
                    off += 1;
                }
            }
        }
        let last = g.fwd_bwd(&p, &x, &y, false).0 / b as f64;
        assert!(last < first - 0.5, "memorising one batch: {first} -> {last}");
    }
}
