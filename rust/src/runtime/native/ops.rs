//! The op library of the native layer-graph engine.
//!
//! Each op is one executable DNN layer over flat `f32` buffers in
//! per-sample channels-last (NHWC) layout — the same layout the PJRT
//! artifact family uses, so parameters and activations stay
//! interchangeable between engines. Ops expose a uniform
//! forward / backward / param_shapes interface; `super::graph::LayerGraph`
//! composes them and owns every offset.
//!
//! `backward` consumes the op's *input* activation (cached by the graph
//! during the forward pass) and the upstream error `dy`, accumulates this
//! op's parameter gradients into `dp` (its tensors concatenated flat, ABI
//! order), and — except at the graph input, where `dx` is `None` — writes
//! the downstream error into `dx` (every element; ops that scatter, like
//! max-pool, zero-fill first).
//!
//! Numerics note: on the [`KernelPath::Scalar`] path the Dense loops
//! (bias copy, zero-input skip, k-order accumulation) reproduce the
//! retired fused mlp backend instruction for instruction, so the graph
//! engine is bit-identical to it — the golden test in `super::tests` pins
//! this. The [`KernelPath::Vectorized`] path (the default) runs `Dense`
//! and `Conv2d` on the blocked kernels in [`super::kernels`] — same math,
//! different (faster) summation order; parity is bounded by tolerance in
//! `rust/tests/kernel_parity.rs`, and each path is individually
//! deterministic.

use crate::rng::Rng;

use super::kernels::{self, KernelPath};

/// One executable layer.
pub trait Op: Send + Sync {
    fn name(&self) -> &'static str;

    /// Per-sample input element count.
    fn in_len(&self) -> usize;

    /// Per-sample output element count.
    fn out_len(&self) -> usize;

    /// Parameter tensor shapes in ABI order; empty for param-free ops.
    fn param_shapes(&self) -> Vec<Vec<usize>> {
        Vec::new()
    }

    /// Deterministic parameter init: He-normal weights drawn from `rng`,
    /// zero biases. `None` requests the zero-init head (all-zero logits at
    /// init, so the initial loss is exactly ln C).
    fn init_params(&self, _rng: Option<&mut Rng>) -> Vec<Vec<f32>> {
        Vec::new()
    }

    /// Per-sample forward. `params` holds this op's tensors (ABI order);
    /// `out` has exactly `out_len()` elements and is fully written.
    fn forward(&self, params: &[&[f32]], x: &[f32], out: &mut [f32]);

    /// Per-sample backward; see the module docs for the contract.
    fn backward(
        &self,
        params: &[&[f32]],
        x: &[f32],
        dy: &[f32],
        dx: Option<&mut [f32]>,
        dp: &mut [f32],
    );
}

/// He-normal weight buffer: `normal() * sqrt(2 / fan_in)`, drawn
/// sequentially so the init stream is deterministic per graph seed.
fn he_normal(rng: &mut Rng, n: usize, fan_in: usize) -> Vec<f32> {
    let scale = (2.0 / fan_in as f64).sqrt();
    (0..n).map(|_| (rng.normal() * scale) as f32).collect()
}

// ---------------------------------------------------------------------------
// Dense
// ---------------------------------------------------------------------------

/// Fully connected: `out = x · W + b`, `W` row-major `[si, so]`.
pub struct Dense {
    pub si: usize,
    pub so: usize,
    pub kernel: KernelPath,
}

impl Op for Dense {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn in_len(&self) -> usize {
        self.si
    }

    fn out_len(&self) -> usize {
        self.so
    }

    fn param_shapes(&self) -> Vec<Vec<usize>> {
        vec![vec![self.si, self.so], vec![self.so]]
    }

    fn init_params(&self, rng: Option<&mut Rng>) -> Vec<Vec<f32>> {
        let w = match rng {
            Some(rng) => he_normal(rng, self.si * self.so, self.si),
            None => vec![0.0; self.si * self.so],
        };
        vec![w, vec![0.0; self.so]]
    }

    fn forward(&self, params: &[&[f32]], x: &[f32], out: &mut [f32]) {
        let (w, b) = (params[0], params[1]);
        out.copy_from_slice(b);
        match self.kernel {
            KernelPath::Scalar => {
                for i in 0..self.si {
                    let xi = x[i];
                    if xi != 0.0 {
                        let row = &w[i * self.so..(i + 1) * self.so];
                        for j in 0..self.so {
                            out[j] += xi * row[j];
                        }
                    }
                }
            }
            KernelPath::Vectorized => {
                // Same i-order accumulation as the scalar loop (axpy is
                // per-coordinate), just 8-wide; the zero-input skip is
                // kept — ReLU outputs make x genuinely sparse.
                for i in 0..self.si {
                    let xi = x[i];
                    if xi != 0.0 {
                        kernels::axpy(xi, &w[i * self.so..(i + 1) * self.so], out);
                    }
                }
            }
        }
    }

    fn backward(
        &self,
        params: &[&[f32]],
        x: &[f32],
        dy: &[f32],
        dx: Option<&mut [f32]>,
        dp: &mut [f32],
    ) {
        let w = params[0];
        let (dw, db) = dp.split_at_mut(self.si * self.so);
        match self.kernel {
            KernelPath::Scalar => {
                if let Some(dx) = dx {
                    for i in 0..self.si {
                        let row = &w[i * self.so..(i + 1) * self.so];
                        let mut acc = 0.0f32;
                        for j in 0..self.so {
                            acc += row[j] * dy[j];
                        }
                        dx[i] = acc;
                    }
                }
                for i in 0..self.si {
                    let xi = x[i];
                    if xi != 0.0 {
                        let drow = &mut dw[i * self.so..(i + 1) * self.so];
                        for j in 0..self.so {
                            drow[j] += xi * dy[j];
                        }
                    }
                }
            }
            KernelPath::Vectorized => {
                if let Some(dx) = dx {
                    // dx = W · dy, one lane-blocked dot per input row.
                    for i in 0..self.si {
                        dx[i] = kernels::dot(&w[i * self.so..(i + 1) * self.so], dy);
                    }
                }
                for i in 0..self.si {
                    let xi = x[i];
                    if xi != 0.0 {
                        kernels::axpy(xi, dy, &mut dw[i * self.so..(i + 1) * self.so]);
                    }
                }
            }
        }
        for j in 0..self.so {
            db[j] += dy[j];
        }
    }
}

// ---------------------------------------------------------------------------
// Conv2d (SAME padding, stride 1, odd kernel, HWIO weights)
// ---------------------------------------------------------------------------

/// 2-D convolution over an `h x w x ci` channels-last input, producing
/// `h x w x co` (SAME padding, stride 1). Weights are HWIO
/// `[kh, kw, ci, co]` — the JAX/artifact convention.
pub struct Conv2d {
    pub ci: usize,
    pub co: usize,
    pub h: usize,
    pub w: usize,
    pub kh: usize,
    pub kw: usize,
    pub kernel: KernelPath,
}

impl Conv2d {
    /// (output-row range, input-row delta) for kernel row `kr`: SAME
    /// padding clips positions whose input row falls off the image.
    #[inline]
    fn row_range(&self, kr: usize) -> (usize, usize) {
        let ph = (self.kh - 1) / 2;
        let lo = ph.saturating_sub(kr);
        let hi = (self.h + ph).saturating_sub(kr).min(self.h);
        (lo, hi)
    }

    #[inline]
    fn col_range(&self, kc: usize) -> (usize, usize) {
        let pw = (self.kw - 1) / 2;
        let lo = pw.saturating_sub(kc);
        let hi = (self.w + pw).saturating_sub(kc).min(self.w);
        (lo, hi)
    }

    /// Vectorized forward: gather the receptive fields into a per-worker
    /// patch matrix `P [h·w, kh·kw·ci]`, then `out = bias + P · W` as one
    /// register-blocked matmul over the HWIO weight matrix
    /// `[kh·kw·ci, co]`.
    fn forward_vectorized(&self, params: &[&[f32]], x: &[f32], out: &mut [f32]) {
        let (wt, b) = (params[0], params[1]);
        let (m, kk, co) = (self.h * self.w, self.kh * self.kw * self.ci, self.co);
        for p in 0..m {
            out[p * co..(p + 1) * co].copy_from_slice(b);
        }
        kernels::with_conv_scratch(|s| {
            kernels::ensure(&mut s.patches, m * kk);
            let patches = &mut s.patches[..m * kk];
            kernels::im2col(x, self.h, self.w, self.ci, self.kh, self.kw, patches);
            kernels::matmul(patches, wt, out, m, kk, co);
        });
    }

    /// Vectorized backward over the same patch matrix: `dW = Pᵀ · dY`
    /// (rank-1 updates), `dP = dY · Wᵀ` (dot products, no transpose
    /// scratch) scattered back through the im2col adjoint.
    fn backward_vectorized(
        &self,
        params: &[&[f32]],
        x: &[f32],
        dy: &[f32],
        dx: Option<&mut [f32]>,
        dp: &mut [f32],
    ) {
        let wt = params[0];
        let (m, kk, co) = (self.h * self.w, self.kh * self.kw * self.ci, self.co);
        let (dwt, db) = dp.split_at_mut(kk * co);
        for p in 0..m {
            let dyrow = &dy[p * co..(p + 1) * co];
            for oc in 0..co {
                db[oc] += dyrow[oc];
            }
        }
        kernels::with_conv_scratch(|s| {
            kernels::ensure(&mut s.patches, m * kk);
            let patches = &mut s.patches[..m * kk];
            kernels::im2col(x, self.h, self.w, self.ci, self.kh, self.kw, patches);
            kernels::matmul_tn(patches, dy, dwt, m, kk, co);
            if let Some(dx) = dx {
                kernels::ensure(&mut s.dpatches, m * kk);
                let dpatches = &mut s.dpatches[..m * kk];
                dpatches.fill(0.0);
                kernels::matmul_bt(dy, wt, dpatches, m, co, kk);
                kernels::col2im_add(dpatches, self.h, self.w, self.ci, self.kh, self.kw, dx);
            }
        });
    }
}

impl Op for Conv2d {
    fn name(&self) -> &'static str {
        "conv2d"
    }

    fn in_len(&self) -> usize {
        self.h * self.w * self.ci
    }

    fn out_len(&self) -> usize {
        self.h * self.w * self.co
    }

    fn param_shapes(&self) -> Vec<Vec<usize>> {
        vec![vec![self.kh, self.kw, self.ci, self.co], vec![self.co]]
    }

    fn init_params(&self, rng: Option<&mut Rng>) -> Vec<Vec<f32>> {
        let n = self.kh * self.kw * self.ci * self.co;
        let w = match rng {
            Some(rng) => he_normal(rng, n, self.kh * self.kw * self.ci),
            None => vec![0.0; n],
        };
        vec![w, vec![0.0; self.co]]
    }

    fn forward(&self, params: &[&[f32]], x: &[f32], out: &mut [f32]) {
        if self.kernel == KernelPath::Vectorized {
            return self.forward_vectorized(params, x, out);
        }
        let (wt, b) = (params[0], params[1]);
        let (w, ci, co) = (self.w, self.ci, self.co);
        let (ph, pw) = ((self.kh - 1) / 2, (self.kw - 1) / 2);
        for p in 0..self.h * w {
            out[p * co..(p + 1) * co].copy_from_slice(b);
        }
        for kr in 0..self.kh {
            let (oh_lo, oh_hi) = self.row_range(kr);
            for kc in 0..self.kw {
                let (ow_lo, ow_hi) = self.col_range(kc);
                let wbase = (kr * self.kw + kc) * ci * co;
                for oh in oh_lo..oh_hi {
                    let ih = oh + kr - ph;
                    for ow in ow_lo..ow_hi {
                        let iw = ow + kc - pw;
                        let xoff = (ih * w + iw) * ci;
                        let ooff = (oh * w + ow) * co;
                        for ic in 0..ci {
                            let xv = x[xoff + ic];
                            if xv != 0.0 {
                                let wrow = &wt[wbase + ic * co..wbase + (ic + 1) * co];
                                let orow = &mut out[ooff..ooff + co];
                                for oc in 0..co {
                                    orow[oc] += xv * wrow[oc];
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    fn backward(
        &self,
        params: &[&[f32]],
        x: &[f32],
        dy: &[f32],
        mut dx: Option<&mut [f32]>,
        dp: &mut [f32],
    ) {
        if self.kernel == KernelPath::Vectorized {
            return self.backward_vectorized(params, x, dy, dx, dp);
        }
        let wt = params[0];
        let (w, ci, co) = (self.w, self.ci, self.co);
        let (ph, pw) = ((self.kh - 1) / 2, (self.kw - 1) / 2);
        let (dwt, db) = dp.split_at_mut(self.kh * self.kw * ci * co);
        for p in 0..self.h * w {
            let dyrow = &dy[p * co..(p + 1) * co];
            for oc in 0..co {
                db[oc] += dyrow[oc];
            }
        }
        if let Some(dx) = dx.as_deref_mut() {
            dx.fill(0.0);
        }
        for kr in 0..self.kh {
            let (oh_lo, oh_hi) = self.row_range(kr);
            for kc in 0..self.kw {
                let (ow_lo, ow_hi) = self.col_range(kc);
                let wbase = (kr * self.kw + kc) * ci * co;
                for oh in oh_lo..oh_hi {
                    let ih = oh + kr - ph;
                    for ow in ow_lo..ow_hi {
                        let iw = ow + kc - pw;
                        let xoff = (ih * w + iw) * ci;
                        let ooff = (oh * w + ow) * co;
                        let dyrow = &dy[ooff..ooff + co];
                        match dx.as_deref_mut() {
                            Some(dx) => {
                                for ic in 0..ci {
                                    let xv = x[xoff + ic];
                                    let wrow = &wt[wbase + ic * co..wbase + (ic + 1) * co];
                                    let mut acc = 0.0f32;
                                    if xv != 0.0 {
                                        let drow =
                                            &mut dwt[wbase + ic * co..wbase + (ic + 1) * co];
                                        for oc in 0..co {
                                            let d = dyrow[oc];
                                            acc += wrow[oc] * d;
                                            drow[oc] += xv * d;
                                        }
                                    } else {
                                        for oc in 0..co {
                                            acc += wrow[oc] * dyrow[oc];
                                        }
                                    }
                                    dx[xoff + ic] += acc;
                                }
                            }
                            None => {
                                for ic in 0..ci {
                                    let xv = x[xoff + ic];
                                    if xv != 0.0 {
                                        let drow =
                                            &mut dwt[wbase + ic * co..wbase + (ic + 1) * co];
                                        for oc in 0..co {
                                            drow[oc] += xv * dyrow[oc];
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// MaxPool2d (non-overlapping windows)
// ---------------------------------------------------------------------------

/// Max pooling with a `kh x kw` window and equal stride (non-overlapping),
/// per channel, over an `hi x wi x c` channels-last input.
pub struct MaxPool2d {
    pub c: usize,
    pub hi: usize,
    pub wi: usize,
    pub kh: usize,
    pub kw: usize,
}

impl MaxPool2d {
    fn ho(&self) -> usize {
        self.hi / self.kh
    }

    fn wo(&self) -> usize {
        self.wi / self.kw
    }
}

impl Op for MaxPool2d {
    fn name(&self) -> &'static str {
        "maxpool2d"
    }

    fn in_len(&self) -> usize {
        self.hi * self.wi * self.c
    }

    fn out_len(&self) -> usize {
        self.ho() * self.wo() * self.c
    }

    fn forward(&self, _params: &[&[f32]], x: &[f32], out: &mut [f32]) {
        let (ho, wo, c) = (self.ho(), self.wo(), self.c);
        for oh in 0..ho {
            for ow in 0..wo {
                for ch in 0..c {
                    let mut m = f32::NEG_INFINITY;
                    for ih in oh * self.kh..(oh + 1) * self.kh {
                        for iw in ow * self.kw..(ow + 1) * self.kw {
                            let v = x[(ih * self.wi + iw) * c + ch];
                            if v > m {
                                m = v;
                            }
                        }
                    }
                    out[(oh * wo + ow) * c + ch] = m;
                }
            }
        }
    }

    fn backward(
        &self,
        _params: &[&[f32]],
        x: &[f32],
        dy: &[f32],
        dx: Option<&mut [f32]>,
        _dp: &mut [f32],
    ) {
        let Some(dx) = dx else { return };
        dx.fill(0.0);
        let (ho, wo, c) = (self.ho(), self.wo(), self.c);
        for oh in 0..ho {
            for ow in 0..wo {
                for ch in 0..c {
                    // Route to the first-in-scan-order argmax (ties go to
                    // the earliest cell); windows don't overlap, so plain
                    // assignment is enough.
                    let (mut bi, mut bv) = (0usize, f32::NEG_INFINITY);
                    for ih in oh * self.kh..(oh + 1) * self.kh {
                        for iw in ow * self.kw..(ow + 1) * self.kw {
                            let idx = (ih * self.wi + iw) * c + ch;
                            if x[idx] > bv {
                                bv = x[idx];
                                bi = idx;
                            }
                        }
                    }
                    dx[bi] = dy[(oh * wo + ow) * c + ch];
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// ReLU / Flatten
// ---------------------------------------------------------------------------

/// Elementwise `max(x, 0)`.
pub struct Relu {
    pub n: usize,
}

impl Op for Relu {
    fn name(&self) -> &'static str {
        "relu"
    }

    fn in_len(&self) -> usize {
        self.n
    }

    fn out_len(&self) -> usize {
        self.n
    }

    fn forward(&self, _params: &[&[f32]], x: &[f32], out: &mut [f32]) {
        for i in 0..self.n {
            out[i] = x[i].max(0.0);
        }
    }

    fn backward(
        &self,
        _params: &[&[f32]],
        x: &[f32],
        dy: &[f32],
        dx: Option<&mut [f32]>,
        _dp: &mut [f32],
    ) {
        let Some(dx) = dx else { return };
        for i in 0..self.n {
            dx[i] = if x[i] > 0.0 { dy[i] } else { 0.0 };
        }
    }
}

/// Shape-only bridge from spatial NHWC to flat features. Channels-last
/// row-major flattening means the buffer is already in FC order, so this
/// is a plain copy.
pub struct Flatten {
    pub n: usize,
}

impl Op for Flatten {
    fn name(&self) -> &'static str {
        "flatten"
    }

    fn in_len(&self) -> usize {
        self.n
    }

    fn out_len(&self) -> usize {
        self.n
    }

    fn forward(&self, _params: &[&[f32]], x: &[f32], out: &mut [f32]) {
        out.copy_from_slice(x);
    }

    fn backward(
        &self,
        _params: &[&[f32]],
        _x: &[f32],
        dy: &[f32],
        dx: Option<&mut [f32]>,
        _dp: &mut [f32],
    ) {
        if let Some(dx) = dx {
            dx.copy_from_slice(dy);
        }
    }
}

// ---------------------------------------------------------------------------
// Softmax cross-entropy head
// ---------------------------------------------------------------------------

/// The loss head: stable log-softmax cross-entropy over C logits, argmax
/// correctness, and (optionally) the mean-loss logit gradient. Same
/// arithmetic, in the same order, as the retired fused mlp backend — the
/// golden test depends on that.
pub struct SoftmaxXent {
    pub classes: usize,
}

impl SoftmaxXent {
    /// Returns (per-sample loss, argmax == label). When `inv_b` is
    /// `Some(1/B)`, additionally writes dL/dz of the MEAN batch loss into
    /// `dz` (matching `jax.grad` of a batch-averaged cross-entropy).
    pub fn loss_grad(
        &self,
        z: &[f32],
        label: usize,
        inv_b: Option<f32>,
        dz: &mut [f32],
    ) -> (f64, bool) {
        let c = self.classes;
        let zmax = z.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut expsum = 0.0f32;
        for k in 0..c {
            dz[k] = (z[k] - zmax).exp();
            expsum += dz[k];
        }
        let loss = (expsum.ln() + zmax - z[label]) as f64;
        let mut best = 0usize;
        for k in 1..c {
            if z[k] > z[best] {
                best = k;
            }
        }
        if let Some(inv_b) = inv_b {
            // dL/dz = (softmax - onehot) / B.
            let scale = inv_b / expsum;
            for k in 0..c {
                dz[k] *= scale;
            }
            dz[label] -= inv_b;
        }
        (loss, best == label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn normal_vec(rng: &mut Rng, n: usize, scale: f64) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * scale) as f32).collect()
    }

    /// Finite-difference check of `backward` against `forward` under the
    /// probe loss L = Σ_i c_i · out_i (so dL/dout = c). Probes every
    /// parameter coordinate and every input coordinate.
    fn fd_check(op: &dyn Op, params: &[Vec<f32>], x: &[f32], tol: f64) {
        let mut rng = Rng::new(0x9d);
        let c = normal_vec(&mut rng, op.out_len(), 1.0);
        let loss = |params: &[Vec<f32>], x: &[f32]| -> f64 {
            let pv: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
            let mut out = vec![0.0f32; op.out_len()];
            op.forward(&pv, x, &mut out);
            out.iter().zip(&c).map(|(&o, &w)| o as f64 * w as f64).sum()
        };

        let ptotal: usize = params.iter().map(|p| p.len()).sum();
        let pv: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
        let mut dp = vec![0.0f32; ptotal];
        let mut dx = vec![0.0f32; op.in_len()];
        op.backward(&pv, x, &c, Some(&mut dx), &mut dp);

        let eps = 1e-2f32;
        let check = |num: f64, ana: f64, what: &str| {
            assert!(
                (num - ana).abs() < tol + 0.02 * ana.abs(),
                "{} {what}: numeric {num} vs analytic {ana}",
                op.name()
            );
        };
        // Parameter coordinates.
        let mut flat = 0usize;
        for (t, tensor) in params.iter().enumerate() {
            for i in 0..tensor.len() {
                let mut hi = params.to_vec();
                hi[t][i] += eps;
                let mut lo = params.to_vec();
                lo[t][i] -= eps;
                let num = (loss(&hi, x) - loss(&lo, x)) / (2.0 * eps as f64);
                check(num, dp[flat] as f64, &format!("param[{t}][{i}]"));
                flat += 1;
            }
        }
        // Input coordinates.
        for i in 0..x.len() {
            let mut hi = x.to_vec();
            hi[i] += eps;
            let mut lo = x.to_vec();
            lo[i] -= eps;
            let num = (loss(params, &hi) - loss(params, &lo)) / (2.0 * eps as f64);
            check(num, dx[i] as f64, &format!("x[{i}]"));
        }
    }

    #[test]
    fn dense_finite_difference() {
        // Both kernel paths must satisfy the same analytic gradients.
        for kernel in [KernelPath::Scalar, KernelPath::Vectorized] {
            let op = Dense { si: 7, so: 5, kernel };
            let mut rng = Rng::new(1);
            let params = op.init_params(Some(&mut rng));
            let x = normal_vec(&mut rng, 7, 0.8);
            fd_check(&op, &params, &x, 2e-3);
        }
    }

    #[test]
    fn conv2d_finite_difference() {
        for kernel in [KernelPath::Scalar, KernelPath::Vectorized] {
            let op = Conv2d { ci: 2, co: 3, h: 4, w: 4, kh: 3, kw: 3, kernel };
            let mut rng = Rng::new(2);
            let mut params = op.init_params(Some(&mut rng));
            // Non-zero bias so db is exercised away from the init point.
            params[1] = normal_vec(&mut rng, 3, 0.5);
            let x = normal_vec(&mut rng, op.in_len(), 0.8);
            fd_check(&op, &params, &x, 5e-3);
        }
    }

    #[test]
    fn maxpool_finite_difference_and_routing() {
        let op = MaxPool2d { c: 2, hi: 4, wi: 4, kh: 2, kw: 2 };
        // Deterministic input with well-separated values (min gap 0.1 >>
        // 2*eps) so the finite difference never flips an argmax.
        let x: Vec<f32> = (0..op.in_len()).map(|i| ((i * 37) % 101) as f32 * 0.1).collect();
        fd_check(&op, &[], &x, 2e-3);

        // Forward picks the window max.
        let mut out = vec![0.0f32; op.out_len()];
        op.forward(&[], &x, &mut out);
        for (o, &v) in out.iter().enumerate() {
            assert!(x.contains(&v), "out[{o}]={v} not an input value");
        }
    }

    #[test]
    fn relu_finite_difference() {
        let op = Relu { n: 8 };
        // Stay away from the kink at 0 (|x| >= 0.15 > eps).
        let x: Vec<f32> = (0..8).map(|i| (i as f32 - 3.5) * 0.3).collect();
        fd_check(&op, &[], &x, 2e-3);
    }

    #[test]
    fn flatten_is_identity() {
        let op = Flatten { n: 6 };
        let x: Vec<f32> = vec![1.0, -2.0, 3.0, 0.0, 5.5, -0.5];
        let mut out = vec![0.0f32; 6];
        op.forward(&[], &x, &mut out);
        assert_eq!(out, x);
        let dy: Vec<f32> = vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6];
        let mut dx = vec![0.0f32; 6];
        op.backward(&[], &x, &dy, Some(&mut dx), &mut []);
        assert_eq!(dx, dy);
    }

    #[test]
    fn softmax_xent_zero_logits_is_ln_c() {
        let head = SoftmaxXent { classes: 10 };
        let z = vec![0.0f32; 10];
        let mut dz = vec![0.0f32; 10];
        let (loss, _) = head.loss_grad(&z, 3, Some(1.0), &mut dz);
        assert!((loss - 10f64.ln()).abs() < 1e-6, "loss {loss}");
        // Gradient sums to zero and is negative only at the label.
        let sum: f32 = dz.iter().sum();
        assert!(sum.abs() < 1e-6);
        for (k, &d) in dz.iter().enumerate() {
            if k == 3 {
                assert!(d < 0.0);
            } else {
                assert!(d > 0.0);
            }
        }
    }

    #[test]
    fn conv_init_uses_kernel_fan_in() {
        // fan_in = kh*kw*ci = 27 for the cnn's first conv; the He std is
        // sqrt(2/27) ~ 0.27 — check the sample std lands near it.
        let op = Conv2d {
            ci: 3,
            co: 16,
            h: 8,
            w: 8,
            kh: 3,
            kw: 3,
            kernel: KernelPath::default(),
        };
        let mut rng = Rng::new(3);
        let p = op.init_params(Some(&mut rng));
        assert_eq!(p[0].len(), 3 * 3 * 3 * 16);
        assert!(p[1].iter().all(|&v| v == 0.0));
        let n = p[0].len() as f64;
        let var: f64 = p[0].iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / n;
        assert!((var - 2.0 / 27.0).abs() < 0.02, "var {var}");
    }
}
