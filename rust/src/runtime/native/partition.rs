//! Split-execution runtime: the paper's device/gateway DNN partition
//! (§II-B) actually *executed*, not just costed.
//!
//! [`PartitionedBackend`] composes two [`LayerGraph`] halves compiled from
//! one `dnn::ModelSpec` at a spec-layer cut point `l` — the same `l` the
//! DDSRA scheduler optimises in Eq. 21 and the Table II cost model prices:
//!
//! ```text
//!   device (bottom l layers)                 gateway (top L − l layers)
//!   ────────────────────────                 ──────────────────────────
//!   forward(x) ──── smashed activation ────▶ forward + softmax-xent head
//!   backward  ◀──── cut gradient dL/da ───── backward (also yields top ∇)
//!   bottom ∇
//! ```
//!
//! One train step per sample: the device runs its half forward and uploads
//! the smashed activation at the cut; the gateway completes the forward
//! pass, computes the loss, runs its half backward and returns the cut
//! gradient; the device finishes backward. Both halves' gradients
//! concatenate into the fused flat-gradient ABI, and the batch uses the
//! same rayon fan-out and order-preserving reduction as the fused engine —
//! so split execution is **byte-identical** to fused execution at every
//! cut point (pinned by `rust/tests/partition.rs` and
//! `examples/partitioned_step.rs`).
//!
//! The exchanged tensor sizes are *measured* here
//! ([`PartitionedBackend::cut_activation_elems`]), making the cost model's
//! communication terms observable instead of assumed.

use anyhow::{bail, Result};
use rayon::prelude::*;

use crate::dnn::ModelSpec;
use crate::rng::Rng;

use super::super::backend::{Backend, Params};
use super::super::meta::ModelMeta;
use super::graph::{self, GraphScratch, LayerGraph};
use super::kernels::{self, KernelPath};
use super::{
    apply_sgd, check_batch_against, check_params_against, check_samples_against, EVAL_BATCH,
    NUM_CLASSES, TRAIN_BATCH,
};

/// A device/gateway split of one executable preset at spec-layer `cut`.
pub struct PartitionedBackend {
    meta: ModelMeta,
    /// Bottom `cut` layers (headless) — trains on the device.
    device: LayerGraph,
    /// Top `L − cut` layers + loss head — trains on the gateway.
    gateway: LayerGraph,
    /// Spec-layer partition point `l ∈ 0..=L` (C5).
    cut: usize,
    /// Number of ABI parameter tensors held by the device half.
    bottom_tensors: usize,
    init_seed: u64,
}

impl PartitionedBackend {
    /// Split `spec` at spec-layer boundary `cut` (`0..=depth`): the bottom
    /// `cut` layers run on the device, the rest (plus the loss head) on
    /// the gateway. Fails when the spec is not natively executable or the
    /// cut is out of range. Uses the default [`KernelPath`].
    pub fn from_spec(spec: &ModelSpec, cut: usize, init_seed: u64) -> Result<Self> {
        Self::from_spec_kernel(spec, cut, init_seed, KernelPath::default())
    }

    /// [`Self::from_spec`] with an explicit [`KernelPath`]: BOTH halves
    /// compile onto the same path, so split execution stays byte-identical
    /// to the equally-configured fused engine at every cut.
    pub fn from_spec_kernel(
        spec: &ModelSpec,
        cut: usize,
        init_seed: u64,
        kernel: KernelPath,
    ) -> Result<Self> {
        let depth = spec.depth();
        if cut > depth {
            bail!("{}: partition point {cut} outside 0..={depth}", spec.name);
        }
        let device =
            LayerGraph::from_spec_range_kernel(spec, NUM_CLASSES, 0, cut, false, kernel)?;
        let gateway =
            LayerGraph::from_spec_range_kernel(spec, NUM_CLASSES, cut, depth, true, kernel)?;
        if device.out_len() != gateway.in_len() {
            bail!(
                "{} cut {cut}: halves do not chain ({} != {})",
                spec.name,
                device.out_len(),
                gateway.in_len()
            );
        }
        let mut param_shapes = device.param_shapes().to_vec();
        param_shapes.extend(gateway.param_shapes().iter().cloned());
        let mut input_train = vec![TRAIN_BATCH];
        input_train.extend_from_slice(device.input_shape());
        let mut input_eval = vec![EVAL_BATCH];
        input_eval.extend_from_slice(device.input_shape());
        let meta = ModelMeta {
            preset: format!("{}@cut{cut}", spec.name),
            train_batch: TRAIN_BATCH,
            eval_batch: EVAL_BATCH,
            num_classes: NUM_CLASSES,
            input_train,
            input_eval,
            param_total: device.param_total() + gateway.param_total(),
            train_k: 0,
            param_shapes,
        };
        let bottom_tensors = device.param_shapes().len();
        Ok(PartitionedBackend { meta, device, gateway, cut, bottom_tensors, init_seed })
    }

    /// Split an executable preset by name (`"mlp"` or `"cnn"`), resolved
    /// through the same preset registry as the fused `NativeBackend` — so
    /// `init_params` is byte-identical to the fused preset's.
    pub fn preset(name: &str, cut: usize) -> Result<Self> {
        Self::preset_kernel(name, cut, KernelPath::default())
    }

    /// [`Self::preset`] with an explicit [`KernelPath`].
    pub fn preset_kernel(name: &str, cut: usize, kernel: KernelPath) -> Result<Self> {
        let (spec, seed) = super::preset_spec_and_seed(name)?;
        Self::from_spec_kernel(&spec, cut, seed, kernel)
    }

    /// The spec-layer partition point this backend executes.
    pub fn cut(&self) -> usize {
        self.cut
    }

    /// The kernel path both halves run on.
    pub fn kernel(&self) -> KernelPath {
        self.device.kernel()
    }

    /// MEASURED per-sample element count of the smashed activation the
    /// device uploads at the cut (the returned cut gradient has the same
    /// size). Multiply by 4 (f32) and the batch size for bytes per
    /// exchange — the quantity the Table II cost model's communication
    /// terms assume.
    pub fn cut_activation_elems(&self) -> usize {
        self.device.out_len()
    }

    /// Flat parameter count of the device (bottom) half — the gateway
    /// half's coordinates start here in the fused gradient ABI.
    pub fn device_param_total(&self) -> usize {
        self.device.param_total()
    }

    /// Number of ABI parameter tensors held by the device half.
    pub fn device_tensor_count(&self) -> usize {
        self.bottom_tensors
    }

    fn check_params(&self, params: &Params) -> Result<()> {
        check_params_against(&self.meta, params)
    }

    fn check_samples(&self, x: &[f32], y: &[i32]) -> Result<()> {
        check_samples_against(&self.meta, self.device.in_len(), x, y)
    }

    fn check_batch(&self, x: &[f32], y: &[i32], batch: usize) -> Result<()> {
        check_batch_against(&self.meta, self.device.in_len(), x, y, batch)
    }

    /// One sample through the split pipeline on this worker's scratch:
    /// device forward → activation exchange → gateway forward + head
    /// (+ backward → gradient exchange → device backward when `g` is
    /// set, accumulating into `g`). The flat gradient is the device
    /// half's block followed by the gateway half's — the fused ABI.
    fn split_sample(
        &self,
        bottom: &[Vec<f32>],
        top: &[Vec<f32>],
        xs: &[f32],
        label: usize,
        grad_scale: Option<f32>,
        g: Option<&mut [f32]>,
    ) -> (f64, bool) {
        graph::with_scratch(|s| {
            let GraphScratch { acts, acts2, dy, dx, dz, dcut } = s;
            // Device: bottom forward to the cut.
            let dev_acts = self.device.forward_arena_into(bottom, xs, acts);
            let cut_act = self.device.output_slice(xs, dev_acts);
            // Gateway: top forward + loss head.
            let gw_acts = self.gateway.forward_arena_into(top, cut_act, acts2);
            let logits = self.gateway.output_slice(cut_act, gw_acts);
            let nc = self.meta.num_classes;
            kernels::ensure(dz, nc);
            let dz = &mut dz[..nc];
            let (loss, ok) = self.gateway.head_loss_grad(logits, label, grad_scale, dz);
            let Some(g) = g else { return (loss, ok) };
            // Gateway: top backward — yields the top gradients AND the cut
            // gradient to ship back (skipped when the device half is empty,
            // matching the fused graph's dx=None at op 0).
            let (g_bottom, g_top) = g.split_at_mut(self.device.param_total());
            let want_dcut = self.device.num_ops() > 0;
            let has_dcut = self
                .gateway
                .backward_arena(top, cut_act, gw_acts, dz, g_top, dy, dx, want_dcut);
            // Device: bottom backward from the gateway's cut gradient —
            // staged into its own buffer, since `dx` is about to be
            // reused as the device half's backward scratch.
            if has_dcut {
                let n = self.device.out_len();
                kernels::ensure(dcut, n);
                dcut[..n].copy_from_slice(&dx[..n]);
                self.device
                    .backward_arena(bottom, xs, dev_acts, &dcut[..n], g_bottom, dy, dx, false);
            }
            (loss, ok)
        })
    }

    /// Batched split execution through the same deterministic blocked
    /// executor as the fused engine (block size set by the kernel path),
    /// so split results stay byte-identical to fused ones per path.
    fn split_fwd_bwd(
        &self,
        params: &Params,
        x: &[f32],
        y: &[i32],
        want_grad: bool,
    ) -> (f64, usize, Option<Vec<f32>>) {
        let b = y.len();
        let in_len = self.device.in_len();
        let grad_scale = want_grad.then_some(1.0f32 / b as f32);
        let (bottom, top) = params.split_at(self.bottom_tensors);
        graph::run_blocked(
            b,
            self.device.sample_block(),
            self.meta.param_total,
            want_grad,
            |s, g| {
                self.split_sample(
                    bottom,
                    top,
                    &x[s * in_len..(s + 1) * in_len],
                    y[s] as usize,
                    grad_scale,
                    g,
                )
            },
        )
    }

    // ------------------------------------------------------------------
    // Wire halves (`net::serve` / `runtime::remote`): the SAME device and
    // gateway graphs exposed as standalone batch operations so the two
    // halves can run in different processes. The in-process methods above
    // stay untouched — they are THE byte-parity oracle the wire path is
    // pinned against (`rust/tests/wire.rs`).
    // ------------------------------------------------------------------

    /// Op count of the device (bottom) half — zero at cut 0, where no cut
    /// gradient flows back (matching `split_sample`'s `want_dcut`).
    pub(crate) fn device_num_ops(&self) -> usize {
        self.device.num_ops()
    }

    /// Device half, forward only: fill `out` with the batch's smashed
    /// activations (`b × cut_activation_elems`, sample-major). Pure
    /// per-sample computation, so the rayon fan-out order is irrelevant.
    pub(crate) fn device_forward_batch(&self, bottom: &[Vec<f32>], x: &[f32], out: &mut [f32]) {
        let in_len = self.device.in_len();
        let n_cut = self.device.out_len();
        debug_assert_eq!(x.len() * n_cut, out.len() * in_len);
        out.par_chunks_mut(n_cut).zip(x.par_chunks(in_len)).for_each(|(o, xs)| {
            graph::with_scratch(|s| {
                let dev_acts = self.device.forward_arena_into(bottom, xs, &mut s.acts);
                o.copy_from_slice(self.device.output_slice(xs, dev_acts));
            })
        });
    }

    /// The gateway portion of [`Self::split_sample`], verbatim arithmetic:
    /// top forward + loss head, optionally top backward with the cut
    /// gradient staged into `dcut_out` instead of flowing straight into a
    /// co-located device half.
    fn gateway_sample(
        &self,
        top: &[Vec<f32>],
        cut_act: &[f32],
        label: usize,
        grad_scale: Option<f32>,
        g_top: Option<&mut [f32]>,
        dcut_out: Option<&mut [f32]>,
    ) -> (f64, bool) {
        graph::with_scratch(|s| {
            let GraphScratch { acts2, dy, dx, dz, .. } = s;
            let gw_acts = self.gateway.forward_arena_into(top, cut_act, acts2);
            let logits = self.gateway.output_slice(cut_act, gw_acts);
            let nc = self.meta.num_classes;
            kernels::ensure(dz, nc);
            let dz = &mut dz[..nc];
            let (loss, ok) = self.gateway.head_loss_grad(logits, label, grad_scale, dz);
            if g_top.is_none() && dcut_out.is_none() {
                return (loss, ok);
            }
            // A head-only gateway (deepest cut) owns no parameters; give
            // the backward pass an empty accumulator in that case.
            let mut no_params: [f32; 0] = [];
            let g_top = g_top.unwrap_or(&mut no_params);
            let want_dcut = dcut_out.is_some();
            let has_dcut =
                self.gateway.backward_arena(top, cut_act, gw_acts, dz, g_top, dy, dx, want_dcut);
            if let Some(out) = dcut_out {
                debug_assert!(has_dcut);
                out.copy_from_slice(&dx[..out.len()]);
            }
            (loss, ok)
        })
    }

    /// Serve one wire split request: loss/accuracy over the uploaded
    /// smashed activations and, when `want_grad`, the gateway-half
    /// gradient plus the per-sample cut gradients ⇣ to ship back. Runs the
    /// SAME blocked executors as [`Self::split_fwd_bwd`] with the same
    /// block size and gateway-computed `grad_scale`, so the loss fold and
    /// `g_top` are bit-identical to the in-process step's.
    ///
    /// Returns `(loss_sum, correct, g_top, dcut)`; `dcut` is empty when
    /// the device half has no ops (cut 0) or no gradient was requested.
    pub(crate) fn gateway_split_batch(
        &self,
        top: &Params,
        acts: &[f32],
        y: &[i32],
        want_grad: bool,
    ) -> Result<(f64, usize, Vec<f32>, Vec<f32>)> {
        let b = y.len();
        let n_cut = self.device.out_len();
        if b == 0 {
            bail!("empty split batch");
        }
        if acts.len() != b * n_cut {
            bail!(
                "smashed activations: {} elements != batch {b} x cut width {n_cut}",
                acts.len()
            );
        }
        let shapes = &self.meta.param_shapes[self.bottom_tensors..];
        if top.len() != shapes.len() {
            bail!("expected {} gateway param tensors, got {}", shapes.len(), top.len());
        }
        for (i, (buf, shape)) in top.iter().zip(shapes).enumerate() {
            let want: usize = shape.iter().product();
            if buf.len() != want {
                bail!("gateway param tensor {i}: {} elements, expected {want}", buf.len());
            }
        }
        for &l in y {
            if l < 0 || l as usize >= self.meta.num_classes {
                bail!("label {l} outside 0..{}", self.meta.num_classes);
            }
        }
        let grad_scale = want_grad.then_some(1.0f32 / b as f32);
        let block = self.device.sample_block();
        let act = |s: usize| &acts[s * n_cut..(s + 1) * n_cut];
        if !want_grad {
            let (loss_sum, correct, _) = graph::run_blocked(b, block, 0, false, |s, _| {
                self.gateway_sample(top, act(s), y[s] as usize, grad_scale, None, None)
            });
            return Ok((loss_sum, correct, Vec::new(), Vec::new()));
        }
        let gw_total = self.gateway.param_total();
        if self.device.num_ops() == 0 {
            // Cut 0: nothing below the cut wants a gradient.
            let (loss_sum, correct, grad) = graph::run_blocked(b, block, gw_total, true, |s, g| {
                self.gateway_sample(top, act(s), y[s] as usize, grad_scale, g, None)
            });
            return Ok((loss_sum, correct, grad.expect("gradient requested"), Vec::new()));
        }
        let mut dcut = vec![0.0f32; b * n_cut];
        let (loss_sum, correct, g_top) =
            graph::run_blocked_sink(b, block, gw_total, n_cut, &mut dcut, |s, g, o| {
                self.gateway_sample(top, act(s), y[s] as usize, grad_scale, g, Some(o))
            });
        Ok((loss_sum, correct, g_top, dcut))
    }

    /// Device half, backward: fold the gateway's per-sample cut gradients
    /// into the device-half flat gradient through the same blocked
    /// executor — bit-identical to the device-half coordinates of the
    /// in-process step's fused gradient.
    pub(crate) fn device_backward_batch(
        &self,
        bottom: &[Vec<f32>],
        x: &[f32],
        dcut: &[f32],
        b: usize,
    ) -> Vec<f32> {
        let in_len = self.device.in_len();
        let n_cut = self.device.out_len();
        debug_assert_eq!(x.len(), b * in_len);
        debug_assert_eq!(dcut.len(), b * n_cut);
        let (_, _, grad) =
            graph::run_blocked(b, self.device.sample_block(), self.device.param_total(), true, |s, g| {
                if let Some(g) = g {
                    graph::with_scratch(|sc| {
                        let GraphScratch { acts, dy, dx, .. } = sc;
                        let xs = &x[s * in_len..(s + 1) * in_len];
                        let dev_acts = self.device.forward_arena_into(bottom, xs, acts);
                        self.device.backward_arena(
                            bottom,
                            xs,
                            dev_acts,
                            &dcut[s * n_cut..(s + 1) * n_cut],
                            g,
                            dy,
                            dx,
                            false,
                        );
                    });
                }
                (0.0, false)
            });
        grad.expect("gradient requested")
    }
}

impl Backend for PartitionedBackend {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Byte-identical to the fused preset's init: one RNG stream walks the
    /// device half then the gateway half, zero-initialising the model head
    /// (the globally last parameterized op) wherever it lives.
    fn init_params(&self) -> Result<Params> {
        let mut rng = Rng::new(self.init_seed);
        let top_has_params = self.gateway.param_total() > 0;
        let mut p = self.device.init_params_with(&mut rng, !top_has_params);
        p.extend(self.gateway.init_params_with(&mut rng, top_has_params));
        Ok(p)
    }

    fn train_step(
        &self,
        params: &Params,
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<(Params, f32)> {
        self.check_params(params)?;
        self.check_batch(x, y, self.meta.train_batch)?;
        let (loss_sum, _, grad) = self.split_fwd_bwd(params, x, y, true);
        let g = grad.expect("gradient requested");
        Ok((apply_sgd(params, &g, lr), (loss_sum / y.len() as f64) as f32))
    }

    fn eval_batch(&self, params: &Params, x: &[f32], y: &[i32]) -> Result<(f64, f64)> {
        self.check_params(params)?;
        self.check_batch(x, y, self.meta.eval_batch)?;
        let (loss_sum, correct, _) = self.split_fwd_bwd(params, x, y, false);
        Ok((loss_sum, correct as f64))
    }

    fn eval_partial_batch(
        &self,
        params: &Params,
        x: &[f32],
        y: &[i32],
    ) -> Result<Option<(f64, f64)>> {
        self.check_params(params)?;
        self.check_samples(x, y)?;
        let (loss_sum, correct, _) = self.split_fwd_bwd(params, x, y, false);
        Ok(Some((loss_sum, correct as f64)))
    }

    fn grad(&self, params: &Params, x: &[f32], y: &[i32]) -> Result<Vec<f32>> {
        self.check_params(params)?;
        self.check_batch(x, y, self.meta.train_batch)?;
        let (_, _, grad) = self.split_fwd_bwd(params, x, y, true);
        Ok(grad.expect("gradient requested"))
    }
}

/// The full split stack for one executable preset: a backend per legal
/// partition point `l ∈ 0..=L`, indexed by `l`. This is what the
/// orchestrator dispatches on when `--execute-partition` is set: device
/// `n`'s local step runs through `stack[plan.partition[n]]`.
pub fn make_partitioned_stack(preset: &str) -> Result<Vec<PartitionedBackend>> {
    make_partitioned_stack_kernel(preset, KernelPath::default())
}

/// [`make_partitioned_stack`] with an explicit [`KernelPath`] for every
/// backend in the stack.
pub fn make_partitioned_stack_kernel(
    preset: &str,
    kernel: KernelPath,
) -> Result<Vec<PartitionedBackend>> {
    let (spec, seed) = super::preset_spec_and_seed(preset)?;
    (0..=spec.depth())
        .map(|cut| PartitionedBackend::from_spec_kernel(&spec, cut, seed, kernel))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::NativeBackend;
    use super::*;

    fn batch(seed: u64, n: usize, dim: usize) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32 * 0.5).collect();
        let y: Vec<i32> = (0..n).map(|_| rng.below(NUM_CLASSES) as i32).collect();
        (x, y)
    }

    fn assert_bits_eq(a: &Params, b: &Params, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: tensor count");
        for (t, (ta, tb)) in a.iter().zip(b).enumerate() {
            assert_eq!(ta.len(), tb.len(), "{what}: tensor {t} len");
            for (i, (va, vb)) in ta.iter().zip(tb).enumerate() {
                assert_eq!(
                    va.to_bits(),
                    vb.to_bits(),
                    "{what}: tensor {t} idx {i}: {va} vs {vb}"
                );
            }
        }
    }

    #[test]
    fn mlp_split_matches_fused_at_every_cut() {
        let fused = NativeBackend::mlp();
        let p0 = fused.init_params().unwrap();
        let dim = fused.meta().sample_dim();
        let (x, y) = batch(0x51, TRAIN_BATCH, dim);
        let (fused_next, fused_loss) = fused.train_step(&p0, &x, &y, 0.05).unwrap();
        for cut in 0..=2 {
            let split = PartitionedBackend::preset("mlp", cut).unwrap();
            assert_bits_eq(&split.init_params().unwrap(), &p0, "init");
            let (next, loss) = split.train_step(&p0, &x, &y, 0.05).unwrap();
            assert_eq!(loss.to_bits(), fused_loss.to_bits(), "cut {cut} loss");
            assert_bits_eq(&next, &fused_next, "params after split step");
        }
    }

    #[test]
    fn cut_sizes_are_measured_from_the_compiled_halves() {
        // cnn spec: conv16@32² / pool / conv32@16² / pool / conv64@8² /
        // pool / fc1024→128 / fc128→10.
        let expect = [
            32 * 32 * 3,  // cut 0: raw input
            32 * 32 * 16, // after conv1
            16 * 16 * 16, // after pool1
            16 * 16 * 32,
            8 * 8 * 32,
            8 * 8 * 64,
            4 * 4 * 64, // = 1024, the flatten boundary
            128,
            10, // cut 8: the logits themselves
        ];
        for (cut, &e) in expect.iter().enumerate() {
            let b = PartitionedBackend::preset("cnn", cut).unwrap();
            assert_eq!(b.cut_activation_elems(), e, "cut {cut}");
            assert_eq!(b.cut(), cut);
        }
    }

    #[test]
    fn stack_covers_every_cut_and_shares_the_fused_abi() {
        let stack = make_partitioned_stack("mlp").unwrap();
        assert_eq!(stack.len(), 3);
        let fused = NativeBackend::mlp();
        for b in &stack {
            assert_eq!(b.meta().param_shapes, fused.meta().param_shapes);
            assert_eq!(b.meta().param_total, fused.meta().param_total);
            assert_eq!(b.meta().train_batch, fused.meta().train_batch);
        }
        assert!(make_partitioned_stack("resnet").is_err());
    }

    #[test]
    fn rejects_out_of_range_cuts_and_malformed_inputs() {
        assert!(PartitionedBackend::preset("mlp", 3).is_err());
        assert!(PartitionedBackend::preset("resnet", 0).is_err());
        let b = PartitionedBackend::preset("mlp", 1).unwrap();
        let p = b.init_params().unwrap();
        let (x, y) = batch(9, TRAIN_BATCH, 3072);
        assert!(b.train_step(&p, &x[..10], &y, 0.1).is_err());
        assert!(b.train_step(&p, &x, &y[..10], 0.1).is_err());
        let bad_y: Vec<i32> = vec![11; TRAIN_BATCH];
        assert!(b.train_step(&p, &x, &bad_y, 0.1).is_err());
        let mut bad_p = p.clone();
        bad_p[0].pop();
        assert!(b.train_step(&bad_p, &x, &y, 0.1).is_err());
    }
}
