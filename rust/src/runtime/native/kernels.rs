//! Vectorized compute kernels for the native layer-graph engine.
//!
//! One small library of f32 primitives — `axpy`, `dot`, and three
//! register-blocked matmul variants — that `ops::Dense` and `ops::Conv2d`
//! dispatch onto when running the [`KernelPath::Vectorized`] path. The
//! kernels are written as hand-unrolled safe Rust: fixed-width lane
//! blocks (`LANES` = 8) expressed through `chunks_exact`, which gives
//! LLVM compile-time-known trip counts to auto-vectorize. No `unsafe`,
//! no `std::simd` (nightly-only), no `#[target_feature]` — FMA contraction
//! would make results machine-dependent, and determinism is part of the
//! engine's contract.
//!
//! Determinism policy:
//! * Every kernel has ONE fixed summation order — `dot` folds its 8
//!   accumulator lanes in lane order after the main loop, `matmul`
//!   accumulates along `k` in index order — so a given kernel path is
//!   byte-reproducible across runs and thread counts.
//! * The vectorized order is deliberately DIFFERENT from the scalar
//!   loops' order (that is where the speed comes from). Cross-path
//!   agreement is therefore bounded by tolerance, not bit equality; the
//!   scalar path ([`KernelPath::Scalar`]) is kept verbatim as the
//!   bit-exactness oracle (`rust/tests/kernel_parity.rs`).
//!
//! Convolution runs on these kernels via im2col: each output position's
//! receptive field is gathered into a row of a patch matrix `P` of shape
//! `[h·w, kh·kw·ci]`, whose column order matches the HWIO weight layout
//! `[kh·kw·ci, co]` row-major — so `out = P · W` is one `matmul` call,
//! `dW = Pᵀ · dY` is one [`matmul_tn`], and `dP = dY · Wᵀ` is one
//! [`matmul_bt`] scattered back through [`col2im_add`]. Patch matrices
//! live in a per-worker thread-local scratch (the crate-private
//! `with_conv_scratch`), so the hot path performs no per-sample heap
//! allocation.

use std::cell::RefCell;

/// Which inner-loop implementation the native engine runs.
///
/// `Scalar` is the original per-sample scalar code, kept verbatim: it is
/// the bit-exactness oracle (the golden mlp test pins it against the
/// retired fused backend) and reproduces pre-kernel-refactor run bytes
/// exactly. `Vectorized` (the default) runs the blocked kernels in this
/// module plus the sample-blocked batch executor — deterministic within
/// itself, but with a different (faster) summation order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KernelPath {
    /// Verbatim scalar loops — the bit-exact compatibility oracle.
    Scalar,
    /// Blocked/unrolled kernels — the fast default.
    #[default]
    Vectorized,
}

impl KernelPath {
    pub fn as_str(self) -> &'static str {
        match self {
            KernelPath::Scalar => "scalar",
            KernelPath::Vectorized => "vectorized",
        }
    }
}

impl std::fmt::Display for KernelPath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for KernelPath {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "scalar" => Ok(KernelPath::Scalar),
            "vectorized" => Ok(KernelPath::Vectorized),
            other => anyhow::bail!(
                "unknown kernel path {other:?} (expected \"scalar\" or \"vectorized\")"
            ),
        }
    }
}

/// Unroll width of the inner loops. 8 f32 lanes = one AVX2 register /
/// two NEON registers; `chunks_exact(LANES)` makes the trip count a
/// compile-time constant so LLVM vectorizes the lane loop.
const LANES: usize = 8;

/// Rows of `C` updated together by [`matmul`] — each B-row load is reused
/// across `MR` accumulator rows (register blocking).
const MR: usize = 4;

/// `y += a · x` over equal-length slices, 8-wide.
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let main = x.len() - x.len() % LANES;
    for (xv, yv) in x[..main].chunks_exact(LANES).zip(y[..main].chunks_exact_mut(LANES)) {
        for l in 0..LANES {
            yv[l] += a * xv[l];
        }
    }
    for (xv, yv) in x[main..].iter().zip(y[main..].iter_mut()) {
        *yv += a * xv;
    }
}

/// Dot product with 8 independent accumulator lanes, folded in lane
/// order (then the scalar tail) — one fixed, input-length-determined
/// summation order.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let main = x.len() - x.len() % LANES;
    let mut lanes = [0.0f32; LANES];
    for (xv, yv) in x[..main].chunks_exact(LANES).zip(y[..main].chunks_exact(LANES)) {
        for l in 0..LANES {
            lanes[l] += xv[l] * yv[l];
        }
    }
    let mut acc = 0.0f32;
    for l in lanes {
        acc += l;
    }
    for (xv, yv) in x[main..].iter().zip(&y[main..]) {
        acc += xv * yv;
    }
    acc
}

/// `C += A · B`, all row-major: `A` is `m×k`, `B` is `k×n`, `C` is `m×n`.
///
/// Register-blocked over `MR` rows of `C`: one pass over each B row
/// updates four C rows, so B traffic is amortized 4×. Accumulation along
/// `k` is in index order for every C coordinate — deterministic.
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let mut i = 0usize;
    while i + MR <= m {
        let (c01, c23) = c[i * n..(i + MR) * n].split_at_mut(2 * n);
        let (c0, c1) = c01.split_at_mut(n);
        let (c2, c3) = c23.split_at_mut(n);
        let a0 = &a[i * k..(i + 1) * k];
        let a1 = &a[(i + 1) * k..(i + 2) * k];
        let a2 = &a[(i + 2) * k..(i + 3) * k];
        let a3 = &a[(i + 3) * k..(i + 4) * k];
        for p in 0..k {
            let (x0, x1, x2, x3) = (a0[p], a1[p], a2[p], a3[p]);
            let brow = &b[p * n..(p + 1) * n];
            for j in 0..n {
                let bv = brow[j];
                c0[j] += x0 * bv;
                c1[j] += x1 * bv;
                c2[j] += x2 * bv;
                c3[j] += x3 * bv;
            }
        }
        i += MR;
    }
    while i < m {
        let crow = &mut c[i * n..(i + 1) * n];
        let arow = &a[i * k..(i + 1) * k];
        for p in 0..k {
            axpy(arow[p], &b[p * n..(p + 1) * n], crow);
        }
        i += 1;
    }
}

/// `C += Aᵀ · B`: `A` is `m×k` row-major (used transposed), `B` is `m×n`,
/// `C` is `k×n`. Expressed as `m` rank-1 updates — for each row `p`,
/// `C[i, :] += A[p, i] · B[p, :]` — so every C coordinate accumulates in
/// `p` order. Zero A entries skip the update (an exact no-op for finite
/// operands, and patch matrices are full of padding/ReLU zeros).
pub fn matmul_tn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(c.len(), k * n);
    for p in 0..m {
        let arow = &a[p * k..(p + 1) * k];
        let brow = &b[p * n..(p + 1) * n];
        for i in 0..k {
            let av = arow[i];
            if av != 0.0 {
                axpy(av, brow, &mut c[i * n..(i + 1) * n]);
            }
        }
    }
}

/// `C += A · Bᵀ`: `A` is `m×k`, `B` is `n×k` row-major (used transposed),
/// `C` is `m×n`. Each C coordinate is one [`dot`] of an A row with a B
/// row — no transpose scratch needed.
pub fn matmul_bt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            crow[j] += dot(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

/// Gather a SAME-padded stride-1 convolution input `x` (`[h, w, ci]`
/// channels-last) into the patch matrix `patches` (`[h·w, kh·kw·ci]`):
/// row `oh·w + ow` holds output position `(oh, ow)`'s receptive field,
/// with column `(kr·kw + kc)·ci + ic` matching the HWIO weight row order.
/// Out-of-image taps are zero (the padding).
pub fn im2col(
    x: &[f32],
    h: usize,
    w: usize,
    ci: usize,
    kh: usize,
    kw: usize,
    patches: &mut [f32],
) {
    let kk = kh * kw * ci;
    debug_assert_eq!(x.len(), h * w * ci);
    debug_assert_eq!(patches.len(), h * w * kk);
    patches.fill(0.0);
    let (ph, pw) = ((kh - 1) / 2, (kw - 1) / 2);
    for oh in 0..h {
        for ow in 0..w {
            let prow = &mut patches[(oh * w + ow) * kk..(oh * w + ow + 1) * kk];
            for kr in 0..kh {
                let ih = oh + kr;
                if ih < ph || ih >= h + ph {
                    continue;
                }
                let ih = ih - ph;
                for kc in 0..kw {
                    let iw = ow + kc;
                    if iw < pw || iw >= w + pw {
                        continue;
                    }
                    let iw = iw - pw;
                    let src = &x[(ih * w + iw) * ci..(ih * w + iw + 1) * ci];
                    let col = (kr * kw + kc) * ci;
                    prow[col..col + ci].copy_from_slice(src);
                }
            }
        }
    }
}

/// Adjoint of [`im2col`]: scatter-add the patch-space gradient `dpatches`
/// (`[h·w, kh·kw·ci]`) back onto the input image gradient `dx`
/// (`[h, w, ci]`, fully written — zero-filled first). Taps that fell in
/// the padding are dropped.
pub fn col2im_add(
    dpatches: &[f32],
    h: usize,
    w: usize,
    ci: usize,
    kh: usize,
    kw: usize,
    dx: &mut [f32],
) {
    let kk = kh * kw * ci;
    debug_assert_eq!(dpatches.len(), h * w * kk);
    debug_assert_eq!(dx.len(), h * w * ci);
    dx.fill(0.0);
    let (ph, pw) = ((kh - 1) / 2, (kw - 1) / 2);
    for oh in 0..h {
        for ow in 0..w {
            let prow = &dpatches[(oh * w + ow) * kk..(oh * w + ow + 1) * kk];
            for kr in 0..kh {
                let ih = oh + kr;
                if ih < ph || ih >= h + ph {
                    continue;
                }
                let ih = ih - ph;
                for kc in 0..kw {
                    let iw = ow + kc;
                    if iw < pw || iw >= w + pw {
                        continue;
                    }
                    let iw = iw - pw;
                    let dst = &mut dx[(ih * w + iw) * ci..(ih * w + iw + 1) * ci];
                    let col = (kr * kw + kc) * ci;
                    for (d, s) in dst.iter_mut().zip(&prow[col..col + ci]) {
                        *d += *s;
                    }
                }
            }
        }
    }
}

/// Per-worker im2col scratch: patch and patch-gradient matrices reused
/// across samples (grow-only, never shrunk). A separate thread-local from
/// the graph's arena scratch so a conv op running inside a graph pass
/// never double-borrows.
#[derive(Default)]
pub(crate) struct ConvScratch {
    pub patches: Vec<f32>,
    pub dpatches: Vec<f32>,
}

thread_local! {
    static CONV_SCRATCH: RefCell<ConvScratch> = RefCell::new(ConvScratch::default());
}

pub(crate) fn with_conv_scratch<R>(f: impl FnOnce(&mut ConvScratch) -> R) -> R {
    CONV_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Grow-only resize of a scratch buffer. Contents beyond a previous use
/// are stale, never zero — every kernel/op fully writes its outputs, so
/// no consumer may rely on scratch being cleared.
#[inline]
pub(crate) fn ensure(buf: &mut Vec<f32>, n: usize) {
    if buf.len() < n {
        buf.resize(n, 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * 0.5) as f32).collect()
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= tol + tol * x.abs(),
                "{what}[{i}]: {x} vs {y}"
            );
        }
    }

    #[test]
    fn kernel_path_parses_and_prints() {
        assert_eq!("scalar".parse::<KernelPath>().unwrap(), KernelPath::Scalar);
        assert_eq!(
            "vectorized".parse::<KernelPath>().unwrap(),
            KernelPath::Vectorized
        );
        assert!("simd".parse::<KernelPath>().is_err());
        assert_eq!(KernelPath::default(), KernelPath::Vectorized);
        assert_eq!(KernelPath::Scalar.to_string(), "scalar");
        assert_eq!(KernelPath::Vectorized.as_str(), "vectorized");
    }

    #[test]
    fn axpy_and_dot_match_naive_at_awkward_lengths() {
        let mut rng = Rng::new(0xa0);
        // Lengths straddling the 8-lane boundary, incl. 0, 1, and tails.
        for n in [0usize, 1, 7, 8, 9, 16, 23, 64, 100] {
            let x = randv(&mut rng, n);
            let y0 = randv(&mut rng, n);
            let a = 0.37f32;
            let mut y = y0.clone();
            axpy(a, &x, &mut y);
            let expect: Vec<f32> = y0.iter().zip(&x).map(|(y, x)| y + a * x).collect();
            // axpy touches each coordinate once: exactly the naive result.
            assert_eq!(y, expect, "axpy n={n}");

            let d = dot(&x, &y);
            let naive: f64 = x.iter().zip(&y).map(|(a, b)| *a as f64 * *b as f64).sum();
            assert!((d as f64 - naive).abs() < 1e-4 + 1e-4 * naive.abs(), "dot n={n}");
        }
    }

    #[test]
    fn matmul_variants_match_naive_reference() {
        let mut rng = Rng::new(0xb1);
        // (m, k, n) shapes hitting the MR tail (m % 4 != 0) and the lane
        // tail (n % 8 != 0), plus degenerate 1-row/1-col edges.
        for (m, k, n) in [(1usize, 1usize, 1usize), (3, 5, 7), (4, 8, 8), (6, 9, 13), (5, 1, 9)] {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, k * n);
            let mut naive = vec![0.0f64; m * n];
            for i in 0..m {
                for p in 0..k {
                    for j in 0..n {
                        naive[i * n + j] += a[i * k + p] as f64 * b[p * n + j] as f64;
                    }
                }
            }
            let naive32: Vec<f32> = naive.iter().map(|&v| v as f32).collect();

            let mut c = vec![0.0f32; m * n];
            matmul(&a, &b, &mut c, m, k, n);
            assert_close(&c, &naive32, 1e-4, &format!("matmul {m}x{k}x{n}"));

            // Aᵀ·B via matmul_tn: feed Aᵀ as the logical A.
            let mut at = vec![0.0f32; k * m];
            for i in 0..m {
                for p in 0..k {
                    at[p * m + i] = a[i * k + p];
                }
            }
            let mut c = vec![0.0f32; m * n];
            matmul_tn(&at, &b, &mut c, k, m, n);
            assert_close(&c, &naive32, 1e-4, &format!("matmul_tn {m}x{k}x{n}"));

            // A·Bᵀ via matmul_bt: feed Bᵀ as the stored B.
            let mut bt = vec![0.0f32; n * k];
            for p in 0..k {
                for j in 0..n {
                    bt[j * k + p] = b[p * n + j];
                }
            }
            let mut c = vec![0.0f32; m * n];
            matmul_bt(&a, &bt, &mut c, m, k, n);
            assert_close(&c, &naive32, 1e-4, &format!("matmul_bt {m}x{k}x{n}"));
        }
    }

    #[test]
    fn matmul_accumulates_into_c() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 4.0];
        let mut c = vec![10.0f32];
        matmul(&a, &b, &mut c, 1, 2, 1);
        assert_eq!(c[0], 10.0 + 1.0 * 3.0 + 2.0 * 4.0);
    }

    #[test]
    fn im2col_gathers_receptive_fields_with_zero_padding() {
        // 1-channel 3x3 image, 3x3 kernel: the center row of the patch
        // matrix is the whole image; corners see 4 padding zeros.
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let mut p = vec![0.0f32; 9 * 9];
        im2col(&x, 3, 3, 1, 3, 3, &mut p);
        let center = &p[4 * 9..5 * 9];
        assert_eq!(center, x.as_slice());
        // Top-left output (0,0): only taps (kr,kc) with kr>=1, kc>=1 land
        // in-image; tap (1,1) is x[0,0] = 1.
        let corner = &p[0..9];
        assert_eq!(corner[4], 1.0);
        assert_eq!(corner[0], 0.0);
        assert_eq!(corner[1], 0.0);
        assert_eq!(corner[3], 0.0);
        // 1x1 kernel: the patch matrix IS the image.
        let mut p1 = vec![0.0f32; 9];
        im2col(&x, 3, 3, 1, 1, 1, &mut p1);
        assert_eq!(p1, x);
    }

    #[test]
    fn col2im_is_the_adjoint_of_im2col() {
        // <im2col(x), P> == <x, col2im(P)> for any P — the defining
        // adjoint identity the conv backward pass relies on.
        let mut rng = Rng::new(0xc2);
        let (h, w, ci, kh, kw) = (4usize, 5usize, 3usize, 3usize, 3usize);
        let x = randv(&mut rng, h * w * ci);
        let p = randv(&mut rng, h * w * kh * kw * ci);
        let mut gx = vec![0.0f32; h * w * kh * kw * ci];
        im2col(&x, h, w, ci, kh, kw, &mut gx);
        let lhs: f64 = gx.iter().zip(&p).map(|(a, b)| *a as f64 * *b as f64).sum();
        let mut back = vec![0.0f32; h * w * ci];
        col2im_add(&p, h, w, ci, kh, kw, &mut back);
        let rhs: f64 = back.iter().zip(&x).map(|(a, b)| *a as f64 * *b as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3 + 1e-4 * lhs.abs(), "{lhs} vs {rhs}");
    }

    #[test]
    fn ensure_grows_and_never_shrinks() {
        let mut v = Vec::new();
        ensure(&mut v, 4);
        assert_eq!(v.len(), 4);
        v[0] = 7.0;
        ensure(&mut v, 2);
        assert_eq!(v.len(), 4);
        assert_eq!(v[0], 7.0);
        ensure(&mut v, 8);
        assert_eq!(v.len(), 8);
    }
}
