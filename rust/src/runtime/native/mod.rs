//! Pure-Rust execution backend: a composable layer-graph engine over flat
//! `Vec<f32>` buffers. No PJRT, no artifacts, no native libraries — every
//! executable preset trains end-to-end on a fresh checkout.
//!
//! Structure:
//! * [`kernels`] — the vectorized compute layer: blocked f32 matmul
//!   variants, `axpy`/`dot`, im2col/col2im, and the [`KernelPath`]
//!   selector (scalar bit-exact oracle vs the fast vectorized default);
//! * [`ops`] — the op library (`Dense`, `Conv2d`, `MaxPool2d`, `ReLU`,
//!   `Flatten`, softmax cross-entropy), each a uniform
//!   forward/backward/param_shapes implementation dispatching on the
//!   graph's [`KernelPath`];
//! * [`graph`] — [`LayerGraph`], which compiles a `dnn::ModelSpec` (the
//!   SAME description the scheduler's Table II cost model uses) into an op
//!   chain — whole, or any contiguous spec-layer segment — and owns all
//!   offset bookkeeping;
//! * [`partition`] — [`PartitionedBackend`], the split-execution runtime:
//!   a device half and a gateway half of one model cut at the DDSRA
//!   partition point, exchanging the smashed activation forward and the
//!   cut gradient backward (byte-identical to fused execution);
//! * this module — [`NativeBackend`], the [`Backend`] implementation: the
//!   `mlp` (3072 → 64 ReLU → 10) and `cnn` (VGG-mini:
//!   3× [conv3x3 + ReLU + maxpool2] → 1024 → 128 → 10) presets.
//!
//! The ABI matches the artifact family exactly: parameters travel
//! weights-then-bias per layer in layer order, `train_step` returns the
//! loss at the *pre-step* parameters (like `jax.value_and_grad`),
//! `eval_batch` returns (sum loss, num correct), and `grad` returns the
//! flat concatenated minibatch gradient. For `mlp` on the
//! [`KernelPath::Scalar`] oracle path, the graph engine is bit-identical
//! to the fused dense backend it replaced (He-normal hidden init, zero
//! head, identical accumulation order) — the golden test below pins that
//! with a verbatim copy of the retired implementation. The default
//! [`KernelPath::Vectorized`] path is the same math on blocked kernels,
//! bounded against scalar by tolerance in `rust/tests/kernel_parity.rs`.

pub mod graph;
pub mod kernels;
pub mod ops;
pub mod partition;

use anyhow::{bail, Result};

use super::backend::{Backend, Params};
use super::meta::ModelMeta;
use crate::dnn::{models, ModelSpec};

pub use graph::LayerGraph;
pub use kernels::KernelPath;
pub use partition::{make_partitioned_stack, make_partitioned_stack_kernel, PartitionedBackend};

/// Batch shapes shared by every native preset (python/compile/model.py
/// bakes the same ones into the AOT artifacts).
pub const TRAIN_BATCH: usize = 64;
pub const EVAL_BATCH: usize = 256;
pub const NUM_CLASSES: usize = 10;

/// The executable-preset registry: (spec, default init seed) by name —
/// the ONE place the fused backend, the split backend and the
/// partitioned-stack builder all resolve a preset, so their init streams
/// can never drift apart.
pub(crate) fn preset_spec_and_seed(name: &str) -> Result<(ModelSpec, u64)> {
    match name {
        "mlp" => Ok((models::mlp(), 0x6d6c70)),  // "mlp"
        "cnn" => Ok((models::vgg_mini(), 0x636e6e)), // "cnn"
        other => bail!(
            "unknown preset {other:?}: the native layer-graph engine implements \
             \"mlp\" and \"cnn\""
        ),
    }
}

/// Shared input validation for the native backend family (fused and
/// split): parameter tensors must match the meta's ABI shapes.
pub(crate) fn check_params_against(meta: &ModelMeta, params: &Params) -> Result<()> {
    if params.len() != meta.param_shapes.len() {
        bail!(
            "expected {} param tensors, got {}",
            meta.param_shapes.len(),
            params.len()
        );
    }
    for (buf, shape) in params.iter().zip(&meta.param_shapes) {
        let expect: usize = shape.iter().product();
        if buf.len() != expect {
            bail!("param tensor size {} != shape {shape:?}", buf.len());
        }
    }
    Ok(())
}

/// Validate per-sample geometry and labels for an arbitrary-size batch
/// of `dim` features per sample.
pub(crate) fn check_samples_against(
    meta: &ModelMeta,
    dim: usize,
    x: &[f32],
    y: &[i32],
) -> Result<()> {
    if y.is_empty() {
        bail!("empty batch");
    }
    if x.len() != y.len() * dim {
        bail!("input size {} != {}x{dim}", x.len(), y.len());
    }
    let classes = meta.num_classes as i32;
    for &c in y {
        if !(0..classes).contains(&c) {
            bail!("label {c} outside 0..{classes}");
        }
    }
    Ok(())
}

/// [`check_samples_against`] plus an exact batch-size requirement.
pub(crate) fn check_batch_against(
    meta: &ModelMeta,
    dim: usize,
    x: &[f32],
    y: &[i32],
    batch: usize,
) -> Result<()> {
    if y.len() != batch {
        bail!("label batch {} != expected {batch}", y.len());
    }
    check_samples_against(meta, dim, x, y)
}

/// One SGD update over the flat mean-loss gradient, walking the ABI
/// tensors in order — the exact loop the golden mlp oracle pins, shared
/// by the fused and split backends.
pub(crate) fn apply_sgd(params: &Params, g: &[f32], lr: f32) -> Params {
    let mut new = params.clone();
    let mut off = 0usize;
    for t in new.iter_mut() {
        for v in t.iter_mut() {
            *v -= lr * g[off];
            off += 1;
        }
    }
    new
}

/// Dependency-free layer-graph runtime.
pub struct NativeBackend {
    meta: ModelMeta,
    graph: LayerGraph,
    init_seed: u64,
}

impl NativeBackend {
    /// The `mlp` preset with the default deterministic init seed.
    pub fn mlp() -> Self {
        Self::mlp_seeded(preset_spec_and_seed("mlp").expect("registered preset").1)
    }

    /// Same preset, custom init seed (distinct seeds give distinct inits,
    /// each individually deterministic).
    pub fn mlp_seeded(init_seed: u64) -> Self {
        Self::from_spec(&models::mlp(), init_seed).expect("mlp preset is executable")
    }

    /// The `cnn` (VGG-mini) preset with the default init seed.
    pub fn cnn() -> Self {
        Self::cnn_seeded(preset_spec_and_seed("cnn").expect("registered preset").1)
    }

    pub fn cnn_seeded(init_seed: u64) -> Self {
        Self::from_spec(&models::vgg_mini(), init_seed).expect("cnn preset is executable")
    }

    /// Compile any executable `ModelSpec` into a backend — the spec is the
    /// single source of truth shared with the scheduler's cost model.
    /// Uses the default [`KernelPath`] (vectorized).
    pub fn from_spec(spec: &ModelSpec, init_seed: u64) -> Result<Self> {
        Self::from_spec_kernel(spec, init_seed, KernelPath::default())
    }

    /// [`Self::from_spec`] with an explicit [`KernelPath`] — `Scalar`
    /// selects the bit-exact oracle loops, `Vectorized` the blocked
    /// kernels. Init bytes are identical on both paths.
    pub fn from_spec_kernel(
        spec: &ModelSpec,
        init_seed: u64,
        kernel: KernelPath,
    ) -> Result<Self> {
        let graph = LayerGraph::from_spec_kernel(spec, NUM_CLASSES, kernel)?;
        let mut input_train = vec![TRAIN_BATCH];
        input_train.extend_from_slice(graph.input_shape());
        let mut input_eval = vec![EVAL_BATCH];
        input_eval.extend_from_slice(graph.input_shape());
        let meta = ModelMeta {
            preset: spec.name.clone(),
            train_batch: TRAIN_BATCH,
            eval_batch: EVAL_BATCH,
            num_classes: NUM_CLASSES,
            input_train,
            input_eval,
            param_total: graph.param_total(),
            train_k: 0,
            param_shapes: graph.param_shapes().to_vec(),
        };
        Ok(NativeBackend { meta, graph, init_seed })
    }

    /// The kernel path this backend's graph runs on.
    pub fn kernel(&self) -> KernelPath {
        self.graph.kernel()
    }

    fn check_params(&self, params: &Params) -> Result<()> {
        check_params_against(&self.meta, params)
    }

    /// Validate per-sample geometry and labels for an arbitrary-size batch.
    fn check_samples(&self, x: &[f32], y: &[i32]) -> Result<()> {
        check_samples_against(&self.meta, self.graph.in_len(), x, y)
    }

    fn check_batch(&self, x: &[f32], y: &[i32], batch: usize) -> Result<()> {
        check_batch_against(&self.meta, self.graph.in_len(), x, y, batch)
    }
}

impl Backend for NativeBackend {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn init_params(&self) -> Result<Params> {
        Ok(self.graph.init_params(self.init_seed))
    }

    fn train_step(
        &self,
        params: &Params,
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<(Params, f32)> {
        self.check_params(params)?;
        self.check_batch(x, y, self.meta.train_batch)?;
        let (loss_sum, _, grad) = self.graph.fwd_bwd(params, x, y, true);
        let g = grad.expect("gradient requested");
        Ok((apply_sgd(params, &g, lr), (loss_sum / y.len() as f64) as f32))
    }

    fn eval_batch(&self, params: &Params, x: &[f32], y: &[i32]) -> Result<(f64, f64)> {
        self.check_params(params)?;
        self.check_batch(x, y, self.meta.eval_batch)?;
        let (loss_sum, correct, _) = self.graph.fwd_bwd(params, x, y, false);
        Ok((loss_sum, correct as f64))
    }

    fn eval_partial_batch(
        &self,
        params: &Params,
        x: &[f32],
        y: &[i32],
    ) -> Result<Option<(f64, f64)>> {
        self.check_params(params)?;
        self.check_samples(x, y)?;
        let (loss_sum, correct, _) = self.graph.fwd_bwd(params, x, y, false);
        Ok(Some((loss_sum, correct as f64)))
    }

    fn grad(&self, params: &Params, x: &[f32], y: &[i32]) -> Result<Vec<f32>> {
        self.check_params(params)?;
        self.check_batch(x, y, self.meta.train_batch)?;
        let (_, _, grad) = self.graph.fwd_bwd(params, x, y, true);
        Ok(grad.expect("gradient requested"))
    }
}

#[cfg(test)]
mod golden {
    //! Byte-exact oracle: the pre-refactor fused mlp backend, kept
    //! VERBATIM as a test-only reference. The layer-graph engine must
    //! reproduce its numerics bit for bit — init stream, forward, loss,
    //! gradient accumulation order, and SGD update alike.

    use crate::rng::Rng;
    use crate::runtime::backend::Params;

    pub const INPUT_DIM: usize = 3072;
    pub const HIDDEN: usize = 64;
    pub const CLASSES: usize = 10;

    pub const O_W1: usize = 0;
    pub const O_B1: usize = INPUT_DIM * HIDDEN;
    pub const O_W2: usize = O_B1 + HIDDEN;
    pub const O_B2: usize = O_W2 + HIDDEN * CLASSES;
    pub const PARAM_TOTAL: usize = O_B2 + CLASSES;

    pub fn init(seed: u64) -> Params {
        let mut rng = Rng::new(seed);
        let scale = (2.0 / INPUT_DIM as f64).sqrt();
        let w1: Vec<f32> =
            (0..INPUT_DIM * HIDDEN).map(|_| (rng.normal() * scale) as f32).collect();
        vec![
            w1,
            vec![0.0; HIDDEN],
            vec![0.0; HIDDEN * CLASSES],
            vec![0.0; CLASSES],
        ]
    }

    pub fn fwd_bwd(
        params: &Params,
        x: &[f32],
        y: &[i32],
        want_grad: bool,
    ) -> (f64, usize, Option<Vec<f32>>) {
        let b = y.len();
        let (w1, b1, w2, b2) = (&params[0], &params[1], &params[2], &params[3]);
        let inv_b = 1.0f32 / b as f32;
        let mut grad = if want_grad { Some(vec![0.0f32; PARAM_TOTAL]) } else { None };

        let mut pre = vec![0.0f32; HIDDEN];
        let mut act = vec![0.0f32; HIDDEN];
        let mut z = vec![0.0f32; CLASSES];
        let mut dz = vec![0.0f32; CLASSES];
        let mut dh = vec![0.0f32; HIDDEN];
        let mut loss_sum = 0.0f64;
        let mut correct = 0usize;

        for s in 0..b {
            let xs = &x[s * INPUT_DIM..(s + 1) * INPUT_DIM];

            pre.copy_from_slice(b1);
            for i in 0..INPUT_DIM {
                let xi = xs[i];
                if xi != 0.0 {
                    let row = &w1[i * HIDDEN..(i + 1) * HIDDEN];
                    for j in 0..HIDDEN {
                        pre[j] += xi * row[j];
                    }
                }
            }
            for j in 0..HIDDEN {
                act[j] = pre[j].max(0.0);
            }

            z.copy_from_slice(b2);
            for j in 0..HIDDEN {
                let aj = act[j];
                if aj != 0.0 {
                    let row = &w2[j * CLASSES..(j + 1) * CLASSES];
                    for k in 0..CLASSES {
                        z[k] += aj * row[k];
                    }
                }
            }

            let label = y[s] as usize;
            let zmax = z.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let mut expsum = 0.0f32;
            for k in 0..CLASSES {
                dz[k] = (z[k] - zmax).exp();
                expsum += dz[k];
            }
            loss_sum += (expsum.ln() + zmax - z[label]) as f64;

            let mut best = 0usize;
            for k in 1..CLASSES {
                if z[k] > z[best] {
                    best = k;
                }
            }
            if best == label {
                correct += 1;
            }

            if let Some(g) = grad.as_mut() {
                for k in 0..CLASSES {
                    dz[k] *= inv_b / expsum;
                }
                dz[label] -= inv_b;

                for j in 0..HIDDEN {
                    let aj = act[j];
                    let row = &w2[j * CLASSES..(j + 1) * CLASSES];
                    let mut acc = 0.0f32;
                    for k in 0..CLASSES {
                        acc += row[k] * dz[k];
                        g[O_W2 + j * CLASSES + k] += aj * dz[k];
                    }
                    dh[j] = if pre[j] > 0.0 { acc } else { 0.0 };
                }
                for k in 0..CLASSES {
                    g[O_B2 + k] += dz[k];
                }

                for i in 0..INPUT_DIM {
                    let xi = xs[i];
                    if xi != 0.0 {
                        let row = &mut g[O_W1 + i * HIDDEN..O_W1 + (i + 1) * HIDDEN];
                        for j in 0..HIDDEN {
                            row[j] += xi * dh[j];
                        }
                    }
                }
                for j in 0..HIDDEN {
                    g[O_B1 + j] += dh[j];
                }
            }
        }
        (loss_sum, correct, grad)
    }

    pub fn train_step(params: &Params, x: &[f32], y: &[i32], lr: f32) -> (Params, f32) {
        let (loss_sum, _, grad) = fwd_bwd(params, x, y, true);
        let g = grad.expect("gradient requested");
        let mut new = params.clone();
        let mut off = 0usize;
        for t in new.iter_mut() {
            for v in t.iter_mut() {
                *v -= lr * g[off];
                off += 1;
            }
        }
        (new, (loss_sum / y.len() as f64) as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::golden::{CLASSES, HIDDEN, INPUT_DIM, O_B1, O_B2, O_W1, O_W2, PARAM_TOTAL};
    use super::*;
    use crate::rng::Rng;

    fn batch(seed: u64, n: usize) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n * INPUT_DIM).map(|_| rng.normal() as f32 * 0.5).collect();
        let y: Vec<i32> = (0..n).map(|_| rng.below(CLASSES) as i32).collect();
        (x, y)
    }

    fn assert_bits_eq(a: &Params, b: &Params, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: tensor count");
        for (t, (ta, tb)) in a.iter().zip(b).enumerate() {
            assert_eq!(ta.len(), tb.len(), "{what}: tensor {t} len");
            for (i, (va, vb)) in ta.iter().zip(tb).enumerate() {
                assert_eq!(
                    va.to_bits(),
                    vb.to_bits(),
                    "{what}: tensor {t} idx {i}: {va} vs {vb}"
                );
            }
        }
    }

    /// THE refactor-pinning test: the layer-graph mlp on the SCALAR
    /// kernel path must be bit-identical to the retired fused
    /// implementation — init, losses, gradients, and parameters after
    /// several SGD steps. (The vectorized default reorders summation and
    /// is tolerance-bounded instead — rust/tests/kernel_parity.rs.)
    #[test]
    fn mlp_graph_matches_fused_reference_bit_for_bit() {
        for seed in [0x6d6c70u64, 7, 12345] {
            let b = NativeBackend::from_spec_kernel(&models::mlp(), seed, KernelPath::Scalar)
                .expect("mlp preset is executable");
            let mut p = b.init_params().unwrap();
            let mut rp = golden::init(seed);
            assert_bits_eq(&p, &rp, "init");

            for step in 0..4u32 {
                let (x, y) = batch(seed ^ u64::from(step) << 16, 64);
                let (np, loss) = b.train_step(&p, &x, &y, 0.05).unwrap();
                let (nrp, rloss) = golden::train_step(&rp, &x, &y, 0.05);
                assert_eq!(loss.to_bits(), rloss.to_bits(), "loss at step {step}");
                assert_bits_eq(&np, &nrp, "params after step");
                p = np;
                rp = nrp;
            }

            // Gradient and eval parity at the trained point.
            let (x, y) = batch(seed ^ 0xabcd, 64);
            let g = b.grad(&p, &x, &y).unwrap();
            let rg = golden::fwd_bwd(&rp, &x, &y, true).2.unwrap();
            assert_eq!(g.len(), rg.len());
            for (i, (va, vb)) in g.iter().zip(&rg).enumerate() {
                assert_eq!(va.to_bits(), vb.to_bits(), "grad[{i}]");
            }
            let (xe, ye) = batch(seed ^ 0xef01, 256);
            let (le, ce) = b.eval_batch(&p, &xe, &ye).unwrap();
            let (rl, rc, _) = golden::fwd_bwd(&rp, &xe, &ye, false);
            assert_eq!(le.to_bits(), rl.to_bits(), "eval loss");
            assert_eq!(ce as usize, rc, "eval correct");
        }
    }

    #[test]
    fn meta_matches_python_preset() {
        let b = NativeBackend::mlp();
        let m = b.meta();
        assert_eq!(m.preset, "mlp");
        assert_eq!((m.train_batch, m.eval_batch, m.num_classes), (64, 256, 10));
        assert_eq!(m.param_total, 3072 * 64 + 64 + 64 * 10 + 10);
        assert_eq!(m.sample_dim(), 3072);
        assert_eq!(m.input_train, vec![64, 3072]);
    }

    #[test]
    fn cnn_meta_matches_python_preset() {
        let b = NativeBackend::cnn();
        let m = b.meta();
        assert_eq!(m.preset, "cnn");
        assert_eq!((m.train_batch, m.eval_batch, m.num_classes), (64, 256, 10));
        assert_eq!(m.input_train, vec![64, 32, 32, 3]);
        assert_eq!(m.sample_dim(), 3072);
        // python param_count('cnn') = weights + biases over the 5 layers.
        let expect = (432 + 16) + (4608 + 32) + (18432 + 64) + (131072 + 128) + (1280 + 10);
        assert_eq!(m.param_total, expect);
        assert_eq!(m.param_shapes[0], vec![3, 3, 3, 16]);
        assert_eq!(m.param_shapes[8], vec![128, 10]);
    }

    #[test]
    fn init_is_deterministic_and_zero_headed() {
        let b = NativeBackend::mlp();
        let p1 = b.init_params().unwrap();
        let p2 = b.init_params().unwrap();
        assert_eq!(p1, p2);
        assert!(p1[2].iter().all(|&v| v == 0.0));
        assert!(p1[3].iter().all(|&v| v == 0.0));
        assert!(p1[0].iter().any(|&v| v != 0.0));
        // Different seeds give different hidden features.
        let p3 = NativeBackend::mlp_seeded(99).init_params().unwrap();
        assert_ne!(p1[0], p3[0]);
    }

    #[test]
    fn cnn_init_is_deterministic_he_body_zero_head() {
        let b = NativeBackend::cnn();
        let p1 = b.init_params().unwrap();
        assert_eq!(p1, b.init_params().unwrap());
        // Conv + fc1 weights are He-normal, every bias and the head zero.
        for t in [0usize, 2, 4, 6] {
            assert!(p1[t].iter().any(|&v| v != 0.0), "tensor {t}");
        }
        for t in [1usize, 3, 5, 7, 8, 9] {
            assert!(p1[t].iter().all(|&v| v == 0.0), "tensor {t}");
        }
    }

    #[test]
    fn initial_loss_is_ln10_and_zero_lr_is_identity() {
        let b = NativeBackend::mlp();
        let p = b.init_params().unwrap();
        let (x, y) = batch(1, 64);
        let (same, loss) = b.train_step(&p, &x, &y, 0.0).unwrap();
        assert_eq!(same, p);
        assert!((loss - 10f32.ln()).abs() < 1e-5, "loss {loss}");
    }

    #[test]
    fn cnn_initial_loss_is_ln10_and_sgd_reduces_it() {
        let b = NativeBackend::cnn();
        let mut p = b.init_params().unwrap();
        let (x, y) = batch(11, 64);
        let (_, first) = b.train_step(&p, &x, &y, 0.0).unwrap();
        assert!((first - 10f32.ln()).abs() < 1e-5, "zero-head cnn loss {first}");
        for _ in 0..4 {
            let (np, _) = b.train_step(&p, &x, &y, 0.1).unwrap();
            p = np;
        }
        let (_, last) = b.train_step(&p, &x, &y, 0.0).unwrap();
        assert!(
            (last as f64) < first as f64 - 0.01,
            "cnn loss should fall from ln 10: {first} -> {last}"
        );
    }

    #[test]
    fn grad_matches_finite_differences() {
        let b = NativeBackend::mlp();
        let mut p = b.init_params().unwrap();
        // Perturb the head so gradients flow through both layers.
        let mut rng = Rng::new(7);
        for v in p[2].iter_mut().chain(p[3].iter_mut()) {
            *v = (rng.normal() * 0.1) as f32;
        }
        let (x, y) = batch(2, 64);
        let g = b.grad(&p, &x, &y).unwrap();
        assert_eq!(g.len(), PARAM_TOTAL);

        let loss_at = |params: &Params| -> f64 {
            let (_, l) = b.train_step(params, &x, &y, 0.0).unwrap();
            l as f64
        };
        // Probe a few coordinates in every tensor.
        let probes = [
            (0usize, 0usize),     // w1[0,0]
            (0, 5 * HIDDEN + 3),  // w1[5,3]
            (1, 2),               // b1[2]
            (2, 7),               // w2[0,7]
            (2, 4 * CLASSES + 1), // w2[4,1]
            (3, 6),               // b2[6]
        ];
        let offsets = [O_W1, O_B1, O_W2, O_B2];
        let eps = 1e-2f32;
        for (t, i) in probes {
            let mut hi = p.clone();
            hi[t][i] += eps;
            let mut lo = p.clone();
            lo[t][i] -= eps;
            let num = (loss_at(&hi) - loss_at(&lo)) / (2.0 * eps as f64);
            let ana = g[offsets[t] + i] as f64;
            assert!(
                (num - ana).abs() < 1e-3 + 0.05 * ana.abs(),
                "tensor {t} idx {i}: numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn train_step_equals_manual_sgd_on_grad() {
        let b = NativeBackend::mlp();
        let p = b.init_params().unwrap();
        let (x, y) = batch(3, 64);
        let (stepped, _) = b.train_step(&p, &x, &y, 0.01).unwrap();
        let g = b.grad(&p, &x, &y).unwrap();
        let mut manual = p.clone();
        let mut off = 0;
        for t in manual.iter_mut() {
            for v in t.iter_mut() {
                *v -= 0.01 * g[off];
                off += 1;
            }
        }
        assert_eq!(manual, stepped);
    }

    #[test]
    fn sgd_reduces_loss_on_separable_batch() {
        let b = NativeBackend::mlp();
        let mut p = b.init_params().unwrap();
        // One fixed batch: repeated steps must drive its loss down fast.
        let (x, y) = batch(4, 64);
        let (_, first) = b.train_step(&p, &x, &y, 0.0).unwrap();
        for _ in 0..30 {
            let (np, _) = b.train_step(&p, &x, &y, 0.1).unwrap();
            p = np;
        }
        let (_, last) = b.train_step(&p, &x, &y, 0.0).unwrap();
        assert!(
            last < first - 0.5,
            "memorising one batch should cut the loss: {first} -> {last}"
        );
    }

    #[test]
    fn eval_batch_sums_and_counts() {
        let b = NativeBackend::mlp();
        let p = b.init_params().unwrap();
        let (x, y) = batch(5, 256);
        let (loss_sum, correct) = b.eval_batch(&p, &x, &y).unwrap();
        // Zero head: per-sample loss is exactly ln 10.
        assert!((loss_sum / 256.0 - 10f64.ln()).abs() < 1e-5);
        assert!((0.0..=256.0).contains(&correct));
    }

    #[test]
    fn eval_full_chunks_consistently() {
        let b = NativeBackend::mlp();
        let p = b.init_params().unwrap();
        let (x, y) = batch(6, 512);
        let (mean_loss, acc) = b.eval_full(&p, &x, &y).unwrap();
        assert!((mean_loss - 10f64.ln()).abs() < 1e-5);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn eval_full_handles_a_trailing_partial_batch() {
        let b = NativeBackend::mlp();
        let p = b.init_params().unwrap();
        // 612 = 2 full eval batches of 256 + a remainder of 100.
        let (x, y) = batch(9, 612);
        let (mean_loss, acc) = b.eval_full(&p, &x, &y).unwrap();
        assert!((mean_loss - 10f64.ln()).abs() < 1e-5);
        assert!((0.0..=1.0).contains(&acc));
        // The composition equals full batches + the manual partial tail.
        let dim = b.meta().sample_dim();
        let (mut loss, mut correct) = (0.0, 0.0);
        for c in 0..2 {
            let (l, n) = b
                .eval_batch(&p, &x[c * 256 * dim..(c + 1) * 256 * dim], &y[c * 256..(c + 1) * 256])
                .unwrap();
            loss += l;
            correct += n;
        }
        let (l, n) = b
            .eval_partial_batch(&p, &x[512 * dim..], &y[512..])
            .unwrap()
            .expect("native backends run partial batches");
        loss += l;
        correct += n;
        assert_eq!((loss / 612.0).to_bits(), mean_loss.to_bits());
        assert_eq!((correct / 612.0).to_bits(), acc.to_bits());
        // Tiny test sets (below one eval batch) also work.
        let (x1, y1) = batch(10, 3);
        let (ml, a) = b.eval_full(&p, &x1, &y1).unwrap();
        assert!((ml - 10f64.ln()).abs() < 1e-5);
        assert!((0.0..=1.0).contains(&a));
    }

    #[test]
    fn rejects_malformed_inputs() {
        let b = NativeBackend::mlp();
        let p = b.init_params().unwrap();
        let (x, y) = batch(8, 64);
        assert!(b.train_step(&p, &x[..10], &y, 0.1).is_err());
        assert!(b.train_step(&p, &x, &y[..10], 0.1).is_err());
        let bad_y: Vec<i32> = vec![11; 64];
        assert!(b.train_step(&p, &x, &bad_y, 0.1).is_err());
        let mut bad_p = p.clone();
        bad_p[0].pop();
        assert!(b.train_step(&bad_p, &x, &y, 0.1).is_err());
        // Mismatched x/y still fails on the ragged eval path.
        assert!(b.eval_full(&p, &x[..100], &y[..10]).is_err());
        assert!(b.eval_partial_batch(&p, &x[..100], &y[..10]).is_err());
    }
}
