//! PJRT runtime (request path): loads the AOT HLO-text artifacts produced
//! by `make artifacts` and executes them on the PJRT CPU client.
//!
//! Python is never on this path — the artifacts are compiled once at
//! `Engine::load` and executed from the FL round loop.

pub mod engine;
pub mod meta;

pub use engine::{Engine, Params};
pub use meta::ModelMeta;
