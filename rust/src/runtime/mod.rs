//! Execution runtime (request path), behind the pluggable [`Backend`]
//! trait: the orchestrator trains and evaluates through `Box<dyn Backend>`
//! and never sees which engine runs the numerics.
//!
//! * Default build: [`NativeBackend`] — the pure-Rust layer-graph engine
//!   (`native/{ops,graph}`): a composable op library (dense, conv2d,
//!   max-pool, relu, flatten, softmax-xent) compiled from the scheduler's
//!   own `dnn::ModelSpec` descriptions, with rayon-parallel batches. Both
//!   executable presets (`mlp`, `cnn`) train with no artifacts and no
//!   native libraries.
//! * Split execution: [`PartitionedBackend`] (`native/partition`) runs the
//!   same presets as a device/gateway pair cut at any spec-layer boundary
//!   — the paper's DNN partition executed for real, byte-identical to the
//!   fused engine at every cut point.
//! * Wire-level split: [`RemoteBackend`] (`remote`) drives the same split
//!   over a TCP connection to a `net::serve` gateway service, with the
//!   in-process [`PartitionedBackend`] as its byte-parity oracle.
//! * Feature `pjrt`: `Engine` loads the AOT HLO-text artifacts produced
//!   by `make artifacts` and executes them on the PJRT CPU client (Python
//!   is never on this path — artifacts compile once at `Engine::load`).
//!
//! [`make_backend`] picks the best available implementation per preset.

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod meta;
pub mod native;
pub mod remote;

pub use backend::{make_backend, make_backend_kernel, Backend, Params};
pub use remote::RemoteBackend;
#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use meta::ModelMeta;
pub use native::{
    make_partitioned_stack, make_partitioned_stack_kernel, KernelPath, LayerGraph,
    NativeBackend, PartitionedBackend,
};
