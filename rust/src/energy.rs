//! Energy model (§III-B/C): harvested-energy arrivals and the training /
//! transmission consumption formulas (Eq. 2, 3, 8, 9).
//!
//! Devices and gateways are battery-operated with energy-harvesting (EH)
//! components; arrivals are IID uniform in [0, E^max] per round, and each
//! round's consumption may not exceed that round's arrival (C9, C10).

use crate::config::SimConfig;
use crate::dnn::ModelSpec;
use crate::rng::Rng;
use crate::topo::{Device, Gateway};

/// One round's energy arrivals.
#[derive(Clone, Debug)]
pub struct EnergyArrivals {
    /// E_n^D(t) per device (J).
    pub device: Vec<f64>,
    /// E_m^G(t) per gateway (J).
    pub gateway: Vec<f64>,
}

impl EnergyArrivals {
    pub fn draw(cfg: &SimConfig, rng: &mut Rng) -> Self {
        EnergyArrivals {
            device: (0..cfg.num_devices)
                .map(|_| rng.uniform(0.0, cfg.device_energy_max))
                .collect(),
            gateway: (0..cfg.num_gateways)
                .map(|_| rng.uniform(0.0, cfg.gw_energy_max))
                .collect(),
        }
    }
}

/// Cycles needed on the device for the bottom `l` layers of one local
/// training pass over `batch` samples: K * batch * Σ(o+o') / phi.
fn device_cycles(model: &ModelSpec, l: usize, batch: usize, k: usize, phi: f64) -> f64 {
    k as f64 * batch as f64 * model.bottom_flops(l) / phi
}

fn gateway_cycles(model: &ModelSpec, l: usize, batch: usize, k: usize, phi: f64) -> f64 {
    k as f64 * batch as f64 * model.top_flops(l) / phi
}

/// e_n^{tra,D}(t) (Eq. 2): device-side training energy at partition l.
pub fn device_train_energy(
    dev: &Device,
    model: &ModelSpec,
    l: usize,
    k: usize,
) -> f64 {
    dev.kappa
        * device_cycles(model, l, dev.train_batch, k, dev.flops_per_cycle)
        * dev.freq
        * dev.freq
}

/// Device-side training time contribution (the first term of Eq. 1).
pub fn device_train_time(dev: &Device, model: &ModelSpec, l: usize, k: usize) -> f64 {
    device_cycles(model, l, dev.train_batch, k, dev.flops_per_cycle) / dev.freq
}

/// e_m^{tra,G} contribution of one offloaded device (Eq. 3) at gateway
/// frequency share `f_g`.
pub fn gateway_train_energy(
    gw: &Gateway,
    dev: &Device,
    model: &ModelSpec,
    l: usize,
    k: usize,
    f_g: f64,
) -> f64 {
    gw.kappa
        * gateway_cycles(model, l, dev.train_batch, k, gw.flops_per_cycle)
        * f_g
        * f_g
}

/// Gateway-side training time for one offloaded device (second term, Eq. 1).
pub fn gateway_train_time(
    gw: &Gateway,
    dev: &Device,
    model: &ModelSpec,
    l: usize,
    k: usize,
    f_g: f64,
) -> f64 {
    if model.top_flops(l) == 0.0 {
        return 0.0;
    }
    gateway_cycles(model, l, dev.train_batch, k, gw.flops_per_cycle) / f_g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::models;
    use crate::topo::Topology;

    fn fixtures() -> (Topology, ModelSpec) {
        let cfg = SimConfig::default();
        let t = Topology::generate(&cfg, &mut Rng::new(1));
        (t, models::vgg11_cifar())
    }

    #[test]
    fn arrivals_within_caps() {
        let cfg = SimConfig::default();
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let a = EnergyArrivals::draw(&cfg, &mut rng);
            assert!(a.device.iter().all(|&e| (0.0..=cfg.device_energy_max).contains(&e)));
            assert!(a.gateway.iter().all(|&e| (0.0..=cfg.gw_energy_max).contains(&e)));
        }
    }

    #[test]
    fn device_energy_monotone_in_partition_point() {
        let (t, m) = fixtures();
        let dev = &t.devices[0];
        for l in 1..=m.depth() {
            assert!(
                device_train_energy(dev, &m, l, 5)
                    >= device_train_energy(dev, &m, l - 1, 5)
            );
        }
        assert_eq!(device_train_energy(dev, &m, 0, 5), 0.0);
    }

    #[test]
    fn full_on_device_vgg11_energy_order_of_magnitude() {
        // §VII-A sanity: full VGG-11 on-device training at ~0.5 GHz should
        // cost a few J per round — comparable to E^D_max = 5 J.
        let (t, m) = fixtures();
        let dev = &t.devices[0];
        let e = device_train_energy(dev, &m, m.depth(), 5);
        assert!(e > 0.05 && e < 500.0, "e = {e}");
    }

    #[test]
    fn gateway_time_scales_inverse_frequency() {
        let (t, m) = fixtures();
        let gw = &t.gateways[0];
        let dev = &t.devices[0];
        let t1 = gateway_train_time(gw, dev, &m, 4, 5, 1.0e9);
        let t2 = gateway_train_time(gw, dev, &m, 4, 5, 2.0e9);
        assert!((t1 / t2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn gateway_energy_scales_square_frequency() {
        let (t, m) = fixtures();
        let gw = &t.gateways[0];
        let dev = &t.devices[0];
        let e1 = gateway_train_energy(gw, dev, &m, 4, 5, 1.0e9);
        let e2 = gateway_train_energy(gw, dev, &m, 4, 5, 2.0e9);
        assert!((e2 / e1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn fully_offloaded_has_zero_device_cost() {
        let (t, m) = fixtures();
        let dev = &t.devices[1];
        assert_eq!(device_train_time(dev, &m, 0, 5), 0.0);
        assert_eq!(device_train_energy(dev, &m, 0, 5), 0.0);
    }

    #[test]
    fn fully_on_device_has_zero_gateway_time() {
        let (t, m) = fixtures();
        let gw = &t.gateways[0];
        let dev = &t.devices[0];
        assert_eq!(gateway_train_time(gw, dev, &m, m.depth(), 5, 1e9), 0.0);
    }
}
