//! Configuration system: every §VII-A experimental constant, loadable from
//! a minimal key = value config file (TOML subset — no external parser is
//! available offline) and overridable from the CLI.
//!
//! Defaults reproduce the paper's setting exactly: M=6 gateways, N=12
//! devices (2 per shop floor), J=3 channels, uniform D_n in (0, 2000],
//! E^D_max = 5 J, E^G_max = 30 J, 2/4 GB memories, K=5 local iterations,
//! alpha = 0.05 sampling ratio, beta = 0.01 step size, and the channel
//! constants of §VII-A.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context};

use crate::runtime::KernelPath;
use crate::sched::SchedPath;

/// Phase-5 aggregation topology. `Flat` folds every surviving update
/// through one cloud-side `WeightedAccum` in plan order — the original
/// path and the bit-exactness oracle. `Hierarchical` folds each gateway's
/// members through the gateway's own accumulator, merges gateway
/// summaries per edge cluster, and merges cluster summaries at the cloud
/// (`fl::hierarchy`), so only tier summaries ever move up.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Aggregation {
    #[default]
    Flat,
    Hierarchical,
}

impl std::str::FromStr for Aggregation {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "flat" => Ok(Aggregation::Flat),
            "hierarchical" => Ok(Aggregation::Hierarchical),
            other => bail!("unknown aggregation {other:?} (known: flat, hierarchical)"),
        }
    }
}

impl std::fmt::Display for Aggregation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Aggregation::Flat => "flat",
            Aggregation::Hierarchical => "hierarchical",
        })
    }
}

/// Split-execution transport. `Inproc` runs both halves in this process
/// through `PartitionedBackend` — the original path and the byte-parity
/// oracle. `Tcp` runs the device half here and the gateway half behind a
/// `net::serve` gateway service over the length-prefixed wire protocol
/// (`net::wire`); a loopback tcp run is byte-identical to the inproc run
/// at every cut (`rust/tests/wire.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Transport {
    #[default]
    Inproc,
    Tcp,
}

impl std::str::FromStr for Transport {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "inproc" => Ok(Transport::Inproc),
            "tcp" => Ok(Transport::Tcp),
            other => bail!("unknown transport {other:?} (known: inproc, tcp)"),
        }
    }
}

impl std::fmt::Display for Transport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Transport::Inproc => "inproc",
            Transport::Tcp => "tcp",
        })
    }
}

/// Deterministic-adversity knobs (`fault.*` config keys): Dirichlet
/// non-IID sharding, stragglers, mid-round device dropout, and gateway
/// outages. All default to "off" so the benign paper environment stays
/// the byte-identical baseline; the `flaky-plant` / `churn-metro`
/// scenarios arm them as presets. Consumed by `fl::fault::FaultPlan`,
/// which draws every fault from dedicated `STREAM_FAULT_*` RNG domains
/// so adversity runs replay byte-identically across thread counts.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultConfig {
    /// Dirichlet concentration for non-IID label sharding (phase 0).
    /// 0 = off (keep the menu-based `non_iid_degree` sharder); smaller
    /// positive values = more skew.
    pub dirichlet_alpha: f64,
    /// Per-(round, device) probability of a straggler episode (phase 2).
    pub straggler_prob: f64,
    /// Max delay multiplier of a straggler episode: the realized factor
    /// is U(1, slowdown). Must be >= 1.
    pub straggler_slowdown: f64,
    /// Per-(round, device) probability the device drops mid-round and
    /// contributes nothing to aggregation (phases 3-4).
    pub dropout_prob: f64,
    /// Per-(round, gateway) probability of a whole-floor outage: the
    /// gateway counts as failed and none of its members train.
    pub gateway_outage_prob: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            dirichlet_alpha: 0.0,
            straggler_prob: 0.0,
            straggler_slowdown: 1.0,
            dropout_prob: 0.0,
            gateway_outage_prob: 0.0,
        }
    }
}

impl FaultConfig {
    /// True when every knob is at its benign default — the engine skips
    /// all fault machinery (and all fault-stream draws) in that case.
    pub fn is_benign(&self) -> bool {
        *self == FaultConfig::default()
    }
}

/// All simulation parameters. Units are SI (Hz, W, J, bytes, seconds)
/// except where a field name says otherwise.
#[derive(Clone, Debug)]
pub struct SimConfig {
    // ---- topology ----
    pub num_gateways: usize, // M
    pub num_devices: usize,  // N (distributed evenly across gateways)
    pub num_channels: usize, // J
    /// Edge clusters the gateways partition into (contiguous, draw-free).
    /// 1 = the flat two-tier topology of the paper.
    pub num_clusters: usize,

    // ---- devices ----
    pub dataset_min: usize, // D_n ~ U(dataset_min, dataset_max]
    pub dataset_max: usize,
    pub device_energy_max: f64,   // E_n^{D,max} J per round
    pub device_mem: f64,          // G_n^{D,max} bytes
    pub device_freq_min: f64,     // f_n^D lower bound (Hz)
    pub device_freq_max: f64,     // f_n^D upper bound (Hz)
    pub device_flops_per_cycle: f64, // phi_n^D
    pub device_kappa: f64,        // v_n^D effective switched capacitance

    // ---- gateways ----
    pub gw_dist_min: f64,       // d_m ~ U[min,max] meters
    pub gw_dist_max: f64,
    pub gw_energy_max: f64,     // E_m^{G,max} J per round
    pub gw_mem: f64,            // G_m^{G,max} bytes
    pub gw_freq_max: f64,       // f_m^{G,max} Hz
    pub gw_freq_min: f64,       // f_m^{G,min} Hz (C6 lower bound)
    pub gw_flops_per_cycle: f64, // phi_m^G
    pub gw_kappa: f64,          // v_m^G
    pub gw_power_max: f64,      // P_m^max W

    // ---- channel ----
    pub ref_dist: f64,          // d_0 m
    pub path_loss_exp: f64,     // nu
    pub bw_up: f64,             // B^u Hz
    pub bw_down: f64,           // B^d Hz
    pub noise_psd: f64,         // N_0 W/Hz
    pub path_loss_const_db: f64, // h_0 dB
    pub bs_power: f64,          // P^B W
    /// Std-dev range of the Gaussian co-channel interference amplitude per
    /// channel ("different variances" across channels in §VII-A); the
    /// interference power is the squared amplitude.
    pub interference_amp_min: f64,
    pub interference_amp_max: f64,

    // ---- FL ----
    pub local_iters: usize, // K
    pub sample_ratio: f64,  // alpha: training batch = alpha * D_n
    pub lr: f64,            // beta
    pub rounds: usize,      // T
    pub lyapunov_v: f64,    // V

    // ---- models / data ----
    /// Cost-model preset the scheduler plans with ("vgg11", "cnn", "mlp").
    pub cost_model: String,
    /// Executable preset the runtime trains ("mlp" or "cnn"); both run
    /// natively on the layer-graph engine, no artifacts required.
    pub exec_model: String,
    /// Execute training SPLIT at the DNN partition point each scheduler
    /// plan selects (§II-B): device half / gateway half with an
    /// activation-forward, gradient-backward exchange at the cut. Requires
    /// `cost_model == exec_model` so the planned cut indexes the executed
    /// network. Off = the fused engine runs and the partition is
    /// cost-model-only (the pre-split behaviour). On the native engine
    /// (the default build) the two modes are byte-identical; a pjrt build
    /// with compiled artifacts refuses the flag rather than mix PJRT
    /// eval/init with native split training.
    pub execute_partition: bool,
    /// Native compute-kernel path: `vectorized` (blocked matmul + im2col
    /// conv, the default) or `scalar` (the original naive loops, kept as
    /// the bit-exactness oracle). Applies to the native layer-graph
    /// engine only; a PJRT build with artifacts ignores it.
    pub kernel: KernelPath,
    /// Split-execution transport (`inproc` or `tcp`). `tcp` requires
    /// `execute_partition` (the wire carries the split exchange), flat
    /// aggregation (the gateway service hosts one `WeightedAccum` fold),
    /// and a reachable `gateway_addr`.
    pub transport: Transport,
    /// Gateway-service address a `tcp` run dials (and the default listen
    /// address of `serve-gateway`).
    pub gateway_addr: String,
    /// Dial/read/write timeout for wire exchanges, milliseconds. On
    /// expiry the peer counts as lost and the device maps onto the
    /// `FaultPlan` dropout path.
    pub wire_timeout_ms: u64,
    /// DDSRA λ-sweep path: `incremental` (ascending-cap augmenting-path
    /// matching, the default) or `sweep` (the verbatim per-cap Hungarian
    /// re-solve, kept as the decision-parity oracle). Both produce
    /// bit-identical decisions; only the per-round scheduling cost
    /// differs. Ignored by the non-DDSRA baseline schedulers.
    pub sched_path: SchedPath,
    /// Synthetic dataset flavour: "svhn" (easier) or "cifar" (harder).
    pub dataset: String,
    /// Non-IID degree chi (proportion of q_m-class-restricted samples).
    pub non_iid_degree: f64,
    /// Test-set size (multiple of the eval batch).
    pub test_size: usize,
    /// Evaluate on a per-round deterministic sample of this many test
    /// points instead of the full test set (`STREAM_EVAL` domain).
    /// 0 (default) or >= `test_size` = full evaluation, byte-identical to
    /// the pre-knob behaviour.
    pub eval_sample: usize,
    /// Synthesize each device's shard on demand instead of materializing
    /// all N up front. Byte-identical to eager sharding (the same
    /// per-device `Rng::stream` replays); mandatory at nation scale where
    /// eager shards would need tens of GB.
    pub lazy_shards: bool,
    /// Phase-5 aggregation topology (`flat` or `hierarchical`).
    pub aggregation: Aggregation,
    /// Relay/Ψ energy coefficient (J per uplink bit) for hierarchical
    /// aggregation: partial aggregates are relayed tier-by-tier, and the
    /// scheduler charges Ψ·Γ against each scheduled gateway's energy
    /// budget (Hashempour et al., PAPERS.md). 0 (default) = off with
    /// byte-identical scheduler costs.
    pub relay_psi: f64,

    /// Deterministic-adversity block (`fault.*` keys). Benign by default.
    pub fault: FaultConfig,

    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            num_gateways: 6,
            num_devices: 12,
            num_channels: 3,
            num_clusters: 1,
            dataset_min: 200,
            dataset_max: 2000,
            device_energy_max: 5.0,
            device_mem: 2.0e9,
            device_freq_min: 0.1e9,
            device_freq_max: 1.0e9,
            device_flops_per_cycle: 16.0,
            device_kappa: 1e-27,
            gw_dist_min: 1000.0,
            gw_dist_max: 2000.0,
            gw_energy_max: 30.0,
            gw_mem: 4.0e9,
            gw_freq_max: 4.0e9,
            gw_freq_min: 0.1e9,
            gw_flops_per_cycle: 32.0,
            gw_kappa: 1e-27,
            gw_power_max: 0.2,
            ref_dist: 1.0,
            path_loss_exp: 2.0,
            bw_up: 1.0e6,
            bw_down: 20.0e6,
            noise_psd: dbm_per_hz_to_w(-174.0),
            path_loss_const_db: -30.0,
            bs_power: 1.0,
            interference_amp_min: 1e-8,
            interference_amp_max: 1e-7,
            local_iters: 5,
            sample_ratio: 0.05,
            lr: 0.01,
            rounds: 100,
            lyapunov_v: 0.01,
            cost_model: "vgg11".into(),
            exec_model: "mlp".into(),
            execute_partition: false,
            kernel: KernelPath::default(),
            transport: Transport::Inproc,
            gateway_addr: "127.0.0.1:7700".into(),
            wire_timeout_ms: 5000,
            sched_path: SchedPath::default(),
            dataset: "svhn".into(),
            non_iid_degree: 1.0,
            test_size: 2048,
            eval_sample: 0,
            lazy_shards: false,
            aggregation: Aggregation::Flat,
            relay_psi: 0.0,
            fault: FaultConfig::default(),
            seed: 2022,
        }
    }
}

/// dBm/Hz -> W/Hz.
pub fn dbm_per_hz_to_w(dbm: f64) -> f64 {
    10f64.powf(dbm / 10.0) * 1e-3
}

/// dB -> linear power ratio.
pub fn db_to_lin(db: f64) -> f64 {
    10f64.powf(db / 10.0)
}

impl SimConfig {
    /// Devices per gateway (the paper deploys them evenly: 2 per floor).
    pub fn devices_per_gateway(&self) -> usize {
        self.num_devices / self.num_gateways
    }

    /// Linear path-loss constant h_0.
    pub fn h0_lin(&self) -> f64 {
        db_to_lin(self.path_loss_const_db)
    }

    /// Parse `key = value` lines (comments with `#`, blank lines, and
    /// `[section]` headers permitted and ignored — a TOML subset).
    pub fn from_str_cfg(text: &str) -> anyhow::Result<Self> {
        let mut kv = BTreeMap::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() || (line.starts_with('[') && line.ends_with(']')) {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("config line {}: expected key = value, got {raw:?}", ln + 1);
            };
            kv.insert(k.trim().to_string(), v.trim().trim_matches('"').to_string());
        }
        let mut cfg = SimConfig::default();
        for (k, v) in kv {
            cfg.set(&k, &v)
                .with_context(|| format!("config key {k:?} = {v:?}"))?;
        }
        Ok(cfg)
    }

    pub fn from_file(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        Self::from_str_cfg(&text)
    }

    /// Set one field by config-file key. Used by both the parser and the
    /// CLI `--set key=value` override mechanism.
    pub fn set(&mut self, key: &str, val: &str) -> anyhow::Result<()> {
        macro_rules! num {
            () => {
                val.parse().map_err(|e| anyhow::anyhow!("parse {val:?}: {e}"))?
            };
        }
        match key {
            "num_gateways" => self.num_gateways = num!(),
            "num_devices" => self.num_devices = num!(),
            "num_channels" => self.num_channels = num!(),
            "num_clusters" => self.num_clusters = num!(),
            "dataset_min" => self.dataset_min = num!(),
            "dataset_max" => self.dataset_max = num!(),
            "device_energy_max" => self.device_energy_max = num!(),
            "device_mem" => self.device_mem = num!(),
            "device_freq_min" => self.device_freq_min = num!(),
            "device_freq_max" => self.device_freq_max = num!(),
            "device_flops_per_cycle" => self.device_flops_per_cycle = num!(),
            "device_kappa" => self.device_kappa = num!(),
            "gw_dist_min" => self.gw_dist_min = num!(),
            "gw_dist_max" => self.gw_dist_max = num!(),
            "gw_energy_max" => self.gw_energy_max = num!(),
            "gw_mem" => self.gw_mem = num!(),
            "gw_freq_max" => self.gw_freq_max = num!(),
            "gw_freq_min" => self.gw_freq_min = num!(),
            "gw_flops_per_cycle" => self.gw_flops_per_cycle = num!(),
            "gw_kappa" => self.gw_kappa = num!(),
            "gw_power_max" => self.gw_power_max = num!(),
            "ref_dist" => self.ref_dist = num!(),
            "path_loss_exp" => self.path_loss_exp = num!(),
            "bw_up" => self.bw_up = num!(),
            "bw_down" => self.bw_down = num!(),
            "noise_psd" => self.noise_psd = num!(),
            "path_loss_const_db" => self.path_loss_const_db = num!(),
            "bs_power" => self.bs_power = num!(),
            "interference_amp_min" => self.interference_amp_min = num!(),
            "interference_amp_max" => self.interference_amp_max = num!(),
            "local_iters" => self.local_iters = num!(),
            "sample_ratio" => self.sample_ratio = num!(),
            "lr" => self.lr = num!(),
            "rounds" => self.rounds = num!(),
            "lyapunov_v" => self.lyapunov_v = num!(),
            "cost_model" => self.cost_model = val.into(),
            "exec_model" => self.exec_model = val.into(),
            // The first boolean key: accept both bool literals and the
            // 0/1 style every numeric neighbor uses.
            "execute_partition" => {
                self.execute_partition = match val {
                    "true" | "1" => true,
                    "false" | "0" => false,
                    other => bail!("expected true/false/1/0, got {other:?}"),
                }
            }
            // Validated at parse time: only "scalar" / "vectorized" exist.
            "kernel" => self.kernel = val.parse()?,
            // Validated at parse time: only "inproc" / "tcp" exist.
            "transport" => self.transport = val.parse()?,
            "gateway_addr" => self.gateway_addr = val.into(),
            "wire_timeout_ms" => self.wire_timeout_ms = num!(),
            // Validated at parse time: only "sweep" / "incremental" exist.
            "sched_path" => self.sched_path = val.parse()?,
            "dataset" => self.dataset = val.into(),
            "non_iid_degree" => self.non_iid_degree = num!(),
            "test_size" => self.test_size = num!(),
            "eval_sample" => self.eval_sample = num!(),
            "lazy_shards" => {
                self.lazy_shards = match val {
                    "true" | "1" => true,
                    "false" | "0" => false,
                    other => bail!("expected true/false/1/0, got {other:?}"),
                }
            }
            // Validated at parse time: only "flat" / "hierarchical" exist.
            "aggregation" => self.aggregation = val.parse()?,
            "relay_psi" => self.relay_psi = num!(),
            "fault.dirichlet_alpha" => self.fault.dirichlet_alpha = num!(),
            "fault.straggler_prob" => self.fault.straggler_prob = num!(),
            "fault.straggler_slowdown" => self.fault.straggler_slowdown = num!(),
            "fault.dropout_prob" => self.fault.dropout_prob = num!(),
            "fault.gateway_outage_prob" => self.fault.gateway_outage_prob = num!(),
            "seed" => self.seed = num!(),
            other => bail!("unknown config key {other:?}"),
        }
        Ok(())
    }

    /// Apply a named scale scenario: a consistent (M, N, J, shard-size,
    /// test-size) working point for the large-N round engine. Scenarios
    /// are applied BEFORE `--set` overrides, so individual knobs can still
    /// be tuned on top; everything re-validates afterwards — nothing is
    /// relaxed silently.
    ///
    /// | scenario | gateways M | devices N | channels J | D_n range |
    /// |---|---|---|---|---|
    /// | `paper`  | 6 (default) | 12 | 3 | (200, 2000] |
    /// | `plant`  | 24 | 240 | 8 | (32, 256] |
    /// | `campus` | 48 | 960 | 12 | (32, 128] |
    /// | `metro`  | 96 | 2880 | 16 | (16, 64] |
    /// | `nation` | 2000 | 100&thinsp;000 | 8 | (16, 64] |
    /// | `nation-xl` | 20&thinsp;000 | 1&thinsp;000&thinsp;000 | 8 | (16, 64] |
    ///
    /// The two `nation`-class presets go beyond `metro` by switching the
    /// machinery the tentpole layers provide: hierarchical aggregation
    /// over edge clusters (`aggregation = hierarchical`, `num_clusters`),
    /// lazy on-demand shards (`lazy_shards`, eager shards would need tens
    /// of GB), sampled evaluation (`eval_sample`), and the relay/Ψ energy
    /// term (`relay_psi`) that prices tier-summary relaying.
    ///
    /// Two adversity presets layer a `FaultConfig` on top of a scale
    /// working point (every fault drawn from dedicated RNG streams, so
    /// these runs stay byte-replayable):
    ///
    /// | scenario | base | Dirichlet α | straggler | dropout | outage |
    /// |---|---|---|---|---|---|
    /// | `flaky-plant` | `plant` | 0.5 | p=0.15, ×≤4 | 0.10 | 0.05 |
    /// | `churn-metro` | `metro` | 0.3 | p=0.20, ×≤6 | 0.25 | 0.10 |
    ///
    /// The per-device dataset sizes shrink as N grows so total shard
    /// memory stays bounded; the training batch each device feeds the
    /// backend is the preset's fixed batch either way (D̃_n only weights
    /// aggregation and the cost model).
    pub fn apply_scenario(&mut self, name: &str) -> anyhow::Result<()> {
        match name {
            // The paper's §VII-A working point — the defaults.
            "paper" => {}
            "plant" => {
                self.num_gateways = 24;
                self.num_devices = 240;
                self.num_channels = 8;
                self.dataset_min = 32;
                self.dataset_max = 256;
                self.test_size = 512;
            }
            "campus" => {
                self.num_gateways = 48;
                self.num_devices = 960;
                self.num_channels = 12;
                self.dataset_min = 32;
                self.dataset_max = 128;
                self.test_size = 512;
            }
            "metro" => {
                self.num_gateways = 96;
                self.num_devices = 2880;
                self.num_channels = 16;
                self.dataset_min = 16;
                self.dataset_max = 64;
                self.test_size = 256;
            }
            // Nation-class working points: hierarchical aggregation over
            // edge clusters, lazy shards, sampled eval, and the relay/Ψ
            // energy term — the beyond-metro configuration in one knob.
            "nation" => {
                self.num_gateways = 2000;
                self.num_devices = 100_000;
                self.num_channels = 8;
                self.num_clusters = 40;
                self.dataset_min = 16;
                self.dataset_max = 64;
                self.test_size = 512;
                self.eval_sample = 128;
                self.lazy_shards = true;
                self.aggregation = Aggregation::Hierarchical;
                self.relay_psi = 1e-8;
            }
            "nation-xl" => {
                self.apply_scenario("nation")?;
                self.num_gateways = 20_000;
                self.num_devices = 1_000_000;
                self.num_clusters = 200;
            }
            // Adversity presets: a scale base plus an armed fault block.
            // A mid-size flaky plant — moderate skew, occasional floor
            // outages — and a metro deployment with heavy churn.
            "flaky-plant" => {
                self.apply_scenario("plant")?;
                self.fault = FaultConfig {
                    dirichlet_alpha: 0.5,
                    straggler_prob: 0.15,
                    straggler_slowdown: 4.0,
                    dropout_prob: 0.10,
                    gateway_outage_prob: 0.05,
                };
            }
            "churn-metro" => {
                self.apply_scenario("metro")?;
                self.fault = FaultConfig {
                    dirichlet_alpha: 0.3,
                    straggler_prob: 0.20,
                    straggler_slowdown: 6.0,
                    dropout_prob: 0.25,
                    gateway_outage_prob: 0.10,
                };
            }
            other => bail!(
                "unknown scenario {other:?} (known: paper, plant, campus, metro, \
                 nation, nation-xl, flaky-plant, churn-metro)"
            ),
        }
        Ok(())
    }

    /// Validate cross-field invariants before a run.
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.num_gateways == 0 || self.num_devices == 0 {
            bail!("topology must be non-empty");
        }
        if self.num_devices < self.num_gateways {
            bail!(
                "num_devices ({}) < num_gateways ({}): every shop floor needs at \
                 least one member device",
                self.num_devices,
                self.num_gateways
            );
        }
        if self.num_devices % self.num_gateways != 0 {
            bail!(
                "num_devices ({}) must be divisible by num_gateways ({})",
                self.num_devices,
                self.num_gateways
            );
        }
        if self.num_channels > self.num_gateways {
            bail!("C3 requires J <= M (every channel assigned to a distinct gateway)");
        }
        if self.num_clusters == 0 || self.num_clusters > self.num_gateways {
            bail!(
                "num_clusters ({}) must be in 1..=num_gateways ({})",
                self.num_clusters,
                self.num_gateways
            );
        }
        // Eager shards hold every device's images in memory at once; past
        // a few GB that is a configuration error, not a workload.
        let eager_shard_bytes = self.num_devices as u64
            * self.dataset_max as u64
            * (crate::data::synth::IMG_DIM as u64)
            * 4;
        if !self.lazy_shards && eager_shard_bytes > 8 << 30 {
            bail!(
                "eager shards for num_devices = {} x dataset_max = {} would need \
                 ~{} GiB; set lazy_shards = true (byte-identical, on-demand shards)",
                self.num_devices,
                self.dataset_max,
                eager_shard_bytes >> 30
            );
        }
        if !(self.relay_psi >= 0.0 && self.relay_psi.is_finite()) {
            bail!("relay_psi must be finite and >= 0 (J per relayed bit), got {}", self.relay_psi);
        }
        if !(0.0 < self.sample_ratio && self.sample_ratio <= 1.0) {
            bail!("sample_ratio must be in (0, 1]");
        }
        if self.dataset_min == 0 || self.dataset_min > self.dataset_max {
            bail!("dataset size range invalid");
        }
        if !matches!(self.exec_model.as_str(), "mlp" | "cnn") {
            bail!(
                "exec_model {:?} is not an executable preset (\"mlp\" or \"cnn\")",
                self.exec_model
            );
        }
        if crate::dnn::models::by_name(&self.cost_model).is_none() {
            bail!(
                "cost_model {:?} is not in the model zoo (\"vgg11\", \"cnn\", \"mlp\")",
                self.cost_model
            );
        }
        if self.execute_partition && self.cost_model != self.exec_model {
            bail!(
                "execute_partition requires cost_model == exec_model (got {:?} vs {:?}): \
                 the partition point the scheduler picks must index the network that \
                 actually executes",
                self.cost_model,
                self.exec_model
            );
        }
        if self.transport == Transport::Tcp {
            if !self.execute_partition {
                bail!(
                    "transport = tcp requires execute_partition: the wire carries the \
                     split exchange (smashed activations / cut gradients), so there must \
                     be a partition to execute"
                );
            }
            if self.aggregation != Aggregation::Flat {
                bail!(
                    "transport = tcp requires aggregation = flat: the gateway service \
                     hosts a single flat WeightedAccum fold"
                );
            }
            if self.gateway_addr.is_empty() {
                bail!("transport = tcp requires a non-empty gateway_addr");
            }
        }
        if self.wire_timeout_ms == 0 {
            bail!("wire_timeout_ms must be > 0 (it is the peer-lost detection horizon)");
        }
        let f = &self.fault;
        if !(f.dirichlet_alpha >= 0.0 && f.dirichlet_alpha.is_finite()) {
            bail!("fault.dirichlet_alpha must be finite and >= 0 (0 = off)");
        }
        for (name, p) in [
            ("fault.straggler_prob", f.straggler_prob),
            ("fault.dropout_prob", f.dropout_prob),
            ("fault.gateway_outage_prob", f.gateway_outage_prob),
        ] {
            if !(0.0..=1.0).contains(&p) {
                bail!("{name} must be a probability in [0, 1], got {p}");
            }
        }
        if !(f.straggler_slowdown >= 1.0 && f.straggler_slowdown.is_finite()) {
            bail!(
                "fault.straggler_slowdown must be finite and >= 1 (a delay multiplier), got {}",
                f.straggler_slowdown
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_section7a() {
        let c = SimConfig::default();
        assert_eq!((c.num_gateways, c.num_devices, c.num_channels), (6, 12, 3));
        assert_eq!(c.local_iters, 5);
        assert_eq!(c.sample_ratio, 0.05);
        assert_eq!(c.lr, 0.01);
        assert_eq!(c.device_flops_per_cycle, 16.0);
        assert_eq!(c.gw_flops_per_cycle, 32.0);
        assert!((c.noise_psd - 3.98e-21).abs() < 1e-22);
        c.validate().unwrap();
    }

    #[test]
    fn parse_roundtrip() {
        let cfg = SimConfig::from_str_cfg(
            "# comment\n[fl]\nrounds = 42\nlyapunov_v = 1000\ndataset = \"cifar\"\n",
        )
        .unwrap();
        assert_eq!(cfg.rounds, 42);
        assert_eq!(cfg.lyapunov_v, 1000.0);
        assert_eq!(cfg.dataset, "cifar");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(SimConfig::from_str_cfg("what is this").is_err());
        assert!(SimConfig::from_str_cfg("unknown_key = 3").is_err());
        assert!(SimConfig::from_str_cfg("rounds = banana").is_err());
    }

    #[test]
    fn validate_catches_bad_topology() {
        let mut c = SimConfig::default();
        c.num_devices = 13;
        assert!(c.validate().is_err());
        let mut c2 = SimConfig::default();
        c2.num_channels = 7;
        assert!(c2.validate().is_err());
        // Fewer devices than gateways would leave empty shop floors; the
        // dedicated check fires with the clear message.
        let mut c3 = SimConfig::default();
        c3.num_devices = 3;
        c3.num_gateways = 6;
        c3.num_channels = 3;
        let err = c3.validate().unwrap_err().to_string();
        assert!(err.contains("shop floor"), "{err}");
    }

    #[test]
    fn scenarios_scale_and_validate() {
        for (name, n, m, j) in [
            ("paper", 12, 6, 3),
            ("plant", 240, 24, 8),
            ("campus", 960, 48, 12),
            ("metro", 2880, 96, 16),
            ("nation", 100_000, 2000, 8),
            ("nation-xl", 1_000_000, 20_000, 8),
        ] {
            let mut c = SimConfig::default();
            c.apply_scenario(name).unwrap();
            assert_eq!((c.num_devices, c.num_gateways, c.num_channels), (n, m, j), "{name}");
            c.validate().unwrap();
            // Devices spread evenly, at least one per floor.
            assert!(c.devices_per_gateway() >= 1, "{name}");
        }
        assert!(SimConfig::default().apply_scenario("galaxy").is_err());
        // Scenario + override composition: knobs on top still validate.
        let mut c = SimConfig::default();
        c.apply_scenario("plant").unwrap();
        c.set("num_devices", "480").unwrap();
        c.validate().unwrap();
        assert_eq!(c.devices_per_gateway(), 20);
    }

    #[test]
    fn hierarchy_knobs_default_off_and_parse() {
        let c = SimConfig::default();
        assert_eq!(c.aggregation, Aggregation::Flat);
        assert_eq!(c.num_clusters, 1);
        assert_eq!(c.eval_sample, 0);
        assert!(!c.lazy_shards);
        assert_eq!(c.relay_psi, 0.0);
        c.validate().unwrap();

        let cfg = SimConfig::from_str_cfg(
            "aggregation = \"hierarchical\"\nnum_clusters = 3\neval_sample = 64\n\
             lazy_shards = true\nrelay_psi = 1e-8\n",
        )
        .unwrap();
        assert_eq!(cfg.aggregation, Aggregation::Hierarchical);
        assert_eq!(cfg.num_clusters, 3);
        assert_eq!(cfg.eval_sample, 64);
        assert!(cfg.lazy_shards);
        assert_eq!(cfg.relay_psi, 1e-8);
        cfg.validate().unwrap();

        // Typos fail at parse time, not mid-run.
        assert!(SimConfig::from_str_cfg("aggregation = pyramid\n").is_err());
        assert!(SimConfig::from_str_cfg("lazy_shards = maybe\n").is_err());
        // The 0/1 style works like every other boolean key.
        assert!(SimConfig::from_str_cfg("lazy_shards = 1\n").unwrap().lazy_shards);
    }

    #[test]
    fn hierarchy_knob_validation_rejects_bad_values() {
        let mut c = SimConfig::default();
        c.num_clusters = 0;
        assert!(c.validate().unwrap_err().to_string().contains("num_clusters"));
        let mut c = SimConfig::default();
        c.num_clusters = 7; // > num_gateways = 6
        assert!(c.validate().is_err());
        let mut c = SimConfig::default();
        c.relay_psi = -1.0;
        assert!(c.validate().unwrap_err().to_string().contains("relay_psi"));
        let mut c = SimConfig::default();
        c.relay_psi = f64::NAN;
        assert!(c.validate().is_err());
    }

    #[test]
    fn nation_presets_arm_the_hierarchy_machinery() {
        let mut c = SimConfig::default();
        c.apply_scenario("nation").unwrap();
        assert_eq!((c.num_devices, c.num_gateways, c.num_channels), (100_000, 2000, 8));
        assert_eq!(c.aggregation, Aggregation::Hierarchical);
        assert_eq!(c.num_clusters, 40);
        assert_eq!(c.eval_sample, 128);
        assert!(c.lazy_shards);
        assert!(c.relay_psi > 0.0);
        c.validate().unwrap();

        // Eager shards at nation scale are a configuration error, caught
        // up front with a pointer at the fix.
        c.lazy_shards = false;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("lazy_shards"), "{err}");

        let mut xl = SimConfig::default();
        xl.apply_scenario("nation-xl").unwrap();
        assert_eq!((xl.num_devices, xl.num_gateways), (1_000_000, 20_000));
        assert_eq!(xl.num_clusters, 200);
        xl.validate().unwrap();
    }

    #[test]
    fn fault_block_defaults_benign_and_parses() {
        let c = SimConfig::default();
        assert!(c.fault.is_benign());
        c.validate().unwrap();

        let cfg = SimConfig::from_str_cfg(
            "[fault]\nfault.dirichlet_alpha = 0.5\nfault.dropout_prob = 0.1\n\
             fault.straggler_prob = 0.2\nfault.straggler_slowdown = 3\n\
             fault.gateway_outage_prob = 0.05\n",
        )
        .unwrap();
        assert!(!cfg.fault.is_benign());
        assert_eq!(cfg.fault.dirichlet_alpha, 0.5);
        assert_eq!(cfg.fault.dropout_prob, 0.1);
        assert_eq!(cfg.fault.straggler_prob, 0.2);
        assert_eq!(cfg.fault.straggler_slowdown, 3.0);
        assert_eq!(cfg.fault.gateway_outage_prob, 0.05);
        cfg.validate().unwrap();
    }

    #[test]
    fn fault_block_validation_rejects_bad_knobs() {
        let mut c = SimConfig::default();
        c.fault.dropout_prob = 1.5;
        assert!(c.validate().unwrap_err().to_string().contains("dropout_prob"));
        let mut c = SimConfig::default();
        c.fault.straggler_slowdown = 0.5; // a speed-up is not a straggler
        assert!(c.validate().is_err());
        let mut c = SimConfig::default();
        c.fault.dirichlet_alpha = -1.0;
        assert!(c.validate().is_err());
        let mut c = SimConfig::default();
        c.fault.gateway_outage_prob = f64::NAN;
        assert!(c.validate().is_err());
    }

    #[test]
    fn adversity_scenarios_arm_faults_and_validate() {
        let mut c = SimConfig::default();
        c.apply_scenario("flaky-plant").unwrap();
        // Scale working point inherited from `plant`...
        assert_eq!((c.num_devices, c.num_gateways, c.num_channels), (240, 24, 8));
        // ...with the fault block armed on top.
        assert_eq!(c.fault.dirichlet_alpha, 0.5);
        assert_eq!(c.fault.dropout_prob, 0.10);
        c.validate().unwrap();

        let mut c = SimConfig::default();
        c.apply_scenario("churn-metro").unwrap();
        assert_eq!((c.num_devices, c.num_gateways, c.num_channels), (2880, 96, 16));
        assert_eq!(c.fault.dropout_prob, 0.25);
        c.validate().unwrap();

        // Overrides still compose on top of an adversity preset.
        let mut c = SimConfig::default();
        c.apply_scenario("flaky-plant").unwrap();
        c.set("fault.dropout_prob", "0").unwrap();
        assert_eq!(c.fault.dropout_prob, 0.0);
        c.validate().unwrap();
    }

    #[test]
    fn validate_checks_model_presets() {
        let mut c = SimConfig::default();
        c.exec_model = "cnn".into();
        c.validate().unwrap();
        c.exec_model = "vgg11".into(); // cost-model-only, not executable
        assert!(c.validate().is_err());
        let mut c2 = SimConfig::default();
        c2.cost_model = "resnet".into();
        assert!(c2.validate().is_err());
    }

    #[test]
    fn execute_partition_requires_matching_models() {
        let mut c = SimConfig::default();
        c.execute_partition = true; // cost vgg11 vs exec mlp
        assert!(c.validate().is_err());
        c.cost_model = "mlp".into();
        c.validate().unwrap();
        let cfg = SimConfig::from_str_cfg(
            "execute_partition = true\ncost_model = \"cnn\"\nexec_model = \"cnn\"\n",
        )
        .unwrap();
        assert!(cfg.execute_partition);
        cfg.validate().unwrap();
        // The 0/1 style of every other config key works too.
        let c1 = SimConfig::from_str_cfg("execute_partition = 1\n").unwrap();
        assert!(c1.execute_partition);
        let c0 = SimConfig::from_str_cfg("execute_partition = 0\n").unwrap();
        assert!(!c0.execute_partition);
        assert!(SimConfig::from_str_cfg("execute_partition = maybe\n").is_err());
    }

    #[test]
    fn kernel_knob_defaults_vectorized_and_parses() {
        let c = SimConfig::default();
        assert_eq!(c.kernel, KernelPath::Vectorized);
        c.validate().unwrap();

        let cfg = SimConfig::from_str_cfg("kernel = \"scalar\"\n").unwrap();
        assert_eq!(cfg.kernel, KernelPath::Scalar);
        cfg.validate().unwrap();
        let cfg = SimConfig::from_str_cfg("kernel = vectorized\n").unwrap();
        assert_eq!(cfg.kernel, KernelPath::Vectorized);

        // Typos fail loudly instead of silently running the wrong path.
        assert!(SimConfig::from_str_cfg("kernel = simd\n").is_err());
    }

    #[test]
    fn transport_knob_defaults_inproc_and_parses() {
        let c = SimConfig::default();
        assert_eq!(c.transport, Transport::Inproc);
        assert_eq!(c.gateway_addr, "127.0.0.1:7700");
        assert_eq!(c.wire_timeout_ms, 5000);
        c.validate().unwrap();

        let cfg = SimConfig::from_str_cfg(
            "transport = \"tcp\"\ngateway_addr = \"127.0.0.1:9901\"\n\
             wire_timeout_ms = 750\nexecute_partition = true\n\
             cost_model = \"mlp\"\nexec_model = \"mlp\"\n",
        )
        .unwrap();
        assert_eq!(cfg.transport, Transport::Tcp);
        assert_eq!(cfg.gateway_addr, "127.0.0.1:9901");
        assert_eq!(cfg.wire_timeout_ms, 750);
        cfg.validate().unwrap();

        // Typos fail loudly instead of silently running in-process.
        assert!(SimConfig::from_str_cfg("transport = udp\n").is_err());
    }

    #[test]
    fn transport_tcp_validation_requires_split_and_flat_fold() {
        // tcp without a partition to execute is meaningless.
        let mut c = SimConfig::default();
        c.transport = Transport::Tcp;
        assert!(c.validate().unwrap_err().to_string().contains("execute_partition"));
        // Armed correctly it validates...
        c.execute_partition = true;
        c.cost_model = "mlp".into();
        c.validate().unwrap();
        // ...but not over a hierarchical fold,
        c.aggregation = Aggregation::Hierarchical;
        assert!(c.validate().unwrap_err().to_string().contains("flat"));
        c.aggregation = Aggregation::Flat;
        // nor with nowhere to dial,
        c.gateway_addr.clear();
        assert!(c.validate().unwrap_err().to_string().contains("gateway_addr"));
        c.gateway_addr = "127.0.0.1:7700".into();
        // nor with a zero peer-lost horizon.
        c.wire_timeout_ms = 0;
        assert!(c.validate().unwrap_err().to_string().contains("wire_timeout_ms"));
    }

    #[test]
    fn sched_path_knob_defaults_incremental_and_parses() {
        let c = SimConfig::default();
        assert_eq!(c.sched_path, SchedPath::Incremental);
        c.validate().unwrap();

        let cfg = SimConfig::from_str_cfg("sched_path = \"sweep\"\n").unwrap();
        assert_eq!(cfg.sched_path, SchedPath::Sweep);
        cfg.validate().unwrap();
        let cfg = SimConfig::from_str_cfg("sched_path = incremental\n").unwrap();
        assert_eq!(cfg.sched_path, SchedPath::Incremental);

        // Typos fail loudly instead of silently running the wrong path.
        assert!(SimConfig::from_str_cfg("sched_path = hungarian\n").is_err());
    }

    #[test]
    fn unit_helpers() {
        assert!((dbm_per_hz_to_w(0.0) - 1e-3).abs() < 1e-12);
        assert!((db_to_lin(-30.0) - 1e-3).abs() < 1e-12);
    }
}
