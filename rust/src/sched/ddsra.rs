//! DDSRA — Dynamic Device Scheduling and Resource Allocation (§V).
//!
//! Per communication round:
//! 1. For every (gateway m, channel j) pair, minimise the total delay
//!    Λ_{m,j} (Eq. 18) over the DNN partition points l_n, the gateway
//!    frequency shares f^G_{m,n} and the transmit power P_m, subject to
//!    C4–C10, by block coordinate descent (Algorithm 1, line 6):
//!      * l-step  (Eq. 21): exact per-device minimisation under the
//!        memory/energy budgets (the layer count is small, so direct
//!        enumeration replaces the paper's bisection — same optimum,
//!        simpler, still polynomial);
//!      * f-step  (Eq. 22): bisection on the min-max objective value θ,
//!        allocating each device the minimal frequency meeting θ;
//!      * P-step  (Eq. 23–24): closed-form/bisection root of the
//!        energy-balance equation, clipped to P^max.
//! 2. Assign channels (Eq. 26–31): sweep the auxiliary cap λ over the MJ
//!    candidate values V·Λ_{m,j}; for each, Hungarian-solve the composite
//!    assignment (Eq. 28–29) and keep the assignment minimising the true
//!    drift-plus-penalty objective V·max Λ − Σ Q_m. (The paper alternates
//!    λ and I(t); the sweep visits every fixed point of that iteration.)
//!    Two implementations share one verbatim per-cap evaluation
//!    ([`SchedPath`]): `sweep` re-solves Hungarian at every candidate cap
//!    (the decision-parity oracle), `incremental` (the default) walks the
//!    caps ascending with an [`IncrementalMatcher`] over the growing
//!    admissibility graph and only evaluates the caps where the matching
//!    provably changes — the objective can improve nowhere else, so the
//!    two paths return bit-identical decisions.
//! 3. Update the virtual queues Q_m (Eq. 14), which enforce the
//!    device-specific participation-rate constraint C11 in time average.

use crate::opt::{bisect_decreasing, bisect_root, hungarian_min, IncrementalMatcher};
use crate::sched::latency::{plan_cost, INFEASIBLE};
use crate::sched::{Decision, GatewayPlan, RoundCtx, SchedPath, Scheduler};

/// Hungarian penalty Ψ for inadmissible pairs (Eq. 29).
const PSI: f64 = 1e15;

/// The DDSRA scheduler state (Algorithm 1).
pub struct Ddsra {
    /// Lyapunov trade-off parameter V (Eq. 15–17): larger V weighs the
    /// round-delay penalty more against the participation-queue drift —
    /// the O(1/V) vs O(√V) trade-off of Theorem 2.
    pub v: f64,
    /// Device-specific participation rates Γ_m (Eq. 13, derived from the
    /// Theorem 1 divergence bounds Φ_m via `fl::participation`).
    pub gamma: Vec<f64>,
    /// Virtual queues Q_m(t) (Eq. 14): Q_m(t+1) = max(Q_m(t) − 1_m(t) +
    /// Γ_m, 0) — their stability enforces constraint C11 in time average.
    pub queues: Vec<f64>,
    /// BCD outer iterations for the (l, f, P) subproblem (Algorithm 1
    /// line 6; the paper iterates to convergence, 3 suffices in practice).
    pub bcd_iters: usize,
    /// Run the per-(m,j) Λ solves on the rayon pool (§V-C scalability).
    pub parallel: bool,
    /// λ-sweep implementation: `Incremental` (default) or the verbatim
    /// per-cap `Sweep` oracle. Decisions are bit-identical either way
    /// (`rust/tests/sched_parity.rs`).
    pub sched_path: SchedPath,
}

impl Ddsra {
    /// A DDSRA instance with trade-off parameter `v` (Eq. 17) and
    /// per-gateway participation rates `gamma` (Eq. 13); virtual queues
    /// start empty, Q_m(0) = 0.
    pub fn new(v: f64, gamma: Vec<f64>) -> Self {
        let queues = vec![0.0; gamma.len()];
        Ddsra {
            v,
            gamma,
            queues,
            bcd_iters: 3,
            parallel: false,
            sched_path: SchedPath::default(),
        }
    }

    // ------------------------------------------------------------------
    // Per-(m, j) resource allocation: minimise Λ_{m,j} (Eq. 20).
    // ------------------------------------------------------------------

    /// Solve the (l, f, P) subproblem for gateway m on channel j —
    /// minimise the round delay Λ_{m,j} (Eq. 20) by block coordinate
    /// descent over the partition points (l-step, Eq. 21), the gateway
    /// frequency shares (f-step, Eq. 22) and the transmit power (P-step,
    /// Eq. 23–24), under C4–C10. Returns the best feasible
    /// [`GatewayPlan`] — whose `partition` vector is what the runtime
    /// executes under `--execute-partition` — or None when no feasible
    /// allocation exists this round.
    ///
    /// Single-pair convenience entry: builds the per-gateway context and
    /// scratch locally. `lambda_matrix` builds them once per gateway and
    /// reuses them across all J channel solves instead.
    pub fn solve_gateway(ctx: &RoundCtx, m: usize, j: usize, bcd_iters: usize) -> Option<GatewayPlan> {
        let g = GatewayCtx::new(ctx, m)?;
        let mut scratch = SolveScratch::default();
        Self::solve_channel(ctx, &g, j, bcd_iters, &mut scratch)
    }

    /// The BCD solve for one channel, on a prebuilt channel-invariant
    /// [`GatewayCtx`]. Same iterates as the historical in-line version:
    /// every hoisted quantity is read from a table whose entries are the
    /// exact expressions the loop used to evaluate in place.
    fn solve_channel(
        ctx: &RoundCtx,
        g: &GatewayCtx,
        j: usize,
        bcd_iters: usize,
        scratch: &mut SolveScratch,
    ) -> Option<GatewayPlan> {
        let m = g.m;
        let gw = &ctx.topo.gateways[m];
        let model = ctx.model;
        let nm = gw.members.len();
        let f_floor = g.f_floor;
        let gamma_bits = model.gamma_bits();

        // Initial point: balanced partition (mid-depth, clamped feasible),
        // modest frequency split, half power. BCD refines from here; each
        // step degrades gracefully so that later iterations can recover
        // from an infeasible intermediate iterate.
        let mut part: Vec<usize> = g.init_part.clone();
        let mut freq: Vec<f64> = vec![gw.freq_max / (8.0 * nm as f64); nm];
        let mut power = 0.5 * gw.power_max;

        let mut best: Option<GatewayPlan> = None;
        for _ in 0..bcd_iters {
            // --- l-step (Eq. 21) ------------------------------------------
            // Greedy exact enumeration under the coupled gateway budgets:
            // process devices by batch weight (heaviest first), track the
            // remaining gateway memory/energy budget.
            let e_up = ctx.chan.energy_up(ctx.state, m, j, power, gamma_bits);
            let mut mem_left = gw.mem;
            let mut energy_left = (ctx.arrivals.gateway[m] - e_up).max(0.0);
            // Each device in turn picks the fastest partition fitting the
            // budget left over by the (heavier) devices processed before
            // it, then debits its own share. Devices later in the order
            // see only the remainder — nothing is reserved for them ahead
            // of their turn.
            for &i in &g.order {
                let n = gw.members[i];
                let dev = &ctx.topo.devices[n];
                let mut best_l = None;
                let mut best_t = f64::INFINITY;
                for &l in &g.feasible_l[i] {
                    let top_mem = g.top_mem(i, l);
                    // Energy admissibility is probed at the LOWEST frequency
                    // the f-step may later choose (f_floor): "is there any
                    // frequency at which this partition fits the budget?".
                    let e_gw_min = g.e_gw_floor(i, l);
                    if top_mem > mem_left || e_gw_min > energy_left {
                        continue;
                    }
                    let f_rank = freq[i].max(f_floor);
                    let t = g.t_dev(i, l)
                        + crate::energy::gateway_train_time(
                            gw, dev, model, l, ctx.cfg.local_iters, f_rank,
                        );
                    if t < best_t {
                        best_t = t;
                        best_l = Some(l);
                    }
                }
                // No admissible l under the remaining budget: fall back to
                // the most on-device feasible partition and let the final
                // feasibility evaluation judge the iterate.
                let l = best_l.unwrap_or_else(|| *g.feasible_l[i].last().unwrap());
                part[i] = l;
                mem_left = (mem_left - g.top_mem(i, l)).max(0.0);
                energy_left = (energy_left - g.e_gw_floor(i, l)).max(0.0);
            }

            // --- f-step (Eq. 22) ------------------------------------------
            // Bisect the min-max completion time θ; each device needs
            // f_i(θ) = top_cycles / (θ - t_dev_i). Value gathers and the
            // per-probe frequency profile run in the reusable scratch
            // buffers: the 80-probe bisection allocates nothing.
            scratch.t_dev.clear();
            scratch.t_dev.extend((0..nm).map(|i| g.t_dev(i, part[i])));
            scratch.top_cycles.clear();
            scratch.top_cycles.extend((0..nm).map(|i| g.top_cycles(i, part[i])));
            let t_dev = &scratch.t_dev;
            let top_cycles = &scratch.top_cycles;
            let any_offload = top_cycles.iter().any(|&c| c > 0.0);
            // Same channel energy as the l-step saw: power has not moved
            // since, so the historical second energy_up call is elided.
            let e_budget = (ctx.arrivals.gateway[m] - e_up).max(0.0);

            let fs = &mut scratch.fs;
            let feasible = |theta: f64| -> bool {
                if !fill_freqs(theta, t_dev, top_cycles, fs) {
                    return false;
                }
                let total: f64 = fs.iter().sum();
                if total > gw.freq_max {
                    return false;
                }
                let e: f64 = (0..nm).map(|i| gw.kappa * top_cycles[i] * fs[i] * fs[i]).sum();
                e <= e_budget
            };

            if any_offload {
                let lo = t_dev.iter().cloned().fold(0.0, f64::max).max(1e-9);
                // Upper bound: run every offloaded piece at a tiny share.
                let hi = (0..nm)
                    .map(|i| t_dev[i] + if top_cycles[i] > 0.0 { top_cycles[i] / f_floor } else { 0.0 })
                    .fold(lo, f64::max)
                    * 1.01;
                match bisect_decreasing(lo, hi, 1e-6, 80, feasible) {
                    Some(theta) => {
                        let fs = &mut scratch.fs;
                        if !fill_freqs(theta, t_dev, top_cycles, fs) {
                            fs.clear();
                            fs.resize(nm, 0.0);
                        }
                        // C6 lower bound: scale up if the total allocated
                        // frequency is below f^{G,min} (more f never hurts
                        // latency; re-check the energy budget).
                        let total: f64 = fs.iter().sum();
                        if total > 0.0 && total < gw.freq_min {
                            let scale = gw.freq_min / total;
                            let e: f64 = (0..nm)
                                .map(|i| gw.kappa * top_cycles[i] * fs[i] * fs[i] * scale * scale)
                                .sum();
                            if e <= e_budget {
                                for f in fs.iter_mut() {
                                    *f *= scale;
                                }
                            }
                        }
                        freq.clear();
                        freq.extend_from_slice(fs);
                    }
                    // No θ satisfies the budget at the current power — fall
                    // back to the cheapest profile; the next P-step frees
                    // energy and the following iteration retries.
                    None => {
                        freq.clear();
                        freq.extend(
                            (0..nm).map(|i| if top_cycles[i] > 0.0 { f_floor } else { 0.0 }),
                        );
                    }
                }
            } else {
                freq.clear();
                freq.resize(nm, 0.0);
            }

            // --- P-step (Eq. 23–24) ---------------------------------------
            let e_train: f64 =
                (0..nm).map(|i| gw.kappa * top_cycles[i] * freq[i] * freq[i]).sum();
            let e_rem = ctx.arrivals.gateway[m] - e_train;
            let h = ctx.state.up_gain[m][j];
            let sigma = ctx.chan.bw_up * ctx.chan.noise_psd + ctx.state.up_intf[m][j];
            // Minimum possible uplink energy is the P -> 0 limit
            // gamma * sigma * ln2 / (B h); below that, transmission is
            // impossible this round (Eq. 24 first branch).
            let min_energy = gamma_bits * sigma * std::f64::consts::LN_2 / (ctx.chan.bw_up * h);
            if e_rem <= min_energy {
                // Transmission unaffordable at this iterate (Eq. 24 first
                // branch) — skip evaluation and let the next iteration pick
                // a cheaper partition/frequency profile.
                power = 0.5 * gw.power_max;
                continue;
            }
            let g = |x: f64| {
                ctx.chan.bw_up / gamma_bits * e_rem * (1.0 + h * x / sigma).log2() - x
            };
            power = if g(gw.power_max) >= 0.0 {
                gw.power_max
            } else {
                // Root exists in (0, P^max) since g'(0) > 0 and g(P^max) < 0.
                bisect_root(1e-12, gw.power_max, 1e-9, 100, g).unwrap_or(gw.power_max)
            };

            // Evaluate the iterate; keep the best feasible one.
            let mut plan = GatewayPlan {
                gateway: m,
                channel: j,
                power,
                partition: part.clone(),
                freq: freq.clone(),
                lambda: 0.0,
            };
            let cost = plan_cost(ctx, &plan);
            if cost.feasible() {
                plan.lambda = cost.lambda();
                let improves = match &best {
                    None => true,
                    Some(b) => plan.lambda < b.lambda,
                };
                if improves {
                    best = Some(plan);
                }
            }
        }
        best
    }

    /// Λ matrix for all (m, j) pairs; INFEASIBLE when no allocation exists.
    ///
    /// Per gateway row, the channel-invariant [`GatewayCtx`] (feasible
    /// partition sets, train-time/cycle/memory/energy tables, solve order)
    /// is built ONCE and shared by all J channel solves, and one
    /// [`SolveScratch`] backs every bisection probe in the row.
    fn lambda_matrix(&self, ctx: &RoundCtx) -> Vec<Vec<Option<GatewayPlan>>> {
        let mm = ctx.topo.num_gateways();
        let jj = ctx.cfg.num_channels;
        let solve_row = |m: usize| -> Vec<Option<GatewayPlan>> {
            let Some(g) = GatewayCtx::new(ctx, m) else {
                return vec![None; jj];
            };
            let mut scratch = SolveScratch::default();
            (0..jj)
                .map(|j| Self::solve_channel(ctx, &g, j, self.bcd_iters, &mut scratch))
                .collect()
        };
        if self.parallel {
            // §V-C: the MJ subproblems are independent — solve the M rows
            // on the rayon pool. Ordering is preserved by into_par_iter, so
            // the result is identical to the serial path.
            use rayon::prelude::*;
            (0..mm).into_par_iter().map(solve_row).collect()
        } else {
            (0..mm).map(solve_row).collect()
        }
    }

    /// One cap of the λ-sweep, evaluated verbatim: build Θ (Eq. 29),
    /// Hungarian-solve it, reject matchings that pay Ψ, and score the
    /// true objective (Eq. 17). Both [`SchedPath`]s funnel through this —
    /// they differ only in WHICH caps reach it, so bit-identity of their
    /// decisions holds by construction.
    fn eval_cap(
        &self,
        plans: &[Vec<Option<GatewayPlan>>],
        cap: f64,
    ) -> Option<(f64, Vec<Option<usize>>)> {
        let mm = plans.len();
        let jj = plans.first().map_or(0, |r| r.len());
        let lam = |m: usize, j: usize| -> f64 {
            plans[m][j].as_ref().map_or(INFEASIBLE, |p| p.lambda)
        };
        // Θ_{m,j} (Eq. 29): −Q_m admissible, Ψ otherwise.
        let cost: Vec<Vec<f64>> = (0..mm)
            .map(|m| {
                (0..jj)
                    .map(|j| {
                        let l = lam(m, j);
                        if l >= INFEASIBLE || self.v * l > cap {
                            PSI
                        } else {
                            -self.queues[m]
                        }
                    })
                    .collect()
            })
            .collect();
        let (assign, total) = hungarian_min(&cost);
        if total >= PSI / 2.0 {
            return None; // no admissible perfect matching under this cap
        }
        // True objective (Eq. 17): V·max Λ − Σ Q.
        let mut max_l = 0.0f64;
        let mut sum_q = 0.0;
        for (m, a) in assign.iter().enumerate() {
            if let Some(j) = a {
                max_l = max_l.max(lam(m, *j));
                sum_q += self.queues[m];
            }
        }
        Some((self.v * max_l - sum_q, assign))
    }

    /// The historical Eq. 26–31 λ-sweep: evaluate EVERY candidate cap —
    /// each finite V·Λ value plus the ∞ fallback — and keep the first
    /// strict objective improvement. Θ(M·J) Hungarian solves per round;
    /// kept verbatim as the decision-parity oracle for `incremental`.
    fn sweep_caps(&self, plans: &[Vec<Option<GatewayPlan>>]) -> Option<Vec<Option<usize>>> {
        let mm = plans.len();
        let jj = plans.first().map_or(0, |r| r.len());
        let lam = |m: usize, j: usize| -> f64 {
            plans[m][j].as_ref().map_or(INFEASIBLE, |p| p.lambda)
        };

        // Candidate caps: every finite V·Λ value (+∞ fallback).
        let mut caps: Vec<f64> = (0..mm)
            .flat_map(|m| (0..jj).map(move |j| lam(m, j)))
            .filter(|&l| l < INFEASIBLE)
            .map(|l| self.v * l)
            .collect();
        caps.sort_by(|a, b| a.partial_cmp(b).unwrap());
        caps.dedup();
        caps.push(f64::INFINITY);

        let mut best_obj = f64::INFINITY;
        let mut best_assign: Option<Vec<Option<usize>>> = None;
        for &cap in &caps {
            if let Some((obj, assign)) = self.eval_cap(plans, cap) {
                if obj < best_obj {
                    best_obj = obj;
                    best_assign = Some(assign);
                }
            }
        }
        best_assign
    }

    /// The incremental λ-sweep. Caps are processed ascending, so the
    /// admissible edge set only ever GROWS; an [`IncrementalMatcher`]
    /// maintains a maximum-cardinality, maximum-queue-weight matching by
    /// augmenting paths, and the verbatim [`Self::eval_cap`] runs only at
    /// caps where the matching provably changes — where it first becomes
    /// perfect, or where its total queue weight strictly rises.
    ///
    /// Those are exactly the caps where the swept objective
    /// V·max Λ − Σ Q can improve: within a run of caps whose optimal
    /// matchings have equal weight, the earliest cap bounds max Λ
    /// tightest (caps ARE the V·Λ values, compared exactly), and the ∞
    /// fallback re-evaluates the largest finite cap's Θ verbatim, so
    /// skipping the rest changes nothing. Expected evaluations drop from
    /// M·J to ≈ J·ln(M/J) — ~44 instead of 16 000 at nation scale.
    fn incremental_caps(&self, plans: &[Vec<Option<GatewayPlan>>]) -> Option<Vec<Option<usize>>> {
        let mm = plans.len();
        let jj = plans.first().map_or(0, |r| r.len());
        if jj == 0 || jj > 64 {
            // Degenerate or beyond the matcher's 64-bit adjacency rows:
            // fall back to the oracle (no validated SimConfig hits this).
            return self.sweep_caps(plans);
        }
        let lam = |m: usize, j: usize| -> f64 {
            plans[m][j].as_ref().map_or(INFEASIBLE, |p| p.lambda)
        };

        // One edge (V·Λ, m, j) per feasible pair, sorted ascending by cap.
        // Equal caps form one batch — mirroring exactly what the oracle's
        // `caps.dedup()` merges into a single evaluation.
        let mut edges: Vec<(f64, usize, usize)> = (0..mm)
            .flat_map(|m| (0..jj).map(move |j| (lam(m, j), m, j)))
            .filter(|&(l, _, _)| l < INFEASIBLE)
            .map(|(l, m, j)| (self.v * l, m, j))
            .collect();
        edges.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

        let mut matcher = IncrementalMatcher::new(&self.queues[..mm], jj);
        let mut best_obj = f64::INFINITY;
        let mut best_assign: Option<Vec<Option<usize>>> = None;
        let mut batch: Vec<(usize, usize)> = Vec::new();
        let mut i = 0;
        while i < edges.len() {
            let cap = edges[i].0;
            batch.clear();
            while i < edges.len() && edges[i].0 == cap {
                batch.push((edges[i].1, edges[i].2));
                i += 1;
            }
            if matcher.add_edges(&batch) {
                if let Some((obj, assign)) = self.eval_cap(plans, cap) {
                    if obj < best_obj {
                        best_obj = obj;
                        best_assign = Some(assign);
                    }
                }
            }
        }
        // No feasible pair at all: the oracle would see every Θ entry at
        // Ψ for every cap and return an empty decision — so do we.
        best_assign
    }

    /// Channel assignment (Eq. 26–31): λ-sweep + Hungarian, routed by
    /// [`Self::sched_path`]; the winning assignment's plans materialise
    /// into the [`Decision`] in gateway order.
    pub fn assign(&self, plans: Vec<Vec<Option<GatewayPlan>>>) -> Decision {
        let best_assign = match self.sched_path {
            SchedPath::Sweep => self.sweep_caps(&plans),
            SchedPath::Incremental => self.incremental_caps(&plans),
        };

        let mut decision = Decision::default();
        if let Some(assign) = best_assign {
            let mut plans = plans;
            for (m, a) in assign.into_iter().enumerate() {
                if let Some(j) = a {
                    if let Some(plan) = plans[m][j].take() {
                        decision.plans.push(plan);
                    }
                }
            }
        }
        decision
    }
}

// ----------------------------------------------------------------------
// Channel-invariant per-gateway solve context.
// ----------------------------------------------------------------------

/// Everything the (l, f, P) BCD solve needs that does NOT depend on the
/// channel j: device-feasible partition sets, the heaviest-batch-first
/// solve order, the initial partition, and flattened per-(member, l)
/// tables of the pure cost-model quantities the loop evaluates. Built
/// once per (gateway, round) and shared by all J channel solves, where
/// the historical code recomputed each entry J × BCD-iter times.
///
/// Every table entry is the EXACT expression the in-line code evaluated
/// (same operand order), so reading a table is bit-identical to the call
/// it replaces. `gateway_train_time` stays a direct call: it depends on
/// the iterate's frequency, which is not channel-invariant.
struct GatewayCtx {
    /// Gateway index this context was built for.
    m: usize,
    /// Per member: partition points satisfying C5/C7/C10' on the device
    /// side (ascending; never empty — `new` returns None instead).
    feasible_l: Vec<Vec<usize>>,
    /// Mid-depth (clamped feasible) starting partition per member.
    init_part: Vec<usize>,
    /// Member indices sorted heaviest train batch first (l-step order).
    order: Vec<usize>,
    /// `device_train_time(dev, model, l, K)`, flattened `[i · stride + l]`.
    t_dev_l: Vec<f64>,
    /// `K · batch · top_flops(l) / flops_per_cycle` — gateway-side cycles.
    top_cycles_l: Vec<f64>,
    /// `model.top_mem(l, batch)` — gateway-side memory for member i at l.
    top_mem_l: Vec<f64>,
    /// `gateway_train_energy(..., f_floor)` — the lowest-frequency energy
    /// probe the l-step admissibility test uses.
    e_gw_floor_l: Vec<f64>,
    /// Lowest frequency share the f-step may assign (C6 working floor).
    f_floor: f64,
    /// Row stride of the flattened tables: depth + 1 partition points.
    stride: usize,
}

impl GatewayCtx {
    /// Build the context for gateway `m`, or None when some member has no
    /// device-feasible partition at all (the whole row is infeasible this
    /// round, exactly as the historical per-channel solve concluded).
    fn new(ctx: &RoundCtx, m: usize) -> Option<GatewayCtx> {
        let gw = &ctx.topo.gateways[m];
        let model = ctx.model;
        let nm = gw.members.len();
        let depth = model.depth();
        let k = ctx.cfg.local_iters as f64;
        let stride = depth + 1;

        // Device-feasible partition sets (C5, C7, C10'): independent of f, P.
        let mut feasible_l: Vec<Vec<usize>> = Vec::with_capacity(nm);
        for &n in &gw.members {
            let dev = &ctx.topo.devices[n];
            let ls: Vec<usize> = (0..=depth)
                .filter(|&l| {
                    model.bottom_mem(l, dev.train_batch as u64) <= dev.mem
                        && crate::energy::device_train_energy(dev, model, l, ctx.cfg.local_iters)
                            <= ctx.arrivals.device[n]
                })
                .collect();
            if ls.is_empty() {
                return None; // not even l = 0 fits (cannot happen: l=0 is free)
            }
            feasible_l.push(ls);
        }

        let f_floor = gw.freq_max / (100.0 * nm as f64);
        let init_part: Vec<usize> = feasible_l
            .iter()
            .map(|ls| *ls.iter().min_by_key(|&&l| l.abs_diff(depth / 2)).unwrap())
            .collect();
        let mut order: Vec<usize> = (0..nm).collect();
        order.sort_by(|&a, &b| {
            ctx.topo.devices[gw.members[b]]
                .train_batch
                .cmp(&ctx.topo.devices[gw.members[a]].train_batch)
        });

        let mut t_dev_l = Vec::with_capacity(nm * stride);
        let mut top_cycles_l = Vec::with_capacity(nm * stride);
        let mut top_mem_l = Vec::with_capacity(nm * stride);
        let mut e_gw_floor_l = Vec::with_capacity(nm * stride);
        for &n in &gw.members {
            let dev = &ctx.topo.devices[n];
            for l in 0..=depth {
                t_dev_l.push(crate::energy::device_train_time(
                    dev, model, l, ctx.cfg.local_iters,
                ));
                top_cycles_l
                    .push(k * dev.train_batch as f64 * model.top_flops(l) / gw.flops_per_cycle);
                top_mem_l.push(model.top_mem(l, dev.train_batch as u64));
                e_gw_floor_l.push(crate::energy::gateway_train_energy(
                    gw, dev, model, l, ctx.cfg.local_iters, f_floor,
                ));
            }
        }

        Some(GatewayCtx {
            m,
            feasible_l,
            init_part,
            order,
            t_dev_l,
            top_cycles_l,
            top_mem_l,
            e_gw_floor_l,
            f_floor,
            stride,
        })
    }

    #[inline]
    fn t_dev(&self, i: usize, l: usize) -> f64 {
        self.t_dev_l[i * self.stride + l]
    }

    #[inline]
    fn top_cycles(&self, i: usize, l: usize) -> f64 {
        self.top_cycles_l[i * self.stride + l]
    }

    #[inline]
    fn top_mem(&self, i: usize, l: usize) -> f64 {
        self.top_mem_l[i * self.stride + l]
    }

    #[inline]
    fn e_gw_floor(&self, i: usize, l: usize) -> f64 {
        self.e_gw_floor_l[i * self.stride + l]
    }
}

/// Reusable buffers for the f-step: the partition-dependent value gathers
/// and the per-probe frequency profile. One instance serves a whole
/// gateway row — the historical code allocated a fresh `Vec` for every
/// one of the ~80 bisection probes of every BCD iteration of every
/// channel (budgeted in `rust/tests/sched_alloc.rs`).
#[derive(Default)]
struct SolveScratch {
    t_dev: Vec<f64>,
    top_cycles: Vec<f64>,
    fs: Vec<f64>,
}

/// Fill `out` with the Eq. 22 frequency profile at min-max value `theta`:
/// `top_cycles_i / (θ − t_dev_i)`, 0 for members with nothing offloaded.
/// Returns false (contents unspecified) when some offloading member has
/// non-positive slack — θ is below its device-side time.
fn fill_freqs(theta: f64, t_dev: &[f64], top_cycles: &[f64], out: &mut Vec<f64>) -> bool {
    out.clear();
    for (&td, &tc) in t_dev.iter().zip(top_cycles) {
        if tc == 0.0 {
            out.push(0.0);
            continue;
        }
        let slack = theta - td;
        if slack <= 0.0 {
            return false;
        }
        out.push(tc / slack);
    }
    true
}

impl Scheduler for Ddsra {
    fn name(&self) -> String {
        format!("ddsra_v{}", self.v)
    }

    fn schedule(&mut self, ctx: &RoundCtx) -> Decision {
        let decision = self.assign(self.lambda_matrix(ctx));
        // Virtual queue update (Eq. 14) on the realised selection.
        for m in 0..self.queues.len() {
            let served = if decision.selected(m) { 1.0 } else { 0.0 };
            self.queues[m] = (self.queues[m] - served + self.gamma[m]).max(0.0);
        }
        decision
    }

    fn queues(&self) -> Option<&[f64]> {
        Some(&self.queues)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::dnn::models;
    use crate::energy::EnergyArrivals;
    use crate::net::ChannelModel;
    use crate::rng::Rng;
    use crate::topo::Topology;

    struct Fixture {
        cfg: SimConfig,
        topo: Topology,
        model: crate::dnn::ModelSpec,
        chan: ChannelModel,
    }

    fn fixture(seed: u64) -> (Fixture, Rng) {
        let cfg = SimConfig::default();
        let mut rng = Rng::new(seed);
        let topo = Topology::generate(&cfg, &mut rng);
        let chan = ChannelModel::new(&cfg, &topo, &mut rng);
        (
            Fixture { cfg, topo, model: models::vgg11_cifar(), chan },
            rng,
        )
    }

    fn ctx<'a>(
        f: &'a Fixture,
        state: &'a crate::net::ChannelState,
        arr: &'a EnergyArrivals,
    ) -> RoundCtx<'a> {
        RoundCtx {
            cfg: &f.cfg,
            topo: &f.topo,
            model: &f.model,
            chan: &f.chan,
            state,
            arrivals: arr,
            round: 0,
        }
    }

    #[test]
    fn solve_gateway_produces_feasible_plans() {
        let (f, mut rng) = fixture(1);
        let mut solved = 0;
        for _ in 0..10 {
            let state = f.chan.draw(&mut rng);
            let arr = EnergyArrivals::draw(&f.cfg, &mut rng);
            let c = ctx(&f, &state, &arr);
            for m in 0..f.topo.num_gateways() {
                for j in 0..f.cfg.num_channels {
                    if let Some(plan) = Ddsra::solve_gateway(&c, m, j, 3) {
                        let cost = plan_cost(&c, &plan);
                        assert!(cost.feasible(), "violations: {:?}", cost.violations);
                        assert!(plan.lambda > 0.0 && plan.lambda < INFEASIBLE);
                        assert!(plan.power > 0.0 && plan.power <= f.topo.gateways[m].power_max + 1e-12);
                        solved += 1;
                    }
                }
            }
        }
        assert!(solved > 0, "no feasible allocation found in 10 rounds");
    }

    #[test]
    fn schedule_selects_exactly_j_gateways_when_feasible() {
        let (f, mut rng) = fixture(2);
        let mut d = Ddsra::new(1000.0, vec![0.5; 6]);
        let mut counts = Vec::new();
        for _ in 0..10 {
            let state = f.chan.draw(&mut rng);
            let arr = EnergyArrivals::draw(&f.cfg, &mut rng);
            let c = ctx(&f, &state, &arr);
            let dec = d.schedule(&c);
            counts.push(dec.plans.len());
            // distinct gateways and channels (C2, C3)
            let mut gws: Vec<_> = dec.plans.iter().map(|p| p.gateway).collect();
            let mut chs: Vec<_> = dec.plans.iter().map(|p| p.channel).collect();
            gws.sort_unstable();
            gws.dedup();
            chs.sort_unstable();
            chs.dedup();
            assert_eq!(gws.len(), dec.plans.len());
            assert_eq!(chs.len(), dec.plans.len());
            assert!(dec.plans.len() <= f.cfg.num_channels);
        }
        assert!(counts.iter().any(|&c| c == f.cfg.num_channels), "{counts:?}");
    }

    #[test]
    fn queues_track_unserved_gateways() {
        let (f, mut rng) = fixture(3);
        let gamma = vec![0.9; 6];
        let mut d = Ddsra::new(0.0, gamma.clone());
        for _ in 0..30 {
            let state = f.chan.draw(&mut rng);
            let arr = EnergyArrivals::draw(&f.cfg, &mut rng);
            let c = ctx(&f, &state, &arr);
            let _ = d.schedule(&c);
        }
        // With ΣΓ = 5.4 > J = 3 the queues cannot all stay empty; but V=0
        // should keep them bounded-ish (largest-queue-first service).
        assert!(d.queues.iter().all(|&q| q.is_finite()));
        assert!(d.queues.iter().any(|&q| q > 0.0));
    }

    #[test]
    fn v_zero_serves_largest_queues() {
        let (f, mut rng) = fixture(4);
        let mut d = Ddsra::new(0.0, vec![0.0; 6]);
        d.queues = vec![10.0, 0.0, 9.0, 0.0, 8.0, 0.0];
        let state = f.chan.draw(&mut rng);
        let arr = EnergyArrivals::draw(&f.cfg, &mut rng);
        let c = ctx(&f, &state, &arr);
        let dec = d.schedule(&c);
        let mut sel: Vec<_> = dec.plans.iter().map(|p| p.gateway).collect();
        sel.sort_unstable();
        assert_eq!(sel, vec![0, 2, 4], "V=0 must serve the longest queues");
    }

    #[test]
    fn large_v_minimizes_delay() {
        // With V huge and equal queues, DDSRA must pick the assignment
        // minimising max Λ over all candidate assignments it evaluated.
        let (f, mut rng) = fixture(5);
        let mut dv = Ddsra::new(1e12, vec![0.0; 6]);
        let state = f.chan.draw(&mut rng);
        let arr = EnergyArrivals::draw(&f.cfg, &mut rng);
        let c = ctx(&f, &state, &arr);
        let dec_fast = dv.schedule(&c);
        let mut dq = Ddsra::new(0.0, vec![0.0; 6]);
        dq.queues = vec![0.0, 0.0, 0.0, 1.0, 1.0, 1.0]; // force others
        let dec_slow = dq.schedule(&c);
        assert!(dec_fast.round_delay() <= dec_slow.round_delay() + 1e-9);
    }

    #[test]
    fn parallel_matches_serial() {
        let (f, mut rng) = fixture(6);
        let state = f.chan.draw(&mut rng);
        let arr = EnergyArrivals::draw(&f.cfg, &mut rng);
        let c = ctx(&f, &state, &arr);
        let mut a = Ddsra::new(100.0, vec![0.5; 6]);
        let mut b = Ddsra::new(100.0, vec![0.5; 6]);
        b.parallel = true;
        let da = a.schedule(&c);
        let db = b.schedule(&c);
        let key = |d: &Decision| {
            let mut v: Vec<_> = d.plans.iter().map(|p| (p.gateway, p.channel)).collect();
            v.sort_unstable();
            v
        };
        assert_eq!(key(&da), key(&db));
        assert!((da.round_delay() - db.round_delay()).abs() < 1e-9);
    }

    #[test]
    fn new_defaults_to_incremental_path() {
        let d = Ddsra::new(1.0, vec![0.5; 4]);
        assert_eq!(d.sched_path, SchedPath::Incremental);
    }

    #[test]
    fn sweep_and_incremental_make_bit_identical_decisions() {
        // Full-stack parity on the real fixture: same Λ solves, both
        // assignment paths, across rounds (so queue states diverge if
        // decisions ever differ) and across V regimes including V = 0
        // (every cap collapses into one batch).
        for &v in &[0.0, 100.0, 1e12] {
            let (f, mut rng) = fixture(7);
            let mut sweep = Ddsra::new(v, vec![0.7; 6]);
            sweep.sched_path = SchedPath::Sweep;
            let mut inc = Ddsra::new(v, vec![0.7; 6]);
            assert_eq!(inc.sched_path, SchedPath::Incremental);
            for round in 0..12 {
                let state = f.chan.draw(&mut rng);
                let arr = EnergyArrivals::draw(&f.cfg, &mut rng);
                let c = ctx(&f, &state, &arr);
                let ds = sweep.schedule(&c);
                let di = inc.schedule(&c);
                let key = |d: &Decision| {
                    d.plans
                        .iter()
                        .map(|p| (p.gateway, p.channel, p.lambda.to_bits()))
                        .collect::<Vec<_>>()
                };
                assert_eq!(key(&ds), key(&di), "v={v} round={round}");
                assert_eq!(
                    ds.round_delay().to_bits(),
                    di.round_delay().to_bits(),
                    "v={v} round={round}"
                );
                for m in 0..6 {
                    assert_eq!(
                        sweep.queues[m].to_bits(),
                        inc.queues[m].to_bits(),
                        "queues diverged: v={v} round={round} m={m}"
                    );
                }
            }
        }
    }

    #[test]
    fn assign_handles_all_infeasible_plans_on_both_paths() {
        for path in [SchedPath::Sweep, SchedPath::Incremental] {
            let mut d = Ddsra::new(10.0, vec![0.5; 4]);
            d.sched_path = path;
            let plans: Vec<Vec<Option<GatewayPlan>>> = vec![vec![None, None]; 4];
            let dec = d.assign(plans);
            assert!(dec.plans.is_empty(), "{path:?} must return an empty decision");
        }
    }
}
