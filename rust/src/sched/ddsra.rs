//! DDSRA — Dynamic Device Scheduling and Resource Allocation (§V).
//!
//! Per communication round:
//! 1. For every (gateway m, channel j) pair, minimise the total delay
//!    Λ_{m,j} (Eq. 18) over the DNN partition points l_n, the gateway
//!    frequency shares f^G_{m,n} and the transmit power P_m, subject to
//!    C4–C10, by block coordinate descent (Algorithm 1, line 6):
//!      * l-step  (Eq. 21): exact per-device minimisation under the
//!        memory/energy budgets (the layer count is small, so direct
//!        enumeration replaces the paper's bisection — same optimum,
//!        simpler, still polynomial);
//!      * f-step  (Eq. 22): bisection on the min-max objective value θ,
//!        allocating each device the minimal frequency meeting θ;
//!      * P-step  (Eq. 23–24): closed-form/bisection root of the
//!        energy-balance equation, clipped to P^max.
//! 2. Assign channels (Eq. 26–31): sweep the auxiliary cap λ over the MJ
//!    candidate values V·Λ_{m,j}; for each, Hungarian-solve the composite
//!    assignment (Eq. 28–29) and keep the assignment minimising the true
//!    drift-plus-penalty objective V·max Λ − Σ Q_m. (The paper alternates
//!    λ and I(t); the sweep visits every fixed point of that iteration.)
//! 3. Update the virtual queues Q_m (Eq. 14), which enforce the
//!    device-specific participation-rate constraint C11 in time average.

use crate::opt::{bisect_decreasing, bisect_root, hungarian_min};
use crate::sched::latency::{plan_cost, INFEASIBLE};
use crate::sched::{Decision, GatewayPlan, RoundCtx, Scheduler};

/// Hungarian penalty Ψ for inadmissible pairs (Eq. 29).
const PSI: f64 = 1e15;

/// The DDSRA scheduler state (Algorithm 1).
pub struct Ddsra {
    /// Lyapunov trade-off parameter V (Eq. 15–17): larger V weighs the
    /// round-delay penalty more against the participation-queue drift —
    /// the O(1/V) vs O(√V) trade-off of Theorem 2.
    pub v: f64,
    /// Device-specific participation rates Γ_m (Eq. 13, derived from the
    /// Theorem 1 divergence bounds Φ_m via `fl::participation`).
    pub gamma: Vec<f64>,
    /// Virtual queues Q_m(t) (Eq. 14): Q_m(t+1) = max(Q_m(t) − 1_m(t) +
    /// Γ_m, 0) — their stability enforces constraint C11 in time average.
    pub queues: Vec<f64>,
    /// BCD outer iterations for the (l, f, P) subproblem (Algorithm 1
    /// line 6; the paper iterates to convergence, 3 suffices in practice).
    pub bcd_iters: usize,
    /// Run the per-(m,j) Λ solves on the rayon pool (§V-C scalability).
    pub parallel: bool,
}

impl Ddsra {
    /// A DDSRA instance with trade-off parameter `v` (Eq. 17) and
    /// per-gateway participation rates `gamma` (Eq. 13); virtual queues
    /// start empty, Q_m(0) = 0.
    pub fn new(v: f64, gamma: Vec<f64>) -> Self {
        let queues = vec![0.0; gamma.len()];
        Ddsra { v, gamma, queues, bcd_iters: 3, parallel: false }
    }

    // ------------------------------------------------------------------
    // Per-(m, j) resource allocation: minimise Λ_{m,j} (Eq. 20).
    // ------------------------------------------------------------------

    /// Solve the (l, f, P) subproblem for gateway m on channel j —
    /// minimise the round delay Λ_{m,j} (Eq. 20) by block coordinate
    /// descent over the partition points (l-step, Eq. 21), the gateway
    /// frequency shares (f-step, Eq. 22) and the transmit power (P-step,
    /// Eq. 23–24), under C4–C10. Returns the best feasible
    /// [`GatewayPlan`] — whose `partition` vector is what the runtime
    /// executes under `--execute-partition` — or None when no feasible
    /// allocation exists this round.
    pub fn solve_gateway(ctx: &RoundCtx, m: usize, j: usize, bcd_iters: usize) -> Option<GatewayPlan> {
        let gw = &ctx.topo.gateways[m];
        let model = ctx.model;
        let nm = gw.members.len();
        let depth = model.depth();
        let k = ctx.cfg.local_iters as f64;

        // Device-feasible partition sets (C5, C7, C10'): independent of f, P.
        let mut feasible_l: Vec<Vec<usize>> = Vec::with_capacity(nm);
        for &n in &gw.members {
            let dev = &ctx.topo.devices[n];
            let ls: Vec<usize> = (0..=depth)
                .filter(|&l| {
                    model.bottom_mem(l, dev.train_batch as u64) <= dev.mem
                        && crate::energy::device_train_energy(dev, model, l, ctx.cfg.local_iters)
                            <= ctx.arrivals.device[n]
                })
                .collect();
            if ls.is_empty() {
                return None; // not even l = 0 fits (cannot happen: l=0 is free)
            }
            feasible_l.push(ls);
        }

        // Initial point: balanced partition (mid-depth, clamped feasible),
        // modest frequency split, half power. BCD refines from here; each
        // step degrades gracefully so that later iterations can recover
        // from an infeasible intermediate iterate.
        let f_floor = gw.freq_max / (100.0 * nm as f64);
        let mut part: Vec<usize> = feasible_l
            .iter()
            .map(|ls| *ls.iter().min_by_key(|&&l| l.abs_diff(depth / 2)).unwrap())
            .collect();
        let mut freq: Vec<f64> = vec![gw.freq_max / (8.0 * nm as f64); nm];
        let mut power = 0.5 * gw.power_max;

        let mut best: Option<GatewayPlan> = None;
        for _ in 0..bcd_iters {
            // --- l-step (Eq. 21) ------------------------------------------
            // Greedy exact enumeration under the coupled gateway budgets:
            // process devices by batch weight (heaviest first), track the
            // remaining gateway memory/energy budget.
            let e_up = ctx.chan.energy_up(ctx.state, m, j, power, model.gamma_bits());
            let mut order: Vec<usize> = (0..nm).collect();
            order.sort_by(|&a, &b| {
                ctx.topo.devices[gw.members[b]]
                    .train_batch
                    .cmp(&ctx.topo.devices[gw.members[a]].train_batch)
            });
            let mut mem_left = gw.mem;
            let mut energy_left = (ctx.arrivals.gateway[m] - e_up).max(0.0);
            // Reserve budgets already taken by devices later in the order
            // at their current partitions, then refine one at a time.
            for &i in &order {
                let n = gw.members[i];
                let dev = &ctx.topo.devices[n];
                // Free this device's current share.
                let mut best_l = None;
                let mut best_t = f64::INFINITY;
                for &l in &feasible_l[i] {
                    let top_mem = model.top_mem(l, dev.train_batch as u64);
                    // Energy admissibility is probed at the LOWEST frequency
                    // the f-step may later choose (f_floor): "is there any
                    // frequency at which this partition fits the budget?".
                    let e_gw_min = crate::energy::gateway_train_energy(
                        gw, dev, model, l, ctx.cfg.local_iters, f_floor,
                    );
                    if top_mem > mem_left || e_gw_min > energy_left {
                        continue;
                    }
                    let f_rank = freq[i].max(f_floor);
                    let t = crate::energy::device_train_time(dev, model, l, ctx.cfg.local_iters)
                        + crate::energy::gateway_train_time(
                            gw, dev, model, l, ctx.cfg.local_iters, f_rank,
                        );
                    if t < best_t {
                        best_t = t;
                        best_l = Some(l);
                    }
                }
                // No admissible l under the remaining budget: fall back to
                // the most on-device feasible partition and let the final
                // feasibility evaluation judge the iterate.
                let l = best_l.unwrap_or_else(|| *feasible_l[i].last().unwrap());
                part[i] = l;
                mem_left = (mem_left - model.top_mem(l, dev.train_batch as u64)).max(0.0);
                energy_left = (energy_left
                    - crate::energy::gateway_train_energy(
                        gw, dev, model, l, ctx.cfg.local_iters, f_floor,
                    ))
                .max(0.0);
            }

            // --- f-step (Eq. 22) ------------------------------------------
            // Bisect the min-max completion time θ; each device needs
            // f_i(θ) = top_cycles / (θ - t_dev_i).
            let t_dev: Vec<f64> = (0..nm)
                .map(|i| {
                    crate::energy::device_train_time(
                        &ctx.topo.devices[gw.members[i]], model, part[i], ctx.cfg.local_iters,
                    )
                })
                .collect();
            let top_cycles: Vec<f64> = (0..nm)
                .map(|i| {
                    let dev = &ctx.topo.devices[gw.members[i]];
                    k * dev.train_batch as f64 * model.top_flops(part[i])
                        / gw.flops_per_cycle
                })
                .collect();
            let any_offload = top_cycles.iter().any(|&c| c > 0.0);
            let e_budget = (ctx.arrivals.gateway[m]
                - ctx.chan.energy_up(ctx.state, m, j, power, model.gamma_bits()))
            .max(0.0);

            let freqs_for = |theta: f64| -> Option<Vec<f64>> {
                let mut fs = Vec::with_capacity(nm);
                for i in 0..nm {
                    if top_cycles[i] == 0.0 {
                        fs.push(0.0);
                        continue;
                    }
                    let slack = theta - t_dev[i];
                    if slack <= 0.0 {
                        return None;
                    }
                    fs.push(top_cycles[i] / slack);
                }
                Some(fs)
            };
            let feasible = |theta: f64| -> bool {
                let Some(fs) = freqs_for(theta) else { return false };
                let total: f64 = fs.iter().sum();
                if total > gw.freq_max {
                    return false;
                }
                let e: f64 = (0..nm).map(|i| gw.kappa * top_cycles[i] * fs[i] * fs[i]).sum();
                e <= e_budget
            };

            if any_offload {
                let lo = t_dev.iter().cloned().fold(0.0, f64::max).max(1e-9);
                // Upper bound: run every offloaded piece at a tiny share.
                let hi = (0..nm)
                    .map(|i| t_dev[i] + if top_cycles[i] > 0.0 { top_cycles[i] / f_floor } else { 0.0 })
                    .fold(lo, f64::max)
                    * 1.01;
                match bisect_decreasing(lo, hi, 1e-6, 80, feasible) {
                    Some(theta) => {
                        let mut fs = freqs_for(theta).unwrap_or_else(|| vec![0.0; nm]);
                        // C6 lower bound: scale up if the total allocated
                        // frequency is below f^{G,min} (more f never hurts
                        // latency; re-check the energy budget).
                        let total: f64 = fs.iter().sum();
                        if total > 0.0 && total < gw.freq_min {
                            let scale = gw.freq_min / total;
                            let e: f64 = (0..nm)
                                .map(|i| gw.kappa * top_cycles[i] * fs[i] * fs[i] * scale * scale)
                                .sum();
                            if e <= e_budget {
                                for f in &mut fs {
                                    *f *= scale;
                                }
                            }
                        }
                        freq = fs;
                    }
                    // No θ satisfies the budget at the current power — fall
                    // back to the cheapest profile; the next P-step frees
                    // energy and the following iteration retries.
                    None => {
                        freq = (0..nm)
                            .map(|i| if top_cycles[i] > 0.0 { f_floor } else { 0.0 })
                            .collect();
                    }
                }
            } else {
                freq = vec![0.0; nm];
            }

            // --- P-step (Eq. 23–24) ---------------------------------------
            let e_train: f64 =
                (0..nm).map(|i| gw.kappa * top_cycles[i] * freq[i] * freq[i]).sum();
            let e_rem = ctx.arrivals.gateway[m] - e_train;
            let h = ctx.state.up_gain[m][j];
            let sigma = ctx.chan.bw_up * ctx.chan.noise_psd + ctx.state.up_intf[m][j];
            let gamma_bits = model.gamma_bits();
            // Minimum possible uplink energy is the P -> 0 limit
            // gamma * sigma * ln2 / (B h); below that, transmission is
            // impossible this round (Eq. 24 first branch).
            let min_energy = gamma_bits * sigma * std::f64::consts::LN_2 / (ctx.chan.bw_up * h);
            if e_rem <= min_energy {
                // Transmission unaffordable at this iterate (Eq. 24 first
                // branch) — skip evaluation and let the next iteration pick
                // a cheaper partition/frequency profile.
                power = 0.5 * gw.power_max;
                continue;
            }
            let g = |x: f64| {
                ctx.chan.bw_up / gamma_bits * e_rem * (1.0 + h * x / sigma).log2() - x
            };
            power = if g(gw.power_max) >= 0.0 {
                gw.power_max
            } else {
                // Root exists in (0, P^max) since g'(0) > 0 and g(P^max) < 0.
                bisect_root(1e-12, gw.power_max, 1e-9, 100, g).unwrap_or(gw.power_max)
            };

            // Evaluate the iterate; keep the best feasible one.
            let mut plan = GatewayPlan {
                gateway: m,
                channel: j,
                power,
                partition: part.clone(),
                freq: freq.clone(),
                lambda: 0.0,
            };
            let cost = plan_cost(ctx, &plan);
            if cost.feasible() {
                plan.lambda = cost.lambda();
                let improves = match &best {
                    None => true,
                    Some(b) => plan.lambda < b.lambda,
                };
                if improves {
                    best = Some(plan);
                }
            }
        }
        best
    }

    /// Λ matrix for all (m, j) pairs; INFEASIBLE when no allocation exists.
    fn lambda_matrix(&self, ctx: &RoundCtx) -> Vec<Vec<Option<GatewayPlan>>> {
        let mm = ctx.topo.num_gateways();
        let jj = ctx.cfg.num_channels;
        let solve_row = |m: usize| -> Vec<Option<GatewayPlan>> {
            (0..jj).map(|j| Self::solve_gateway(ctx, m, j, self.bcd_iters)).collect()
        };
        if self.parallel {
            // §V-C: the MJ subproblems are independent — solve the M rows
            // on the rayon pool. Ordering is preserved by into_par_iter, so
            // the result is identical to the serial path.
            use rayon::prelude::*;
            (0..mm).into_par_iter().map(solve_row).collect()
        } else {
            (0..mm).map(solve_row).collect()
        }
    }

    /// Channel assignment (Eq. 26–31): λ-sweep + Hungarian.
    fn assign(&self, plans: Vec<Vec<Option<GatewayPlan>>>) -> Decision {
        let mm = plans.len();
        let jj = plans.first().map_or(0, |r| r.len());
        let lam = |m: usize, j: usize| -> f64 {
            plans[m][j].as_ref().map_or(INFEASIBLE, |p| p.lambda)
        };

        // Candidate caps: every finite V·Λ value (+∞ fallback).
        let mut caps: Vec<f64> = (0..mm)
            .flat_map(|m| (0..jj).map(move |j| lam(m, j)))
            .filter(|&l| l < INFEASIBLE)
            .map(|l| self.v * l)
            .collect();
        caps.sort_by(|a, b| a.partial_cmp(b).unwrap());
        caps.dedup();
        caps.push(f64::INFINITY);

        let mut best_obj = f64::INFINITY;
        let mut best_assign: Option<Vec<Option<usize>>> = None;
        for &cap in &caps {
            // Θ_{m,j} (Eq. 29): −Q_m admissible, Ψ otherwise.
            let cost: Vec<Vec<f64>> = (0..mm)
                .map(|m| {
                    (0..jj)
                        .map(|j| {
                            let l = lam(m, j);
                            if l >= INFEASIBLE || self.v * l > cap {
                                PSI
                            } else {
                                -self.queues[m]
                            }
                        })
                        .collect()
                })
                .collect();
            let (assign, total) = hungarian_min(&cost);
            if total >= PSI / 2.0 {
                continue; // no admissible perfect matching under this cap
            }
            // True objective (Eq. 17): V·max Λ − Σ Q.
            let mut max_l = 0.0f64;
            let mut sum_q = 0.0;
            for (m, a) in assign.iter().enumerate() {
                if let Some(j) = a {
                    max_l = max_l.max(lam(m, *j));
                    sum_q += self.queues[m];
                }
            }
            let obj = self.v * max_l - sum_q;
            if obj < best_obj {
                best_obj = obj;
                best_assign = Some(assign);
            }
        }

        let mut decision = Decision::default();
        if let Some(assign) = best_assign {
            let mut plans = plans;
            for (m, a) in assign.into_iter().enumerate() {
                if let Some(j) = a {
                    if let Some(plan) = plans[m][j].take() {
                        decision.plans.push(plan);
                    }
                }
            }
        }
        decision
    }
}

impl Scheduler for Ddsra {
    fn name(&self) -> String {
        format!("ddsra_v{}", self.v)
    }

    fn schedule(&mut self, ctx: &RoundCtx) -> Decision {
        let decision = self.assign(self.lambda_matrix(ctx));
        // Virtual queue update (Eq. 14) on the realised selection.
        for m in 0..self.queues.len() {
            let served = if decision.selected(m) { 1.0 } else { 0.0 };
            self.queues[m] = (self.queues[m] - served + self.gamma[m]).max(0.0);
        }
        decision
    }

    fn queues(&self) -> Option<&[f64]> {
        Some(&self.queues)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::dnn::models;
    use crate::energy::EnergyArrivals;
    use crate::net::ChannelModel;
    use crate::rng::Rng;
    use crate::topo::Topology;

    struct Fixture {
        cfg: SimConfig,
        topo: Topology,
        model: crate::dnn::ModelSpec,
        chan: ChannelModel,
    }

    fn fixture(seed: u64) -> (Fixture, Rng) {
        let cfg = SimConfig::default();
        let mut rng = Rng::new(seed);
        let topo = Topology::generate(&cfg, &mut rng);
        let chan = ChannelModel::new(&cfg, &topo, &mut rng);
        (
            Fixture { cfg, topo, model: models::vgg11_cifar(), chan },
            rng,
        )
    }

    fn ctx<'a>(
        f: &'a Fixture,
        state: &'a crate::net::ChannelState,
        arr: &'a EnergyArrivals,
    ) -> RoundCtx<'a> {
        RoundCtx {
            cfg: &f.cfg,
            topo: &f.topo,
            model: &f.model,
            chan: &f.chan,
            state,
            arrivals: arr,
            round: 0,
        }
    }

    #[test]
    fn solve_gateway_produces_feasible_plans() {
        let (f, mut rng) = fixture(1);
        let mut solved = 0;
        for _ in 0..10 {
            let state = f.chan.draw(&mut rng);
            let arr = EnergyArrivals::draw(&f.cfg, &mut rng);
            let c = ctx(&f, &state, &arr);
            for m in 0..f.topo.num_gateways() {
                for j in 0..f.cfg.num_channels {
                    if let Some(plan) = Ddsra::solve_gateway(&c, m, j, 3) {
                        let cost = plan_cost(&c, &plan);
                        assert!(cost.feasible(), "violations: {:?}", cost.violations);
                        assert!(plan.lambda > 0.0 && plan.lambda < INFEASIBLE);
                        assert!(plan.power > 0.0 && plan.power <= f.topo.gateways[m].power_max + 1e-12);
                        solved += 1;
                    }
                }
            }
        }
        assert!(solved > 0, "no feasible allocation found in 10 rounds");
    }

    #[test]
    fn schedule_selects_exactly_j_gateways_when_feasible() {
        let (f, mut rng) = fixture(2);
        let mut d = Ddsra::new(1000.0, vec![0.5; 6]);
        let mut counts = Vec::new();
        for _ in 0..10 {
            let state = f.chan.draw(&mut rng);
            let arr = EnergyArrivals::draw(&f.cfg, &mut rng);
            let c = ctx(&f, &state, &arr);
            let dec = d.schedule(&c);
            counts.push(dec.plans.len());
            // distinct gateways and channels (C2, C3)
            let mut gws: Vec<_> = dec.plans.iter().map(|p| p.gateway).collect();
            let mut chs: Vec<_> = dec.plans.iter().map(|p| p.channel).collect();
            gws.sort_unstable();
            gws.dedup();
            chs.sort_unstable();
            chs.dedup();
            assert_eq!(gws.len(), dec.plans.len());
            assert_eq!(chs.len(), dec.plans.len());
            assert!(dec.plans.len() <= f.cfg.num_channels);
        }
        assert!(counts.iter().any(|&c| c == f.cfg.num_channels), "{counts:?}");
    }

    #[test]
    fn queues_track_unserved_gateways() {
        let (f, mut rng) = fixture(3);
        let gamma = vec![0.9; 6];
        let mut d = Ddsra::new(0.0, gamma.clone());
        for _ in 0..30 {
            let state = f.chan.draw(&mut rng);
            let arr = EnergyArrivals::draw(&f.cfg, &mut rng);
            let c = ctx(&f, &state, &arr);
            let _ = d.schedule(&c);
        }
        // With ΣΓ = 5.4 > J = 3 the queues cannot all stay empty; but V=0
        // should keep them bounded-ish (largest-queue-first service).
        assert!(d.queues.iter().all(|&q| q.is_finite()));
        assert!(d.queues.iter().any(|&q| q > 0.0));
    }

    #[test]
    fn v_zero_serves_largest_queues() {
        let (f, mut rng) = fixture(4);
        let mut d = Ddsra::new(0.0, vec![0.0; 6]);
        d.queues = vec![10.0, 0.0, 9.0, 0.0, 8.0, 0.0];
        let state = f.chan.draw(&mut rng);
        let arr = EnergyArrivals::draw(&f.cfg, &mut rng);
        let c = ctx(&f, &state, &arr);
        let dec = d.schedule(&c);
        let mut sel: Vec<_> = dec.plans.iter().map(|p| p.gateway).collect();
        sel.sort_unstable();
        assert_eq!(sel, vec![0, 2, 4], "V=0 must serve the longest queues");
    }

    #[test]
    fn large_v_minimizes_delay() {
        // With V huge and equal queues, DDSRA must pick the assignment
        // minimising max Λ over all candidate assignments it evaluated.
        let (f, mut rng) = fixture(5);
        let mut dv = Ddsra::new(1e12, vec![0.0; 6]);
        let state = f.chan.draw(&mut rng);
        let arr = EnergyArrivals::draw(&f.cfg, &mut rng);
        let c = ctx(&f, &state, &arr);
        let dec_fast = dv.schedule(&c);
        let mut dq = Ddsra::new(0.0, vec![0.0; 6]);
        dq.queues = vec![0.0, 0.0, 0.0, 1.0, 1.0, 1.0]; // force others
        let dec_slow = dq.schedule(&c);
        assert!(dec_fast.round_delay() <= dec_slow.round_delay() + 1e-9);
    }

    #[test]
    fn parallel_matches_serial() {
        let (f, mut rng) = fixture(6);
        let state = f.chan.draw(&mut rng);
        let arr = EnergyArrivals::draw(&f.cfg, &mut rng);
        let c = ctx(&f, &state, &arr);
        let mut a = Ddsra::new(100.0, vec![0.5; 6]);
        let mut b = Ddsra::new(100.0, vec![0.5; 6]);
        b.parallel = true;
        let da = a.schedule(&c);
        let db = b.schedule(&c);
        let key = |d: &Decision| {
            let mut v: Vec<_> = d.plans.iter().map(|p| (p.gateway, p.channel)).collect();
            v.sort_unstable();
            v
        };
        assert_eq!(key(&da), key(&db));
        assert!((da.round_delay() - db.round_delay()).abs() < 1e-9);
    }
}
