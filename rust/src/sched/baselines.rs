//! Baseline schedulers (§VII-A): Random Scheduling, Round Robin,
//! Loss-Driven Scheduling and Delay-Driven Scheduling.
//!
//! Per the paper, the baselines FIX the transmit power, the gateway
//! computation frequency and the DNN partition point; consequently "devices
//! and gateways often fail to complete the local model training and
//! transmitting due to energy shortage" — the orchestrator drops such
//! updates, which is exactly what degrades their accuracy in Fig. 4–6.

use crate::opt::hungarian_min;
use crate::rng::Rng;
use crate::sched::latency::{plan_cost, INFEASIBLE};
use crate::sched::{Decision, GatewayPlan, RoundCtx, RoundFeedback, Scheduler};

/// The fixed resource allocation shared by all baselines:
/// l_n = L/2 (clamped to the device memory bound so the plan is at least
/// *storable*), even gateway frequency split, maximum transmit power.
pub fn fixed_plan(ctx: &RoundCtx, m: usize, j: usize) -> GatewayPlan {
    let gw = &ctx.topo.gateways[m];
    let model = ctx.model;
    let depth = model.depth();
    let nm = gw.members.len();
    let partition: Vec<usize> = gw
        .members
        .iter()
        .map(|&n| {
            let dev = &ctx.topo.devices[n];
            let mut l = depth / 2;
            while l > 0 && model.bottom_mem(l, dev.train_batch as u64) > dev.mem {
                l -= 1;
            }
            l
        })
        .collect();
    let mut plan = GatewayPlan {
        gateway: m,
        channel: j,
        power: gw.power_max,
        partition,
        freq: vec![gw.freq_max / nm as f64; nm],
        lambda: 0.0,
    };
    plan.lambda = plan_cost(ctx, &plan).lambda();
    plan
}

fn decision_from(ctx: &RoundCtx, picks: &[(usize, usize)]) -> Decision {
    Decision {
        plans: picks.iter().map(|&(m, j)| fixed_plan(ctx, m, j)).collect(),
    }
}

// ---------------------------------------------------------------- Random

/// Uniformly selects J gateways and assigns channels randomly [26].
pub struct RandomSched {
    rng: Rng,
}

impl RandomSched {
    pub fn new(seed: u64) -> Self {
        RandomSched { rng: Rng::new(seed) }
    }
}

impl Scheduler for RandomSched {
    fn name(&self) -> String {
        "random".into()
    }

    fn schedule(&mut self, ctx: &RoundCtx) -> Decision {
        let j = ctx.cfg.num_channels;
        let gws = self.rng.choose_k(ctx.topo.num_gateways(), j);
        let picks: Vec<(usize, usize)> =
            gws.into_iter().enumerate().map(|(ch, m)| (m, ch)).collect();
        decision_from(ctx, &picks)
    }
}

// ------------------------------------------------------------ Round Robin

/// Divides the M gateways into ⌈M/J⌉ groups served consecutively [26].
pub struct RoundRobin {
    group: usize,
}

impl RoundRobin {
    pub fn new() -> Self {
        RoundRobin { group: 0 }
    }
}

impl Default for RoundRobin {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for RoundRobin {
    fn name(&self) -> String {
        "round_robin".into()
    }

    fn schedule(&mut self, ctx: &RoundCtx) -> Decision {
        let m = ctx.topo.num_gateways();
        let j = ctx.cfg.num_channels;
        let groups = m.div_ceil(j);
        let start = (self.group % groups) * j;
        self.group += 1;
        let picks: Vec<(usize, usize)> = (0..j)
            .filter_map(|i| {
                let gw = start + i;
                (gw < m).then_some((gw, i))
            })
            .collect();
        decision_from(ctx, &picks)
    }
}

// ------------------------------------------------------------ Loss-Driven

/// Selects the J gateways with the LOWEST observed local training loss
/// (highest training accuracy) — which, as Fig. 6 shows, starves exactly
/// the gateways whose devices hold the widest class variety.
pub struct LossDriven {
    /// EMA of each gateway's local loss; initialised to ln(10).
    loss: Vec<f64>,
    rng: Rng,
}

impl LossDriven {
    pub fn new(num_gateways: usize, seed: u64) -> Self {
        LossDriven { loss: vec![(10.0f64).ln(); num_gateways], rng: Rng::new(seed) }
    }
}

impl Scheduler for LossDriven {
    fn name(&self) -> String {
        "loss_driven".into()
    }

    fn schedule(&mut self, ctx: &RoundCtx) -> Decision {
        let j = ctx.cfg.num_channels;
        let mut order: Vec<usize> = (0..ctx.topo.num_gateways()).collect();
        // random jitter breaks ties deterministically-per-seed
        let jitter: Vec<f64> = order.iter().map(|_| self.rng.f64() * 1e-9).collect();
        order.sort_by(|&a, &b| {
            (self.loss[a] + jitter[a])
                .partial_cmp(&(self.loss[b] + jitter[b]))
                .unwrap()
        });
        let picks: Vec<(usize, usize)> =
            order.into_iter().take(j).enumerate().map(|(ch, m)| (m, ch)).collect();
        decision_from(ctx, &picks)
    }

    fn observe(&mut self, fb: &RoundFeedback) {
        for (m, l) in fb.avg_loss.iter().enumerate() {
            if let Some(l) = l {
                self.loss[m] = 0.5 * self.loss[m] + 0.5 * l;
            }
        }
    }
}

// ----------------------------------------------------------- Delay-Driven

/// Selects gateways/channels minimising this round's FL latency
/// (min-max Λ under the fixed resource allocation).
pub struct DelayDriven;

impl Scheduler for DelayDriven {
    fn name(&self) -> String {
        "delay_driven".into()
    }

    fn schedule(&mut self, ctx: &RoundCtx) -> Decision {
        let mm = ctx.topo.num_gateways();
        let jj = ctx.cfg.num_channels;
        // Λ under fixed resources for every pair.
        let lam: Vec<Vec<f64>> = (0..mm)
            .map(|m| (0..jj).map(|j| fixed_plan(ctx, m, j).lambda).collect())
            .collect();
        // Min-max assignment: sweep thresholds, check a perfect matching
        // of channels to distinct gateways exists among Λ <= thr, then
        // min-sum among admissible pairs.
        let mut cands: Vec<f64> = lam.iter().flatten().cloned().collect();
        cands.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut picks: Vec<(usize, usize)> = Vec::new();
        for thr in cands {
            let cost: Vec<Vec<f64>> = (0..mm)
                .map(|m| {
                    (0..jj)
                        .map(|j| if lam[m][j] <= thr { lam[m][j] } else { INFEASIBLE })
                        .collect()
                })
                .collect();
            let (assign, total) = hungarian_min(&cost);
            if total < INFEASIBLE / 2.0 {
                picks = assign
                    .into_iter()
                    .enumerate()
                    .filter_map(|(m, a)| a.map(|j| (m, j)))
                    .collect();
                break;
            }
        }
        decision_from(ctx, &picks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::dnn::models;
    use crate::energy::EnergyArrivals;
    use crate::net::ChannelModel;
    use crate::topo::Topology;

    struct Fx {
        cfg: SimConfig,
        topo: Topology,
        model: crate::dnn::ModelSpec,
        chan: ChannelModel,
    }

    fn fx(seed: u64) -> (Fx, Rng) {
        let cfg = SimConfig::default();
        let mut rng = Rng::new(seed);
        let topo = Topology::generate(&cfg, &mut rng);
        let chan = ChannelModel::new(&cfg, &topo, &mut rng);
        (Fx { cfg, topo, model: models::vgg11_cifar(), chan }, rng)
    }

    fn round<'a>(
        f: &'a Fx,
        st: &'a crate::net::ChannelState,
        ar: &'a EnergyArrivals,
    ) -> RoundCtx<'a> {
        RoundCtx {
            cfg: &f.cfg,
            topo: &f.topo,
            model: &f.model,
            chan: &f.chan,
            state: st,
            arrivals: ar,
            round: 0,
        }
    }

    fn check_valid(dec: &Decision, j: usize) {
        assert_eq!(dec.plans.len(), j);
        let mut gws: Vec<_> = dec.plans.iter().map(|p| p.gateway).collect();
        let mut chs: Vec<_> = dec.plans.iter().map(|p| p.channel).collect();
        gws.sort_unstable();
        gws.dedup();
        chs.sort_unstable();
        chs.dedup();
        assert_eq!(gws.len(), j);
        assert_eq!(chs.len(), j);
    }

    #[test]
    fn all_baselines_emit_valid_decisions() {
        let (f, mut rng) = fx(1);
        let st = f.chan.draw(&mut rng);
        let ar = EnergyArrivals::draw(&f.cfg, &mut rng);
        let ctx = round(&f, &st, &ar);
        let mut scheds: Vec<Box<dyn Scheduler>> = vec![
            Box::new(RandomSched::new(1)),
            Box::new(RoundRobin::new()),
            Box::new(LossDriven::new(6, 2)),
            Box::new(DelayDriven),
        ];
        for s in &mut scheds {
            let d = s.schedule(&ctx);
            check_valid(&d, f.cfg.num_channels);
        }
    }

    #[test]
    fn round_robin_cycles_all_gateways() {
        let (f, mut rng) = fx(2);
        let mut rr = RoundRobin::new();
        let mut seen = vec![0usize; 6];
        for _ in 0..4 {
            let st = f.chan.draw(&mut rng);
            let ar = EnergyArrivals::draw(&f.cfg, &mut rng);
            let ctx = round(&f, &st, &ar);
            for p in rr.schedule(&ctx).plans {
                seen[p.gateway] += 1;
            }
        }
        // after 2 full cycles every gateway served exactly twice
        assert_eq!(seen, vec![2; 6]);
    }

    #[test]
    fn loss_driven_prefers_low_loss() {
        let (f, mut rng) = fx(3);
        let mut ld = LossDriven::new(6, 7);
        ld.observe(&RoundFeedback {
            avg_loss: vec![
                Some(0.1),
                Some(2.0),
                Some(0.2),
                Some(2.0),
                Some(0.3),
                Some(2.0),
            ],
        });
        let st = f.chan.draw(&mut rng);
        let ar = EnergyArrivals::draw(&f.cfg, &mut rng);
        let ctx = round(&f, &st, &ar);
        let mut sel: Vec<_> = ld.schedule(&ctx).plans.iter().map(|p| p.gateway).collect();
        sel.sort_unstable();
        assert_eq!(sel, vec![0, 2, 4]);
    }

    #[test]
    fn delay_driven_minimizes_max_lambda() {
        let (f, mut rng) = fx(4);
        let st = f.chan.draw(&mut rng);
        let ar = EnergyArrivals::draw(&f.cfg, &mut rng);
        let ctx = round(&f, &st, &ar);
        let d = DelayDriven.schedule(&ctx);
        let dd_delay = d.round_delay();
        // compare against 20 random assignments — none may beat it
        let mut r = Rng::new(99);
        for _ in 0..20 {
            let gws = r.choose_k(6, 3);
            let picks: Vec<(usize, usize)> =
                gws.into_iter().enumerate().map(|(ch, m)| (m, ch)).collect();
            let rd = decision_from(&ctx, &picks).round_delay();
            assert!(dd_delay <= rd + 1e-9, "delay-driven {dd_delay} beaten by {rd}");
        }
    }

    #[test]
    fn fixed_plan_uses_max_power_and_even_freq() {
        let (f, mut rng) = fx(5);
        let st = f.chan.draw(&mut rng);
        let ar = EnergyArrivals::draw(&f.cfg, &mut rng);
        let ctx = round(&f, &st, &ar);
        let p = fixed_plan(&ctx, 0, 0);
        assert_eq!(p.power, f.topo.gateways[0].power_max);
        let nm = f.topo.gateways[0].members.len();
        for &fr in &p.freq {
            assert!((fr - f.topo.gateways[0].freq_max / nm as f64).abs() < 1e-9);
        }
    }
}
