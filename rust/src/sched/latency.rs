//! The Λ latency model (Eq. 18) and the per-plan cost/feasibility
//! evaluation used by every scheduler.
//!
//! Λ_{m,j}(t) = max_n [device-side + gateway-side training time]   (Eq. 1)
//!            + τ^down_{m,j}                                        (Eq. 6)
//!            + τ^up_{m,j}(P_m)                                     (Eq. 7)
//!
//! Feasibility covers C7–C10: device/gateway memory (Eq. 4–5) and
//! device/gateway per-round harvested-energy budgets (Eq. 2, 3, 9).

use crate::energy;
use crate::sched::{GatewayPlan, RoundCtx};

/// Sentinel delay for infeasible configurations.
pub const INFEASIBLE: f64 = 1e18;

/// Constraint violations for a plan (baselines run with fixed resources
/// and may violate them — the orchestrator then drops the update, exactly
/// the "training failure" behaviour the paper attributes to the baselines).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Violation {
    /// C7: device n's bottom layers exceed its memory.
    DeviceMem(usize),
    /// C8: offloaded top layers exceed the gateway memory.
    GatewayMem,
    /// C9 (device part, paper C9/C10'): device training energy exceeds
    /// this round's arrival.
    DeviceEnergy(usize),
    /// C10: gateway training + uplink energy exceeds this round's arrival.
    GatewayEnergy,
}

/// Fully-evaluated cost of a gateway plan.
#[derive(Clone, Debug)]
pub struct PlanCost {
    /// max_n per-device training time (Eq. 1, inner max).
    pub train_time: f64,
    pub tau_down: f64,
    pub tau_up: f64,
    /// e^{tra,D}_n per member device.
    pub device_energy: Vec<f64>,
    /// e^G_m = e^{tra,G}_m + e^up_m (Eq. 9).
    pub gateway_energy: f64,
    /// G^D_n per member device.
    pub device_mem: Vec<f64>,
    /// G^G_m.
    pub gateway_mem: f64,
    pub violations: Vec<Violation>,
}

impl PlanCost {
    /// Λ_{m,j} = training + downlink + uplink.
    pub fn lambda(&self) -> f64 {
        self.train_time + self.tau_down + self.tau_up
    }

    pub fn feasible(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Evaluate one gateway plan against the round's channel/energy state.
pub fn plan_cost(ctx: &RoundCtx, plan: &GatewayPlan) -> PlanCost {
    let m = plan.gateway;
    let gw = &ctx.topo.gateways[m];
    let k = ctx.cfg.local_iters;
    let model = ctx.model;
    let gamma = model.gamma_bits();

    let mut train_time: f64 = 0.0;
    let mut device_energy = Vec::with_capacity(gw.members.len());
    let mut device_mem = Vec::with_capacity(gw.members.len());
    let mut gw_train_energy = 0.0;
    let mut gw_mem = 0.0;
    let mut violations = Vec::new();

    for (i, &n) in gw.members.iter().enumerate() {
        let dev = &ctx.topo.devices[n];
        let l = plan.partition[i];
        let f_g = plan.freq[i];

        let t_dev = energy::device_train_time(dev, model, l, k);
        let t_gw = energy::gateway_train_time(gw, dev, model, l, k, f_g);
        train_time = train_time.max(t_dev + t_gw);

        let e_dev = energy::device_train_energy(dev, model, l, k);
        if e_dev > ctx.arrivals.device[n] {
            violations.push(Violation::DeviceEnergy(n));
        }
        device_energy.push(e_dev);

        let g_dev = model.bottom_mem(l, dev.train_batch as u64);
        if g_dev > dev.mem {
            violations.push(Violation::DeviceMem(n));
        }
        device_mem.push(g_dev);

        gw_train_energy += energy::gateway_train_energy(gw, dev, model, l, k, f_g);
        gw_mem += model.top_mem(l, dev.train_batch as u64);
    }

    if gw_mem > gw.mem {
        violations.push(Violation::GatewayMem);
    }

    let tau_down = ctx.chan.tau_down(ctx.state, m, plan.channel, gamma);
    let tau_up = ctx.chan.tau_up(ctx.state, m, plan.channel, plan.power, gamma);
    let e_up = ctx.chan.energy_up(ctx.state, m, plan.channel, plan.power, gamma);
    let mut gateway_energy = gw_train_energy + e_up;
    // Relay/Ψ term (hierarchical aggregation): the gateway's partial
    // aggregate — Γ model bits — is relayed up the tier chain, charged at
    // Ψ J/bit against the gateway's energy budget (relay-assisted
    // aggregation, Hashempour et al., PAPERS.md). Gated so the default
    // Ψ = 0 leaves every scheduler cost byte untouched.
    if ctx.cfg.relay_psi > 0.0 {
        gateway_energy += ctx.cfg.relay_psi * gamma;
    }
    if gateway_energy > ctx.arrivals.gateway[m] {
        violations.push(Violation::GatewayEnergy);
    }

    PlanCost {
        train_time,
        tau_down,
        tau_up,
        device_energy,
        gateway_energy,
        device_mem,
        gateway_mem: gw_mem,
        violations,
    }
}
