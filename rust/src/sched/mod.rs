//! Device scheduling and resource allocation (§V): the DDSRA algorithm and
//! the four baseline schedulers, sharing the Λ latency model (Eq. 18) and
//! the feasibility checks (C4–C10).

pub mod baselines;
pub mod ddsra;
pub mod latency;

pub use baselines::{DelayDriven, LossDriven, RandomSched, RoundRobin};
pub use ddsra::Ddsra;
pub use latency::{plan_cost, PlanCost, Violation, INFEASIBLE};

use crate::config::SimConfig;
use crate::dnn::ModelSpec;
use crate::energy::EnergyArrivals;
use crate::net::{ChannelModel, ChannelState};
use crate::topo::Topology;

/// Which λ-sweep implementation DDSRA's channel-assignment step runs.
///
/// `Sweep` is the original Eq. 26–31 machinery kept verbatim: a fresh
/// Θ cost matrix and an O(n³) Hungarian solve for every candidate cap —
/// the decision-parity oracle. `Incremental` (the default) walks the
/// caps in ascending order maintaining a max-weight matching over the
/// growing admissibility graph via augmenting paths, and only runs the
/// verbatim per-cap evaluation at the few caps where the matching
/// actually changes. Both paths produce bit-identical [`Decision`]s
/// (`rust/tests/sched_parity.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SchedPath {
    /// Verbatim per-cap Hungarian re-solve — the decision-parity oracle.
    Sweep,
    /// Ascending-cap augmenting-path matching — the fast default.
    #[default]
    Incremental,
}

impl SchedPath {
    pub fn as_str(self) -> &'static str {
        match self {
            SchedPath::Sweep => "sweep",
            SchedPath::Incremental => "incremental",
        }
    }
}

impl std::fmt::Display for SchedPath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for SchedPath {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "sweep" => Ok(SchedPath::Sweep),
            "incremental" => Ok(SchedPath::Incremental),
            other => anyhow::bail!(
                "unknown sched path {other:?} (expected \"sweep\" or \"incremental\")"
            ),
        }
    }
}

/// Everything a scheduler may observe at the start of round t.
pub struct RoundCtx<'a> {
    pub cfg: &'a SimConfig,
    pub topo: &'a Topology,
    /// Cost-model DNN (the objective DNN the scheduler plans for).
    pub model: &'a ModelSpec,
    pub chan: &'a ChannelModel,
    pub state: &'a ChannelState,
    pub arrivals: &'a EnergyArrivals,
    pub round: usize,
}

/// Resource allocation for one selected gateway in one round:
/// X(t) = [I(t), l(t), P(t), f^G(t)] restricted to gateway m.
#[derive(Clone, Debug)]
pub struct GatewayPlan {
    pub gateway: usize,
    /// Assigned channel j (I_{m,j} = 1).
    pub channel: usize,
    /// Uplink transmit power P_m(t) (W).
    pub power: f64,
    /// DNN partition point l_n(t) per member device (aligned with
    /// `topo.gateways[m].members`): the bottom l_n layers train on the
    /// device, the top L − l_n on the gateway (C5). Besides pricing the
    /// round via the Table II cost model, this is surfaced to the runtime:
    /// with `execute_partition` on, the orchestrator runs device n's local
    /// step through the split-execution backend at exactly this cut.
    pub partition: Vec<usize>,
    /// Gateway frequency share f^G_{m,n}(t) per member device (Hz).
    pub freq: Vec<f64>,
    /// Λ_{m,j}(t): this gateway's total round delay (Eq. 18).
    pub lambda: f64,
}

/// A full scheduling decision for one round.
#[derive(Clone, Debug, Default)]
pub struct Decision {
    pub plans: Vec<GatewayPlan>,
}

impl Decision {
    /// 1_m^t: was gateway m selected?
    pub fn selected(&self, m: usize) -> bool {
        self.plans.iter().any(|p| p.gateway == m)
    }

    /// τ(t) (Eq. 10): the round delay is the max over selected gateways.
    pub fn round_delay(&self) -> f64 {
        self.plans.iter().map(|p| p.lambda).fold(0.0, f64::max)
    }
}

/// Post-round feedback for adaptive schedulers (Loss-Driven uses the
/// observed local losses; DDSRA updates its virtual queues internally).
#[derive(Clone, Debug)]
pub struct RoundFeedback {
    /// Average local training loss per gateway, where observed this round.
    pub avg_loss: Vec<Option<f64>>,
}

/// The scheduler interface: one decision per communication round.
pub trait Scheduler {
    fn name(&self) -> String;
    fn schedule(&mut self, ctx: &RoundCtx) -> Decision;
    fn observe(&mut self, _fb: &RoundFeedback) {}
    /// Virtual queue lengths (DDSRA only) — exposed for the Theorem-2
    /// trade-off experiments.
    fn queues(&self) -> Option<&[f64]> {
        None
    }
}
