//! The session API: the one public way to run experiments.
//!
//! A [`Session`] owns a fully-built [`Experiment`] plus validated run
//! options, and executes schedulers through the streaming
//! [`RoundEngine`](crate::fl::round::RoundEngine):
//!
//! ```text
//!   Session::builder(cfg)            typed knobs, validated once
//!     └─ Session                     Experiment + RunOpts + cached Γ
//!          └─ RoundEngine::run       §III-A phases, per-round records
//!               └─ RoundObserver*    CsvSink / JsonlSink / ProgressSink /
//!                                    MemorySink — each RoundRecord is
//!                                    delivered AS IT IS PRODUCED
//! ```
//!
//! Schedulers are named by the typed [`SchedulerSpec`] enum (with a
//! [`FromStr`] bridge for the CLI); the Γ_m participation rates that
//! DDSRA variants need are estimated once per session and shared, so a
//! paired sweep ([`Session::run_paired`]) probes gradients once and runs
//! every scheduler against byte-identical environment streams.
//!
//! Early stopping lives in the engine, once: the builder's
//! [`until_accuracy`](SessionBuilder::until_accuracy) (run-to-target —
//! the paper's Fig. 4–6 convergence-time metric) and
//! [`max_rounds_wall`](SessionBuilder::max_rounds_wall) (simulated
//! wall-clock budget Σ τ(t)) knobs, plus any observer returning
//! [`ControlFlow::Break`]. A stopped run's records are byte-identical
//! to the first k records of the full run (pinned by
//! `rust/tests/session.rs`) because each round's RNG streams depend
//! only on `(seed, round, device)`, never on the future. When the
//! stopping round itself skipped the periodic eval, the engine runs a
//! forced final eval and delivers the patched record via
//! [`RoundObserver::on_final_eval`] — so an early-stopped run never ends
//! with `test_acc = None`, without perturbing the prefix property.
//!
//! Transport is invisible here by design: `transport = tcp` in
//! [`SimConfig`] routes every split local step and the phase-5 fold
//! over the wire to a `serve-gateway` process, but it does so behind
//! the [`Backend`](crate::runtime::Backend) trait
//! ([`RemoteBackend`](crate::runtime::RemoteBackend)) and the round
//! engine's fold seam — the Session API, its observers, and the
//! prefix/early-stop guarantees above are unchanged, and loopback runs
//! are byte-identical to in-process ones (`rust/tests/wire.rs`).
//!
//! # Example
//!
//! ```no_run
//! use iiot_fl::config::SimConfig;
//! use iiot_fl::fl::{SchedulerSpec, Session};
//!
//! # fn main() -> anyhow::Result<()> {
//! let session = Session::builder(SimConfig::default())
//!     .rounds(10)
//!     .eval_every(2)
//!     .build()?;
//! let log = session.run(&SchedulerSpec::ddsra())?;
//! println!("final accuracy: {:?}", log.final_accuracy());
//! # Ok(())
//! # }
//! ```

use std::fmt;
use std::ops::ControlFlow;
use std::path::PathBuf;
use std::str::FromStr;
use std::sync::OnceLock;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::SimConfig;
use crate::fl::round::RoundEngine;
use crate::sched::Scheduler;

use super::orchestrator::{Experiment, RoundRecord, RunLog};

// ---------------------------------------------------------------- options

/// Validated engine options for one run. Constructed by
/// [`SessionBuilder::build`] — callers go through the builder (or the
/// compat [`Experiment::run`] shim) instead of filling this in by hand.
#[derive(Clone, Debug)]
pub struct RunOpts {
    pub rounds: usize,
    /// Evaluate on the test set every this many rounds (0 = never).
    pub eval_every: usize,
    /// Track ||ŵ_m − v^{K,t}|| against a centralized-GD shadow (Fig. 2);
    /// forces all devices to train each round for measurement.
    pub track_divergence: bool,
    /// Execute real training through the backend. When false, only the
    /// scheduling/delay simulation runs (scheduling-only sweeps).
    pub train: bool,
    /// Stop once an eval round reports test accuracy ≥ this target.
    pub until_accuracy: Option<f64>,
    /// Stop once the simulated cumulative round delay Σ τ(t) reaches
    /// this budget (seconds).
    pub max_sim_delay: Option<f64>,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            rounds: 50,
            eval_every: 5,
            track_divergence: false,
            train: true,
            until_accuracy: None,
            max_sim_delay: None,
        }
    }
}

// -------------------------------------------------------------- observers

/// Metadata delivered to observers before the first round.
#[derive(Clone, Debug)]
pub struct RunMeta {
    /// Scheduler display name ([`Scheduler::name`]) — becomes
    /// [`RunLog::scheme`].
    pub scheme: String,
    /// Planned round count (early stopping may end the run sooner).
    pub rounds: usize,
    pub gateways: usize,
    pub devices: usize,
}

/// Why a run ended before its planned round count.
#[derive(Clone, Debug, PartialEq)]
pub enum StopCause {
    /// `until_accuracy`: an eval round reported accuracy ≥ the target.
    TargetAccuracy { round: usize, accuracy: f64 },
    /// `max_rounds_wall`: the simulated cumulative delay Σ τ(t) reached
    /// the budget.
    DelayBudget { round: usize, cum_delay: f64 },
    /// An observer returned [`ControlFlow::Break`].
    Observer { round: usize },
}

impl StopCause {
    /// Index of the last executed round.
    pub fn round(&self) -> usize {
        match *self {
            StopCause::TargetAccuracy { round, .. }
            | StopCause::DelayBudget { round, .. }
            | StopCause::Observer { round } => round,
        }
    }

    /// Stable machine-readable tag (used by [`crate::metrics::JsonlSink`]).
    pub fn kind(&self) -> &'static str {
        match self {
            StopCause::TargetAccuracy { .. } => "target_accuracy",
            StopCause::DelayBudget { .. } => "delay_budget",
            StopCause::Observer { .. } => "observer",
        }
    }
}

impl fmt::Display for StopCause {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StopCause::TargetAccuracy { round, accuracy } => {
                write!(f, "reached target accuracy {:.2}% at round {round}", accuracy * 100.0)
            }
            StopCause::DelayBudget { round, cum_delay } => {
                write!(f, "simulated delay budget hit at round {round} (Σ τ = {cum_delay:.1}s)")
            }
            StopCause::Observer { round } => write!(f, "observer stopped the run at round {round}"),
        }
    }
}

/// End-of-run summary delivered to observers (and returned by the
/// streaming entry points, which buffer nothing themselves).
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub scheme: String,
    pub rounds_planned: usize,
    /// Rounds actually executed (== `rounds_planned` unless stopped).
    pub rounds_run: usize,
    pub stop: Option<StopCause>,
    /// Empirical participation rate per gateway over the executed
    /// rounds: (1/T) Σ_t 1_m^t.
    pub participation: Vec<f64>,
    /// Effective participation (selected AND feasible).
    pub effective_participation: Vec<f64>,
}

/// Receives each [`RoundRecord`] as the engine produces it.
///
/// Implementations stream (CSV/JSONL rows written during the run),
/// report (stderr heartbeats), or buffer (`MemorySink`, which rebuilds a
/// [`RunLog`]). Returning [`ControlFlow::Break`] stops the run after the
/// current round — the record that triggered the stop is always
/// delivered to every observer first.
pub trait RoundObserver {
    /// Called once before round 0.
    fn on_start(&mut self, _meta: &RunMeta) -> Result<()> {
        Ok(())
    }

    /// Called after every executed round, in round order.
    fn on_record(&mut self, record: &RoundRecord) -> Result<ControlFlow<()>>;

    /// Called at most once, only on an early-stopped run whose stopping
    /// round the periodic eval gate skipped: `record` is the final round's
    /// record with `test_loss`/`test_acc` filled in by a forced final
    /// eval. Delivered OUTSIDE the `on_record` stream so a stopped run's
    /// per-round records stay a byte-identical prefix of the full run;
    /// buffering observers typically replace their last record with this
    /// one (`MemorySink` does).
    fn on_final_eval(&mut self, _record: &RoundRecord) -> Result<()> {
        Ok(())
    }

    /// Called once after the last round (stopped or not).
    fn on_finish(&mut self, _summary: &RunSummary) -> Result<()> {
        Ok(())
    }
}

// --------------------------------------------------------- scheduler spec

/// Typed scheduler selection, replacing the stringly
/// `make_scheduler("ddsra")` surface. The [`FromStr`] impl bridges the
/// CLI (`--scheme ddsra`); everything else names schedulers through this
/// enum.
#[derive(Clone, Debug, PartialEq)]
pub enum SchedulerSpec {
    /// DDSRA (§V): Lyapunov V from the config, or overridden per spec —
    /// `SchedulerSpec::ddsra_with_v(1000.0)` is Fig. 4's "DDSRA
    /// (V=1000)" curve.
    Ddsra { v: Option<f64> },
    /// DDSRA with V = 0 — the pure device-specific participation-rate
    /// policy of Fig. 3.
    Participation,
    Random,
    RoundRobin,
    LossDriven,
    DelayDriven,
}

impl SchedulerSpec {
    /// DDSRA with the config's Lyapunov V.
    pub fn ddsra() -> Self {
        SchedulerSpec::Ddsra { v: None }
    }

    /// DDSRA with an explicit Lyapunov V (the Fig. 4/5 sweeps).
    pub fn ddsra_with_v(v: f64) -> Self {
        SchedulerSpec::Ddsra { v: Some(v) }
    }

    /// The canonical scheduler menu (one spec per CLI scheme name).
    pub fn all() -> [SchedulerSpec; 6] {
        [
            SchedulerSpec::ddsra(),
            SchedulerSpec::Participation,
            SchedulerSpec::Random,
            SchedulerSpec::RoundRobin,
            SchedulerSpec::LossDriven,
            SchedulerSpec::DelayDriven,
        ]
    }

    /// CLI scheme names accepted by the [`FromStr`] bridge.
    pub const NAMES: &[&str] =
        &["ddsra", "participation", "random", "round_robin", "loss_driven", "delay_driven"];

    /// Stable label for file names and result tables: distinguishes
    /// DDSRA V-variants (`ddsra_v1000`) where [`Scheduler::name`] is the
    /// run-time source of truth.
    pub fn label(&self) -> String {
        match self {
            SchedulerSpec::Ddsra { v: None } => "ddsra".into(),
            SchedulerSpec::Ddsra { v: Some(v) } => format!("ddsra_v{v}"),
            SchedulerSpec::Participation => "participation".into(),
            SchedulerSpec::Random => "random".into(),
            SchedulerSpec::RoundRobin => "round_robin".into(),
            SchedulerSpec::LossDriven => "loss_driven".into(),
            SchedulerSpec::DelayDriven => "delay_driven".into(),
        }
    }

    /// Does building this scheduler require the Γ_m participation rates
    /// (one gradient-probe pass, §IV)?
    pub fn needs_gamma(&self) -> bool {
        matches!(self, SchedulerSpec::Ddsra { .. } | SchedulerSpec::Participation)
    }

    /// Instantiate the scheduler against an experiment. `gamma` must be
    /// provided when [`needs_gamma`](Self::needs_gamma) — callers go
    /// through [`Session::scheduler`], which caches the estimate.
    pub fn build(&self, exp: &Experiment, gamma: Option<&[f64]>) -> Result<Box<dyn Scheduler>> {
        use crate::sched::{Ddsra, DelayDriven, LossDriven, RandomSched, RoundRobin};
        let need_gamma = || -> Result<Vec<f64>> {
            Ok(gamma
                .with_context(|| format!("{} needs the Γ_m participation rates", self.label()))?
                .to_vec())
        };
        // Production DDSRA runs the rayon row solves (§V-C) and the
        // config's λ-sweep path; the serial/sweep combination stays
        // reachable through `Ddsra::new` for the parity tests.
        let ddsra = |v: f64| -> Result<Box<dyn Scheduler>> {
            let mut d = Ddsra::new(v, need_gamma()?);
            d.parallel = true;
            d.sched_path = exp.cfg.sched_path;
            Ok(Box::new(d))
        };
        Ok(match self {
            SchedulerSpec::Ddsra { v } => ddsra(v.unwrap_or(exp.cfg.lyapunov_v))?,
            SchedulerSpec::Participation => ddsra(0.0)?,
            SchedulerSpec::Random => Box::new(RandomSched::new(exp.cfg.seed ^ 0xaa11)),
            SchedulerSpec::RoundRobin => Box::new(RoundRobin::new()),
            SchedulerSpec::LossDriven => {
                Box::new(LossDriven::new(exp.topo.num_gateways(), exp.cfg.seed ^ 0xbb22))
            }
            SchedulerSpec::DelayDriven => Box::new(DelayDriven),
        })
    }
}

impl FromStr for SchedulerSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "ddsra" => SchedulerSpec::ddsra(),
            "participation" => SchedulerSpec::Participation,
            "random" => SchedulerSpec::Random,
            "round_robin" => SchedulerSpec::RoundRobin,
            "loss_driven" => SchedulerSpec::LossDriven,
            "delay_driven" => SchedulerSpec::DelayDriven,
            other => {
                // Round-trip the labels too: "ddsra_v1000" parses back.
                if let Some(v) = other.strip_prefix("ddsra_v") {
                    let v: f64 =
                        v.parse().map_err(|e| anyhow::anyhow!("bad DDSRA V in {other:?}: {e}"))?;
                    return Ok(SchedulerSpec::ddsra_with_v(v));
                }
                anyhow::bail!(
                    "unknown scheme {other:?} (expected one of: {})",
                    SchedulerSpec::NAMES.join(", ")
                )
            }
        })
    }
}

// ---------------------------------------------------------------- session

/// Builder for a [`Session`] — every run knob is a typed method, and
/// cross-knob constraints are validated once in [`build`](Self::build)
/// instead of silently misbehaving mid-run.
#[derive(Clone, Debug)]
pub struct SessionBuilder {
    cfg: SimConfig,
    artifacts: PathBuf,
    rounds: Option<usize>,
    eval_every: usize,
    divergence: bool,
    train: bool,
    until_accuracy: Option<f64>,
    max_sim_delay: Option<f64>,
}

impl SessionBuilder {
    /// Communication rounds T (default: `cfg.rounds`).
    pub fn rounds(mut self, n: usize) -> Self {
        self.rounds = Some(n);
        self
    }

    /// Evaluate on the test set every `n` rounds (0 = never; the planned
    /// final round always evaluates when training). Default 5.
    pub fn eval_every(mut self, n: usize) -> Self {
        self.eval_every = n;
        self
    }

    /// Track the Fig. 2 divergence `‖ŵ_m − v^{K,t}‖` every round (all
    /// devices train for measurement; implies training).
    pub fn divergence(mut self) -> Self {
        self.divergence = true;
        self
    }

    /// Scheduling/delay simulation only — no backend training (the
    /// Theorem-2 sweeps and scheduler benches).
    pub fn schedule_only(mut self) -> Self {
        self.train = false;
        self
    }

    /// Stop as soon as an eval round reports test accuracy ≥ `target` —
    /// run-to-target, the paper's Fig. 4–6 convergence-time metric.
    /// Requires training and a nonzero eval cadence.
    pub fn until_accuracy(mut self, target: f64) -> Self {
        self.until_accuracy = Some(target);
        self
    }

    /// Stop once the simulated FL wall-clock Σ τ(t) (cumulative round
    /// delay, seconds) reaches `budget_s` — compare schedulers by what
    /// they learn within a fixed latency budget.
    pub fn max_rounds_wall(mut self, budget_s: f64) -> Self {
        self.max_sim_delay = Some(budget_s);
        self
    }

    /// Directory with compiled PJRT artifacts (default `artifacts/`;
    /// only consulted by the `pjrt` feature).
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts = dir.into();
        self
    }

    /// Validate the knobs and build the experiment (topology, channels,
    /// data, execution backend).
    pub fn build(self) -> Result<Session> {
        anyhow::ensure!(
            self.train || !self.divergence,
            "divergence tracking trains every device — it cannot be combined with schedule_only()"
        );
        if let Some(target) = self.until_accuracy {
            anyhow::ensure!(
                (0.0..=1.0).contains(&target),
                "until_accuracy target {target} outside [0, 1]"
            );
            anyhow::ensure!(
                self.train && self.eval_every > 0,
                "until_accuracy needs training and eval_every > 0 to observe accuracy"
            );
        }
        if let Some(budget) = self.max_sim_delay {
            anyhow::ensure!(budget > 0.0, "max_rounds_wall budget must be positive");
        }
        if let Some(r) = self.rounds {
            anyhow::ensure!(r > 0, "a session needs at least one round");
        }
        let exp = Experiment::with_artifacts(self.cfg, &self.artifacts)?;
        let rounds = self.rounds.unwrap_or(exp.cfg.rounds);
        anyhow::ensure!(rounds > 0, "a session needs at least one round");
        Ok(Session {
            exp,
            opts: RunOpts {
                rounds,
                eval_every: self.eval_every,
                track_divergence: self.divergence,
                train: self.train,
                until_accuracy: self.until_accuracy,
                max_sim_delay: self.max_sim_delay,
            },
            gamma: OnceLock::new(),
        })
    }
}

/// One paired-comparison entry from [`Session::run_paired`].
#[derive(Clone, Debug)]
pub struct PairedRun {
    /// [`SchedulerSpec::label`] of the scheduler that produced the log.
    pub label: String,
    pub log: RunLog,
    /// Wall-clock seconds spent executing the run (scheduler
    /// construction and Γ estimation excluded — they are shared).
    pub wall_secs: f64,
}

/// A built experiment plus validated run options; the entry point for
/// every runner in the repo (CLI, benches, examples, tests).
pub struct Session {
    exp: Experiment,
    opts: RunOpts,
    /// Γ_m participation rates, estimated at most once per session and
    /// shared by every DDSRA-family scheduler (§IV gradient probes are
    /// the expensive part).
    gamma: OnceLock<Vec<f64>>,
}

impl Session {
    pub fn builder(cfg: SimConfig) -> SessionBuilder {
        SessionBuilder {
            cfg,
            artifacts: PathBuf::from("artifacts"),
            rounds: None,
            eval_every: 5,
            divergence: false,
            train: true,
            until_accuracy: None,
            max_sim_delay: None,
        }
    }

    /// The underlying experiment (topology, shards, channel model, ...).
    pub fn experiment(&self) -> &Experiment {
        &self.exp
    }

    pub fn config(&self) -> &SimConfig {
        &self.exp.cfg
    }

    pub fn opts(&self) -> &RunOpts {
        &self.opts
    }

    /// The Γ_m participation rates (Eq. 13), estimated from §IV gradient
    /// probes on first use and cached for the session's lifetime.
    pub fn gamma(&self) -> Result<&[f64]> {
        if self.gamma.get().is_none() {
            let g = self.exp.derive_gamma()?;
            let _ = self.gamma.set(g);
        }
        Ok(self.gamma.get().expect("gamma cache populated above"))
    }

    /// Instantiate a scheduler, sharing the session's cached Γ_m.
    pub fn scheduler(&self, spec: &SchedulerSpec) -> Result<Box<dyn Scheduler>> {
        let gamma = if spec.needs_gamma() { Some(self.gamma()?) } else { None };
        spec.build(&self.exp, gamma)
    }

    /// Run one scheduler to completion, buffering records through a
    /// [`crate::metrics::MemorySink`] into the back-compat [`RunLog`].
    pub fn run(&self, spec: &SchedulerSpec) -> Result<RunLog> {
        let mut sched = self.scheduler(spec)?;
        self.run_scheduler(sched.as_mut())
    }

    /// Streaming variant: records flow to `observers` as they are
    /// produced; nothing is buffered unless an observer buffers.
    pub fn run_with(
        &self,
        spec: &SchedulerSpec,
        observers: &mut [&mut dyn RoundObserver],
    ) -> Result<RunSummary> {
        let mut sched = self.scheduler(spec)?;
        self.run_scheduler_with(sched.as_mut(), observers)
    }

    /// Run a caller-constructed scheduler instance (custom V sweeps,
    /// schedulers not in the spec menu) into a [`RunLog`].
    pub fn run_scheduler(&self, sched: &mut dyn Scheduler) -> Result<RunLog> {
        RoundEngine::new(&self.exp).run_logged(sched, &self.opts)
    }

    /// Streaming variant of [`run_scheduler`](Self::run_scheduler).
    pub fn run_scheduler_with(
        &self,
        sched: &mut dyn Scheduler,
        observers: &mut [&mut dyn RoundObserver],
    ) -> Result<RunSummary> {
        RoundEngine::new(&self.exp).run(sched, &self.opts, observers)
    }

    /// The paper's paired-comparison experiment as one call: k
    /// schedulers over ONE experiment, so every run faces byte-identical
    /// channel/energy streams (they depend only on `(seed, round)`) and
    /// the DDSRA family shares one Γ estimation. Returns one
    /// [`PairedRun`] per spec, in order.
    pub fn run_paired(&self, specs: &[SchedulerSpec]) -> Result<Vec<PairedRun>> {
        let mut out = Vec::with_capacity(specs.len());
        for spec in specs {
            let mut sched = self.scheduler(spec)?;
            let t0 = Instant::now();
            let log = self.run_scheduler(sched.as_mut())?;
            out.push(PairedRun {
                label: spec.label(),
                log,
                wall_secs: t0.elapsed().as_secs_f64(),
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheduler_spec_parses_every_cli_name() {
        for &name in SchedulerSpec::NAMES {
            let spec: SchedulerSpec = name.parse().unwrap();
            assert_eq!(spec.label(), name);
        }
        assert_eq!("ddsra".parse::<SchedulerSpec>().unwrap(), SchedulerSpec::ddsra());
        assert_eq!(
            "ddsra_v1000".parse::<SchedulerSpec>().unwrap(),
            SchedulerSpec::ddsra_with_v(1000.0)
        );
        assert_eq!(SchedulerSpec::ddsra_with_v(0.01).label(), "ddsra_v0.01");
        let err = "dsdra".parse::<SchedulerSpec>().unwrap_err().to_string();
        assert!(err.contains("ddsra"), "{err}");
        assert!("ddsra_vfast".parse::<SchedulerSpec>().is_err());
    }

    #[test]
    fn builder_rejects_contradictory_knobs() {
        let base = || Session::builder(SimConfig::default());
        assert!(base().schedule_only().divergence().build().is_err());
        assert!(base().eval_every(0).until_accuracy(0.5).build().is_err());
        assert!(base().until_accuracy(1.5).build().is_err());
        assert!(base().max_rounds_wall(0.0).build().is_err());
        assert!(base().rounds(0).build().is_err());
    }

    #[test]
    fn stop_cause_reports_round_and_kind() {
        let s = StopCause::TargetAccuracy { round: 7, accuracy: 0.5 };
        assert_eq!((s.round(), s.kind()), (7, "target_accuracy"));
        let s = StopCause::DelayBudget { round: 3, cum_delay: 10.0 };
        assert_eq!((s.round(), s.kind()), (3, "delay_budget"));
        let s = StopCause::Observer { round: 0 };
        assert_eq!((s.round(), s.kind()), (0, "observer"));
    }
}
