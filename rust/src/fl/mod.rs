//! Federated learning core: FedAvg aggregation (streaming accumulators in
//! [`vecmath`], tier folds in [`hierarchy`]), the §IV device-specific
//! participation-rate machinery, the experiment orchestrator, the parallel
//! streaming [`round`] engine that executes the communication rounds, and
//! the [`session`] API — typed run builder, scheduler specs, and the
//! observer/sink layer — that everything (CLI, benches, examples, tests)
//! drives runs through.

pub mod fault;
pub mod hierarchy;
pub mod orchestrator;
pub mod participation;
pub mod round;
pub mod session;
pub mod vecmath;

pub use fault::{FaultPlan, RoundFaults};
pub use hierarchy::{AggFold, HierFold};
pub use orchestrator::{Experiment, GatewayMask, RoundRecord, RunLog};
pub use participation::{gamma_rates, phi_m, GradStats};
pub use round::RoundEngine;
pub use session::{
    PairedRun, RoundObserver, RunMeta, RunOpts, RunSummary, SchedulerSpec, Session,
    SessionBuilder, StopCause,
};
