//! Federated learning core: FedAvg aggregation (streaming accumulators in
//! [`vecmath`]), the §IV device-specific participation-rate machinery, the
//! experiment orchestrator that ties scheduling, simulation and backend
//! execution together, and the parallel streaming [`round`] engine that
//! executes the communication rounds.

pub mod orchestrator;
pub mod participation;
pub mod round;
pub mod vecmath;

pub use orchestrator::{Experiment, RoundRecord, RunLog, RunOpts};
pub use participation::{gamma_rates, phi_m, GradStats};
pub use round::RoundEngine;
