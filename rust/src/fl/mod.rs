//! Federated learning core: FedAvg aggregation, the §IV device-specific
//! participation-rate machinery, and the round-loop orchestrator that ties
//! scheduling, simulation and backend execution together.

pub mod orchestrator;
pub mod participation;
pub mod vecmath;

pub use orchestrator::{Experiment, RoundRecord, RunLog, RunOpts};
pub use participation::{gamma_rates, phi_m, GradStats};
