//! Device-specific participation rate (§IV).
//!
//! Theorem 1 bounds the divergence between a shop floor's aggregated model
//! and the centralized-GD trajectory:
//!
//!   Φ_m = Σ_n  (a_{m,n} D̃_n / Σ a D̃) · (σ_n/(L_n √D̃_n) + δ_n/L_n)
//!         · ((β L_n + 1)^K − 1)                                 (Eq. 12)
//!
//! and Eq. 13 turns the Φ's into rates: Γ_m = min(J · (1/Φ_m)/Σ(1/Φ), 1).
//! Gateways whose devices' data better represent the global distribution
//! (small σ_n, δ_n) get larger Γ_m — they join more rounds.

use crate::topo::Topology;

/// Per-device gradient statistics estimated from the running model
/// (Assumptions 1–2 made measurable; see
/// `fl::Experiment::estimate_grad_stats` for the probing estimators).
#[derive(Clone, Debug)]
pub struct GradStats {
    /// σ_n: per-sample gradient variance bound (§IV Assumption 1,
    /// E‖∇F̃_n − ∇F_n‖ ≤ σ_n/√D̃_n).
    pub sigma: Vec<f64>,
    /// δ_n: local-vs-global gradient divergence (§IV Assumption 2,
    /// ‖∇F_n − ∇F‖ ≤ δ_n).
    pub delta: Vec<f64>,
    /// L_n: smoothness (Lipschitz-gradient) estimate of F_n (§IV).
    pub lsmooth: Vec<f64>,
}

/// Φ_m — the Theorem 1 divergence bound between shop floor m's aggregated
/// model and the centralized-GD trajectory after K local iterations
/// (Eq. 12) — for gateway m.
pub fn phi_m(
    topo: &Topology,
    m: usize,
    stats: &GradStats,
    beta: f64,
    local_iters: usize,
) -> f64 {
    let gw = &topo.gateways[m];
    let total_batch: f64 = gw
        .members
        .iter()
        .map(|&n| topo.devices[n].train_batch as f64)
        .sum();
    gw.members
        .iter()
        .map(|&n| {
            let dn = topo.devices[n].train_batch as f64;
            let ln = stats.lsmooth[n].max(1e-9);
            let growth = (beta * ln + 1.0).powi(local_iters as i32) - 1.0;
            (dn / total_batch)
                * (stats.sigma[n] / (ln * dn.sqrt()) + stats.delta[n] / ln)
                * growth
        })
        .sum()
}

/// Γ_m for every gateway from divergence bounds `phis` — Eq. 13:
/// Γ_m = min(J · (1/Φ_m) / Σ_m'(1/Φ_m'), 1). Small Φ (representative
/// data) ⇒ large Γ (participate often); DDSRA's virtual queues (Eq. 14)
/// then enforce these rates in time average (C11).
///
/// ```
/// use iiot_fl::fl::participation::gamma_from_phi;
/// // The gateway with the smallest divergence bound gets the highest
/// // participation rate, and every rate is capped at 1.
/// let g = gamma_from_phi(&[0.5, 1.0, 2.0], 2);
/// assert!(g[0] > g[1] && g[1] > g[2]);
/// assert!(g.iter().all(|&x| (0.0..=1.0).contains(&x)));
/// ```
pub fn gamma_from_phi(phis: &[f64], num_channels: usize) -> Vec<f64> {
    let inv: Vec<f64> = phis.iter().map(|&p| 1.0 / p.max(1e-30)).collect();
    let total: f64 = inv.iter().sum();
    inv.iter()
        .map(|&i| (num_channels as f64 * i / total).min(1.0))
        .collect()
}

/// Convenience: Φ then Γ for all gateways.
pub fn gamma_rates(
    topo: &Topology,
    stats: &GradStats,
    num_channels: usize,
    beta: f64,
    local_iters: usize,
) -> (Vec<f64>, Vec<f64>) {
    let phis: Vec<f64> = (0..topo.num_gateways())
        .map(|m| phi_m(topo, m, stats, beta, local_iters))
        .collect();
    let gammas = gamma_from_phi(&phis, num_channels);
    (phis, gammas)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::rng::Rng;
    use crate::topo::Topology;

    fn topo() -> Topology {
        Topology::generate(&SimConfig::default(), &mut Rng::new(1))
    }

    fn uniform_stats(n: usize, sigma: f64, delta: f64) -> GradStats {
        GradStats {
            sigma: vec![sigma; n],
            delta: vec![delta; n],
            lsmooth: vec![1.0; n],
        }
    }

    #[test]
    fn equal_stats_give_equal_gamma() {
        let t = topo();
        let s = uniform_stats(12, 1.0, 1.0);
        let (_, g) = gamma_rates(&t, &s, 3, 0.01, 5);
        // batch sizes differ per device, so rates are only approximately
        // equal — but all must lie in (0, 1] and sum <= J (before clipping,
        // exactly J).
        assert!(g.iter().all(|&x| x > 0.0 && x <= 1.0));
        let sum: f64 = g.iter().sum();
        assert!(sum <= 3.0 + 1e-9);
    }

    #[test]
    fn better_distribution_gets_higher_rate() {
        let t = topo();
        let mut s = uniform_stats(12, 1.0, 1.0);
        // gateway 0's devices have much lower divergence
        for &n in &t.gateways[0].members {
            s.delta[n] = 0.05;
            s.sigma[n] = 0.05;
        }
        let (phis, g) = gamma_rates(&t, &s, 3, 0.01, 5);
        for m in 1..6 {
            assert!(phis[0] < phis[m]);
            assert!(g[0] >= g[m]);
        }
    }

    #[test]
    fn gamma_clipped_at_one() {
        // One overwhelmingly good gateway must still have Γ <= 1.
        let g = gamma_from_phi(&[1e-6, 1.0, 1.0, 1.0, 1.0, 1.0], 3);
        assert!(g[0] <= 1.0);
        assert!(g.iter().all(|&x| (0.0..=1.0).contains(&x)));
    }

    #[test]
    fn phi_grows_with_local_epochs() {
        // Theorem 1: divergence increases with K.
        let t = topo();
        let s = uniform_stats(12, 1.0, 1.0);
        let p1 = phi_m(&t, 0, &s, 0.01, 1);
        let p5 = phi_m(&t, 0, &s, 0.01, 5);
        let p20 = phi_m(&t, 0, &s, 0.01, 20);
        assert!(p1 < p5 && p5 < p20);
    }

    #[test]
    fn phi_shrinks_with_larger_training_batch() {
        // Theorem 1: larger D̃_n ⇒ smaller divergence (σ term only).
        let t = topo();
        let s = GradStats {
            sigma: vec![1.0; 12],
            delta: vec![0.0; 12],
            lsmooth: vec![1.0; 12],
        };
        // scale batch sizes up by cloning topo with bigger sample ratio
        let mut cfg = SimConfig::default();
        cfg.sample_ratio = 0.5;
        let t_big = Topology::generate(&cfg, &mut Rng::new(1));
        let small = phi_m(&t, 0, &s, 0.01, 5);
        let big = phi_m(&t_big, 0, &s, 0.01, 5);
        assert!(big < small, "big {big} small {small}");
    }
}
