//! Flat-vector arithmetic over model parameters (`Params`): FedAvg,
//! divergence norms, and manual SGD steps for the centralized-GD shadow
//! run all reduce to these primitives.

use crate::runtime::Params;

/// ||a - b||_2 across all tensors.
pub fn l2_diff(a: &Params, b: &Params) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (ta, tb) in a.iter().zip(b) {
        debug_assert_eq!(ta.len(), tb.len());
        for (&x, &y) in ta.iter().zip(tb) {
            let d = (x - y) as f64;
            acc += d * d;
        }
    }
    acc.sqrt()
}

/// ||a||_2 of a flat vector.
pub fn norm(a: &[f32]) -> f64 {
    a.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// ||a - b||_2 of flat vectors.
pub fn flat_l2_diff(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// Weighted average of parameter sets (FedAvg): Σ w_i p_i / Σ w_i.
pub fn weighted_average(sets: &[(&Params, f64)]) -> Params {
    assert!(!sets.is_empty(), "FedAvg over empty participant set");
    let total: f64 = sets.iter().map(|(_, w)| w).sum();
    assert!(total > 0.0, "FedAvg weights sum to zero");
    let proto = sets[0].0;
    let mut out: Params = proto.iter().map(|t| vec![0.0f32; t.len()]).collect();
    for (params, w) in sets {
        let scale = (w / total) as f32;
        for (o, t) in out.iter_mut().zip(params.iter()) {
            for (ov, &tv) in o.iter_mut().zip(t) {
                *ov += scale * tv;
            }
        }
    }
    out
}

/// In-place SGD step on params from a flat gradient: p -= lr * g.
pub fn sgd_step_flat(params: &mut Params, flat_grad: &[f32], lr: f32) {
    let mut off = 0;
    for t in params.iter_mut() {
        for v in t.iter_mut() {
            *v -= lr * flat_grad[off];
            off += 1;
        }
    }
    debug_assert_eq!(off, flat_grad.len());
}

/// Element-wise mean of flat vectors.
pub fn mean_flat(vs: &[Vec<f32>]) -> Vec<f32> {
    assert!(!vs.is_empty());
    let mut out = vec![0.0f32; vs[0].len()];
    let scale = 1.0 / vs.len() as f32;
    for v in vs {
        for (o, &x) in out.iter_mut().zip(v) {
            *o += scale * x;
        }
    }
    out
}

/// Weighted mean of flat vectors.
pub fn weighted_mean_flat(vs: &[(&[f32], f64)]) -> Vec<f32> {
    assert!(!vs.is_empty());
    let total: f64 = vs.iter().map(|(_, w)| w).sum();
    let mut out = vec![0.0f32; vs[0].0.len()];
    for (v, w) in vs {
        let s = (w / total) as f32;
        for (o, &x) in out.iter_mut().zip(v.iter()) {
            *o += s * x;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(vals: &[&[f32]]) -> Params {
        vals.iter().map(|v| v.to_vec()).collect()
    }

    #[test]
    fn l2_diff_basic() {
        let a = p(&[&[0.0, 3.0], &[4.0]]);
        let b = p(&[&[0.0, 0.0], &[0.0]]);
        assert!((l2_diff(&a, &b) - 5.0).abs() < 1e-12);
        assert_eq!(l2_diff(&a, &a), 0.0);
    }

    #[test]
    fn fedavg_weighted() {
        let a = p(&[&[0.0]]);
        let b = p(&[&[10.0]]);
        let avg = weighted_average(&[(&a, 1.0), (&b, 3.0)]);
        assert!((avg[0][0] - 7.5).abs() < 1e-6);
    }

    #[test]
    fn fedavg_identity_single() {
        let a = p(&[&[1.0, 2.0], &[3.0]]);
        let avg = weighted_average(&[(&a, 5.0)]);
        assert_eq!(avg, a);
    }

    #[test]
    fn fedavg_preserves_convex_hull() {
        let a = p(&[&[1.0]]);
        let b = p(&[&[2.0]]);
        let c = p(&[&[3.0]]);
        let avg = weighted_average(&[(&a, 1.0), (&b, 1.0), (&c, 1.0)]);
        assert!(avg[0][0] >= 1.0 && avg[0][0] <= 3.0);
        assert!((avg[0][0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn sgd_step() {
        let mut params = p(&[&[1.0, 1.0], &[1.0]]);
        sgd_step_flat(&mut params, &[1.0, 2.0, 3.0], 0.1);
        assert!((params[0][0] - 0.9).abs() < 1e-6);
        assert!((params[0][1] - 0.8).abs() < 1e-6);
        assert!((params[1][0] - 0.7).abs() < 1e-6);
    }

    #[test]
    fn means() {
        let m = mean_flat(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m, vec![2.0, 3.0]);
        let wm = weighted_mean_flat(&[(&[0.0][..], 1.0), (&[4.0][..], 3.0)]);
        assert!((wm[0] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn norms() {
        assert!((norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert!((flat_l2_diff(&[1.0, 1.0], &[1.0, 2.0]) - 1.0).abs() < 1e-12);
    }
}
