//! Flat-vector arithmetic over model parameters (`Params`): FedAvg,
//! divergence norms, and manual SGD steps for the centralized-GD shadow
//! run all reduce to these primitives.
//!
//! The streaming accumulators ([`WeightedAccum`], [`FlatWeightedAccum`])
//! are the round engine's O(1)-copy aggregation substrate: updates fold
//! in one at a time and are dropped immediately, so FedAvg over N devices
//! holds ONE parameter-shaped buffer instead of N. The batch helpers
//! ([`weighted_average`], [`weighted_mean_flat`]) are thin folds through
//! the same accumulators, which pins the two paths to each other
//! bit-for-bit by construction (and by test).

use crate::runtime::Params;

/// ||a - b||_2 across all tensors.
pub fn l2_diff(a: &Params, b: &Params) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (ta, tb) in a.iter().zip(b) {
        debug_assert_eq!(ta.len(), tb.len());
        for (&x, &y) in ta.iter().zip(tb) {
            let d = (x - y) as f64;
            acc += d * d;
        }
    }
    acc.sqrt()
}

/// ||a||_2 of a flat vector.
pub fn norm(a: &[f32]) -> f64 {
    a.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// ||a - b||_2 of flat vectors.
pub fn flat_l2_diff(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// Streaming FedAvg accumulator: Σ w_i·p_i (held in f64 so thousands of
/// devices accumulate without f32 cancellation) plus Σ w_i — ONE
/// parameter-shaped buffer no matter how many updates stream through.
/// The FP result depends only on the SEQUENCE of [`WeightedAccum::add`]
/// calls, never on wall-clock interleaving: fold in a fixed order
/// (the round engine uses device order) and the aggregate bytes are
/// independent of the thread count.
#[derive(Clone, Debug, Default)]
pub struct WeightedAccum {
    /// Σ w_i·p_i per tensor; allocated lazily on the first `add`.
    sum: Option<Vec<Vec<f64>>>,
    total: f64,
    count: usize,
}

impl WeightedAccum {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of updates folded in so far.
    pub fn count(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Σ w_i so far.
    pub fn total_weight(&self) -> f64 {
        self.total
    }

    /// Fold one weighted parameter set in. Panics when the tensor layout
    /// differs from the first update's (mixed-model aggregation is a bug).
    pub fn add(&mut self, p: &Params, w: f64) {
        assert!(w.is_finite() && w >= 0.0, "bad FedAvg weight {w}");
        match &mut self.sum {
            None => {
                let scaled: Vec<Vec<f64>> =
                    p.iter().map(|t| t.iter().map(|&v| v as f64 * w).collect()).collect();
                self.sum = Some(scaled);
            }
            Some(sum) => {
                assert_eq!(sum.len(), p.len(), "FedAvg tensor count changed mid-stream");
                for (st, pt) in sum.iter_mut().zip(p) {
                    assert_eq!(st.len(), pt.len(), "FedAvg tensor shape changed mid-stream");
                    for (sv, &pv) in st.iter_mut().zip(pt) {
                        *sv += pv as f64 * w;
                    }
                }
            }
        }
        self.total += w;
        self.count += 1;
    }

    /// Fold another accumulator in: Σ-sums add element-wise, weights and
    /// counts add. This is the tier-merge primitive of the hierarchical
    /// aggregation path (`fl::hierarchy`): a gateway folds its members
    /// through its own accumulator, then only the summary moves up via
    /// `merge`. Merging partial accumulators in a fixed order is as
    /// deterministic as streaming `add` calls in a fixed order — the
    /// result depends only on the merge sequence. Panics when both sides
    /// are non-empty with different tensor layouts.
    pub fn merge(&mut self, other: Self) {
        match (&mut self.sum, other.sum) {
            (_, None) => {}
            (None, Some(osum)) => self.sum = Some(osum),
            (Some(sum), Some(osum)) => {
                assert_eq!(sum.len(), osum.len(), "FedAvg tensor count differs across tiers");
                for (st, ot) in sum.iter_mut().zip(osum) {
                    assert_eq!(st.len(), ot.len(), "FedAvg tensor shape differs across tiers");
                    for (sv, ov) in st.iter_mut().zip(ot) {
                        *sv += ov;
                    }
                }
            }
        }
        self.total += other.total;
        self.count += other.count;
    }

    /// Σ w_i·p_i / Σ w_i. `None` when nothing was folded in; panics when
    /// the folded weights sum to zero (FedAvg is undefined there).
    pub fn finish(self) -> Option<Params> {
        let sum = self.sum?;
        assert!(self.total > 0.0, "FedAvg weights sum to zero");
        let inv = 1.0 / self.total;
        let mut out: Params = Vec::with_capacity(sum.len());
        for t in sum {
            out.push(t.into_iter().map(|v| (v * inv) as f32).collect());
        }
        Some(out)
    }
}

/// Streaming weighted mean over FLAT f32 vectors — the gradient-space
/// analogue of [`WeightedAccum`], used by the §IV probes and the
/// centralized-GD shadow so no O(N) gradient buffer ever exists.
#[derive(Clone, Debug, Default)]
pub struct FlatWeightedAccum {
    sum: Option<Vec<f64>>,
    total: f64,
    count: usize,
}

impl FlatWeightedAccum {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn count(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn total_weight(&self) -> f64 {
        self.total
    }

    /// Fold one weighted flat vector in.
    pub fn add(&mut self, v: &[f32], w: f64) {
        assert!(w.is_finite() && w >= 0.0, "bad weight {w}");
        match &mut self.sum {
            None => self.sum = Some(v.iter().map(|&x| x as f64 * w).collect()),
            Some(sum) => {
                assert_eq!(sum.len(), v.len(), "flat vector length changed mid-stream");
                for (s, &x) in sum.iter_mut().zip(v) {
                    *s += x as f64 * w;
                }
            }
        }
        self.total += w;
        self.count += 1;
    }

    /// Fold another accumulator in — the flat-vector analogue of
    /// [`WeightedAccum::merge`]. Panics when both sides are non-empty
    /// with different lengths.
    pub fn merge(&mut self, other: Self) {
        match (&mut self.sum, other.sum) {
            (_, None) => {}
            (None, Some(osum)) => self.sum = Some(osum),
            (Some(sum), Some(osum)) => {
                assert_eq!(sum.len(), osum.len(), "flat vector length differs across merges");
                for (s, o) in sum.iter_mut().zip(osum) {
                    *s += o;
                }
            }
        }
        self.total += other.total;
        self.count += other.count;
    }

    /// Σ w_i·v_i / Σ w_i; `None` when nothing was folded in.
    pub fn finish(self) -> Option<Vec<f32>> {
        let sum = self.sum?;
        assert!(self.total > 0.0, "weights sum to zero");
        let inv = 1.0 / self.total;
        Some(sum.into_iter().map(|v| (v * inv) as f32).collect())
    }
}

/// Weighted average of parameter sets (FedAvg): Σ w_i p_i / Σ w_i.
/// A fold through [`WeightedAccum`], so the batch helper and streaming
/// aggregation are bit-identical on the same inputs in the same order.
pub fn weighted_average(sets: &[(&Params, f64)]) -> Params {
    assert!(!sets.is_empty(), "FedAvg over empty participant set");
    let mut acc = WeightedAccum::new();
    for (p, w) in sets {
        acc.add(p, *w);
    }
    acc.finish().expect("non-empty FedAvg")
}

/// In-place SGD step on params from a flat gradient: p -= lr * g.
pub fn sgd_step_flat(params: &mut Params, flat_grad: &[f32], lr: f32) {
    let mut off = 0;
    for t in params.iter_mut() {
        for v in t.iter_mut() {
            *v -= lr * flat_grad[off];
            off += 1;
        }
    }
    debug_assert_eq!(off, flat_grad.len());
}

/// Element-wise mean of flat vectors.
pub fn mean_flat(vs: &[Vec<f32>]) -> Vec<f32> {
    assert!(!vs.is_empty());
    let mut out = vec![0.0f32; vs[0].len()];
    let scale = 1.0 / vs.len() as f32;
    for v in vs {
        for (o, &x) in out.iter_mut().zip(v) {
            *o += scale * x;
        }
    }
    out
}

/// Weighted mean of flat vectors — a fold through [`FlatWeightedAccum`].
pub fn weighted_mean_flat(vs: &[(&[f32], f64)]) -> Vec<f32> {
    assert!(!vs.is_empty());
    let mut acc = FlatWeightedAccum::new();
    for (v, w) in vs {
        acc.add(v, *w);
    }
    acc.finish().expect("non-empty weighted mean")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(vals: &[&[f32]]) -> Params {
        vals.iter().map(|v| v.to_vec()).collect()
    }

    #[test]
    fn l2_diff_basic() {
        let a = p(&[&[0.0, 3.0], &[4.0]]);
        let b = p(&[&[0.0, 0.0], &[0.0]]);
        assert!((l2_diff(&a, &b) - 5.0).abs() < 1e-12);
        assert_eq!(l2_diff(&a, &a), 0.0);
    }

    #[test]
    fn fedavg_weighted() {
        let a = p(&[&[0.0]]);
        let b = p(&[&[10.0]]);
        let avg = weighted_average(&[(&a, 1.0), (&b, 3.0)]);
        assert!((avg[0][0] - 7.5).abs() < 1e-6);
    }

    #[test]
    fn fedavg_identity_single() {
        let a = p(&[&[1.0, 2.0], &[3.0]]);
        let avg = weighted_average(&[(&a, 5.0)]);
        assert_eq!(avg, a);
    }

    #[test]
    fn fedavg_preserves_convex_hull() {
        let a = p(&[&[1.0]]);
        let b = p(&[&[2.0]]);
        let c = p(&[&[3.0]]);
        let avg = weighted_average(&[(&a, 1.0), (&b, 1.0), (&c, 1.0)]);
        assert!(avg[0][0] >= 1.0 && avg[0][0] <= 3.0);
        assert!((avg[0][0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn sgd_step() {
        let mut params = p(&[&[1.0, 1.0], &[1.0]]);
        sgd_step_flat(&mut params, &[1.0, 2.0, 3.0], 0.1);
        assert!((params[0][0] - 0.9).abs() < 1e-6);
        assert!((params[0][1] - 0.8).abs() < 1e-6);
        assert!((params[1][0] - 0.7).abs() < 1e-6);
    }

    #[test]
    fn means() {
        let m = mean_flat(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m, vec![2.0, 3.0]);
        let wm = weighted_mean_flat(&[(&[0.0][..], 1.0), (&[4.0][..], 3.0)]);
        assert!((wm[0] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn norms() {
        assert!((norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert!((flat_l2_diff(&[1.0, 1.0], &[1.0, 2.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_accum_streams_to_the_batch_average_bitwise() {
        let sets = [
            (p(&[&[1.0, -2.0], &[0.5]]), 2.0),
            (p(&[&[3.0, 0.25], &[-1.5]]), 5.0),
            (p(&[&[-0.75, 4.0], &[2.0]]), 0.5),
        ];
        let refs: Vec<(&Params, f64)> = sets.iter().map(|(p, w)| (p, *w)).collect();
        let batch = weighted_average(&refs);
        let mut acc = WeightedAccum::new();
        for (params, w) in &sets {
            acc.add(params, *w);
        }
        assert_eq!(acc.count(), 3);
        assert!((acc.total_weight() - 7.5).abs() < 1e-12);
        let streamed = acc.finish().unwrap();
        for (tb, ts) in batch.iter().zip(&streamed) {
            for (vb, vs) in tb.iter().zip(ts) {
                assert_eq!(vb.to_bits(), vs.to_bits());
            }
        }
    }

    #[test]
    fn weighted_accum_empty_and_shape_guards() {
        assert!(WeightedAccum::new().finish().is_none());
        assert!(WeightedAccum::new().is_empty());
        let mut acc = WeightedAccum::new();
        acc.add(&p(&[&[1.0, 2.0]]), 1.0);
        let bad = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            acc.add(&p(&[&[1.0, 2.0, 3.0]]), 1.0);
        }));
        assert!(bad.is_err(), "shape change mid-stream must panic");
    }

    #[test]
    fn merge_of_ordered_partials_matches_single_fold_bitwise() {
        // Dyadic values and small integer weights keep every product and
        // partial sum exactly representable in f64, so the split fold and
        // the single fold compute the same exact sum regardless of
        // association — byte equality is deterministic here.
        let sets = [
            (p(&[&[1.5, -2.25], &[0.5]]), 2.0),
            (p(&[&[3.0, 0.25], &[-1.5]]), 5.0),
            (p(&[&[-0.75, 4.0], &[2.0]]), 3.0),
            (p(&[&[0.125, -8.0], &[1.25]]), 1.0),
        ];
        let mut single = WeightedAccum::new();
        for (params, w) in &sets {
            single.add(params, *w);
        }
        let mut lo = WeightedAccum::new();
        lo.add(&sets[0].0, sets[0].1);
        lo.add(&sets[1].0, sets[1].1);
        let mut hi = WeightedAccum::new();
        hi.add(&sets[2].0, sets[2].1);
        hi.add(&sets[3].0, sets[3].1);
        let mut merged = WeightedAccum::new();
        merged.merge(lo);
        merged.merge(hi);
        assert_eq!(merged.count(), single.count());
        assert_eq!(merged.total_weight().to_bits(), single.total_weight().to_bits());
        let (a, b) = (merged.finish().unwrap(), single.finish().unwrap());
        for (ta, tb) in a.iter().zip(&b) {
            for (va, vb) in ta.iter().zip(tb) {
                assert_eq!(va.to_bits(), vb.to_bits());
            }
        }
    }

    #[test]
    fn merge_empty_sides_are_identities() {
        let params = p(&[&[1.0, 2.0]]);
        let mut acc = WeightedAccum::new();
        acc.merge(WeightedAccum::new()); // empty + empty
        assert!(acc.is_empty());
        let mut filled = WeightedAccum::new();
        filled.add(&params, 3.0);
        acc.merge(filled); // empty + filled takes the partial wholesale
        assert_eq!(acc.count(), 1);
        acc.merge(WeightedAccum::new()); // filled + empty is a no-op
        assert_eq!(acc.count(), 1);
        assert_eq!(acc.finish().unwrap(), params);
    }

    #[test]
    fn merge_shape_guard_panics() {
        let mut a = WeightedAccum::new();
        a.add(&p(&[&[1.0, 2.0]]), 1.0);
        let mut b = WeightedAccum::new();
        b.add(&p(&[&[1.0, 2.0, 3.0]]), 1.0);
        let bad = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            a.merge(b);
        }));
        assert!(bad.is_err(), "cross-tier shape mismatch must panic");
    }

    #[test]
    fn flat_merge_matches_single_fold_bitwise() {
        let a = [0.5f32, -1.0, 2.0];
        let b = [4.0f32, 0.25, -3.0];
        let mut single = FlatWeightedAccum::new();
        single.add(&a, 2.0);
        single.add(&b, 3.0);
        let mut left = FlatWeightedAccum::new();
        left.add(&a, 2.0);
        let mut right = FlatWeightedAccum::new();
        right.add(&b, 3.0);
        left.merge(right);
        assert_eq!(left.count(), 2);
        let (x, y) = (left.finish().unwrap(), single.finish().unwrap());
        for (va, vb) in x.iter().zip(&y) {
            assert_eq!(va.to_bits(), vb.to_bits());
        }
    }

    #[test]
    fn flat_weighted_accum_matches_weighted_mean_flat() {
        let a = [0.5f32, -1.0, 2.0];
        let b = [4.0f32, 0.0, -3.0];
        let batch = weighted_mean_flat(&[(&a[..], 1.5), (&b[..], 3.5)]);
        let mut acc = FlatWeightedAccum::new();
        acc.add(&a, 1.5);
        acc.add(&b, 3.5);
        let streamed = acc.finish().unwrap();
        for (x, y) in batch.iter().zip(&streamed) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert!(FlatWeightedAccum::new().finish().is_none());
    }
}
