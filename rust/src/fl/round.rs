//! The parallel streaming round engine: one §III-A communication round
//! decomposed into explicit phases, executed with rayon device fan-out
//! and O(1)-copy streaming aggregation.
//!
//! ```text
//!   1 draw environment   block-fading channel state + EH energy arrivals
//!   2 schedule           the Scheduler picks J gateways + resources X(t)
//!   3 feasibility        C7–C10 — infeasible plans fail, train nothing
//!   4 local training     K local SGD steps per device, rayon fan-out
//!   5 aggregation        streaming weighted FedAvg — flat (one
//!                        WeightedAccum) or hierarchical tier folds
//!                        (fl::hierarchy), per `cfg.aggregation`
//!   6 evaluation         periodic IID test-set eval (full, or a
//!                        deterministic `eval_sample` subsample)
//! ```
//!
//! A [`FaultPlan`] (the `fault.*` config block, see `fl::fault`) injects
//! deterministic adversity at the phase seams: straggler delay
//! multipliers fold into the phase-2 round delay, gateway outages fail a
//! floor at phase 3, and mid-round device dropout removes a device from
//! the phase-4 fan-out — so a dropped device contributes nothing to the
//! FedAvg fold. Realized faults ride on `RoundRecord::faults`. A benign
//! plan draws nothing and leaves every byte unchanged.
//!
//! Wire-level runs (`transport = tcp`, see `net::transport`) map REAL
//! faults onto the same semantics: a device whose gateway connection is
//! refused, times out, or dies mid-round lands in
//! `RoundRecord::faults.dropped` and contributes nothing to the fold —
//! the run continues. Protocol/version skew still aborts.
//!
//! ## RNG stream map
//!
//! Every random draw comes from a stateless stream derived with
//! [`Rng::stream`]`(cfg.seed, &[DOMAIN, ...])` — no generator state is
//! shared between rounds, devices, or threads:
//!
//! | domain | key path | consumer |
//! |---|---|---|
//! | [`STREAM_CHANNEL`] | `[dom, round]` | block-fading channel state (phase 1) |
//! | [`STREAM_ENERGY`] | `[dom, round]` | EH energy arrivals (phase 1) |
//! | [`STREAM_TRAIN`] | `[dom, round, device]` | the device's K minibatch draws (phase 4) |
//! | [`STREAM_DIVERGENCE`] | `[dom, round, device]` | Fig. 2 all-device local training |
//! | [`STREAM_SHADOW`] | `[dom, round, iter, device]` | centralized-GD shadow minibatches |
//! | [`STREAM_PROBE`] | `[dom, device]` | §IV gradient-probe minibatches |
//! | [`STREAM_SMOOTH`] | `[dom, device]` | §IV L_n perturbation direction |
//! | [`STREAM_EVAL`] | `[dom, round]` | sampled-eval test subset (phase 6, only when `eval_sample` is armed) |
//! | [`STREAM_FAULT_STRAGGLER`] | `[dom, round, device]` | straggler delay multiplier (phase 2) |
//! | [`STREAM_FAULT_DROPOUT`] | `[dom, round, device]` | mid-round device dropout (phases 3-4) |
//! | [`STREAM_FAULT_OUTAGE`] | `[dom, round, gateway]` | whole-floor gateway outage (phase 3) |
//! | [`STREAM_FAULT_SHARD`] | `[dom, device]` | Dirichlet non-IID sharding (phase 0) |
//!
//! Because device n's round-t batch stream depends only on
//! `(seed, t, n)`, local training is **order-independent**: any worker
//! may train any device at any time and the realised batches are
//! identical. Combined with the fixed device-order aggregation fold,
//! round logs are byte-identical across thread counts (pinned by
//! `rust/tests/round_engine.rs`). Environment streams depend only on
//! `(seed, t)`, so different schedulers still face identical conditions —
//! the paper's paired-comparison property survives the refactor.
//!
//! Note (vs the PR 3 engine): the retired loop drew batches from ONE
//! sequential `sample_rng`, so every realisation depended on how many
//! draws every earlier device consumed. The stream keying above changes
//! those sequences once — same distributions, different realisations —
//! in exchange for order independence; `docs/ARCHITECTURE.md` §4 records
//! the trade.
//!
//! ## Streaming aggregation
//!
//! Phase 4 trains devices in *waves* of `wave_width()` units: each wave
//! fans out over rayon, and as results land they fold — in device order —
//! into a [`WeightedAccum`] and are dropped. Live parameter copies are
//! O(wave), never O(N); the fold order (and therefore every output byte)
//! does not depend on the wave width or the worker count.
//!
//! ## Observer delivery
//!
//! The engine buffers nothing itself: each finished round's
//! [`RoundRecord`] goes straight to the
//! [`RoundObserver`](crate::fl::RoundObserver)s (CSV/JSONL rows stream
//! DURING the run, progress heartbeats fire live, and only a
//! [`MemorySink`] buffers — rebuilding the classic [`RunLog`]). The
//! engine also owns every early-stop rule (target accuracy, simulated
//! delay budget, observer break) so callers never re-implement them;
//! see [`crate::fl::Session`] for the builder that assembles the knobs.

use std::ops::ControlFlow;

use anyhow::Result;
use rayon::prelude::*;

use crate::energy::EnergyArrivals;
use crate::fl::fault::{FaultPlan, RoundFaults};
// Fault-stream domains live with their consumer logic in `fl::fault`;
// re-exported here so the full stream map reads from one module.
pub use crate::fl::fault::{
    STREAM_FAULT_DROPOUT, STREAM_FAULT_OUTAGE, STREAM_FAULT_SHARD, STREAM_FAULT_STRAGGLER,
};
use crate::fl::hierarchy::AggFold;
use crate::fl::participation::GradStats;
use crate::fl::session::{RoundObserver, RunMeta, RunOpts, RunSummary, StopCause};
use crate::fl::vecmath::{self, FlatWeightedAccum, WeightedAccum};
use crate::metrics::MemorySink;
use crate::net::transport::{is_peer_lost, FoldSession};
use crate::net::ChannelState;
use crate::rng::Rng;
use crate::runtime::Params;
use crate::sched::{plan_cost, Decision, RoundCtx, RoundFeedback, Scheduler};
use crate::topo::Topology;

use super::orchestrator::{Experiment, GatewayMask, RoundRecord, RunLog};

/// Stream domain: per-round channel fading (phase 1).
pub const STREAM_CHANNEL: u64 = 0xC4A1;
/// Stream domain: per-round energy arrivals (phase 1).
pub const STREAM_ENERGY: u64 = 0xE9E1;
/// Stream domain: per-(round, device) training minibatches (phase 4).
pub const STREAM_TRAIN: u64 = 0x5A3C;
/// Stream domain: per-(round, device) Fig. 2 divergence training.
pub const STREAM_DIVERGENCE: u64 = 0xD1FE;
/// Stream domain: per-(round, iter, device) centralized-GD shadow batches.
pub const STREAM_SHADOW: u64 = 0x54AD;
/// Stream domain: per-device §IV gradient-probe batches.
pub const STREAM_PROBE: u64 = 0x9D0B;
/// Stream domain: per-device §IV smoothness-probe perturbation.
pub const STREAM_SMOOTH: u64 = 0x5100;
/// Stream domain: per-round sampled-evaluation test subset (phase 6).
/// Consulted ONLY when `cfg.eval_sample` is armed, so full-eval runs
/// draw nothing and keep their bytes.
pub const STREAM_EVAL: u64 = 0xE7A1;

/// Devices trained concurrently per streaming wave of phase 4: wide
/// enough to keep every rayon worker busy, narrow enough that only
/// O(wave) parameter copies are ever live. The aggregation fold walks
/// devices in order regardless of the wave width, so this knob never
/// changes the resulting bytes — only the memory/parallelism trade.
fn wave_width() -> usize {
    rayon::current_num_threads().saturating_mul(2).max(8)
}

/// One device's training assignment (phase-3 output, phase-4 input).
#[derive(Clone, Copy, Debug)]
struct TrainUnit {
    device: usize,
    gateway: usize,
    /// Scheduler-chosen partition point (split execution); None = fused.
    cut: Option<usize>,
}

/// Phase-4 output: the aggregate state of local training with every
/// model update already folded away. The fold is flat or hierarchical
/// per `cfg.aggregation`; the loss tallies are identical either way.
struct TrainOutcome {
    agg: RoundFold,
    floor_loss: Vec<f64>,
    floor_count: Vec<usize>,
    loss_sum: f64,
    loss_count: usize,
}

/// Where the phase-5 fold runs: in this process (flat or hierarchical
/// [`AggFold`]) or on the gateway service over the wire
/// ([`FoldSession`], `transport = tcp`). The wire fold drives the SAME
/// order-sensitive `WeightedAccum` the flat local fold uses — adds
/// arrive over one connection in device order — so tcp and inproc runs
/// stay byte-identical (config validation pins tcp to flat
/// aggregation).
enum RoundFold {
    Local(AggFold),
    Remote(FoldSession),
}

impl RoundFold {
    fn for_experiment(exp: &Experiment, gateways: usize) -> Self {
        match &exp.wire {
            Some(pool) => RoundFold::Remote(FoldSession::new(pool.clone())),
            None => RoundFold::Local(AggFold::for_config(exp.cfg.aggregation, gateways)),
        }
    }

    fn add(&mut self, gateway: usize, p: &Params, w: f64) -> Result<()> {
        match self {
            RoundFold::Local(acc) => {
                acc.add(gateway, p, w);
                Ok(())
            }
            RoundFold::Remote(session) => session.add(p, w),
        }
    }

    fn finish(self, topo: &Topology) -> Result<Option<Params>> {
        match self {
            RoundFold::Local(acc) => Ok(acc.finish(topo)),
            RoundFold::Remote(session) => session.finish(),
        }
    }
}

/// Executes communication rounds for one [`Experiment`].
pub struct RoundEngine<'a> {
    exp: &'a Experiment,
    /// Deterministic adversity consulted at the phase seams; built from
    /// the experiment's (validated) `fault.*` block, benign by default.
    fault: FaultPlan,
}

impl<'a> RoundEngine<'a> {
    pub fn new(exp: &'a Experiment) -> Self {
        RoundEngine { exp, fault: FaultPlan::from_config(&exp.cfg) }
    }

    /// Phase 1: draw the round's environment. Streams depend only on
    /// `(seed, round)`, so every scheduler faces identical conditions.
    fn draw_env(&self, t: usize) -> (ChannelState, EnergyArrivals) {
        let seed = self.exp.cfg.seed;
        let mut chan_rng = Rng::stream(seed, &[STREAM_CHANNEL, t as u64]);
        let mut energy_rng = Rng::stream(seed, &[STREAM_ENERGY, t as u64]);
        let state = self.exp.chan.draw(&mut chan_rng);
        let arrivals = EnergyArrivals::draw(&self.exp.cfg, &mut energy_rng);
        (state, arrivals)
    }

    /// Phase 2 fault seam: τ(t) with straggler episodes folded in. A
    /// straggler on gateway m's floor stretches that plan's Λ by its
    /// realized multiplier (the floor waits for its slowest device); the
    /// round delay stays the max over selected gateways. With the knob
    /// unarmed this IS `decision.round_delay()` — and when no episode
    /// fires, `λ · 1.0` is bit-exact, so the bytes cannot drift.
    fn round_delay_with_stragglers(
        &self,
        t: usize,
        decision: &Decision,
        faults: &mut Option<RoundFaults>,
    ) -> f64 {
        if !self.fault.has_stragglers() {
            return decision.round_delay();
        }
        let topo = &self.exp.topo;
        let mut delay = 0.0f64;
        for plan in &decision.plans {
            let mut slow = 1.0f64;
            for &n in &topo.gateways[plan.gateway].members {
                slow = slow.max(self.fault.straggler_multiplier(t, n));
            }
            if let Some(f) = faults.as_mut() {
                f.max_slowdown = f.max_slowdown.max(slow);
            }
            delay = delay.max(plan.lambda * slow);
        }
        delay
    }

    /// Phase 3: feasibility (C7–C10). Marks selected/failed gateways and
    /// expands the surviving plans into per-device training units. A plan
    /// that fails a constraint "fails to complete local model training"
    /// (§VII-C) and contributes no units. Fault seams: a whole-floor
    /// outage fails an otherwise-feasible gateway, and a mid-round device
    /// dropout withholds that device's unit — both recorded in `faults`,
    /// both excluded from the phase-4/5 fold entirely.
    fn feasibility(
        &self,
        t: usize,
        decision: &Decision,
        ctx: &RoundCtx,
        selected: &mut [bool],
        failed: &mut [bool],
        faults: &mut Option<RoundFaults>,
    ) -> Result<Vec<TrainUnit>> {
        let mut units = Vec::new();
        for plan in &decision.plans {
            let m = plan.gateway;
            selected[m] = true;
            if !plan_cost(ctx, plan).feasible() {
                failed[m] = true;
                continue;
            }
            if self.fault.gateway_out(t, m) {
                failed[m] = true;
                if let Some(f) = faults.as_mut() {
                    f.outages.set(m);
                }
                continue;
            }
            for (i, &n) in self.exp.topo.gateways[m].members.iter().enumerate() {
                // The scheduler's chosen partition point for this device —
                // executed for real in split mode, where a malformed plan
                // (entry missing) must fail as loudly as an out-of-range
                // cut, not silently run fused.
                let cut = plan.partition.get(i).copied();
                if self.exp.cfg.execute_partition && cut.is_none() {
                    anyhow::bail!(
                        "gateway {m}'s plan lacks a partition entry for \
                         member {i} (device {n}) in execute-partition mode"
                    );
                }
                if self.fault.device_dropped(t, n) {
                    if let Some(f) = faults.as_mut() {
                        f.dropped.push(n);
                    }
                    continue;
                }
                units.push(TrainUnit { device: n, gateway: m, cut });
            }
        }
        Ok(units)
    }

    /// Phase 4 (+ the folding half of phase 5): rayon-parallel local
    /// training in streaming waves. Each wave's results fold into the
    /// weighted accumulator in device order and are dropped, so live
    /// parameter copies stay O(wave) instead of O(N).
    ///
    /// Wire fault seam (`transport = tcp`): a device whose local steps
    /// lose the gateway — connection refused, timeout, mid-round
    /// disconnect — degrades onto the SAME dropout path as an injected
    /// `FaultPlan` dropout: the device is recorded in `faults.dropped`
    /// and contributes nothing to the fold; the round (and the run)
    /// continues. Any non-I/O error — handshake skew, a protocol
    /// violation, a gateway-side `Err` frame — still aborts: silent
    /// numeric divergence is worse than a crash.
    fn local_training(
        &self,
        t: usize,
        units: &[TrainUnit],
        params: &Params,
        faults: &mut Option<RoundFaults>,
    ) -> Result<TrainOutcome> {
        let exp = self.exp;
        let seed = exp.cfg.seed;
        let mm = exp.topo.num_gateways();
        let mut out = TrainOutcome {
            agg: RoundFold::for_experiment(exp, mm),
            floor_loss: vec![0.0; mm],
            floor_count: vec![0; mm],
            loss_sum: 0.0,
            loss_count: 0,
        };
        for wave in units.chunks(wave_width()) {
            let results: Vec<Result<(Params, f64)>> = wave
                .par_iter()
                .map(|u| {
                    let mut rng = Rng::stream(seed, &[STREAM_TRAIN, t as u64, u.device as u64]);
                    exp.local_train(u.device, u.cut, params, &mut rng)
                })
                .collect();
            for (u, res) in wave.iter().zip(results) {
                let (w, loss) = match res {
                    Ok(r) => r,
                    Err(e) if is_peer_lost(&e) => {
                        // The benign-run report is lazily materialized so
                        // wire dropouts surface on records even with no
                        // fault knob armed.
                        faults
                            .get_or_insert_with(|| RoundFaults::new(mm))
                            .dropped
                            .push(u.device);
                        continue;
                    }
                    Err(e) => return Err(e),
                };
                // FedAvg weight: D̃_n (`Device::fedavg_weight`), the one
                // weighting shared with the shadow and probe folds. Units
                // arrive gateway-contiguous in plan order, so the flat
                // and hierarchical folds see identical add sequences.
                out.agg.add(u.gateway, &w, exp.topo.devices[u.device].fedavg_weight())?;
                out.floor_loss[u.gateway] += loss;
                out.floor_count[u.gateway] += 1;
                out.loss_sum += loss;
                out.loss_count += 1;
            }
        }
        Ok(out)
    }

    /// Phase 6: evaluate the model — on the full IID test set, or (with
    /// `cfg.eval_sample` in `(0, test_size)`) on a per-round
    /// deterministic subsample drawn from the dedicated [`STREAM_EVAL`]
    /// stream keyed `[dom, round]`. Every phase-6 call sites here (the
    /// periodic gate AND the stopping round's final-eval patch), so a
    /// sampled run never mixes sampled and full evals. The stream is
    /// consulted only when sampling is armed: `eval_sample = 0` (and
    /// `>= test_size`, where sampling would be a no-op) runs the full
    /// eval with unchanged bytes.
    fn eval_model(&self, t: usize, params: &Params) -> Result<(f64, f64)> {
        let exp = self.exp;
        let total = exp.test_y.len();
        let k = exp.cfg.eval_sample;
        if k == 0 || k >= total {
            return exp.engine.eval_full(params, &exp.test_x, &exp.test_y);
        }
        let mut rng = Rng::stream(exp.cfg.seed, &[STREAM_EVAL, t as u64]);
        let idx = rng.choose_k(total, k);
        let dim = exp.test_x.len() / total;
        let mut x = Vec::with_capacity(k * dim);
        let mut y = Vec::with_capacity(k);
        for &i in &idx {
            x.extend_from_slice(&exp.test_x[i * dim..(i + 1) * dim]);
            y.push(exp.test_y[i]);
        }
        exp.engine.eval_full(params, &x, &y)
    }

    /// Buffer a full run into the back-compat [`RunLog`] via a
    /// [`MemorySink`] (the [`Experiment::run`] shim and
    /// [`crate::fl::Session::run`] both land here).
    pub fn run_logged(&self, sched: &mut dyn Scheduler, opts: &RunOpts) -> Result<RunLog> {
        let mut mem = MemorySink::new();
        {
            let mut observers: [&mut dyn RoundObserver; 1] = [&mut mem];
            self.run(sched, opts, &mut observers)?;
        }
        Ok(mem.into_log())
    }

    /// Run one scheduler for up to `opts.rounds` communication rounds,
    /// streaming each [`RoundRecord`] to the observers as it is
    /// produced.
    ///
    /// Stop rules — checked once here, for every caller — end the run
    /// after the round that triggered them (that round's record is
    /// always delivered first): `opts.until_accuracy`,
    /// `opts.max_sim_delay`, or any observer returning
    /// [`ControlFlow::Break`]. Because every round's RNG streams depend
    /// only on `(seed, round, device)` and never on later rounds, a
    /// stopped run's records are byte-identical to the same-index
    /// records of the uninterrupted run (pinned by
    /// `rust/tests/session.rs`).
    pub fn run(
        &self,
        sched: &mut dyn Scheduler,
        opts: &RunOpts,
        observers: &mut [&mut dyn RoundObserver],
    ) -> Result<RunSummary> {
        let exp = self.exp;
        let mm = exp.topo.num_gateways();
        let meta = RunMeta {
            scheme: sched.name(),
            rounds: opts.rounds,
            gateways: mm,
            devices: exp.topo.num_devices(),
        };
        for obs in observers.iter_mut() {
            obs.on_start(&meta)?;
        }
        let mut params = exp.engine.init_params()?;
        let mut cum_delay = 0.0;
        let mut sel_counts = vec![0usize; mm];
        let mut eff_counts = vec![0usize; mm];
        let mut rounds_run = 0usize;
        let mut stop: Option<StopCause> = None;

        for t in 0..opts.rounds {
            // Phase 1: environment.
            let (state, arrivals) = self.draw_env(t);
            let ctx = RoundCtx {
                cfg: &exp.cfg,
                topo: &exp.topo,
                model: &exp.cost_model,
                chan: &exp.chan,
                state: &state,
                arrivals: &arrivals,
                round: t,
            };

            // Phase 2: scheduling — X(t) = [I, l, P, f^G] — with straggler
            // episodes folded into τ(t). The per-round fault report only
            // exists while a fault knob is armed (and is attached to the
            // record only if something actually fired).
            let decision = sched.schedule(&ctx);
            let mut faults: Option<RoundFaults> =
                if self.fault.has_round_faults() { Some(RoundFaults::new(mm)) } else { None };
            let delay = self.round_delay_with_stragglers(t, &decision, &mut faults);
            cum_delay += delay;
            // Known as soon as the delay is: whether this round exhausts
            // the simulated-delay budget (the stopping round then gets a
            // final eval below).
            let budget_stop = opts.max_sim_delay.is_some_and(|b| cum_delay >= b);

            // Phase 3: feasibility.
            let mut selected = vec![false; mm];
            let mut failed = vec![false; mm];
            let units =
                self.feasibility(t, &decision, &ctx, &mut selected, &mut failed, &mut faults)?;
            for m in 0..mm {
                sel_counts[m] += selected[m] as usize;
                eff_counts[m] += (selected[m] && !failed[m]) as usize;
            }

            // Phase 4: parallel local training (streaming folds). Wire
            // peer-loss surfaces as additional `faults.dropped` entries.
            let outcome = if opts.train && !units.is_empty() {
                Some(self.local_training(t, &units, &params, &mut faults)?)
            } else {
                None
            };
            let mut avg_loss: Vec<Option<f64>> = vec![None; mm];
            let mut train_loss = None;
            if let Some(o) = &outcome {
                for m in 0..mm {
                    if o.floor_count[m] > 0 {
                        avg_loss[m] = Some(o.floor_loss[m] / o.floor_count[m] as f64);
                    }
                }
                if o.loss_count > 0 {
                    train_loss = Some(o.loss_sum / o.loss_count as f64);
                }
            }

            // Divergence measurement (Fig. 2): from the round's STARTING
            // model, before aggregation replaces it. Purely a probe — it
            // must never touch `avg_loss`, which carries the phase-4
            // training losses to `sched.observe` unconditionally (a
            // loss-driven schedule is identical with and without
            // `--divergence`; pinned by rust/tests/fault.rs).
            let divergence = if opts.track_divergence && opts.train {
                Some(self.measure_divergence(t, &params)?)
            } else {
                None
            };

            // Phase 5: global FedAvg (§III-A step 3). Weighting by D̃_n
            // makes the two-stage (floor, then BS) aggregation a single
            // weighted average; the fold already holds Σ w·p — flat in
            // one accumulator, or hierarchical with gateway partials
            // merged per edge cluster then at the cloud (`fl::hierarchy`).
            if let Some(o) = outcome {
                if let Some(new_params) = o.agg.finish(&exp.topo)? {
                    params = new_params;
                }
            }

            sched.observe(&RoundFeedback { avg_loss });

            // Phase 6: periodic evaluation.
            let (test_loss, test_acc) = if opts.eval_every > 0
                && opts.train
                && (t % opts.eval_every == opts.eval_every - 1 || t + 1 == opts.rounds)
            {
                let (l, a) = self.eval_model(t, &params)?;
                (Some(l), Some(a))
            } else {
                (None, None)
            };

            // Canonicalize the fault report (device order) and attach it
            // only when something realized, so benign rounds — and whole
            // benign runs — serialize exactly as before the fault layer.
            if let Some(f) = faults.as_mut() {
                f.dropped.sort_unstable();
            }
            let faults = faults.filter(|f| f.any());

            let record = RoundRecord {
                round: t,
                delay,
                cum_delay,
                selected: GatewayMask::from_slice(&selected),
                failed: GatewayMask::from_slice(&failed),
                train_loss,
                test_loss,
                test_acc,
                divergence,
                faults,
            };
            rounds_run = t + 1;

            // Engine-level stop rules, then observer votes. The record
            // that triggers a stop is still delivered to every observer.
            if let (Some(target), Some(acc)) = (opts.until_accuracy, record.test_acc) {
                if acc >= target {
                    stop = Some(StopCause::TargetAccuracy { round: t, accuracy: acc });
                }
            }
            if stop.is_none() && budget_stop {
                stop = Some(StopCause::DelayBudget { round: t, cum_delay });
            }
            for obs in observers.iter_mut() {
                if obs.on_record(&record)? == ControlFlow::Break(()) && stop.is_none() {
                    stop = Some(StopCause::Observer { round: t });
                }
            }
            if stop.is_some() {
                // A stopping round that the periodic gate skipped still
                // gets its final eval — a run must not end with
                // `test_acc = None`. The patched record is delivered
                // through the SEPARATE `on_final_eval` hook (never
                // `on_record`), so the on_record stream of a stopped run
                // stays a byte-identical prefix of the uninterrupted run.
                if record.test_acc.is_none() && opts.train && opts.eval_every > 0 {
                    let (l, a) = self.eval_model(t, &params)?;
                    let mut fin = record.clone();
                    fin.test_loss = Some(l);
                    fin.test_acc = Some(a);
                    for obs in observers.iter_mut() {
                        obs.on_final_eval(&fin)?;
                    }
                }
                break;
            }
        }

        let t = rounds_run.max(1) as f64;
        let summary = RunSummary {
            scheme: meta.scheme,
            rounds_planned: opts.rounds,
            rounds_run,
            stop,
            participation: sel_counts.iter().map(|&c| c as f64 / t).collect(),
            effective_participation: eff_counts.iter().map(|&c| c as f64 / t).collect(),
        };
        for obs in observers.iter_mut() {
            obs.on_finish(&summary)?;
        }
        Ok(summary)
    }

    /// Fig. 2 machinery: every device trains locally from the current
    /// global model (rayon fan-out, per-device [`STREAM_DIVERGENCE`]
    /// streams); a centralized-GD shadow runs K steps on the streamed
    /// union gradient; returns `‖ŵ_m − v^{K,t}‖` per gateway. Per-gateway
    /// aggregates stream through [`WeightedAccum`] one shop floor at a
    /// time, so live copies are O(floor), not O(N).
    ///
    /// A pure measurement: its losses stay inside the probe and never
    /// reach scheduler feedback (they cover every device, scheduled or
    /// not — feeding them to `observe` would change loss-driven schedules
    /// whenever `--divergence` is on).
    fn measure_divergence(&self, t: usize, params: &Params) -> Result<Vec<f64>> {
        let exp = self.exp;
        let seed = exp.cfg.seed;
        let n_dev = exp.topo.num_devices();

        // Centralized-GD shadow: v ← v − β·∇F(v), with ∇F the
        // D̃_n-weighted mean of per-device minibatch gradients (the same
        // `fedavg_weight` the phase-5 fold uses — Eq. 7's weighting),
        // streamed through a flat accumulator.
        let mut v = params.clone();
        let devices: Vec<usize> = (0..n_dev).collect();
        for k in 0..exp.cfg.local_iters {
            let mut gacc = FlatWeightedAccum::new();
            for wave in devices.chunks(wave_width()) {
                let grads: Vec<Result<Vec<f32>>> = wave
                    .par_iter()
                    .map(|&n| {
                        let path = [STREAM_SHADOW, t as u64, k as u64, n as u64];
                        let mut rng = Rng::stream(seed, &path);
                        let (x, y) = exp.sample_batch(n, &mut rng);
                        exp.engine.grad(&v, &x, &y)
                    })
                    .collect();
                for (&n, g) in wave.iter().zip(grads) {
                    gacc.add(&g?, exp.topo.devices[n].fedavg_weight());
                }
            }
            let g = gacc.finish().expect("validated: topology is non-empty");
            vecmath::sgd_step_flat(&mut v, &g, exp.cfg.lr as f32);
        }

        // Per-gateway aggregated model vs the shadow, one floor at a time.
        let mut out = Vec::with_capacity(exp.topo.num_gateways());
        for gw in &exp.topo.gateways {
            let members = &gw.members;
            let results: Vec<Result<(Params, f64)>> = members
                .par_iter()
                .map(|&n| {
                    // The divergence probe has no scheduler plan (every
                    // device trains); it always measures through the
                    // fused engine.
                    let mut rng = Rng::stream(seed, &[STREAM_DIVERGENCE, t as u64, n as u64]);
                    exp.local_train(n, None, params, &mut rng)
                })
                .collect();
            let mut acc = WeightedAccum::new();
            for (&n, res) in members.iter().zip(results) {
                let (w, _) = res?;
                acc.add(&w, exp.topo.devices[n].fedavg_weight());
            }
            let w_hat = acc.finish().expect("validated: no empty shop floors");
            out.push(vecmath::l2_diff(&w_hat, &v));
        }
        Ok(out)
    }
}

impl Experiment {
    /// Estimate σ_n, δ_n, L_n (§IV Assumptions) by gradient probing at
    /// the current init: `probes` minibatch gradients per device, drawn
    /// from the per-device [`STREAM_PROBE`] streams and fanned out over
    /// rayon.
    ///
    /// Two streaming passes keep memory O(wave·|w|) instead of
    /// O(N·probes·|w|): pass 1 folds the dataset-size-weighted global
    /// gradient while computing σ_n (Assumption 1) and the L_n
    /// finite-difference smoothness probe; pass 2 REPLAYS each device's
    /// probe stream — stateless streams make the replay free — to
    /// re-derive its mean gradient and measure δ_n (Assumption 2) against
    /// the global mean, so no per-device gradient is ever retained.
    pub fn estimate_grad_stats(&self, probes: usize) -> Result<GradStats> {
        anyhow::ensure!(probes > 0, "need at least one gradient probe per device");
        let params = self.engine.init_params()?;
        let seed = self.cfg.seed;
        let n_dev = self.topo.num_devices();
        let b = self.engine.meta().train_batch as f64;
        let eps = 1e-2f32;

        // The `probes` gradients of device n drawn from `rng` — replayable
        // at will from the device's stateless stream, and the ONE
        // definition both passes share, so the pass-2 replay can never
        // drift from what pass 1 folded. The buffered gradients live only
        // inside one call — O(probes·|w|) per in-flight task, not O(N·|w|).
        let probe_grads = |n: usize, rng: &mut Rng| -> Result<Vec<Vec<f32>>> {
            (0..probes)
                .map(|_| {
                    let (x, y) = self.sample_batch(n, rng);
                    self.engine.grad(&params, &x, &y)
                })
                .collect()
        };

        // Pass 1 per device: σ_n, L_n, and the device's mean gradient for
        // the global fold.
        let probe_device = |n: usize| -> Result<(Vec<f32>, f64, f64)> {
            let mut rng = Rng::stream(seed, &[STREAM_PROBE, n as u64]);
            let gs = probe_grads(n, &mut rng)?;
            let mean = vecmath::mean_flat(&gs);
            // σ_n ≈ √B · E_b ‖g_b − ∇F_n‖ (Assumption 1, minibatch
            // estimator).
            let mean_dev: f64 =
                gs.iter().map(|g| vecmath::flat_l2_diff(g, &mean)).sum::<f64>() / probes as f64;
            let sigma = b.sqrt() * mean_dev;

            // L_n: finite-difference smoothness probe along a random
            // direction, on the stream's next batch.
            let mut pert = params.clone();
            let mut dir_norm_sq = 0.0f64;
            let mut prng = Rng::stream(seed, &[STREAM_SMOOTH, n as u64]);
            for tensor in pert.iter_mut() {
                for v in tensor.iter_mut() {
                    let d = prng.normal() as f32;
                    *v += eps * d;
                    dir_norm_sq += (eps * d) as f64 * (eps * d) as f64;
                }
            }
            let (x, y) = self.sample_batch(n, &mut rng);
            let g0 = self.engine.grad(&params, &x, &y)?;
            let g1 = self.engine.grad(&pert, &x, &y)?;
            let l = (vecmath::flat_l2_diff(&g1, &g0) / dir_norm_sq.sqrt()).max(1e-6);
            Ok((mean, sigma, l))
        };

        let devices: Vec<usize> = (0..n_dev).collect();
        let mut sigma = Vec::with_capacity(n_dev);
        let mut lsmooth = Vec::with_capacity(n_dev);
        let mut gacc = FlatWeightedAccum::new();
        for wave in devices.chunks(wave_width()) {
            let results: Vec<Result<(Vec<f32>, f64, f64)>> =
                wave.par_iter().map(|&n| probe_device(n)).collect();
            for (&n, res) in wave.iter().zip(results) {
                let (mean, s, l) = res?;
                // Global gradient: D̃_n-weighted mean (`fedavg_weight` —
                // the ∇F definition under Eq. 7's weighting, matching
                // the phase-5 and shadow folds), folded in device order.
                gacc.add(&mean, self.topo.devices[n].fedavg_weight());
                sigma.push(s);
                lsmooth.push(l);
            }
        }
        let global = gacc.finish().expect("validated: topology is non-empty");

        // Pass 2: δ_n = ‖∇F_n − ∇F‖ (Assumption 2), replaying each
        // device's probe stream through the same draw sequence as pass 1.
        let delta: Vec<f64> = devices
            .par_iter()
            .map(|&n| -> Result<f64> {
                let mut rng = Rng::stream(seed, &[STREAM_PROBE, n as u64]);
                let mean = vecmath::mean_flat(&probe_grads(n, &mut rng)?);
                Ok(vecmath::flat_l2_diff(&mean, &global))
            })
            .collect::<Result<_>>()?;

        Ok(GradStats { sigma, delta, lsmooth })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Aggregation, SimConfig};
    use crate::fl::hierarchy::HierFold;
    use crate::sched::RoundRobin;

    /// THE dropout aggregation pin: a dropped device contributes nothing
    /// to the `WeightedAccum` fold, bitwise — the armed engine's phase-4/5
    /// aggregate equals a from-scratch fold over exactly the surviving
    /// units, and the unit list is exactly the benign list minus the
    /// dropped devices.
    #[test]
    fn dropout_round_aggregation_excludes_dropped_devices_bitwise() {
        let mut cfg = SimConfig::default();
        cfg.test_size = 256;
        cfg.dataset_max = 400;
        // Budgets generous enough that every scheduled plan is feasible —
        // the test must exercise dropout, not constraint failures.
        cfg.device_energy_max = 500.0;
        cfg.gw_energy_max = 5000.0;
        cfg.fault.dropout_prob = 0.5;
        let exp = Experiment::new(cfg).unwrap();
        let engine = RoundEngine::new(&exp);
        let engine_benign = RoundEngine { exp: &exp, fault: FaultPlan::none() };
        let mm = exp.topo.num_gateways();
        let mut sched = RoundRobin::new();

        // Walk rounds until the (deterministic) dropout realization has
        // both dropped devices and survivors; p=0.5 over ~6 scheduled
        // devices makes the first such round come almost immediately.
        for t in 0..20usize {
            let (state, arrivals) = engine.draw_env(t);
            let ctx = RoundCtx {
                cfg: &exp.cfg,
                topo: &exp.topo,
                model: &exp.cost_model,
                chan: &exp.chan,
                state: &state,
                arrivals: &arrivals,
                round: t,
            };
            let decision = sched.schedule(&ctx);

            let (mut sel_a, mut fail_a) = (vec![false; mm], vec![false; mm]);
            let mut faults = Some(RoundFaults::new(mm));
            let units_armed = engine
                .feasibility(t, &decision, &ctx, &mut sel_a, &mut fail_a, &mut faults)
                .unwrap();
            let dropped = faults.unwrap().dropped;

            let (mut sel_b, mut fail_b) = (vec![false; mm], vec![false; mm]);
            let mut no_faults = None;
            let units_all = engine_benign
                .feasibility(t, &decision, &ctx, &mut sel_b, &mut fail_b, &mut no_faults)
                .unwrap();
            assert!(no_faults.is_none());

            // Selection/failure flags are dropout-independent (only
            // outages fail gateways, and none are armed here).
            assert_eq!(sel_a, sel_b, "round {t}");
            assert_eq!(fail_a, fail_b, "round {t}");
            let survivors: Vec<usize> = units_all
                .iter()
                .map(|u| u.device)
                .filter(|n| !dropped.contains(n))
                .collect();
            assert_eq!(
                units_armed.iter().map(|u| u.device).collect::<Vec<_>>(),
                survivors,
                "round {t}: armed units != benign units minus dropped"
            );

            if dropped.is_empty() || units_armed.is_empty() {
                continue;
            }

            // Fold parity, bit for bit.
            let params = exp.engine.init_params().unwrap();
            let out = engine.local_training(t, &units_armed, &params, &mut None).unwrap();
            let mut acc = WeightedAccum::new();
            for u in &units_armed {
                let mut rng =
                    Rng::stream(exp.cfg.seed, &[STREAM_TRAIN, t as u64, u.device as u64]);
                let (w, _) = exp.local_train(u.device, u.cut, &params, &mut rng).unwrap();
                acc.add(&w, exp.topo.devices[u.device].fedavg_weight());
            }
            let manual = acc.finish().unwrap();
            let folded = out.agg.finish(&exp.topo).unwrap().unwrap();
            assert_eq!(manual.len(), folded.len());
            for (a, b) in manual.iter().zip(&folded) {
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "round {t}: fold bytes diverged");
                }
            }
            return;
        }
        panic!("no round with both dropped devices and survivors in 20 rounds at p=0.5");
    }

    /// THE outage × hierarchy pin: a fully-outaged gateway contributes
    /// nothing to its cluster's fold — its tier accumulator never sees an
    /// add, and the engine's hierarchical aggregate equals a from-scratch
    /// `HierFold` over exactly the surviving units, bitwise.
    #[test]
    fn outaged_gateway_contributes_nothing_to_its_clusters_fold_bitwise() {
        let mut cfg = SimConfig::default();
        cfg.test_size = 256;
        cfg.dataset_max = 400;
        cfg.device_energy_max = 500.0;
        cfg.gw_energy_max = 5000.0;
        cfg.aggregation = Aggregation::Hierarchical;
        cfg.num_clusters = 3;
        cfg.fault.gateway_outage_prob = 0.5;
        let exp = Experiment::new(cfg).unwrap();
        let engine = RoundEngine::new(&exp);
        let mm = exp.topo.num_gateways();
        let mut sched = RoundRobin::new();

        for t in 0..20usize {
            let (state, arrivals) = engine.draw_env(t);
            let ctx = RoundCtx {
                cfg: &exp.cfg,
                topo: &exp.topo,
                model: &exp.cost_model,
                chan: &exp.chan,
                state: &state,
                arrivals: &arrivals,
                round: t,
            };
            let decision = sched.schedule(&ctx);
            let (mut sel, mut fail) = (vec![false; mm], vec![false; mm]);
            let mut faults = Some(RoundFaults::new(mm));
            let units =
                engine.feasibility(t, &decision, &ctx, &mut sel, &mut fail, &mut faults).unwrap();
            let outages = faults.unwrap().outages;
            let out_gws: Vec<usize> = (0..mm).filter(|&m| outages.get(m)).collect();
            // Need a realization with at least one outage AND survivors.
            if out_gws.is_empty() || units.is_empty() {
                continue;
            }
            // An outaged floor is failed and fields no units.
            for &m in &out_gws {
                assert!(fail[m], "round {t}: outaged gateway {m} not marked failed");
            }
            assert!(units.iter().all(|u| !outages.get(u.gateway)));

            let params = exp.engine.init_params().unwrap();
            let out = engine.local_training(t, &units, &params, &mut None).unwrap();
            let mut hier = HierFold::new(mm);
            for u in &units {
                let mut rng =
                    Rng::stream(exp.cfg.seed, &[STREAM_TRAIN, t as u64, u.device as u64]);
                let (w, _) = exp.local_train(u.device, u.cut, &params, &mut rng).unwrap();
                hier.add(u.gateway, &w, exp.topo.devices[u.device].fedavg_weight());
            }
            for &m in &out_gws {
                assert_eq!(hier.gateway_count(m), 0, "outaged gateway {m} must fold nothing");
            }
            let manual = hier.finish(&exp.topo).unwrap();
            let folded = out.agg.finish(&exp.topo).unwrap().unwrap();
            for (a, b) in manual.iter().zip(&folded) {
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "round {t}: tier fold bytes diverged");
                }
            }
            return;
        }
        panic!("no round with both an outage and survivors in 20 rounds at p=0.5");
    }
}
