//! The FL orchestrator: owns one experiment (topology, data, channel and
//! energy processes, execution backend) and runs schedulers against it.
//!
//! One communication round (§III-A):
//!   1. draw the block-fading channel state and the EH energy arrivals;
//!   2. the scheduler picks J gateways + resources (X(t));
//!   3. feasibility is enforced (C7–C10) — infeasible plans "fail" and
//!      contribute no update (the baselines' failure mode in §VII-C);
//!   4. every scheduled device runs K local SGD iterations through the
//!      execution backend — the pure-Rust layer-graph `NativeBackend` by
//!      default (`mlp` and `cnn` presets), the AOT train-step artifact
//!      under the `pjrt` feature. With `execute_partition` set, each
//!      device's step instead runs through the split-execution
//!      `PartitionedBackend` at EXACTLY the partition point l_n the
//!      scheduler chose for it this round (`GatewayPlan::partition`):
//!      device half forward → smashed-activation upload → gateway half
//!      forward/backward → cut-gradient download → device half backward.
//!      Split and fused execution are byte-identical at every cut point
//!      (pinned by rust/tests/partition.rs and examples/partitioned_step),
//!      so turning the flag on changes WHERE the layers run, never the
//!      numbers;
//!   5. shop-floor FedAvg then global FedAvg (both weight by D̃_n);
//!   6. periodic evaluation on the IID test set.
//!
//! Environment realisations (channels, energy, batch sampling) are drawn
//! from RNG streams forked from the config seed, NOT from scheduler state,
//! so different schedulers face identical conditions — paired comparison,
//! as in the paper's figures.

use anyhow::{Context, Result};

use crate::config::SimConfig;
use crate::data::synth::{DatasetFlavor, SynthData, IMG_DIM};
use crate::data::{shard_non_iid, DeviceShard};
use crate::dnn::models;
use crate::dnn::ModelSpec;
use crate::energy::EnergyArrivals;
use crate::fl::participation::GradStats;
use crate::fl::vecmath;
use crate::net::ChannelModel;
use crate::rng::Rng;
use crate::runtime::{make_backend, make_partitioned_stack, Backend, Params, PartitionedBackend};
use crate::sched::latency::plan_cost;
use crate::sched::{RoundCtx, RoundFeedback, Scheduler};
use crate::topo::Topology;

/// Options for one scheduler run.
#[derive(Clone, Debug)]
pub struct RunOpts {
    pub rounds: usize,
    /// Evaluate on the test set every this many rounds (0 = never).
    pub eval_every: usize,
    /// Track ||ŵ_m − v^{K,t}|| against a centralized-GD shadow (Fig. 2);
    /// forces all devices to train each round for measurement.
    pub track_divergence: bool,
    /// Execute real training through the backend. When false, only the
    /// scheduling/delay simulation runs (used by scheduling-only benches).
    pub train: bool,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts { rounds: 50, eval_every: 5, track_divergence: false, train: true }
    }
}

/// Per-round record (one CSV row in the figure harness).
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// τ(t) (Eq. 10) in seconds.
    pub delay: f64,
    pub cum_delay: f64,
    pub selected: Vec<bool>,
    /// Selected but constraint-violating (update dropped).
    pub failed: Vec<bool>,
    /// Mean local training loss over participating devices.
    pub train_loss: Option<f64>,
    pub test_loss: Option<f64>,
    pub test_acc: Option<f64>,
    /// Measured ||ŵ_m − v^{K,t}|| per gateway (divergence mode only).
    pub divergence: Option<Vec<f64>>,
}

/// Full run output.
#[derive(Clone, Debug)]
pub struct RunLog {
    pub scheme: String,
    pub records: Vec<RoundRecord>,
    /// Empirical participation rate per gateway: (1/T) Σ_t 1_m^t.
    pub participation: Vec<f64>,
    /// Effective participation (selected AND feasible).
    pub effective_participation: Vec<f64>,
}

impl RunLog {
    pub fn final_accuracy(&self) -> Option<f64> {
        self.records.iter().rev().find_map(|r| r.test_acc)
    }

    pub fn total_delay(&self) -> f64 {
        self.records.last().map_or(0.0, |r| r.cum_delay)
    }

    /// Mean measured divergence per gateway over rounds (Fig. 2).
    pub fn mean_divergence(&self) -> Option<Vec<f64>> {
        let rows: Vec<&Vec<f64>> =
            self.records.iter().filter_map(|r| r.divergence.as_ref()).collect();
        if rows.is_empty() {
            return None;
        }
        let m = rows[0].len();
        Some(
            (0..m)
                .map(|i| rows.iter().map(|r| r[i]).sum::<f64>() / rows.len() as f64)
                .collect(),
        )
    }
}

/// One fully-instantiated experiment.
pub struct Experiment {
    pub cfg: SimConfig,
    pub topo: Topology,
    /// Cost-model DNN the scheduler plans with.
    pub cost_model: ModelSpec,
    pub chan: ChannelModel,
    pub shards: Vec<DeviceShard>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<i32>,
    pub engine: Box<dyn Backend>,
    /// Split-execution backends indexed by partition point `l ∈ 0..=L`
    /// (built only when `cfg.execute_partition`; empty otherwise). The
    /// round loop dispatches device n's local step to
    /// `partitioned[plan.partition[n]]`.
    pub partitioned: Vec<PartitionedBackend>,
}

impl Experiment {
    /// Build topology, channels, data and the execution backend (native by
    /// default; PJRT artifacts under `artifacts/` when feature-enabled).
    pub fn new(cfg: SimConfig) -> Result<Self> {
        Self::with_artifacts(cfg, std::path::Path::new("artifacts"))
    }

    pub fn with_artifacts(cfg: SimConfig, artifacts: &std::path::Path) -> Result<Self> {
        cfg.validate()?;
        let mut rng = Rng::new(cfg.seed);
        let topo = Topology::generate(&cfg, &mut rng.fork(1));
        let chan = ChannelModel::new(&cfg, &topo, &mut rng.fork(2));
        let flavor = DatasetFlavor::parse(&cfg.dataset)
            .with_context(|| format!("unknown dataset {:?}", cfg.dataset))?;
        let mut data_rng = rng.fork(3);
        let data = SynthData::new(flavor, &mut data_rng);
        let shards = shard_non_iid(&cfg, &topo, &data, &mut data_rng);
        let (test_x, test_y) = data.test_set(cfg.test_size, &mut data_rng);
        let cost_model = models::by_name(&cfg.cost_model)
            .with_context(|| format!("unknown cost model {:?}", cfg.cost_model))?;
        let engine = make_backend(artifacts, &cfg.exec_model)?;
        // Shards store flat 32·32·3 images; every executable preset (the
        // flat mlp and the NHWC cnn) must consume exactly that geometry.
        if engine.meta().sample_dim() != IMG_DIM {
            anyhow::bail!(
                "backend {:?} consumes {} features per sample, data provides {IMG_DIM}",
                engine.meta().preset,
                engine.meta().sample_dim()
            );
        }
        // Split-execution stack: one PartitionedBackend per legal cut of
        // the executed model. cfg.validate() already pinned
        // cost_model == exec_model, so the scheduler's partition indices
        // map 1:1 onto this stack.
        //
        // The stack is NATIVE numerics. When the pjrt feature would select
        // the PJRT engine for eval/init (artifacts present — mirroring
        // make_backend's choice), refuse to mix the two engines: PJRT and
        // native agree only approximately, which would silently break the
        // split-vs-fused byte-parity story.
        #[cfg(feature = "pjrt")]
        if cfg.execute_partition
            && artifacts.join(format!("{}.meta", cfg.exec_model)).exists()
        {
            anyhow::bail!(
                "execute_partition runs the native split stack, but compiled PJRT \
                 artifacts for {:?} would drive init/eval: remove the artifacts (or \
                 build without --features pjrt) so one engine owns the numerics",
                cfg.exec_model
            );
        }
        let partitioned = if cfg.execute_partition {
            make_partitioned_stack(&cfg.exec_model)?
        } else {
            Vec::new()
        };
        Ok(Experiment { cfg, topo, cost_model, chan, shards, test_x, test_y, engine, partitioned })
    }

    /// Construct a scheduler by scheme name. DDSRA variants estimate the
    /// gradient statistics (§IV) to derive the participation rates Γ_m.
    ///
    /// Schemes: "ddsra" (V from config), "participation" (DDSRA with V=0 —
    /// the pure device-specific participation-rate policy of Fig. 3),
    /// "random", "round_robin", "loss_driven", "delay_driven".
    pub fn make_scheduler(&self, scheme: &str) -> Result<Box<dyn Scheduler>> {
        use crate::fl::participation::gamma_rates;
        use crate::sched::{Ddsra, DelayDriven, LossDriven, RandomSched, RoundRobin};
        let gammas = || -> Result<Vec<f64>> {
            let stats = self.estimate_grad_stats(4)?;
            Ok(gamma_rates(
                &self.topo,
                &stats,
                self.cfg.num_channels,
                self.cfg.lr,
                self.cfg.local_iters,
            )
            .1)
        };
        Ok(match scheme {
            "ddsra" => Box::new(Ddsra::new(self.cfg.lyapunov_v, gammas()?)),
            "participation" => Box::new(Ddsra::new(0.0, gammas()?)),
            "random" => Box::new(RandomSched::new(self.cfg.seed ^ 0xaa11)),
            "round_robin" => Box::new(RoundRobin::new()),
            "loss_driven" => {
                Box::new(LossDriven::new(self.topo.num_gateways(), self.cfg.seed ^ 0xbb22))
            }
            "delay_driven" => Box::new(DelayDriven),
            other => anyhow::bail!("unknown scheme {other:?}"),
        })
    }

    /// Sample a training batch (with replacement) from device n's shard.
    fn sample_batch(&self, n: usize, rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
        let b = self.engine.meta().train_batch;
        let shard = &self.shards[n];
        let mut x = Vec::with_capacity(b * IMG_DIM);
        let mut y = Vec::with_capacity(b);
        for _ in 0..b {
            let i = rng.below(shard.len());
            x.extend_from_slice(&shard.images[i * IMG_DIM..(i + 1) * IMG_DIM]);
            y.push(shard.labels[i]);
        }
        (x, y)
    }

    /// K local SGD iterations for device n from `start`; returns the
    /// updated params and the mean local loss.
    ///
    /// `cut` is the DNN partition point the scheduler chose for this
    /// device this round: with `execute_partition` on, the K steps run
    /// through the split device/gateway backend at that cut (the paper's
    /// §II-B training flow); otherwise — and for cut-less callers like the
    /// divergence probe — the fused engine runs.
    ///
    /// The fused engine may batch the K steps into one call when its baked
    /// fused-K matches the config (§Perf: one PJRT call + one parameter
    /// round-trip instead of K); split backends always run K single steps.
    fn local_train(
        &self,
        n: usize,
        cut: Option<usize>,
        start: &Params,
        rng: &mut Rng,
    ) -> Result<(Params, f64)> {
        let k = self.cfg.local_iters;
        let backend: &dyn Backend = match cut {
            Some(l) if !self.partitioned.is_empty() => {
                let stack = &self.partitioned;
                stack.get(l).map(|b| b as &dyn Backend).ok_or_else(|| {
                    anyhow::anyhow!(
                        "partition point {l} outside the executable model's 0..={}",
                        stack.len() - 1
                    )
                })?
            }
            _ => self.engine.as_ref(),
        };
        if backend.fused_k() == Some(k) {
            let b = backend.meta().train_batch;
            let mut xs = Vec::with_capacity(k * b * IMG_DIM);
            let mut ys = Vec::with_capacity(k * b);
            for _ in 0..k {
                let (x, y) = self.sample_batch(n, rng);
                xs.extend(x);
                ys.extend(y);
            }
            let (w, loss) = backend.train_k_steps(start, &xs, &ys, self.cfg.lr as f32)?;
            return Ok((w, loss as f64));
        }
        let mut w = start.clone();
        let mut loss_sum = 0.0;
        for _ in 0..k {
            let (x, y) = self.sample_batch(n, rng);
            let (nw, loss) = backend.train_step(&w, &x, &y, self.cfg.lr as f32)?;
            w = nw;
            loss_sum += loss as f64;
        }
        Ok((w, loss_sum / k as f64))
    }

    /// Estimate σ_n, δ_n, L_n (§IV Assumptions) by gradient probing at the
    /// current init. `probes` minibatch gradients per device.
    pub fn estimate_grad_stats(&self, probes: usize) -> Result<GradStats> {
        let params = self.engine.init_params()?;
        let mut rng = Rng::new(self.cfg.seed ^ 0x9d0b);
        let n_dev = self.topo.num_devices();
        let b = self.engine.meta().train_batch as f64;

        // Per-device mean gradient + per-batch deviations.
        let mut mean_grads: Vec<Vec<f32>> = Vec::with_capacity(n_dev);
        let mut batch_grads: Vec<Vec<Vec<f32>>> = Vec::with_capacity(n_dev);
        for n in 0..n_dev {
            let gs: Vec<Vec<f32>> = (0..probes)
                .map(|_| {
                    let (x, y) = self.sample_batch(n, &mut rng);
                    self.engine.grad(&params, &x, &y)
                })
                .collect::<Result<_>>()?;
            mean_grads.push(vecmath::mean_flat(&gs));
            batch_grads.push(gs);
        }

        // Global gradient: dataset-size-weighted mean (∇F definition).
        let weighted: Vec<(&[f32], f64)> = (0..n_dev)
            .map(|n| (mean_grads[n].as_slice(), self.topo.devices[n].dataset_size as f64))
            .collect();
        let global = vecmath::weighted_mean_flat(&weighted);

        // σ_n ≈ √B · E_b ||g_b − ∇F_n|| (Assumption 1, minibatch estimator).
        let sigma: Vec<f64> = (0..n_dev)
            .map(|n| {
                let mean_dev: f64 = batch_grads[n]
                    .iter()
                    .map(|g| vecmath::flat_l2_diff(g, &mean_grads[n]))
                    .sum::<f64>()
                    / probes as f64;
                b.sqrt() * mean_dev
            })
            .collect();

        // δ_n = ||∇F_n − ∇F|| (Assumption 2).
        let delta: Vec<f64> = (0..n_dev)
            .map(|n| vecmath::flat_l2_diff(&mean_grads[n], &global))
            .collect();

        // L_n: finite-difference smoothness probe along a random direction.
        let mut lsmooth = Vec::with_capacity(n_dev);
        let eps = 1e-2f32;
        for n in 0..n_dev {
            let mut pert = params.clone();
            let mut dir_norm_sq = 0.0f64;
            let mut prng = Rng::new(self.cfg.seed ^ (n as u64) << 8 ^ 0x51);
            for t in pert.iter_mut() {
                for v in t.iter_mut() {
                    let d = prng.normal() as f32;
                    *v += eps * d;
                    dir_norm_sq += (eps * d) as f64 * (eps * d) as f64;
                }
            }
            let (x, y) = self.sample_batch(n, &mut rng);
            let g0 = self.engine.grad(&params, &x, &y)?;
            let g1 = self.engine.grad(&pert, &x, &y)?;
            let l = vecmath::flat_l2_diff(&g1, &g0) / dir_norm_sq.sqrt();
            lsmooth.push(l.max(1e-6));
        }

        Ok(GradStats { sigma, delta, lsmooth })
    }

    /// Run one scheduler for `opts.rounds` communication rounds.
    pub fn run(&self, sched: &mut dyn Scheduler, opts: &RunOpts) -> Result<RunLog> {
        let mm = self.topo.num_gateways();
        // Environment streams: identical across schedulers (paired runs).
        let mut chan_rng = Rng::new(self.cfg.seed ^ 0xc4a1);
        let mut energy_rng = Rng::new(self.cfg.seed ^ 0xe9e1);
        let mut sample_rng = Rng::new(self.cfg.seed ^ 0x5a3c);

        let mut params = self.engine.init_params()?;
        let mut records = Vec::with_capacity(opts.rounds);
        let mut cum_delay = 0.0;
        let mut sel_counts = vec![0usize; mm];
        let mut eff_counts = vec![0usize; mm];

        for t in 0..opts.rounds {
            let state = self.chan.draw(&mut chan_rng);
            let arrivals = EnergyArrivals::draw(&self.cfg, &mut energy_rng);
            let ctx = RoundCtx {
                cfg: &self.cfg,
                topo: &self.topo,
                model: &self.cost_model,
                chan: &self.chan,
                state: &state,
                arrivals: &arrivals,
                round: t,
            };
            let decision = sched.schedule(&ctx);
            let delay = decision.round_delay();
            cum_delay += delay;

            let mut selected = vec![false; mm];
            let mut failed = vec![false; mm];
            let mut avg_loss: Vec<Option<f64>> = vec![None; mm];
            // (params, weight) updates that survive feasibility.
            let mut updates: Vec<(Params, f64)> = Vec::new();
            let mut loss_accum = 0.0;
            let mut loss_count = 0usize;

            for plan in &decision.plans {
                let m = plan.gateway;
                selected[m] = true;
                sel_counts[m] += 1;
                let cost = plan_cost(&ctx, plan);
                if !cost.feasible() {
                    failed[m] = true;
                    continue; // "fails to complete local model training"
                }
                eff_counts[m] += 1;
                if opts.train {
                    let mut floor_loss = 0.0;
                    let members = &self.topo.gateways[m].members;
                    for (i, &n) in members.iter().enumerate() {
                        // The scheduler's chosen partition point for this
                        // device — executed for real in split mode, where a
                        // malformed plan (entry missing) must fail as loudly
                        // as an out-of-range cut, not silently run fused.
                        let cut = plan.partition.get(i).copied();
                        if self.cfg.execute_partition && cut.is_none() {
                            anyhow::bail!(
                                "gateway {m}'s plan lacks a partition entry for \
                                 member {i} (device {n}) in execute-partition mode"
                            );
                        }
                        let (w, loss) = self.local_train(n, cut, &params, &mut sample_rng)?;
                        let weight = self.topo.devices[n].train_batch as f64;
                        updates.push((w, weight));
                        floor_loss += loss;
                        loss_accum += loss;
                        loss_count += 1;
                    }
                    avg_loss[m] = Some(floor_loss / members.len() as f64);
                }
            }

            // Divergence measurement (Fig. 2): every device trains from the
            // current global model; centralized GD shadows on the union.
            let divergence = if opts.track_divergence && opts.train {
                Some(self.measure_divergence(&params, &mut sample_rng, &mut avg_loss)?)
            } else {
                None
            };

            // Global FedAvg (Eq. in §III-A step 3). Weighting by D̃_n makes
            // the two-stage (floor, then BS) aggregation a single weighted
            // average.
            if !updates.is_empty() {
                let refs: Vec<(&Params, f64)> = updates.iter().map(|(p, w)| (p, *w)).collect();
                params = vecmath::weighted_average(&refs);
            }

            sched.observe(&RoundFeedback { avg_loss });

            let (test_loss, test_acc) = if opts.eval_every > 0
                && opts.train
                && (t % opts.eval_every == opts.eval_every - 1 || t + 1 == opts.rounds)
            {
                let (l, a) = self.engine.eval_full(&params, &self.test_x, &self.test_y)?;
                (Some(l), Some(a))
            } else {
                (None, None)
            };

            records.push(RoundRecord {
                round: t,
                delay,
                cum_delay,
                selected,
                failed,
                train_loss: (loss_count > 0).then(|| loss_accum / loss_count as f64),
                test_loss,
                test_acc,
                divergence,
            });
        }

        let t = opts.rounds as f64;
        Ok(RunLog {
            scheme: sched.name(),
            records,
            participation: sel_counts.iter().map(|&c| c as f64 / t).collect(),
            effective_participation: eff_counts.iter().map(|&c| c as f64 / t).collect(),
        })
    }

    /// Fig. 2 machinery: all devices train locally; a centralized-GD shadow
    /// runs K steps on the union gradient; returns ||ŵ_m − v^{K,t}|| per
    /// gateway.
    fn measure_divergence(
        &self,
        params: &Params,
        rng: &mut Rng,
        avg_loss: &mut [Option<f64>],
    ) -> Result<Vec<f64>> {
        let n_dev = self.topo.num_devices();
        // Local updates for every device.
        let mut local: Vec<Params> = Vec::with_capacity(n_dev);
        let mut losses: Vec<f64> = Vec::with_capacity(n_dev);
        for n in 0..n_dev {
            // The divergence probe has no scheduler plan (every device
            // trains); it always measures through the fused engine.
            let (w, loss) = self.local_train(n, None, params, rng)?;
            local.push(w);
            losses.push(loss);
        }
        // Centralized GD shadow: v ← v − β · ∇F(v), with ∇F estimated as
        // the dataset-weighted mean of per-device minibatch gradients.
        let mut v = params.clone();
        for _ in 0..self.cfg.local_iters {
            let grads: Vec<Vec<f32>> = (0..n_dev)
                .map(|n| {
                    let (x, y) = self.sample_batch(n, rng);
                    self.engine.grad(&v, &x, &y)
                })
                .collect::<Result<_>>()?;
            let weighted: Vec<(&[f32], f64)> = (0..n_dev)
                .map(|n| (grads[n].as_slice(), self.topo.devices[n].dataset_size as f64))
                .collect();
            let g = vecmath::weighted_mean_flat(&weighted);
            vecmath::sgd_step_flat(&mut v, &g, self.cfg.lr as f32);
        }
        // Per-gateway aggregated model vs the shadow.
        let mut out = Vec::with_capacity(self.topo.num_gateways());
        for gw in &self.topo.gateways {
            let refs: Vec<(&Params, f64)> = gw
                .members
                .iter()
                .map(|&n| (&local[n], self.topo.devices[n].train_batch as f64))
                .collect();
            let w_hat = vecmath::weighted_average(&refs);
            out.push(vecmath::l2_diff(&w_hat, &v));
            let floor_loss: f64 =
                gw.members.iter().map(|&n| losses[n]).sum::<f64>() / gw.members.len() as f64;
            avg_loss[gw.id] = Some(floor_loss);
        }
        Ok(out)
    }
}
