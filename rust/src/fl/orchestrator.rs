//! The FL orchestrator: owns one experiment (topology, data, channel and
//! energy processes, execution backend) and the local-training primitives.
//! The communication-round loop itself lives in the parallel streaming
//! round engine, [`crate::fl::round`] — phases: draw environment →
//! schedule → feasibility → local training (rayon device fan-out) →
//! streaming aggregation → eval.
//!
//! Per round (§III-A): the scheduler picks J gateways + resources X(t);
//! feasibility (C7–C10) is enforced — infeasible plans "fail" and
//! contribute no update (the baselines' failure mode in §VII-C); every
//! scheduled device runs K local SGD iterations through the execution
//! backend — the pure-Rust layer-graph `NativeBackend` by default (`mlp`
//! and `cnn` presets), the AOT train-step artifact under the `pjrt`
//! feature. With `execute_partition` set, each device's step instead runs
//! through the split-execution `PartitionedBackend` at EXACTLY the
//! partition point l_n the scheduler chose for it this round
//! (`GatewayPlan::partition`): device half forward → smashed-activation
//! upload → gateway half forward/backward → cut-gradient download →
//! device half backward. Split and fused execution are byte-identical at
//! every cut point (pinned by rust/tests/partition.rs and
//! examples/partitioned_step), so turning the flag on changes WHERE the
//! layers run, never the numbers. Shop-floor FedAvg then global FedAvg
//! (both weight by D̃_n) close the round.
//!
//! Environment realisations (channels, energy, batch sampling) are drawn
//! from stateless RNG streams keyed on the config seed (see the stream
//! map in [`crate::fl::round`]), NOT from scheduler state, so different
//! schedulers face identical conditions — paired comparison, as in the
//! paper's figures.

use anyhow::{Context, Result};

use crate::config::SimConfig;
use crate::data::synth::{DatasetFlavor, SynthData, IMG_DIM};
use crate::data::{ShardPlan, ShardStore};
use crate::dnn::models;
use crate::dnn::ModelSpec;
use crate::fl::fault::RoundFaults;
use crate::fl::participation::gamma_rates;
use crate::fl::round::RoundEngine;
use crate::fl::session::{RunOpts, SchedulerSpec};
use crate::net::ChannelModel;
use crate::rng::Rng;
use crate::runtime::{
    make_backend_kernel, make_partitioned_stack_kernel, Backend, Params, PartitionedBackend,
    RemoteBackend,
};
use crate::sched::Scheduler;
use crate::topo::Topology;

/// Compact per-gateway membership set: one bit per gateway instead of a
/// heap `Vec<bool>`, so buffering sinks stay small when records stream
/// at metro scale (M = 96 gateways × thousands of rounds).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct GatewayMask {
    len: usize,
    bits: Vec<u64>,
}

impl GatewayMask {
    /// An empty mask over `len` gateways.
    pub fn new(len: usize) -> Self {
        GatewayMask { len, bits: vec![0u64; len.div_ceil(64)] }
    }

    pub fn from_slice(flags: &[bool]) -> Self {
        let mut mask = Self::new(flags.len());
        for (m, &f) in flags.iter().enumerate() {
            if f {
                mask.set(m);
            }
        }
        mask
    }

    pub fn set(&mut self, m: usize) {
        // Hard assert: a silently dropped or hidden bit would corrupt the
        // num_selected/num_failed telemetry in release builds.
        assert!(m < self.len, "gateway {m} outside 0..{}", self.len);
        self.bits[m / 64] |= 1u64 << (m % 64);
    }

    /// Is gateway `m` in the set? Out-of-range indices are simply absent.
    pub fn get(&self, m: usize) -> bool {
        m < self.len && (self.bits[m / 64] >> (m % 64)) & 1 == 1
    }

    /// Number of gateways the mask ranges over (NOT the popcount).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of gateways in the set.
    pub fn count(&self) -> usize {
        self.bits.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Per-gateway membership flags, in gateway order.
    pub fn iter(&self) -> impl Iterator<Item = bool> + '_ {
        (0..self.len).map(|m| self.get(m))
    }

    /// Expand back to the pre-compaction `Vec<bool>` representation
    /// (the serialization the byte-parity tests pin).
    pub fn to_vec(&self) -> Vec<bool> {
        self.iter().collect()
    }
}

/// Per-round record (one CSV row in the figure harness), delivered to
/// every [`crate::fl::RoundObserver`] as the round completes.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// τ(t) (Eq. 10) in seconds.
    pub delay: f64,
    pub cum_delay: f64,
    /// Gateways selected this round (1_m^t).
    pub selected: GatewayMask,
    /// Selected but constraint-violating (update dropped).
    pub failed: GatewayMask,
    /// Mean local training loss over participating devices.
    pub train_loss: Option<f64>,
    pub test_loss: Option<f64>,
    pub test_acc: Option<f64>,
    /// Measured ||ŵ_m − v^{K,t}|| per gateway (divergence mode only).
    pub divergence: Option<Vec<f64>>,
    /// Faults REALIZED this round (fault-injection runs only): `None`
    /// whenever nothing fired, so benign rounds serialize exactly as
    /// before the adversity layer existed.
    pub faults: Option<RoundFaults>,
}

/// Full run output.
#[derive(Clone, Debug)]
pub struct RunLog {
    pub scheme: String,
    pub records: Vec<RoundRecord>,
    /// Empirical participation rate per gateway: (1/T) Σ_t 1_m^t.
    pub participation: Vec<f64>,
    /// Effective participation (selected AND feasible).
    pub effective_participation: Vec<f64>,
}

impl RunLog {
    pub fn final_accuracy(&self) -> Option<f64> {
        self.records.iter().rev().find_map(|r| r.test_acc)
    }

    pub fn total_delay(&self) -> f64 {
        self.records.last().map_or(0.0, |r| r.cum_delay)
    }

    /// Mean measured divergence per gateway over rounds (Fig. 2).
    pub fn mean_divergence(&self) -> Option<Vec<f64>> {
        let rows: Vec<&Vec<f64>> =
            self.records.iter().filter_map(|r| r.divergence.as_ref()).collect();
        if rows.is_empty() {
            return None;
        }
        let m = rows[0].len();
        Some(
            (0..m)
                .map(|i| rows.iter().map(|r| r[i]).sum::<f64>() / rows.len() as f64)
                .collect(),
        )
    }
}

/// One fully-instantiated experiment.
pub struct Experiment {
    pub cfg: SimConfig,
    pub topo: Topology,
    /// Cost-model DNN the scheduler plans with.
    pub cost_model: ModelSpec,
    pub chan: ChannelModel,
    /// Per-device local datasets: fully materialized by default, a
    /// regenerate-on-demand [`ShardStore::Lazy`] under `lazy_shards`
    /// (nation-scale runs, where resident shards would not fit).
    pub shards: ShardStore,
    pub test_x: Vec<f32>,
    pub test_y: Vec<i32>,
    pub engine: Box<dyn Backend>,
    /// Split-execution backends indexed by partition point `l ∈ 0..=L`
    /// (built only when `cfg.execute_partition`; empty otherwise). The
    /// round loop dispatches device n's local step to
    /// `partitioned[plan.partition[n]]`.
    pub partitioned: Vec<PartitionedBackend>,
    /// Wire-level split execution (`cfg.transport == tcp`): the shared
    /// connection pool to the gateway service. `Some` also routes the
    /// phase-5 fold through the gateway ([`crate::net::transport::FoldSession`]).
    pub(crate) wire: Option<std::sync::Arc<crate::net::transport::ConnPool>>,
    /// Remote split backends indexed by partition point, mirroring
    /// `partitioned` (built only under `transport = tcp`). Local steps
    /// dispatch here first when non-empty.
    pub(crate) remote: Vec<RemoteBackend>,
}

impl Experiment {
    /// Build topology, channels, data and the execution backend (native by
    /// default; PJRT artifacts under `artifacts/` when feature-enabled).
    pub fn new(cfg: SimConfig) -> Result<Self> {
        Self::with_artifacts(cfg, std::path::Path::new("artifacts"))
    }

    pub fn with_artifacts(cfg: SimConfig, artifacts: &std::path::Path) -> Result<Self> {
        cfg.validate()?;
        let mut rng = Rng::new(cfg.seed);
        let topo = Topology::generate(&cfg, &mut rng.fork(1));
        // Structural invariants the round engine divides by (member counts,
        // FedAvg weights) are enforced once, up front.
        topo.validate()?;
        let chan = ChannelModel::new(&cfg, &topo, &mut rng.fork(2));
        let flavor = DatasetFlavor::parse(&cfg.dataset)
            .with_context(|| format!("unknown dataset {:?}", cfg.dataset))?;
        let mut data_rng = rng.fork(3);
        let data = SynthData::new(flavor, &mut data_rng);
        // The plan captures exactly the sequential draws eager sharding
        // consumes, so the test-set draws below — and every later stream —
        // are byte-identical whether shards are eager or lazy.
        let plan = ShardPlan::new(&cfg, &topo, &mut data_rng);
        let (test_x, test_y) = data.test_set(cfg.test_size, &mut data_rng);
        let shards = ShardStore::build(cfg.lazy_shards, plan, &topo, data);
        let cost_model = models::by_name(&cfg.cost_model)
            .with_context(|| format!("unknown cost model {:?}", cfg.cost_model))?;
        let engine = make_backend_kernel(artifacts, &cfg.exec_model, cfg.kernel)?;
        // Shards store flat 32·32·3 images; every executable preset (the
        // flat mlp and the NHWC cnn) must consume exactly that geometry.
        if engine.meta().sample_dim() != IMG_DIM {
            anyhow::bail!(
                "backend {:?} consumes {} features per sample, data provides {IMG_DIM}",
                engine.meta().preset,
                engine.meta().sample_dim()
            );
        }
        // Split-execution stack: one PartitionedBackend per legal cut of
        // the executed model. cfg.validate() already pinned
        // cost_model == exec_model, so the scheduler's partition indices
        // map 1:1 onto this stack.
        //
        // The stack is NATIVE numerics. When the pjrt feature would select
        // the PJRT engine for eval/init (artifacts present — mirroring
        // make_backend's choice), refuse to mix the two engines: PJRT and
        // native agree only approximately, which would silently break the
        // split-vs-fused byte-parity story.
        #[cfg(feature = "pjrt")]
        if cfg.execute_partition
            && artifacts.join(format!("{}.meta", cfg.exec_model)).exists()
        {
            anyhow::bail!(
                "execute_partition runs the native split stack, but compiled PJRT \
                 artifacts for {:?} would drive init/eval: remove the artifacts (or \
                 build without --features pjrt) so one engine owns the numerics",
                cfg.exec_model
            );
        }
        let partitioned = if cfg.execute_partition {
            make_partitioned_stack_kernel(&cfg.exec_model, cfg.kernel)?
        } else {
            Vec::new()
        };
        // Wire-level split (`transport = tcp`): one shared pool to the
        // gateway service, and a RemoteBackend per cut wrapping a second
        // stack (device-half math + metadata live in the wrapped backend;
        // the gateway half executes behind the wire). Validation already
        // pinned execute_partition, so `partitioned` above is non-empty
        // and stays THE in-process byte-parity oracle.
        let (wire, remote) = if cfg.transport == crate::config::Transport::Tcp {
            let pool = std::sync::Arc::new(crate::net::transport::ConnPool::new(
                &cfg.gateway_addr,
                cfg.wire_timeout_ms,
                &cfg.exec_model,
                cfg.kernel,
            ));
            let remote = make_partitioned_stack_kernel(&cfg.exec_model, cfg.kernel)?
                .into_iter()
                .map(|b| RemoteBackend::new(b, pool.clone()))
                .collect();
            (Some(pool), remote)
        } else {
            (None, Vec::new())
        };
        Ok(Experiment {
            cfg,
            topo,
            cost_model,
            chan,
            shards,
            test_x,
            test_y,
            engine,
            partitioned,
            wire,
            remote,
        })
    }

    /// Γ_m participation rates (Eq. 13) from a fresh §IV gradient-probe
    /// pass. [`crate::fl::Session`] caches the result per session; this
    /// helper is the one place the estimation is spelled out.
    pub(crate) fn derive_gamma(&self) -> Result<Vec<f64>> {
        let stats = self.estimate_grad_stats(4)?;
        Ok(gamma_rates(&self.topo, &stats, self.cfg.num_channels, self.cfg.lr, self.cfg.local_iters)
            .1)
    }

    /// Compat shim: construct a scheduler by scheme name through the
    /// typed [`SchedulerSpec`] bridge. Prefer [`crate::fl::Session`],
    /// which shares one Γ_m estimation across schedulers — this shim
    /// re-estimates on every DDSRA-family call.
    pub fn make_scheduler(&self, scheme: &str) -> Result<Box<dyn Scheduler>> {
        let spec: SchedulerSpec = scheme.parse()?;
        let gamma = if spec.needs_gamma() { Some(self.derive_gamma()?) } else { None };
        spec.build(self, gamma.as_deref())
    }

    /// Sample a training batch (with replacement) from device n's shard.
    /// The round engine passes a per-(round, device) stream, so any worker
    /// can draw any device's batches independently.
    pub(crate) fn sample_batch(&self, n: usize, rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
        let b = self.engine.meta().train_batch;
        let shard = self.shards.shard(&self.topo.devices[n]);
        let mut x = Vec::with_capacity(b * IMG_DIM);
        let mut y = Vec::with_capacity(b);
        for _ in 0..b {
            let i = rng.below(shard.len());
            x.extend_from_slice(&shard.images[i * IMG_DIM..(i + 1) * IMG_DIM]);
            y.push(shard.labels[i]);
        }
        (x, y)
    }

    /// Number of classes device n's shard draws from (CLI and figure
    /// participation tables). Materializes the shard under `lazy_shards`,
    /// so callers should reach for it only at table-printing scale.
    pub fn shard_class_count(&self, n: usize) -> usize {
        self.shards.shard(&self.topo.devices[n]).classes.len()
    }

    /// K local SGD iterations for device n from `start`; returns the
    /// updated params and the mean local loss.
    ///
    /// `cut` is the DNN partition point the scheduler chose for this
    /// device this round: with `execute_partition` on, the K steps run
    /// through the split device/gateway backend at that cut (the paper's
    /// §II-B training flow); otherwise — and for cut-less callers like the
    /// divergence probe — the fused engine runs.
    ///
    /// The fused engine may batch the K steps into one call when its baked
    /// fused-K matches the config (§Perf: one PJRT call + one parameter
    /// round-trip instead of K); split backends always run K single steps.
    pub(crate) fn local_train(
        &self,
        n: usize,
        cut: Option<usize>,
        start: &Params,
        rng: &mut Rng,
    ) -> Result<(Params, f64)> {
        let k = self.cfg.local_iters;
        let backend: &dyn Backend = match cut {
            // Wire-level split first: under `transport = tcp` the cut
            // steps cross the network to the gateway service. Cut-less
            // callers (divergence probe, eval) stay on the local engine.
            Some(l) if !self.remote.is_empty() => {
                let stack = &self.remote;
                stack.get(l).map(|b| b as &dyn Backend).ok_or_else(|| {
                    anyhow::anyhow!(
                        "partition point {l} outside the executable model's 0..={}",
                        stack.len() - 1
                    )
                })?
            }
            Some(l) if !self.partitioned.is_empty() => {
                let stack = &self.partitioned;
                stack.get(l).map(|b| b as &dyn Backend).ok_or_else(|| {
                    anyhow::anyhow!(
                        "partition point {l} outside the executable model's 0..={}",
                        stack.len() - 1
                    )
                })?
            }
            _ => self.engine.as_ref(),
        };
        if backend.fused_k() == Some(k) {
            let b = backend.meta().train_batch;
            let mut xs = Vec::with_capacity(k * b * IMG_DIM);
            let mut ys = Vec::with_capacity(k * b);
            for _ in 0..k {
                let (x, y) = self.sample_batch(n, rng);
                xs.extend(x);
                ys.extend(y);
            }
            let (w, loss) = backend.train_k_steps(start, &xs, &ys, self.cfg.lr as f32)?;
            return Ok((w, loss as f64));
        }
        let mut w = start.clone();
        let mut loss_sum = 0.0;
        for _ in 0..k {
            let (x, y) = self.sample_batch(n, rng);
            let (nw, loss) = backend.train_step(&w, &x, &y, self.cfg.lr as f32)?;
            w = nw;
            loss_sum += loss as f64;
        }
        Ok((w, loss_sum / k as f64))
    }

    /// Compat shim: run one scheduler for `opts.rounds` communication
    /// rounds through the streaming round engine, buffering records into
    /// a [`RunLog`]. Prefer [`crate::fl::Session`], whose builder is the
    /// only place [`RunOpts`] is assembled and whose observer layer
    /// streams records instead of buffering them. See
    /// [`crate::fl::round`] for the phase structure, the RNG stream map,
    /// and the determinism guarantees.
    pub fn run(&self, sched: &mut dyn Scheduler, opts: &RunOpts) -> Result<RunLog> {
        RoundEngine::new(self).run_logged(sched, opts)
    }
}

#[cfg(test)]
mod tests {
    use super::GatewayMask;

    #[test]
    fn gateway_mask_set_get_count_roundtrip() {
        let flags = [true, false, true, true, false, false];
        let mask = GatewayMask::from_slice(&flags);
        assert_eq!(mask.len(), 6);
        assert!(!mask.is_empty());
        assert_eq!(mask.count(), 3);
        for (m, &f) in flags.iter().enumerate() {
            assert_eq!(mask.get(m), f, "gateway {m}");
        }
        assert_eq!(mask.to_vec(), flags.to_vec());
        assert_eq!(mask.iter().collect::<Vec<_>>(), flags.to_vec());
        // Out-of-range membership is simply absent.
        assert!(!mask.get(6));
        assert!(!mask.get(1000));
    }

    #[test]
    fn gateway_mask_spans_multiple_words() {
        // Metro scale: 96 gateways is more than one u64 word.
        let mut mask = GatewayMask::new(96);
        assert_eq!(mask.count(), 0);
        for m in [0usize, 63, 64, 70, 95] {
            mask.set(m);
        }
        assert_eq!(mask.count(), 5);
        assert!(mask.get(63) && mask.get(64) && mask.get(95));
        assert!(!mask.get(62) && !mask.get(65));
        let roundtrip = GatewayMask::from_slice(&mask.to_vec());
        assert_eq!(roundtrip, mask);
    }

    #[test]
    fn empty_gateway_mask() {
        let mask = GatewayMask::new(0);
        assert!(mask.is_empty());
        assert_eq!(mask.count(), 0);
        assert_eq!(mask.to_vec(), Vec::<bool>::new());
    }
}
