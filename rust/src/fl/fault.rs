//! Deterministic adversity: the [`FaultPlan`] consulted by the round
//! engine at its phase seams (see `fl::round`), plus the per-round
//! [`RoundFaults`] report carried on `RoundRecord`.
//!
//! Every fault is drawn from its own dedicated `Rng::stream` domain keyed
//! `[DOMAIN, round, id]`, never from the env/train/divergence streams, so
//!
//! * fault-injected runs are byte-identical across rayon thread counts
//!   (any worker can reconstruct any fault draw independently), and
//! * a benign [`FaultPlan::none()`] performs ZERO draws and leaves the
//!   engine's output byte-for-byte identical to an engine without the
//!   fault layer — arming a knob cannot perturb any other stream.
//!
//! Fault-stream domains (also listed in the `fl::round` stream map and
//! `docs/ARCHITECTURE.md` §4):
//!
//! | domain | key path | consumer |
//! |---|---|---|
//! | [`STREAM_FAULT_STRAGGLER`] | `[dom, t, device]` | phase-2 delay multiplier |
//! | [`STREAM_FAULT_DROPOUT`]   | `[dom, t, device]` | phase-3/4 device dropout |
//! | [`STREAM_FAULT_OUTAGE`]    | `[dom, t, gateway]` | phase-3 gateway outage |
//! | [`STREAM_FAULT_SHARD`]     | `[dom, device]` | phase-0 Dirichlet sharding |

use crate::config::{FaultConfig, SimConfig};
use crate::fl::orchestrator::GatewayMask;
use crate::rng::Rng;

/// Straggler delay-multiplier stream, keyed `[STREAM_FAULT_STRAGGLER, t, n]`.
pub const STREAM_FAULT_STRAGGLER: u64 = 0xFA57;
/// Mid-round device-dropout stream, keyed `[STREAM_FAULT_DROPOUT, t, n]`.
pub const STREAM_FAULT_DROPOUT: u64 = 0xFAD0;
/// Gateway-outage stream, keyed `[STREAM_FAULT_OUTAGE, t, m]`.
pub const STREAM_FAULT_OUTAGE: u64 = 0xFA07;
/// Dirichlet-sharding stream, keyed `[STREAM_FAULT_SHARD, n]` (phase 0,
/// consumed by `data::shard`).
pub const STREAM_FAULT_SHARD: u64 = 0xFA5D;

/// The validated fault schedule for a run: the `fault.*` config block plus
/// the run seed the fault streams are keyed under. Stateless — every query
/// re-derives its stream from `(seed, domain, round, id)`, so queries may
/// happen from any worker in any order.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    seed: u64,
    cfg: FaultConfig,
}

impl FaultPlan {
    /// The benign plan: no knob armed, no stream ever drawn.
    pub fn none() -> Self {
        FaultPlan { seed: 0, cfg: FaultConfig::default() }
    }

    /// Build the plan for a run (callers validate `cfg` beforehand; the
    /// engine constructs this from an already-validated `SimConfig`).
    pub fn from_config(cfg: &SimConfig) -> Self {
        FaultPlan { seed: cfg.seed, cfg: cfg.fault.clone() }
    }

    /// True when every knob is benign — the engine skips the fault seams.
    pub fn is_none(&self) -> bool {
        self.cfg.is_benign()
    }

    /// Straggler knob armed?
    pub fn has_stragglers(&self) -> bool {
        self.cfg.straggler_prob > 0.0 && self.cfg.straggler_slowdown > 1.0
    }

    /// Device-dropout knob armed?
    pub fn has_dropout(&self) -> bool {
        self.cfg.dropout_prob > 0.0
    }

    /// Gateway-outage knob armed?
    pub fn has_outages(&self) -> bool {
        self.cfg.gateway_outage_prob > 0.0
    }

    /// Any per-round (phase 2-4) fault armed? (Dirichlet sharding is a
    /// phase-0 property of the data, not a per-round fault.)
    pub fn has_round_faults(&self) -> bool {
        self.has_stragglers() || self.has_dropout() || self.has_outages()
    }

    /// Phase 2: the delay multiplier for device n in round t. Exactly 1.0
    /// unless the straggler coin fires, in which case the episode slows
    /// the device by U(1, slowdown). `x * 1.0` is bit-exact in IEEE-754,
    /// so non-straggler rounds leave `round_delay()` bytes untouched.
    pub fn straggler_multiplier(&self, t: usize, n: usize) -> f64 {
        if !self.has_stragglers() {
            return 1.0;
        }
        let mut rng = Rng::stream(self.seed, &[STREAM_FAULT_STRAGGLER, t as u64, n as u64]);
        if rng.f64() < self.cfg.straggler_prob {
            rng.uniform(1.0, self.cfg.straggler_slowdown)
        } else {
            1.0
        }
    }

    /// Phases 3-4: does device n drop out of round t? A dropped device
    /// trains nothing and contributes nothing to the FedAvg fold.
    pub fn device_dropped(&self, t: usize, n: usize) -> bool {
        self.has_dropout()
            && Rng::stream(self.seed, &[STREAM_FAULT_DROPOUT, t as u64, n as u64]).f64()
                < self.cfg.dropout_prob
    }

    /// Phase 3: is gateway m's whole floor out for round t? An out
    /// gateway counts as failed; none of its members train.
    pub fn gateway_out(&self, t: usize, m: usize) -> bool {
        self.has_outages()
            && Rng::stream(self.seed, &[STREAM_FAULT_OUTAGE, t as u64, m as u64]).f64()
                < self.cfg.gateway_outage_prob
    }
}

/// What actually went wrong in one round — the per-round fault report on
/// `RoundRecord`. The engine only attaches it when something REALIZED
/// (`any()`), so benign rounds and benign runs serialize exactly as
/// before the fault layer existed.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundFaults {
    /// Devices that dropped mid-round (sorted ascending).
    pub dropped: Vec<usize>,
    /// Gateways whose whole floor was out this round.
    pub outages: GatewayMask,
    /// Largest realized straggler delay multiplier (1.0 = none fired).
    pub max_slowdown: f64,
}

impl RoundFaults {
    /// An empty report for a topology with `gateways` floors.
    pub fn new(gateways: usize) -> Self {
        RoundFaults { dropped: Vec::new(), outages: GatewayMask::new(gateways), max_slowdown: 1.0 }
    }

    /// Did any fault realize this round?
    pub fn any(&self) -> bool {
        !self.dropped.is_empty() || self.outages.count() > 0 || self.max_slowdown > 1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn benign_plan_never_fires_and_multiplier_is_exactly_one() {
        let plan = FaultPlan::none();
        assert!(plan.is_none());
        assert!(!plan.has_round_faults());
        for t in 0..50 {
            for n in 0..10 {
                assert_eq!(plan.straggler_multiplier(t, n).to_bits(), 1.0f64.to_bits());
                assert!(!plan.device_dropped(t, n));
                assert!(!plan.gateway_out(t, n));
            }
        }
    }

    #[test]
    fn armed_plan_is_replayable_and_stream_keyed() {
        let mut cfg = SimConfig::default();
        cfg.fault.straggler_prob = 0.5;
        cfg.fault.straggler_slowdown = 4.0;
        cfg.fault.dropout_prob = 0.3;
        cfg.fault.gateway_outage_prob = 0.3;
        let plan = FaultPlan::from_config(&cfg);
        assert!(plan.has_round_faults());
        // Stateless replay: the same (t, n) query always answers the same.
        for t in 0..20 {
            for n in 0..8 {
                assert_eq!(
                    plan.straggler_multiplier(t, n).to_bits(),
                    plan.straggler_multiplier(t, n).to_bits()
                );
                assert_eq!(plan.device_dropped(t, n), plan.device_dropped(t, n));
                assert_eq!(plan.gateway_out(t, n), plan.gateway_out(t, n));
            }
        }
        // The knobs actually fire at these probabilities: over 20x8 cells
        // some drop and some survive.
        let drops = (0..20)
            .flat_map(|t| (0..8).map(move |n| (t, n)))
            .filter(|&(t, n)| plan.device_dropped(t, n))
            .count();
        assert!(drops > 0 && drops < 160, "dropout coin looks stuck: {drops}/160");
        // A realized straggler multiplier lands in (1, slowdown).
        let slow = (0..200)
            .map(|t| plan.straggler_multiplier(t, 0))
            .find(|&s| s > 1.0)
            .expect("no straggler fired in 200 rounds at p=0.5");
        assert!(slow < 4.0, "{slow}");
    }

    #[test]
    fn round_faults_any_tracks_realized_faults() {
        let mut f = RoundFaults::new(3);
        assert!(!f.any());
        f.max_slowdown = 2.5;
        assert!(f.any());
        let mut f = RoundFaults::new(3);
        f.dropped.push(7);
        assert!(f.any());
        let mut f = RoundFaults::new(3);
        f.outages.set(1);
        assert!(f.any());
    }
}
