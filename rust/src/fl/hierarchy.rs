//! Hierarchical multi-tier aggregation: device → gateway
//! partial-aggregate → edge cluster → cloud.
//!
//! The flat phase-5 fold streams every surviving update through ONE
//! cloud-side [`WeightedAccum`]; at nation scale that makes the cloud
//! tier the single aggregation hot spot. The hierarchical path
//! ([`HierFold`]) instead folds each scheduled gateway's members through
//! the gateway's OWN accumulator, merges gateway summaries per edge
//! cluster, and merges cluster summaries at the cloud — only tier
//! summaries (one parameter-shaped buffer each) ever move up, so the
//! per-tier fold cost is O(members of that tier), never O(N). The
//! relaying of those summaries is what the scheduler's relay/Ψ energy
//! term prices (`relay_psi`, per Hashempour et al., PAPERS.md).
//!
//! ## Fold order, determinism, and the flat oracle
//!
//! The fold order is FIXED at every tier: units fold into their gateway
//! in plan order (members ascending within a gateway), gateway summaries
//! merge in ascending gateway index within their cluster, and cluster
//! summaries merge in ascending cluster index (`Topology::clusters` is a
//! validated ascending contiguous partition). No ordering depends on
//! wall-clock or worker interleaving, so hierarchical runs are
//! byte-identical across thread counts exactly like flat runs.
//!
//! Against the flat oracle: both paths fold the SAME (update, D̃_n)
//! multiset, and for schedulers whose plans list gateways in ascending
//! order (round-robin, delay-driven, DDSRA) the per-gateway add
//! sequences coincide term-for-term with the flat fold's — the two paths
//! differ only in where gateway/cluster boundaries associate the f64
//! partial sums. Each folded term `D̃_n · p` is exactly representable
//! (24-bit f32 significand × a small integer weight), and the per-
//! coordinate exponent spread across one round's updates is small (every
//! device starts the round from the same global model), so the partial
//! sums stay inside f64's 53-bit window and the regrouped sum is the
//! same exact value — `rust/tests/hierarchy.rs` pins flat == hierarchical
//! bytes on the `paper` and `plant` scenarios end to end.

use crate::config::Aggregation;
use crate::fl::vecmath::WeightedAccum;
use crate::runtime::Params;
use crate::topo::Topology;

/// The gateway tier of one round's aggregation: one [`WeightedAccum`]
/// per gateway (lazily allocated — an unscheduled gateway's slot is an
/// empty accumulator and costs no parameter buffer), merged tier-by-tier
/// at [`HierFold::finish`].
#[derive(Debug, Default)]
pub struct HierFold {
    gateways: Vec<WeightedAccum>,
}

impl HierFold {
    pub fn new(num_gateways: usize) -> Self {
        HierFold { gateways: (0..num_gateways).map(|_| WeightedAccum::new()).collect() }
    }

    /// Fold one device update into its gateway's partial aggregate.
    pub fn add(&mut self, gateway: usize, p: &Params, w: f64) {
        self.gateways[gateway].add(p, w);
    }

    /// Updates folded into gateway `m` so far.
    pub fn gateway_count(&self, m: usize) -> usize {
        self.gateways[m].count()
    }

    /// Total updates folded across all gateways.
    pub fn count(&self) -> usize {
        self.gateways.iter().map(|a| a.count()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.gateways.iter().all(|a| a.is_empty())
    }

    /// Merge the tiers upward and finish: gateway summaries fold per
    /// edge cluster (ascending gateway index), cluster summaries fold at
    /// the cloud (ascending cluster index). `None` when nothing was
    /// folded anywhere — the round then leaves the global model
    /// unchanged, exactly like the flat path.
    pub fn finish(self, topo: &Topology) -> Option<Params> {
        debug_assert_eq!(self.gateways.len(), topo.num_gateways());
        let mut gateways = self.gateways;
        let mut cloud = WeightedAccum::new();
        for cluster in &topo.clusters {
            let mut edge = WeightedAccum::new();
            for &m in &cluster.gateways {
                let summary = std::mem::take(&mut gateways[m]);
                if !summary.is_empty() {
                    edge.merge(summary);
                }
            }
            if !edge.is_empty() {
                cloud.merge(edge);
            }
        }
        cloud.finish()
    }
}

/// The phase-5 fold behind the `aggregation` config knob: `Flat` is the
/// original single-accumulator path (the byte-exactness oracle),
/// `Hierarchical` is the tiered path. Both receive the identical
/// `(gateway, update, weight)` stream from phase 4; `Flat` simply
/// ignores the gateway.
#[derive(Debug)]
pub enum AggFold {
    Flat(WeightedAccum),
    Hierarchical(HierFold),
}

impl AggFold {
    /// The fold the config asks for.
    pub fn for_config(aggregation: Aggregation, num_gateways: usize) -> Self {
        match aggregation {
            Aggregation::Flat => AggFold::Flat(WeightedAccum::new()),
            Aggregation::Hierarchical => AggFold::Hierarchical(HierFold::new(num_gateways)),
        }
    }

    /// Fold one device update in (phase-4 plan order).
    pub fn add(&mut self, gateway: usize, p: &Params, w: f64) {
        match self {
            AggFold::Flat(acc) => acc.add(p, w),
            AggFold::Hierarchical(h) => h.add(gateway, p, w),
        }
    }

    /// Updates folded in so far.
    pub fn count(&self) -> usize {
        match self {
            AggFold::Flat(acc) => acc.count(),
            AggFold::Hierarchical(h) => h.count(),
        }
    }

    /// The round's aggregate; `None` when no update survived to fold.
    pub fn finish(self, topo: &Topology) -> Option<Params> {
        match self {
            AggFold::Flat(acc) => acc.finish(),
            AggFold::Hierarchical(h) => h.finish(topo),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::rng::Rng;
    use crate::topo::Topology;

    fn topo(clusters: usize) -> Topology {
        let mut cfg = SimConfig::default();
        cfg.num_clusters = clusters;
        let t = Topology::generate(&cfg, &mut Rng::new(1));
        t.validate().unwrap();
        t
    }

    /// Dyadic values + small integer weights keep every product and
    /// partial sum exactly representable, so flat and hierarchical folds
    /// compute the same exact sum and byte equality is deterministic.
    fn dyadic_params(n: u64) -> Params {
        let mut rng = Rng::new(100 + n);
        (0..2)
            .map(|_| (0..6).map(|_| (rng.below(64) as f32 - 32.0) / 8.0).collect())
            .collect()
    }

    #[test]
    fn hierarchical_matches_flat_fold_bitwise_on_exact_inputs() {
        for clusters in [1usize, 2, 3, 6] {
            let topo = topo(clusters);
            let mut flat = WeightedAccum::new();
            let mut hier = HierFold::new(topo.num_gateways());
            // Units arrive gateway-contiguous in ascending gateway order —
            // the plan order the round engine feeds both paths.
            for m in 0..topo.num_gateways() {
                for (i, &n) in topo.gateways[m].members.iter().enumerate() {
                    let p = dyadic_params(n as u64);
                    let w = (2 + i) as f64;
                    flat.add(&p, w);
                    hier.add(m, &p, w);
                }
            }
            assert_eq!(hier.count(), flat.count());
            let (f, h) = (flat.finish().unwrap(), hier.finish(&topo).unwrap());
            for (tf, th) in f.iter().zip(&h) {
                for (vf, vh) in tf.iter().zip(th) {
                    assert_eq!(vf.to_bits(), vh.to_bits(), "clusters = {clusters}");
                }
            }
        }
    }

    #[test]
    fn unscheduled_gateways_contribute_nothing() {
        let topo = topo(3);
        let mut hier = HierFold::new(topo.num_gateways());
        let mut only = HierFold::new(topo.num_gateways());
        // Gateway 2 folds in both; gateway 4's extra updates only in one.
        for &n in &topo.gateways[2].members {
            let p = dyadic_params(n as u64);
            hier.add(2, &p, 3.0);
            only.add(2, &p, 3.0);
        }
        for &n in &topo.gateways[4].members {
            hier.add(4, &dyadic_params(n as u64), 5.0);
        }
        assert_eq!(only.gateway_count(4), 0);
        assert_eq!(only.gateway_count(2), topo.gateways[2].members.len());
        // An empty gateway slot is invisible to the merge: dropping
        // gateway 4 entirely gives the gateway-2-only aggregate.
        let with4 = hier.finish(&topo).unwrap();
        let without4 = only.finish(&topo).unwrap();
        assert_ne!(with4, without4, "gateway 4's fold must actually matter");
        let mut solo = WeightedAccum::new();
        for &n in &topo.gateways[2].members {
            solo.add(&dyadic_params(n as u64), 3.0);
        }
        let expect = solo.finish().unwrap();
        for (a, b) in without4.iter().zip(&expect) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn empty_fold_leaves_model_unchanged() {
        let topo = topo(2);
        assert!(HierFold::new(topo.num_gateways()).finish(&topo).is_none());
        let empty = AggFold::for_config(Aggregation::Hierarchical, topo.num_gateways());
        assert_eq!(empty.count(), 0);
        assert!(empty.finish(&topo).is_none());
    }

    #[test]
    fn agg_fold_routes_by_config() {
        let topo = topo(1);
        let p = dyadic_params(7);
        let mut flat = AggFold::for_config(Aggregation::Flat, topo.num_gateways());
        let mut hier = AggFold::for_config(Aggregation::Hierarchical, topo.num_gateways());
        flat.add(0, &p, 2.0);
        hier.add(0, &p, 2.0);
        assert_eq!(flat.count(), 1);
        assert_eq!(hier.count(), 1);
        // A single update averages to itself on both paths.
        assert_eq!(flat.finish(&topo).unwrap(), p);
        assert_eq!(hier.finish(&topo).unwrap(), p);
    }
}
