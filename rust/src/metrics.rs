//! CSV emitters for run logs — every figure in the paper is regenerated as
//! a CSV under `results/` plus a printed table.

use std::fs;
use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::fl::RunLog;

/// Minimal CSV writer (no external deps offline).
pub struct Csv {
    file: fs::File,
    cols: usize,
}

impl Csv {
    pub fn create(path: &Path, header: &[&str]) -> Result<Self> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir).with_context(|| format!("mkdir {dir:?}"))?;
        }
        let mut file = fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        writeln!(file, "{}", header.join(","))?;
        Ok(Csv { file, cols: header.len() })
    }

    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        anyhow::ensure!(fields.len() == self.cols, "row width {} != header {}", fields.len(), self.cols);
        writeln!(self.file, "{}", fields.join(","))?;
        Ok(())
    }

    pub fn rowf(&mut self, fields: &[f64]) -> Result<()> {
        self.row(&fields.iter().map(|v| format!("{v}")).collect::<Vec<_>>())
    }
}

/// Write one run's per-round records.
pub fn write_run_csv(log: &RunLog, path: &Path) -> Result<()> {
    let mut csv = Csv::create(
        path,
        &["round", "delay", "cum_delay", "train_loss", "test_loss", "test_acc", "num_selected", "num_failed"],
    )?;
    for r in &log.records {
        csv.row(&[
            r.round.to_string(),
            format!("{:.6}", r.delay),
            format!("{:.6}", r.cum_delay),
            r.train_loss.map_or(String::new(), |v| format!("{v:.6}")),
            r.test_loss.map_or(String::new(), |v| format!("{v:.6}")),
            r.test_acc.map_or(String::new(), |v| format!("{v:.6}")),
            r.selected.iter().filter(|&&s| s).count().to_string(),
            r.failed.iter().filter(|&&f| f).count().to_string(),
        ])?;
    }
    Ok(())
}

/// Simple fixed-width table printer for terminal summaries.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("iiot_fl_csv_test");
        let path = dir.join("t.csv");
        let mut c = Csv::create(&path, &["a", "b"]).unwrap();
        c.rowf(&[1.5, 2.5]).unwrap();
        c.row(&["x".into(), "y".into()]).unwrap();
        assert!(c.row(&["only-one".into()]).is_err());
        drop(c);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1.5,2.5\nx,y\n");
        let _ = std::fs::remove_dir_all(dir);
    }
}
