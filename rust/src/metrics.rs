//! Telemetry emitters: streaming sinks for run records (CSV, JSONL,
//! stderr progress, in-memory), plus the CSV/table primitives the figure
//! harness uses.
//!
//! Every sink implements [`RoundObserver`] and receives each
//! [`RoundRecord`] AS THE ROUND COMPLETES — a metro-scale run writes its
//! CSV while training, instead of buffering thousands of records for a
//! post-hoc dump. [`MemorySink`] is the one buffering sink: it rebuilds
//! the classic [`RunLog`] for tables, tests and back-compat callers.
//! [`write_run_csv`] (the old post-hoc emitter) is now a thin loop over
//! [`CsvSink`], so streamed and post-hoc CSVs are byte-identical by
//! construction (pinned by `rust/tests/session.rs`).

use std::fs;
use std::io::Write;
use std::ops::ControlFlow;
use std::path::Path;

use anyhow::{Context, Result};

use crate::fl::{RoundObserver, RoundRecord, RunLog, RunMeta, RunSummary};

/// Minimal CSV writer (no external deps offline).
pub struct Csv {
    file: fs::File,
    cols: usize,
}

impl Csv {
    pub fn create(path: &Path, header: &[&str]) -> Result<Self> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir).with_context(|| format!("mkdir {dir:?}"))?;
        }
        let mut file = fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        writeln!(file, "{}", header.join(","))?;
        Ok(Csv { file, cols: header.len() })
    }

    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        let (got, want) = (fields.len(), self.cols);
        anyhow::ensure!(got == want, "row width {got} != header {want}");
        writeln!(self.file, "{}", fields.join(","))?;
        Ok(())
    }

    pub fn rowf(&mut self, fields: &[f64]) -> Result<()> {
        self.row(&fields.iter().map(|v| format!("{v}")).collect::<Vec<_>>())
    }
}

/// Column order of the per-round run CSV (streamed by [`CsvSink`],
/// replayed post-hoc by [`write_run_csv`]).
pub const RUN_CSV_HEADER: &[&str] = &[
    "round",
    "delay",
    "cum_delay",
    "train_loss",
    "test_loss",
    "test_acc",
    "num_selected",
    "num_failed",
];

fn run_csv_row(r: &RoundRecord) -> Vec<String> {
    vec![
        r.round.to_string(),
        format!("{:.6}", r.delay),
        format!("{:.6}", r.cum_delay),
        r.train_loss.map_or(String::new(), |v| format!("{v:.6}")),
        r.test_loss.map_or(String::new(), |v| format!("{v:.6}")),
        r.test_acc.map_or(String::new(), |v| format!("{v:.6}")),
        r.selected.count().to_string(),
        r.failed.count().to_string(),
    ]
}

// ------------------------------------------------------------------ sinks

/// Streams one CSV row per round, during the run.
pub struct CsvSink {
    csv: Csv,
}

impl CsvSink {
    pub fn create(path: &Path) -> Result<Self> {
        Ok(CsvSink { csv: Csv::create(path, RUN_CSV_HEADER)? })
    }

    /// Append one record's row (shared by the streaming observer path
    /// and the post-hoc [`write_run_csv`] replay).
    pub fn write_record(&mut self, r: &RoundRecord) -> Result<()> {
        self.csv.row(&run_csv_row(r))
    }
}

impl RoundObserver for CsvSink {
    fn on_record(&mut self, record: &RoundRecord) -> Result<ControlFlow<()>> {
        self.write_record(record)?;
        Ok(ControlFlow::Continue(()))
    }
}

/// Render a finite f64 as a JSON number (shortest round-trip form);
/// non-finite values have no JSON representation and become `null`.
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

fn json_opt(v: Option<f64>) -> String {
    v.map_or_else(|| "null".into(), json_f64)
}

/// JSON string literal with the mandatory escapes — scheme names come
/// from `Scheduler::name()`, which callers with custom schedulers may
/// populate with anything.
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn json_arr(vs: &[f64]) -> String {
    let body: Vec<String> = vs.iter().map(|&v| json_f64(v)).collect();
    format!("[{}]", body.join(","))
}

fn json_usize_arr(vs: &[usize]) -> String {
    let body: Vec<String> = vs.iter().map(|v| v.to_string()).collect();
    format!("[{}]", body.join(","))
}

/// Streams one JSON object per line: a `meta` line before round 0, one
/// `round` line per record, and a closing `summary` line. The schema is
/// pinned by a golden file in `rust/tests/session.rs`.
pub struct JsonlSink {
    file: fs::File,
}

impl JsonlSink {
    pub fn create(path: &Path) -> Result<Self> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir).with_context(|| format!("mkdir {dir:?}"))?;
        }
        let file = fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        Ok(JsonlSink { file })
    }
}

impl RoundObserver for JsonlSink {
    fn on_start(&mut self, meta: &RunMeta) -> Result<()> {
        writeln!(
            self.file,
            "{{\"type\":\"meta\",\"scheme\":{},\"rounds\":{},\"gateways\":{},\"devices\":{}}}",
            json_str(&meta.scheme),
            meta.rounds,
            meta.gateways,
            meta.devices
        )?;
        Ok(())
    }

    fn on_record(&mut self, r: &RoundRecord) -> Result<ControlFlow<()>> {
        let divergence =
            r.divergence.as_ref().map_or_else(|| "null".into(), |d| json_arr(d));
        // The faults object is appended ONLY when faults realized, so
        // benign runs keep the golden-pinned line bytes unchanged.
        let faults = r.faults.as_ref().map_or_else(String::new, |f| {
            format!(
                ",\"faults\":{{\"dropped\":{},\"outages\":{},\"max_slowdown\":{}}}",
                json_usize_arr(&f.dropped),
                f.outages.count(),
                json_f64(f.max_slowdown),
            )
        });
        writeln!(
            self.file,
            "{{\"type\":\"round\",\"round\":{},\"delay\":{},\"cum_delay\":{},\
             \"selected\":{},\"failed\":{},\"train_loss\":{},\"test_loss\":{},\
             \"test_acc\":{},\"divergence\":{}{}}}",
            r.round,
            json_f64(r.delay),
            json_f64(r.cum_delay),
            r.selected.count(),
            r.failed.count(),
            json_opt(r.train_loss),
            json_opt(r.test_loss),
            json_opt(r.test_acc),
            divergence,
            faults,
        )?;
        Ok(ControlFlow::Continue(()))
    }

    fn on_final_eval(&mut self, r: &RoundRecord) -> Result<()> {
        // The stopping round's forced eval, framed as its own line so the
        // preceding `round` lines stay a byte-identical prefix of the
        // uninterrupted run's stream.
        writeln!(
            self.file,
            "{{\"type\":\"final_eval\",\"round\":{},\"test_loss\":{},\"test_acc\":{}}}",
            r.round,
            json_opt(r.test_loss),
            json_opt(r.test_acc),
        )?;
        Ok(())
    }

    fn on_finish(&mut self, s: &RunSummary) -> Result<()> {
        let stop = s.stop.as_ref().map_or_else(|| "null".into(), |c| format!("\"{}\"", c.kind()));
        writeln!(
            self.file,
            "{{\"type\":\"summary\",\"scheme\":{},\"rounds_run\":{},\"stop\":{},\
             \"participation\":{},\"effective_participation\":{}}}",
            json_str(&s.scheme),
            s.rounds_run,
            stop,
            json_arr(&s.participation),
            json_arr(&s.effective_participation),
        )?;
        Ok(())
    }
}

/// Stderr heartbeat for long (metro-scale) runs: one line every `every`
/// rounds plus a closing summary, so a multi-hour run is observably
/// alive without buffering anything.
pub struct ProgressSink {
    every: usize,
    scheme: String,
    rounds: usize,
}

impl ProgressSink {
    /// Report every `every` rounds (clamped to ≥ 1).
    pub fn every(every: usize) -> Self {
        ProgressSink { every: every.max(1), scheme: String::new(), rounds: 0 }
    }
}

impl RoundObserver for ProgressSink {
    fn on_start(&mut self, meta: &RunMeta) -> Result<()> {
        self.scheme = meta.scheme.clone();
        self.rounds = meta.rounds;
        eprintln!(
            "[{}] starting: {} rounds over {} gateways / {} devices",
            meta.scheme, meta.rounds, meta.gateways, meta.devices
        );
        Ok(())
    }

    fn on_record(&mut self, r: &RoundRecord) -> Result<ControlFlow<()>> {
        if (r.round + 1) % self.every == 0 || r.round + 1 == self.rounds {
            let loss = r.train_loss.map_or("-".into(), |v| format!("{v:.4}"));
            let acc = r.test_acc.map_or("-".into(), |v| format!("{:.1}%", v * 100.0));
            eprintln!(
                "[{}] round {}/{}  τ={:.1}s  Στ={:.1}s  loss={}  acc={}",
                self.scheme,
                r.round + 1,
                self.rounds,
                r.delay,
                r.cum_delay,
                loss,
                acc
            );
        }
        Ok(ControlFlow::Continue(()))
    }

    fn on_finish(&mut self, s: &RunSummary) -> Result<()> {
        match &s.stop {
            Some(cause) => eprintln!("[{}] stopped early: {cause}", s.scheme),
            None => eprintln!("[{}] finished {} rounds", s.scheme, s.rounds_run),
        }
        Ok(())
    }
}

/// The one buffering sink: collects records and the end-of-run summary,
/// rebuilding the classic [`RunLog`] for tables, tests and back-compat
/// callers. Records are memory-lean ([`crate::fl::GatewayMask`] bitmasks
/// instead of `Vec<bool>` per round), so buffering stays cheap even at
/// `--scenario metro`.
#[derive(Default)]
pub struct MemorySink {
    scheme: String,
    records: Vec<RoundRecord>,
    participation: Vec<f64>,
    effective_participation: Vec<f64>,
}

impl MemorySink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn records(&self) -> &[RoundRecord] {
        &self.records
    }

    /// The buffered run as a [`RunLog`] (byte-compatible with what the
    /// pre-session engine returned — pinned by the replay suites).
    pub fn into_log(self) -> RunLog {
        RunLog {
            scheme: self.scheme,
            records: self.records,
            participation: self.participation,
            effective_participation: self.effective_participation,
        }
    }
}

impl RoundObserver for MemorySink {
    fn on_start(&mut self, meta: &RunMeta) -> Result<()> {
        self.scheme = meta.scheme.clone();
        self.records.clear();
        Ok(())
    }

    fn on_record(&mut self, record: &RoundRecord) -> Result<ControlFlow<()>> {
        self.records.push(record.clone());
        Ok(ControlFlow::Continue(()))
    }

    fn on_final_eval(&mut self, record: &RoundRecord) -> Result<()> {
        // The buffered log should end on the evaluated form of the
        // stopping round — callers read `final_accuracy()` off it.
        if let Some(last) = self.records.last_mut() {
            *last = record.clone();
        }
        Ok(())
    }

    fn on_finish(&mut self, s: &RunSummary) -> Result<()> {
        self.participation = s.participation.clone();
        self.effective_participation = s.effective_participation.clone();
        Ok(())
    }
}

/// Write one run's per-round records post-hoc — a replay of the
/// [`CsvSink`] streaming path over a buffered log, guaranteed
/// byte-identical to streaming the same records during the run.
pub fn write_run_csv(log: &RunLog, path: &Path) -> Result<()> {
    let mut sink = CsvSink::create(path)?;
    for r in &log.records {
        sink.write_record(r)?;
    }
    Ok(())
}

/// Simple fixed-width table printer for terminal summaries.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("iiot_fl_csv_test");
        let path = dir.join("t.csv");
        let mut c = Csv::create(&path, &["a", "b"]).unwrap();
        c.rowf(&[1.5, 2.5]).unwrap();
        c.row(&["x".into(), "y".into()]).unwrap();
        assert!(c.row(&["only-one".into()]).is_err());
        drop(c);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1.5,2.5\nx,y\n");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn json_scalars_render_compactly() {
        assert_eq!(json_f64(1.5), "1.5");
        assert_eq!(json_f64(1.0), "1");
        assert_eq!(json_f64(f64::NAN), "null");
        assert_eq!(json_opt(None), "null");
        assert_eq!(json_opt(Some(0.25)), "0.25");
        assert_eq!(json_arr(&[1.0, 0.5]), "[1,0.5]");
        assert_eq!(json_arr(&[]), "[]");
    }
}
