//! Synthetic datasets + non-IID sharding (§VII-A substitution — see
//! DESIGN.md: SVHN/CIFAR-10 cannot be downloaded offline, so we generate
//! class-conditional image data that preserves the properties the paper's
//! experiments depend on: per-class structure, non-IID degradation, and
//! per-device gradient-variance spread).

pub mod shard;
pub mod synth;

pub use shard::{shard_non_iid, DeviceShard, ShardPlan, ShardStore};
pub use synth::{DatasetFlavor, SynthData};
