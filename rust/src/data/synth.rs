//! Class-conditional synthetic image generator.
//!
//! Each of the 10 classes has a random prototype in R^3072 (= 32x32x3);
//! a sample is `prototype * sep + noise`. The "svhn" flavour is more
//! separable than "cifar" — mirroring the relative difficulty in the paper
//! (SVHN converges faster / higher accuracy than CIFAR-10 on VGG-11).

use crate::rng::Rng;

pub const IMG_DIM: usize = 32 * 32 * 3;
pub const NUM_CLASSES: usize = 10;

/// Dataset flavour: controls class separability.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetFlavor {
    Svhn,
    Cifar,
}

impl DatasetFlavor {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "svhn" => Some(DatasetFlavor::Svhn),
            "cifar" | "cifar10" => Some(DatasetFlavor::Cifar),
            _ => None,
        }
    }

    /// Prototype scale (signal) — noise is unit variance.
    ///
    /// With d = 3072 iid-gaussian prototype dims, the expected inter-class
    /// margin is δ = s·√(2d) ≈ 78·s, so the Bayes accuracy ceiling is
    /// ~Φ(δ/2) against each competing class. s is tuned so the ceilings
    /// mirror the paper's VGG-11 results: SVHN ≈ low-90s %, CIFAR ≈ 70s %,
    /// reached over tens of communication rounds rather than instantly.
    fn separation(self) -> f32 {
        match self {
            DatasetFlavor::Svhn => 0.15,
            DatasetFlavor::Cifar => 0.10,
        }
    }
}

/// Generator state: the class prototypes.
#[derive(Clone)]
pub struct SynthData {
    pub flavor: DatasetFlavor,
    prototypes: Vec<Vec<f32>>, // [class][IMG_DIM]
}

impl SynthData {
    pub fn new(flavor: DatasetFlavor, rng: &mut Rng) -> Self {
        let sep = flavor.separation();
        let prototypes = (0..NUM_CLASSES)
            .map(|_| (0..IMG_DIM).map(|_| rng.normal() as f32 * sep).collect())
            .collect();
        SynthData { flavor, prototypes }
    }

    /// Sample one image of class `c` into `out` (length IMG_DIM).
    pub fn sample_into(&self, c: usize, rng: &mut Rng, out: &mut [f32]) {
        let proto = &self.prototypes[c];
        for (o, &p) in out.iter_mut().zip(proto) {
            *o = p + rng.normal() as f32;
        }
    }

    /// Generate `n` samples of the given classes (cycled), returning
    /// (images `[n*IMG_DIM]`, labels `[n]`).
    pub fn generate(
        &self,
        classes: &[usize],
        n: usize,
        rng: &mut Rng,
    ) -> (Vec<f32>, Vec<i32>) {
        let mut images = vec![0.0f32; n * IMG_DIM];
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let c = classes[rng.below(classes.len())];
            self.sample_into(c, rng, &mut images[i * IMG_DIM..(i + 1) * IMG_DIM]);
            labels.push(c as i32);
        }
        (images, labels)
    }

    /// Balanced IID test set.
    pub fn test_set(&self, n: usize, rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
        let mut images = vec![0.0f32; n * IMG_DIM];
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % NUM_CLASSES;
            self.sample_into(c, rng, &mut images[i * IMG_DIM..(i + 1) * IMG_DIM]);
            labels.push(c as i32);
        }
        (images, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let mut rng = Rng::new(1);
        let d = SynthData::new(DatasetFlavor::Svhn, &mut rng);
        let (x, y) = d.generate(&[3, 7], 50, &mut rng);
        assert_eq!(x.len(), 50 * IMG_DIM);
        assert_eq!(y.len(), 50);
        assert!(y.iter().all(|&c| c == 3 || c == 7));
    }

    #[test]
    fn test_set_is_balanced() {
        let mut rng = Rng::new(2);
        let d = SynthData::new(DatasetFlavor::Cifar, &mut rng);
        let (_, y) = d.test_set(100, &mut rng);
        for c in 0..NUM_CLASSES {
            assert_eq!(y.iter().filter(|&&v| v == c as i32).count(), 10);
        }
    }

    #[test]
    fn classes_are_separable() {
        // Nearest-prototype classification on fresh samples must beat
        // chance by a wide margin — otherwise no scheduler can learn.
        let mut rng = Rng::new(3);
        let d = SynthData::new(DatasetFlavor::Svhn, &mut rng);
        let (x, y) = d.test_set(200, &mut rng);
        let mut correct = 0;
        for i in 0..200 {
            let img = &x[i * IMG_DIM..(i + 1) * IMG_DIM];
            let (mut best, mut best_d) = (0usize, f64::INFINITY);
            for (c, proto) in d.prototypes.iter().enumerate() {
                let dist: f64 = img
                    .iter()
                    .zip(proto)
                    .map(|(&a, &b)| ((a - b) as f64).powi(2))
                    .sum();
                if dist < best_d {
                    best_d = dist;
                    best = c;
                }
            }
            if best as i32 == y[i] {
                correct += 1;
            }
        }
        // The separability is deliberately partial (Bayes ceiling < 100%);
        // nearest-TRUE-prototype must still beat 10-class chance (20/200)
        // by a wide margin.
        assert!(correct > 80, "nearest-prototype acc {correct}/200");
    }

    #[test]
    fn svhn_more_separable_than_cifar() {
        assert!(DatasetFlavor::Svhn.separation() > DatasetFlavor::Cifar.separation());
    }

    #[test]
    fn flavor_parse() {
        assert_eq!(DatasetFlavor::parse("svhn"), Some(DatasetFlavor::Svhn));
        assert_eq!(DatasetFlavor::parse("cifar10"), Some(DatasetFlavor::Cifar));
        assert_eq!(DatasetFlavor::parse("mnist"), None);
    }
}
