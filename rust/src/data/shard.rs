//! Non-IID sharding following [50] (Zhao et al.) as instantiated in
//! §VII-A: the devices of shop floor m hold data restricted to q_m classes
//! (chi = 1 means fully q_m-class non-IID; chi < 1 mixes in IID samples).
//!
//! q_m is randomly generated per gateway, except gateway 0 which gets the
//! full class set — reproducing the paper's setup where each device
//! associated with the 1-th gateway has "a local dataset with a wider
//! variety of the q_m-class non-IID data points" (Fig. 2 discussion).

use rayon::prelude::*;

use crate::config::SimConfig;
use crate::data::synth::{SynthData, NUM_CLASSES};
use crate::rng::Rng;
use crate::topo::Topology;

/// One device's local dataset.
#[derive(Clone)]
pub struct DeviceShard {
    pub device: usize,
    /// Classes this device's non-IID portion draws from.
    pub classes: Vec<usize>,
    /// Flattened images [n * IMG_DIM].
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
}

impl DeviceShard {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }
}

/// Shard the synthetic source across all devices per the paper's scheme.
///
/// Per-device generation is embarrassingly parallel: each device draws
/// from a stateless [`Rng::stream`] keyed by its id, so hundreds to
/// thousands of shards generate concurrently and the result is
/// byte-identical regardless of thread count (only the cheap per-gateway
/// menus consume the caller's sequential generator).
pub fn shard_non_iid(
    cfg: &SimConfig,
    topo: &Topology,
    data: &SynthData,
    rng: &mut Rng,
) -> Vec<DeviceShard> {
    // Per-gateway class menus.
    let mut menus: Vec<Vec<usize>> = Vec::with_capacity(topo.num_gateways());
    for m in 0..topo.num_gateways() {
        let q_m = if m == 0 {
            NUM_CLASSES
        } else {
            1 + rng.below(NUM_CLASSES)
        };
        menus.push(rng.choose_k(NUM_CLASSES, q_m));
    }

    let all: Vec<usize> = (0..NUM_CLASSES).collect();
    let base = rng.next_u64();
    topo.devices
        .par_iter()
        .map(|dev| {
            let mut drng = Rng::stream(base, &[dev.id as u64]);
            let menu = &menus[dev.gateway];
            let n = dev.dataset_size;
            let n_noniid = (cfg.non_iid_degree * n as f64).round() as usize;
            let (mut images, mut labels) = data.generate(menu, n_noniid, &mut drng);
            if n_noniid < n {
                let (xi, yi) = data.generate(&all, n - n_noniid, &mut drng);
                images.extend(xi);
                labels.extend(yi);
            }
            DeviceShard {
                device: dev.id,
                classes: menu.clone(),
                images,
                labels,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::DatasetFlavor;

    fn fixtures() -> (SimConfig, Topology, SynthData, Rng) {
        let cfg = SimConfig::default();
        let mut rng = Rng::new(11);
        let topo = Topology::generate(&cfg, &mut rng);
        let data = SynthData::new(DatasetFlavor::Svhn, &mut rng);
        (cfg, topo, data, rng)
    }

    #[test]
    fn shard_sizes_match_dataset_sizes() {
        let (cfg, topo, data, mut rng) = fixtures();
        let shards = shard_non_iid(&cfg, &topo, &data, &mut rng);
        assert_eq!(shards.len(), topo.num_devices());
        for (s, d) in shards.iter().zip(&topo.devices) {
            assert_eq!(s.len(), d.dataset_size);
            assert_eq!(s.images.len(), d.dataset_size * super::super::synth::IMG_DIM);
        }
    }

    #[test]
    fn gateway0_devices_see_all_classes() {
        let (cfg, topo, data, mut rng) = fixtures();
        let shards = shard_non_iid(&cfg, &topo, &data, &mut rng);
        for &n in &topo.gateways[0].members {
            assert_eq!(shards[n].classes.len(), NUM_CLASSES);
        }
    }

    #[test]
    fn full_non_iid_restricts_labels_to_menu() {
        let (cfg, topo, data, mut rng) = fixtures();
        assert_eq!(cfg.non_iid_degree, 1.0);
        let shards = shard_non_iid(&cfg, &topo, &data, &mut rng);
        for s in &shards {
            for &y in &s.labels {
                assert!(s.classes.contains(&(y as usize)), "label {y} not in menu");
            }
        }
    }

    #[test]
    fn devices_on_same_floor_share_menu() {
        let (cfg, topo, data, mut rng) = fixtures();
        let shards = shard_non_iid(&cfg, &topo, &data, &mut rng);
        for g in &topo.gateways {
            let first = &shards[g.members[0]].classes;
            for &n in &g.members {
                assert_eq!(&shards[n].classes, first);
            }
        }
    }

    #[test]
    fn sharding_is_byte_identical_across_thread_counts() {
        let (cfg, topo, data, _) = fixtures();
        let generate = |threads: usize| {
            let pool = rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
            pool.install(|| shard_non_iid(&cfg, &topo, &data, &mut Rng::new(77)))
        };
        let a = generate(1);
        let b = generate(4);
        assert_eq!(a.len(), b.len());
        for (sa, sb) in a.iter().zip(&b) {
            assert_eq!(sa.device, sb.device);
            assert_eq!(sa.classes, sb.classes);
            assert_eq!(sa.labels, sb.labels);
            let same = sa.images.iter().zip(&sb.images).all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(same, "device {} images diverged across pools", sa.device);
        }
    }

    #[test]
    fn partial_non_iid_mixes_in_other_classes() {
        let (mut cfg, topo, data, mut rng) = fixtures();
        cfg.non_iid_degree = 0.5;
        let shards = shard_non_iid(&cfg, &topo, &data, &mut rng);
        // some gateway has a small menu; with chi=0.5 its devices should
        // hold at least one label outside the menu with high probability.
        let mut found_outside = false;
        for s in &shards {
            if s.classes.len() < NUM_CLASSES {
                if s.labels.iter().any(|&y| !s.classes.contains(&(y as usize))) {
                    found_outside = true;
                }
            }
        }
        assert!(found_outside);
    }
}
