//! Non-IID sharding following [50] (Zhao et al.) as instantiated in
//! §VII-A: the devices of shop floor m hold data restricted to q_m classes
//! (chi = 1 means fully q_m-class non-IID; chi < 1 mixes in IID samples).
//!
//! q_m is randomly generated per gateway, except gateway 0 which gets the
//! full class set — reproducing the paper's setup where each device
//! associated with the 1-th gateway has "a local dataset with a wider
//! variety of the q_m-class non-IID data points" (Fig. 2 discussion).
//!
//! With `fault.dirichlet_alpha > 0` the menu scheme is replaced by
//! Dirichlet(α) label sharding (the FL-benchmark standard, e.g. Hsu et
//! al.): each device draws its own class proportions p ~ Dir(α) from its
//! dedicated [`STREAM_FAULT_SHARD`] stream — smaller α, heavier skew.
//! Per-device streams keep generation embarrassingly parallel and
//! byte-identical across thread counts, same as the menu path.

use std::borrow::Cow;

use rayon::prelude::*;

use crate::config::SimConfig;
use crate::data::synth::{SynthData, IMG_DIM, NUM_CLASSES};
use crate::fl::fault::STREAM_FAULT_SHARD;
use crate::rng::Rng;
use crate::topo::{Device, Topology};

/// One device's local dataset.
#[derive(Clone)]
pub struct DeviceShard {
    pub device: usize,
    /// Classes this device's non-IID portion draws from.
    pub classes: Vec<usize>,
    /// Flattened images [n * IMG_DIM].
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
}

impl DeviceShard {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }
}

/// Deferred sharding: everything the sharder draws SEQUENTIALLY from the
/// caller's generator (the per-gateway class menus, the per-device stream
/// base) captured up front, so any device's shard can be materialized
/// independently — and arbitrarily late — afterwards.
///
/// [`ShardPlan::new`] consumes EXACTLY the draws eager sharding consumes
/// (menus then base in menu mode; just the base in Dirichlet mode), so a
/// run that builds a plan and defers materialization leaves the caller's
/// generator — and therefore every later draw in the experiment build —
/// byte-identical to an eager run.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Per-gateway class menus; `None` in Dirichlet mode, where each
    /// device draws its own class proportions instead.
    menus: Option<Vec<Vec<usize>>>,
    /// Base seed of the stateless per-device [`Rng::stream`] closures.
    base: u64,
    non_iid_degree: f64,
    dirichlet_alpha: f64,
}

impl ShardPlan {
    /// Capture the sequential draws of the sharding scheme `cfg` selects.
    pub fn new(cfg: &SimConfig, topo: &Topology, rng: &mut Rng) -> Self {
        if cfg.fault.dirichlet_alpha > 0.0 {
            return ShardPlan {
                menus: None,
                base: rng.next_u64(),
                non_iid_degree: cfg.non_iid_degree,
                dirichlet_alpha: cfg.fault.dirichlet_alpha,
            };
        }
        // Per-gateway class menus.
        let mut menus: Vec<Vec<usize>> = Vec::with_capacity(topo.num_gateways());
        for m in 0..topo.num_gateways() {
            let q_m = if m == 0 {
                NUM_CLASSES
            } else {
                1 + rng.below(NUM_CLASSES)
            };
            menus.push(rng.choose_k(NUM_CLASSES, q_m));
        }
        ShardPlan {
            menus: Some(menus),
            base: rng.next_u64(),
            non_iid_degree: cfg.non_iid_degree,
            dirichlet_alpha: 0.0,
        }
    }

    /// Materialize device `dev`'s shard. Pure in `(plan, dev, data)`: the
    /// per-device closure replays from its stateless stream, so lazy and
    /// eager materialization — in any order, on any thread — produce
    /// byte-identical shards.
    pub fn materialize(&self, dev: &Device, data: &SynthData) -> DeviceShard {
        match &self.menus {
            Some(menus) => {
                let mut drng = Rng::stream(self.base, &[dev.id as u64]);
                let menu = &menus[dev.gateway];
                let all: Vec<usize> = (0..NUM_CLASSES).collect();
                let n = dev.dataset_size;
                let n_noniid = (self.non_iid_degree * n as f64).round() as usize;
                let (mut images, mut labels) = data.generate(menu, n_noniid, &mut drng);
                if n_noniid < n {
                    let (xi, yi) = data.generate(&all, n - n_noniid, &mut drng);
                    images.extend(xi);
                    labels.extend(yi);
                }
                DeviceShard {
                    device: dev.id,
                    classes: menu.clone(),
                    images,
                    labels,
                }
            }
            None => {
                let mut drng = Rng::stream(self.base, &[STREAM_FAULT_SHARD, dev.id as u64]);
                let props = dirichlet(self.dirichlet_alpha, NUM_CLASSES, &mut drng);
                let n = dev.dataset_size;
                let mut images = vec![0.0f32; n * IMG_DIM];
                let mut labels = Vec::with_capacity(n);
                for i in 0..n {
                    // CDF inversion over the proportions; the final class
                    // absorbs any floating-point shortfall.
                    let u = drng.f64();
                    let mut c = NUM_CLASSES - 1;
                    let mut acc = 0.0;
                    for (k, &p) in props.iter().enumerate() {
                        acc += p;
                        if u < acc {
                            c = k;
                            break;
                        }
                    }
                    data.sample_into(c, &mut drng, &mut images[i * IMG_DIM..(i + 1) * IMG_DIM]);
                    labels.push(c as i32);
                }
                let mut classes: Vec<usize> = labels.iter().map(|&y| y as usize).collect();
                classes.sort_unstable();
                classes.dedup();
                DeviceShard { device: dev.id, classes, images, labels }
            }
        }
    }

    /// Materialize every device's shard (the eager path). Embarrassingly
    /// parallel and byte-identical across thread counts: each device
    /// replays its own stateless stream.
    pub fn materialize_all(&self, topo: &Topology, data: &SynthData) -> Vec<DeviceShard> {
        topo.devices.par_iter().map(|dev| self.materialize(dev, data)).collect()
    }
}

/// The experiment's shard storage, behind the `lazy_shards` config knob.
///
/// `Eager` holds every device's materialized shard — the original layout,
/// O(N · D̃_n · IMG_DIM) resident floats. `Lazy` holds only the
/// [`ShardPlan`] plus the synthetic source and regenerates a device's
/// shard on demand, so resident memory never scales with the device
/// count — the enabler for the nation-class (10⁵–10⁶ device) scenarios,
/// which would otherwise need hundreds of GiB of shards for the handful
/// of devices actually scheduled per round. The two stores are
/// byte-identical sample-for-sample (same per-device stream closure);
/// lazy trades regeneration CPU on every access for that memory bound.
pub enum ShardStore {
    Eager(Vec<DeviceShard>),
    Lazy { plan: ShardPlan, data: SynthData },
}

impl ShardStore {
    /// Build the store `lazy` selects, consuming the synthetic source
    /// (eager materializes all shards and drops it).
    pub fn build(lazy: bool, plan: ShardPlan, topo: &Topology, data: SynthData) -> Self {
        if lazy {
            ShardStore::Lazy { plan, data }
        } else {
            ShardStore::Eager(plan.materialize_all(topo, &data))
        }
    }

    /// Device `dev`'s shard: borrowed from the eager store, regenerated
    /// (owned) from the lazy one.
    pub fn shard(&self, dev: &Device) -> Cow<'_, DeviceShard> {
        match self {
            ShardStore::Eager(shards) => Cow::Borrowed(&shards[dev.id]),
            ShardStore::Lazy { plan, data } => Cow::Owned(plan.materialize(dev, data)),
        }
    }
}

/// Shard the synthetic source across all devices per the paper's scheme.
///
/// Per-device generation is embarrassingly parallel: each device draws
/// from a stateless [`Rng::stream`] keyed by its id, so hundreds to
/// thousands of shards generate concurrently and the result is
/// byte-identical regardless of thread count (only the cheap per-gateway
/// menus consume the caller's sequential generator). Thin wrapper over
/// [`ShardPlan`] — plan capture then immediate materialization.
pub fn shard_non_iid(
    cfg: &SimConfig,
    topo: &Topology,
    data: &SynthData,
    rng: &mut Rng,
) -> Vec<DeviceShard> {
    ShardPlan::new(cfg, topo, rng).materialize_all(topo, data)
}

/// Gamma(α, 1) via Marsaglia–Tsang squeeze (only `normal()`/`f64()`
/// primitives are available offline); the α < 1 case uses the boost
/// Gamma(α) = Gamma(α+1) · U^{1/α}.
fn gamma(alpha: f64, rng: &mut Rng) -> f64 {
    if alpha < 1.0 {
        let u = rng.f64().max(f64::MIN_POSITIVE);
        return gamma(alpha + 1.0, rng) * u.powf(1.0 / alpha);
    }
    let d = alpha - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.normal();
        let v = 1.0 + c * x;
        if v <= 0.0 {
            continue;
        }
        let v = v * v * v;
        let u = rng.f64();
        if u < 1.0 - 0.0331 * x * x * x * x {
            return d * v;
        }
        if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
            return d * v;
        }
    }
}

/// Symmetric Dirichlet(α) over `k` classes: normalized i.i.d. Gamma(α)
/// draws. Degenerate draws (all-zero underflow at tiny α) fall back to
/// uniform rather than NaN.
fn dirichlet(alpha: f64, k: usize, rng: &mut Rng) -> Vec<f64> {
    let mut g: Vec<f64> = (0..k).map(|_| gamma(alpha, rng)).collect();
    let sum: f64 = g.iter().sum();
    if !(sum > 0.0 && sum.is_finite()) {
        return vec![1.0 / k as f64; k];
    }
    for v in &mut g {
        *v /= sum;
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::DatasetFlavor;

    fn fixtures() -> (SimConfig, Topology, SynthData, Rng) {
        let cfg = SimConfig::default();
        let mut rng = Rng::new(11);
        let topo = Topology::generate(&cfg, &mut rng);
        let data = SynthData::new(DatasetFlavor::Svhn, &mut rng);
        (cfg, topo, data, rng)
    }

    #[test]
    fn shard_sizes_match_dataset_sizes() {
        let (cfg, topo, data, mut rng) = fixtures();
        let shards = shard_non_iid(&cfg, &topo, &data, &mut rng);
        assert_eq!(shards.len(), topo.num_devices());
        for (s, d) in shards.iter().zip(&topo.devices) {
            assert_eq!(s.len(), d.dataset_size);
            assert_eq!(s.images.len(), d.dataset_size * super::super::synth::IMG_DIM);
        }
    }

    #[test]
    fn gateway0_devices_see_all_classes() {
        let (cfg, topo, data, mut rng) = fixtures();
        let shards = shard_non_iid(&cfg, &topo, &data, &mut rng);
        for &n in &topo.gateways[0].members {
            assert_eq!(shards[n].classes.len(), NUM_CLASSES);
        }
    }

    #[test]
    fn full_non_iid_restricts_labels_to_menu() {
        let (cfg, topo, data, mut rng) = fixtures();
        assert_eq!(cfg.non_iid_degree, 1.0);
        let shards = shard_non_iid(&cfg, &topo, &data, &mut rng);
        for s in &shards {
            for &y in &s.labels {
                assert!(s.classes.contains(&(y as usize)), "label {y} not in menu");
            }
        }
    }

    #[test]
    fn devices_on_same_floor_share_menu() {
        let (cfg, topo, data, mut rng) = fixtures();
        let shards = shard_non_iid(&cfg, &topo, &data, &mut rng);
        for g in &topo.gateways {
            let first = &shards[g.members[0]].classes;
            for &n in &g.members {
                assert_eq!(&shards[n].classes, first);
            }
        }
    }

    #[test]
    fn sharding_is_byte_identical_across_thread_counts() {
        let (cfg, topo, data, _) = fixtures();
        let generate = |threads: usize| {
            let pool = rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
            pool.install(|| shard_non_iid(&cfg, &topo, &data, &mut Rng::new(77)))
        };
        let a = generate(1);
        let b = generate(4);
        assert_eq!(a.len(), b.len());
        for (sa, sb) in a.iter().zip(&b) {
            assert_eq!(sa.device, sb.device);
            assert_eq!(sa.classes, sb.classes);
            assert_eq!(sa.labels, sb.labels);
            let same = sa.images.iter().zip(&sb.images).all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(same, "device {} images diverged across pools", sa.device);
        }
    }

    #[test]
    fn dirichlet_sharding_is_byte_identical_across_thread_counts() {
        let (mut cfg, topo, data, _) = fixtures();
        cfg.fault.dirichlet_alpha = 0.5;
        let generate = |threads: usize| {
            let pool = rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
            pool.install(|| shard_non_iid(&cfg, &topo, &data, &mut Rng::new(77)))
        };
        let a = generate(1);
        let b = generate(4);
        assert_eq!(a.len(), b.len());
        for (sa, sb) in a.iter().zip(&b) {
            assert_eq!(sa.device, sb.device);
            assert_eq!(sa.classes, sb.classes);
            assert_eq!(sa.labels, sb.labels);
            let same = sa.images.iter().zip(&sb.images).all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(same, "device {} images diverged across pools", sa.device);
        }
    }

    #[test]
    fn dirichlet_sharding_sizes_and_labels_are_wellformed() {
        let (mut cfg, topo, data, mut rng) = fixtures();
        cfg.fault.dirichlet_alpha = 0.5;
        let shards = shard_non_iid(&cfg, &topo, &data, &mut rng);
        assert_eq!(shards.len(), topo.num_devices());
        for (s, d) in shards.iter().zip(&topo.devices) {
            assert_eq!(s.len(), d.dataset_size);
            assert_eq!(s.images.len(), d.dataset_size * IMG_DIM);
            // `classes` is exactly the distinct labels present, sorted.
            let mut seen: Vec<usize> = s.labels.iter().map(|&y| y as usize).collect();
            seen.sort_unstable();
            seen.dedup();
            assert_eq!(s.classes, seen);
            assert!(s.labels.iter().all(|&y| (y as usize) < NUM_CLASSES));
        }
    }

    #[test]
    fn dirichlet_concentration_controls_skew() {
        // At tiny α most devices concentrate on few classes; at huge α
        // every device's shard is near-uniform over all 10.
        let (mut cfg, topo, data, mut rng) = fixtures();
        cfg.fault.dirichlet_alpha = 0.05;
        let skewed = shard_non_iid(&cfg, &topo, &data, &mut rng);
        let mean_classes = |shards: &[DeviceShard]| {
            shards.iter().map(|s| s.classes.len()).sum::<usize>() as f64 / shards.len() as f64
        };
        let mut rng2 = Rng::new(11 + 1);
        cfg.fault.dirichlet_alpha = 100.0;
        let uniform = shard_non_iid(&cfg, &topo, &data, &mut rng2);
        assert!(
            mean_classes(&skewed) < mean_classes(&uniform),
            "α=0.05 should be more class-concentrated than α=100: {} vs {}",
            mean_classes(&skewed),
            mean_classes(&uniform)
        );
        // Sanity on the samplers themselves: proportions sum to ~1.
        let mut r = Rng::new(3);
        let p = dirichlet(0.3, NUM_CLASSES, &mut r);
        assert_eq!(p.len(), NUM_CLASSES);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p.iter().all(|&v| (0.0..=1.0).contains(&v)));
        // Gamma(α) has mean α: a loose moment check keeps the sampler
        // honest without pinning realizations.
        let m: f64 = (0..4000).map(|_| gamma(2.5, &mut r)).sum::<f64>() / 4000.0;
        assert!((m - 2.5).abs() < 0.2, "Gamma(2.5) sample mean {m}");
        let m: f64 = (0..4000).map(|_| gamma(0.4, &mut r)).sum::<f64>() / 4000.0;
        assert!((m - 0.4).abs() < 0.1, "Gamma(0.4) sample mean {m}");
    }

    #[test]
    fn partial_non_iid_mixes_in_other_classes() {
        let (mut cfg, topo, data, mut rng) = fixtures();
        cfg.non_iid_degree = 0.5;
        let shards = shard_non_iid(&cfg, &topo, &data, &mut rng);
        // some gateway has a small menu; with chi=0.5 its devices should
        // hold at least one label outside the menu with high probability.
        let mut found_outside = false;
        for s in &shards {
            if s.classes.len() < NUM_CLASSES {
                if s.labels.iter().any(|&y| !s.classes.contains(&(y as usize))) {
                    found_outside = true;
                }
            }
        }
        assert!(found_outside);
    }

    fn assert_shards_bitwise_eq(a: &DeviceShard, b: &DeviceShard) {
        assert_eq!(a.device, b.device);
        assert_eq!(a.classes, b.classes);
        assert_eq!(a.labels, b.labels);
        let same = a.images.iter().zip(&b.images).all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(same, "device {} images diverged", a.device);
    }

    #[test]
    fn plan_consumes_identical_draws_as_eager_sharding() {
        // A deferred plan must leave the caller's generator exactly where
        // eager sharding leaves it, in BOTH sharding modes — that is what
        // makes lazy_shards byte-invisible to every later draw.
        let (mut cfg, topo, data, _) = fixtures();
        for alpha in [0.0, 0.5] {
            cfg.fault.dirichlet_alpha = alpha;
            let mut eager_rng = Rng::new(77);
            let mut plan_rng = Rng::new(77);
            shard_non_iid(&cfg, &topo, &data, &mut eager_rng);
            ShardPlan::new(&cfg, &topo, &mut plan_rng);
            assert_eq!(eager_rng.next_u64(), plan_rng.next_u64(), "alpha = {alpha}");
        }
    }

    #[test]
    fn lazy_store_matches_eager_store_bitwise() {
        let (mut cfg, topo, data, _) = fixtures();
        for alpha in [0.0, 0.5] {
            cfg.fault.dirichlet_alpha = alpha;
            let eager = ShardStore::build(
                false,
                ShardPlan::new(&cfg, &topo, &mut Rng::new(77)),
                &topo,
                data.clone(),
            );
            let lazy = ShardStore::build(
                true,
                ShardPlan::new(&cfg, &topo, &mut Rng::new(77)),
                &topo,
                data.clone(),
            );
            assert!(matches!(eager, ShardStore::Eager(_)));
            assert!(matches!(lazy, ShardStore::Lazy { .. }));
            // Access out of order and repeatedly: lazy materialization is
            // pure, so every access agrees with the eager shard bitwise.
            for dev in topo.devices.iter().rev() {
                let e = eager.shard(dev);
                let l = lazy.shard(dev);
                assert_shards_bitwise_eq(&e, &l);
                assert_shards_bitwise_eq(&l, &lazy.shard(dev));
            }
        }
    }
}
