"""AOT pipeline: HLO text artifacts are well-formed and self-consistent."""

import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_smoke():
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "f32[4]" in text


def test_mlp_train_step_hlo_signature():
    """Entry computation must carry params + x + y + lr and return a tuple —
    the ABI rust/src/runtime relies on."""
    lowered = jax.jit(model.train_step("mlp")).lower(
        [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in model.init_params("mlp")],
        jax.ShapeDtypeStruct(model.input_shape("mlp", model.TRAIN_BATCH), jnp.float32),
        jax.ShapeDtypeStruct((model.TRAIN_BATCH,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert f"f32[{model.TRAIN_BATCH},3072]" in text
    assert f"s32[{model.TRAIN_BATCH}]" in text


@pytest.mark.parametrize("preset", ["mlp", "cnn"])
def test_artifacts_on_disk_if_built(preset):
    """When `make artifacts` has run, every artifact + meta must be present
    and the meta param list must match the model."""
    meta = os.path.join(ART, f"{preset}.meta")
    if not os.path.exists(meta):
        pytest.skip("artifacts not built")
    lines = dict()
    shapes = []
    for line in open(meta):
        k, v = line.strip().split("=", 1)
        if k == "param":
            shapes.append(tuple(int(d) for d in v.split("x")))
        else:
            lines[k] = v
    params = model.init_params(preset)
    assert len(shapes) == len(params)
    for got, p in zip(shapes, params):
        assert got == (p.shape or (1,))
    assert int(lines["param_total"]) == model.param_count(preset)
    kinds = ["init", "train_step", "eval", "grad"]
    if "train_k" in lines:
        kinds.append(f"train_k{lines['train_k']}")
    for kind in kinds:
        path = os.path.join(ART, f"{preset}_{kind}.hlo.txt")
        assert os.path.exists(path), path
        head = open(path).read(4096)
        assert "HloModule" in head
