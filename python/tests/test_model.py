"""L2 correctness: model shapes, training behaviour, partition equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def _data(preset, batch, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, model.input_shape(preset, batch), jnp.float32)
    y = jax.random.randint(ky, (batch,), 0, model.NUM_CLASSES)
    return x, y


@pytest.mark.parametrize("preset", ["mlp", "cnn"])
def test_init_params_shapes_and_determinism(preset):
    p1 = model.init_params(preset, seed=0)
    p2 = model.init_params(preset, seed=0)
    for a, b in zip(p1, p2):
        np.testing.assert_array_equal(a, b)
    assert model.param_count(preset) == sum(int(p.size) for p in p1)


@pytest.mark.parametrize("preset", ["mlp", "cnn"])
def test_forward_shapes(preset):
    p = model.init_params(preset)
    x, _ = _data(preset, 8)
    # forward handles any batch (only AOT artifacts bake static batches)
    logits = model.forward(preset, p, x)
    assert logits.shape == (8, model.NUM_CLASSES)


@pytest.mark.parametrize("preset", ["mlp", "cnn"])
def test_initial_loss_is_ln10(preset):
    """Zero-init head -> uniform predictive distribution -> loss = ln 10."""
    p = model.init_params(preset)
    x, y = _data(preset, 16)
    loss = model.loss_fn(preset, p, x, y)
    np.testing.assert_allclose(loss, np.log(10.0), rtol=1e-5)


@pytest.mark.parametrize("preset", ["mlp"])
def test_train_step_decreases_loss(preset):
    p = model.init_params(preset)
    x, y = _data(preset, model.TRAIN_BATCH)
    step = jax.jit(model.train_step(preset))
    lr = jnp.float32(0.05)
    out = step(p, x, y, lr)
    first = float(out[-1])
    for _ in range(5):
        out = step(list(out[:-1]), x, y, lr)
    assert float(out[-1]) < first


def test_train_step_abi_order():
    """Artifact ABI: outputs are params' (same order) then loss."""
    p = model.init_params("mlp")
    x, y = _data("mlp", model.TRAIN_BATCH)
    out = model.train_step("mlp")(p, x, y, jnp.float32(0.0))
    assert len(out) == len(p) + 1
    # lr = 0 must be the identity on parameters.
    for a, b in zip(out[:-1], p):
        np.testing.assert_array_equal(a, b)


def test_eval_batch_counts():
    p = model.init_params("mlp")
    x, y = _data("mlp", model.EVAL_BATCH)
    sum_loss, correct = model.eval_batch("mlp")(p, x, y)
    assert 0 <= float(correct) <= model.EVAL_BATCH
    np.testing.assert_allclose(
        float(sum_loss) / model.EVAL_BATCH, np.log(10.0), rtol=1e-5
    )


def test_grad_flat_length_and_direction():
    p = model.init_params("mlp")
    x, y = _data("mlp", model.TRAIN_BATCH)
    g = model.grad_flat("mlp")(p, x, y)
    assert g.shape == (model.param_count("mlp"),)
    # one SGD step along -g must equal train_step output
    lr = jnp.float32(0.01)
    stepped = model.train_step("mlp")(p, x, y, lr)
    flat_stepped = jnp.concatenate([q.ravel() for q in stepped[:-1]])
    flat_manual = jnp.concatenate([q.ravel() for q in p]) - lr * g
    np.testing.assert_allclose(flat_stepped, flat_manual, rtol=1e-5, atol=1e-7)


def test_partitioned_step_equals_fused():
    """The paper's DNN-partition mechanism must be numerically exact:
    bottom_fwd + top_step + bottom_bwd == fused train_step."""
    p = model.init_params("cnn")
    x, y = _data("cnn", model.TRAIN_BATCH, seed=3)
    lr = jnp.float32(0.01)
    nb = model.CNN_BOTTOM_PARAMS
    bottom, top = p[:nb], p[nb:]

    act = model.bottom_fwd(bottom, x)
    assert act.shape == model.CNN_CUT_ACT_SHAPE
    tout = model.top_step(top, act, y, lr)
    new_top, d_act, loss_p = list(tout[:-2]), tout[-2], tout[-1]
    new_bottom = model.bottom_bwd(bottom, x, d_act, lr)

    fused = model.train_step("cnn")(p, x, y, lr)
    for a, b in zip(list(new_bottom) + new_top, fused[:-1]):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(loss_p, fused[-1], rtol=1e-6)


def test_train_k_steps_equals_sequential_steps():
    """The fused K-step artifact (§Perf) must be numerically identical to
    K sequential single-step calls."""
    k = 3
    p = model.init_params("mlp")
    lr = jnp.float32(0.02)
    kx, ky = jax.random.split(jax.random.PRNGKey(9))
    xs = jax.random.normal(kx, (k, model.TRAIN_BATCH, model.FLAT_DIM))
    ys = jax.random.randint(ky, (k, model.TRAIN_BATCH), 0, model.NUM_CLASSES)

    out = model.train_k_steps("mlp", k)(p, xs, ys, lr)
    fused_params, fused_loss = list(out[:-1]), out[-1]

    seq = p
    losses = []
    step = model.train_step("mlp")
    for i in range(k):
        o = step(seq, xs[i], ys[i], lr)
        seq = list(o[:-1])
        losses.append(o[-1])
    for a, b in zip(fused_params, seq):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(fused_loss, np.mean(losses), rtol=1e-6)


def test_maxpool2():
    x = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4, 1)
    out = model._maxpool2(x)
    np.testing.assert_array_equal(
        out[0, :, :, 0], jnp.array([[5.0, 7.0], [13.0, 15.0]])
    )


def test_xent_perfect_prediction_is_small():
    logits = jnp.full((4, 10), -30.0).at[jnp.arange(4), jnp.arange(4)].set(30.0)
    loss = model._xent(logits, jnp.arange(4))
    assert float(loss) < 1e-5
