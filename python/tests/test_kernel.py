"""L1 correctness: Pallas kernels vs pure-jnp oracles.

This is the CORE correctness signal for the whole stack: every FLOP in the
AOT artifacts flows through these kernels. hypothesis sweeps shapes, dtypes
and block sizes; assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d_same, im2col, matmul, matmul_pallas
from compile.kernels.matmul import (
    _pick_block,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.ref import conv2d_same_ref, matmul_ref

SETTINGS = dict(max_examples=20, deadline=None)


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


# ----------------------------------------------------------------- matmul

@settings(**SETTINGS)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 150),
    n=st.integers(1, 130),
)
def test_matmul_matches_ref_shapes(m, k, n):
    x = _rand(m * 7 + 1, (m, k), jnp.float32)
    w = _rand(n * 13 + 2, (k, n), jnp.float32)
    np.testing.assert_allclose(
        matmul_pallas(x, w), matmul_ref(x, w), rtol=1e-4, atol=1e-4
    )


@settings(**SETTINGS)
@given(
    bm=st.sampled_from([8, 16, 32, 64, 128]),
    bn=st.sampled_from([8, 32, 128]),
    bk=st.sampled_from([8, 32, 128]),
)
def test_matmul_block_size_invariance(bm, bn, bk):
    """The tiling schedule must never change the numbers (the block shape
    is a pure performance knob; EXPERIMENTS.md §Perf relies on this)."""
    x = _rand(3, (45, 70), jnp.float32)
    w = _rand(4, (70, 33), jnp.float32)
    got = matmul_pallas(x, w, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(got, matmul_ref(x, w), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    x = _rand(5, (32, 48), dtype)
    w = _rand(6, (48, 16), dtype)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(matmul_pallas(x, w), dtype=np.float32),
        np.asarray(matmul_ref(x, w), dtype=np.float32),
        rtol=tol, atol=tol,
    )


def test_matmul_identity():
    x = _rand(7, (17, 17), jnp.float32)
    eye = jnp.eye(17)
    np.testing.assert_allclose(matmul_pallas(x, eye), x, rtol=1e-5, atol=1e-6)


def test_matmul_zero():
    x = jnp.zeros((9, 11))
    w = _rand(8, (11, 5), jnp.float32)
    np.testing.assert_allclose(matmul_pallas(x, w), jnp.zeros((9, 5)))


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        matmul_pallas(jnp.zeros((3, 4)), jnp.zeros((5, 6)))
    with pytest.raises(ValueError):
        matmul_pallas(jnp.zeros((3,)), jnp.zeros((3, 2)))


def test_matmul_custom_vjp_matches_autodiff_of_ref():
    x = _rand(9, (24, 40), jnp.float32)
    w = _rand(10, (40, 12), jnp.float32)

    def f_pallas(x, w):
        return jnp.sum(jnp.sin(matmul(x, w)))

    def f_ref(x, w):
        return jnp.sum(jnp.sin(matmul_ref(x, w)))

    gx_p, gw_p = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx_p, gx_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw_p, gw_r, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(dim=st.integers(1, 4096), target=st.sampled_from([32, 128, 256]))
def test_pick_block_invariants(dim, target):
    b = _pick_block(dim, target)
    assert 1 <= b <= target
    assert b & (b - 1) == 0  # power of two


def test_vmem_footprint_within_tpu_budget():
    # 128^3 f32 tiling must fit comfortably in a 16 MiB VMEM core.
    assert vmem_footprint_bytes(128, 128, 128) < 1 << 20


def test_mxu_utilization_estimate_bounds():
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
    u = mxu_utilization_estimate(130, 10, 27)
    assert 0.0 < u < 1.0


# ------------------------------------------------------------------- conv

@settings(**SETTINGS)
@given(
    b=st.integers(1, 4),
    hw=st.sampled_from([4, 8, 12, 16]),
    cin=st.integers(1, 8),
    cout=st.integers(1, 8),
)
def test_conv_matches_lax(b, hw, cin, cout):
    x = _rand(b * 31 + hw, (b, hw, hw, cin), jnp.float32)
    w = _rand(cin * 17 + cout, (3, 3, cin, cout), jnp.float32)
    np.testing.assert_allclose(
        conv2d_same(x, w), conv2d_same_ref(x, w), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("k", [1, 3, 5])
def test_conv_kernel_sizes(k):
    x = _rand(11, (2, 10, 10, 3), jnp.float32)
    w = _rand(12, (k, k, 3, 4), jnp.float32)
    np.testing.assert_allclose(
        conv2d_same(x, w), conv2d_same_ref(x, w), rtol=1e-4, atol=1e-4
    )


def test_im2col_feature_order_matches_weight_reshape():
    """im2col feature ordering must be (di, dj, c) with c fastest so that
    HWIO weights flatten consistently — the contract conv2d_same relies on."""
    x = jnp.arange(2 * 4 * 4 * 3, dtype=jnp.float32).reshape(2, 4, 4, 3)
    p = im2col(x, 3, 3)
    assert p.shape == (2, 4, 4, 27)
    # centre tap (di=1, dj=1) of an interior pixel must equal the input.
    np.testing.assert_allclose(p[:, 1, 1, 4 * 3 : 5 * 3], x[:, 1, 1, :])


def test_conv_grad_matches_ref():
    x = _rand(13, (2, 6, 6, 3), jnp.float32)
    w = _rand(14, (3, 3, 3, 4), jnp.float32)
    g_p = jax.grad(lambda w: jnp.sum(conv2d_same(x, w) ** 2))(w)
    g_r = jax.grad(lambda w: jnp.sum(conv2d_same_ref(x, w) ** 2))(w)
    np.testing.assert_allclose(g_p, g_r, rtol=1e-3, atol=1e-3)
