# L1: Pallas kernels for the paper's compute hot-spot (GEMM after im2col).
from .matmul import matmul, matmul_pallas, vmem_footprint_bytes, mxu_utilization_estimate  # noqa: F401
from .conv import conv2d_same, im2col  # noqa: F401
