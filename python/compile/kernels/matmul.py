"""L1 Pallas kernel: tiled matmul, the compute hot-spot of the FL workload.

Every FLOP the paper's cost model counts (Table II) is a matmul FLOP after
im2col: convolution forward / error / gradient calculations and the fully
connected layers all reduce to GEMM. This kernel is therefore the single
L1 hot-spot of the whole stack.

TPU mapping (see DESIGN.md §Hardware-Adaptation): output is tiled in
``block_m x block_n`` blocks sized for the 128x128 MXU systolic array; the
K dimension is the innermost grid axis so each output block stays resident
in VMEM while A/B tiles stream HBM->VMEM via the BlockSpec index maps.

``interpret=True`` is mandatory on this CPU-only image: real-TPU lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute. Interpret
mode lowers the same schedule to plain HLO (a fori_loop over the grid), so
the AOT artifact runs on the rust PJRT CPU client.

The backward pass is expressed with the same kernel through a custom VJP
(dX = dY @ W^T, dW = X^T @ dY), keeping both training directions on the
Pallas path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-shaped tile. Overridable for the tiling ablation in
# python/tests/test_kernel.py and the §Perf sweep.
DEFAULT_BLOCK = 128


def _ceil_to(x: int, b: int) -> int:
    return ((x + b - 1) // b) * b


def _pick_block(dim: int, target: int) -> int:
    """Largest power of two <= target that is >= min(dim, 8)."""
    b = 8
    while b * 2 <= target and b < dim:
        b *= 2
    return min(b, target)


def _mm_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """One (i, j, k) grid step: accumulate an MXU-sized partial product.

    The output block is initialised at k == 0 and accumulated across the K
    grid axis; grid iteration order is row-major so k is innermost and the
    o_ref block is revisited nk times while staying in VMEM.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int = DEFAULT_BLOCK,
    block_n: int = DEFAULT_BLOCK,
    block_k: int = DEFAULT_BLOCK,
    interpret: bool = True,
) -> jax.Array:
    """``x @ w`` via the tiled Pallas kernel.

    x: f32[M, K], w: f32[K, N] -> f32[M, N]. Inputs are zero-padded up to
    block multiples (zero padding is exact for matmul) and the result is
    sliced back.
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"matmul_pallas expects 2-D operands, got {x.shape} @ {w.shape}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contracting dims mismatch: {x.shape} @ {w.shape}")

    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    bk = _pick_block(k, block_k)
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)

    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))
    nk = kp // bk

    out = pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


@jax.custom_vjp
def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Differentiable Pallas matmul: fwd and bwd both run the L1 kernel."""
    return matmul_pallas(x, w)


def _matmul_fwd(x, w):
    return matmul_pallas(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    # dX = dY @ W^T ; dW = X^T @ dY — both GEMMs on the Pallas path.
    dx = matmul_pallas(g, w.T)
    dw = matmul_pallas(x.T, g)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_footprint_bytes(block_m: int, block_n: int, block_k: int) -> int:
    """VMEM bytes resident per grid step (f32): A tile + B tile + O tile.

    Used by the §Perf TPU estimate: must stay well under ~16 MiB/core.
    """
    return 4 * (block_m * block_k + block_k * block_n + block_m * block_n)


def mxu_utilization_estimate(m: int, n: int, k: int, block: int = DEFAULT_BLOCK) -> float:
    """Fraction of issued MXU MACs that are useful (non-padding) work."""
    mp, np_, kp = _ceil_to(m, block), _ceil_to(n, block), _ceil_to(k, block)
    return (m * n * k) / float(mp * np_ * kp)
