"""Pure-jnp oracles for the L1 kernels — the build-time correctness signal.

pytest (python/tests/test_kernel.py) sweeps shapes/dtypes with hypothesis
and asserts the Pallas kernels match these references to float32 tolerance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def conv2d_same_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """lax conv oracle: NHWC x HWIO -> NHWC, SAME padding, stride 1."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
