"""Conv2D (SAME, stride 1) via im2col + the L1 Pallas matmul kernel.

The paper's Table II counts convolution FLOPs as 2*B*Ci*Hf*Wf*Co*Ho*Wo for
forward and gradient calculation — exactly the GEMM FLOPs of the im2col
formulation used here, so the executable model and the scheduler's cost
model (rust/src/dnn/cost.rs) count the same work.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .matmul import matmul


def im2col(x: jax.Array, kh: int, kw: int) -> jax.Array:
    """NHWC, SAME padding, stride 1 -> [B, H, W, kh*kw*C] patches.

    Feature ordering is (di, dj, c) with c fastest, matching
    ``w.reshape(kh*kw*cin, cout)`` for HWIO weights.
    """
    b, h, w, c = x.shape
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    cols = [
        xp[:, i : i + h, j : j + w, :] for i in range(kh) for j in range(kw)
    ]
    return jnp.concatenate(cols, axis=-1)


def conv2d_same(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: f32[B,H,W,Cin], w: f32[Kh,Kw,Cin,Cout] -> f32[B,H,W,Cout]."""
    b, h, wd, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2, (x.shape, w.shape)
    patches = im2col(x, kh, kw).reshape(b * h * wd, kh * kw * cin)
    w2d = w.reshape(kh * kw * cin, cout)
    out = matmul(patches, w2d)
    return out.reshape(b, h, wd, cout)
