"""AOT export: lower every L2 entry point to HLO *text* artifacts.

Run once by ``make artifacts``; Python is never on the request path. The
rust runtime (rust/src/runtime) loads these with
``HloModuleProto::from_text_file`` and executes them on the PJRT CPU client.

HLO text — NOT ``lowered.compile()`` or serialized protos — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (the version behind the published ``xla``
crate) rejects; the text parser reassigns ids and round-trips cleanly.

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# K baked into the fused local-training artifact (= the paper's K = 5).
TRAIN_K = 5


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _param_specs(preset: str):
    return [_spec(p.shape) for p in model.init_params(preset)]


def _write(out_dir: str, name: str, lowered) -> None:
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) / 1e6:.2f} MB)")


def export_preset(preset: str, out_dir: str) -> None:
    print(f"[aot] preset {preset}")
    params = _param_specs(preset)
    xt = _spec(model.input_shape(preset, model.TRAIN_BATCH))
    xe = _spec(model.input_shape(preset, model.EVAL_BATCH))
    yt = _spec((model.TRAIN_BATCH,), jnp.int32)
    ye = _spec((model.EVAL_BATCH,), jnp.int32)
    lr = _spec((), jnp.float32)

    _write(out_dir, f"{preset}_init",
           jax.jit(lambda: tuple(model.init_params(preset))).lower())
    _write(out_dir, f"{preset}_train_step",
           jax.jit(model.train_step(preset)).lower(params, xt, yt, lr))
    _write(out_dir, f"{preset}_eval",
           jax.jit(model.eval_batch(preset)).lower(params, xe, ye))
    _write(out_dir, f"{preset}_grad",
           jax.jit(model.grad_flat(preset)).lower(params, xt, yt))
    # Fused K-step local-training artifact (§Perf, L2).
    k = TRAIN_K
    xk = _spec((k,) + model.input_shape(preset, model.TRAIN_BATCH))
    yk = _spec((k, model.TRAIN_BATCH), jnp.int32)
    _write(out_dir, f"{preset}_train_k{k}",
           jax.jit(model.train_k_steps(preset, k)).lower(params, xk, yk, lr))

    # Metadata consumed by rust/src/runtime/meta.rs (line-oriented; the rust
    # side has no JSON dependency offline).
    meta = os.path.join(out_dir, f"{preset}.meta")
    with open(meta, "w") as f:
        f.write(f"preset={preset}\n")
        f.write(f"train_batch={model.TRAIN_BATCH}\n")
        f.write(f"eval_batch={model.EVAL_BATCH}\n")
        f.write(f"num_classes={model.NUM_CLASSES}\n")
        f.write(f"input_train={'x'.join(map(str, xt.shape))}\n")
        f.write(f"input_eval={'x'.join(map(str, xe.shape))}\n")
        f.write(f"param_total={model.param_count(preset)}\n")
        f.write(f"train_k={TRAIN_K}\n")
        for p in params:
            f.write(f"param={'x'.join(map(str, p.shape)) or '1'}\n")
    print(f"  wrote {meta}")


def export_partitioned(out_dir: str) -> None:
    """The paper's DNN-partition mechanism as three separate artifacts."""
    print("[aot] cnn partitioned step (cut at pool2)")
    params = _param_specs("cnn")
    nb = model.CNN_BOTTOM_PARAMS
    bottom, top = params[:nb], params[nb:]
    x = _spec(model.input_shape("cnn", model.TRAIN_BATCH))
    y = _spec((model.TRAIN_BATCH,), jnp.int32)
    act = _spec(model.CNN_CUT_ACT_SHAPE)
    lr = _spec((), jnp.float32)

    _write(out_dir, "cnn_bottom_fwd", jax.jit(model.bottom_fwd).lower(bottom, x))
    _write(out_dir, "cnn_top_step", jax.jit(model.top_step).lower(top, act, y, lr))
    _write(out_dir, "cnn_bottom_bwd",
           jax.jit(model.bottom_bwd).lower(bottom, x, act, lr))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--presets", default="mlp,cnn")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    presets = [p for p in args.presets.split(",") if p]
    for preset in presets:
        export_preset(preset, args.out_dir)
    if "cnn" in presets:
        export_partitioned(args.out_dir)
    print("[aot] done")


if __name__ == "__main__":
    main()
