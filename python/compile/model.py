"""L2: the FL objective DNNs (JAX fwd/bwd), built on the L1 Pallas kernels.

Two executable presets (see DESIGN.md §Substitutions — the scheduler's cost
model separately carries the paper-scale VGG-11 layer table):

* ``mlp``  — 3072 -> 64 -> 10 fully connected; fast preset used by rust
             unit/integration tests and the quickstart example.
* ``cnn``  — VGG-mini: 3x [conv3x3 + ReLU + maxpool2] then 1024 -> 128 -> 10;
             the model actually trained by the figure harness.

All dense compute (conv via im2col, FC) routes through kernels.matmul, so
both fwd and bwd run the Pallas kernel. Parameters travel as a flat, ordered
list of arrays — the ABI the rust runtime marshals as PJRT literals.

The partitioned step (bottom_fwd / top_step / bottom_bwd) realises the
paper's DNN-partition mechanism (§II-B3): the device runs the bottom layers
forward, ships the activation to the gateway, the gateway trains the top
layers and returns the error term of its first layer, and the device
back-propagates through the bottom layers. The native rust split runtime
(``rust/src/runtime/native/partition.rs``) now realises the same mechanism
without artifacts; ``examples/partitioned_step`` verifies ITS composition
is byte-identical to the fused train step at every cut point, and
``rust/tests/partition.rs`` pins the equivalence exhaustively.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import conv2d_same, matmul

# Static batch shapes baked into the AOT artifacts.
TRAIN_BATCH = 64
EVAL_BATCH = 256
NUM_CLASSES = 10
IMAGE_SHAPE = (32, 32, 3)
FLAT_DIM = 32 * 32 * 3

# CNN partition cut for the partitioned artifacts: bottom = conv1+conv2
# (through pool2), top = conv3 + fc1 + fc2. Pool boundaries are where the
# paper says DNNs should be cut to minimise the shipped activation (§II-B3b).
CNN_BOTTOM_PARAMS = 4  # c1w, c1b, c2w, c2b
CNN_CUT_ACT_SHAPE = (TRAIN_BATCH, 8, 8, 32)


# --------------------------------------------------------------------------
# Parameter initialisation (He-normal for ReLU nets, deterministic seed).
# --------------------------------------------------------------------------

def _he(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


def init_params(preset: str, seed: int = 0) -> list[jax.Array]:
    """Flat ordered parameter list for ``preset`` — the artifact ABI order."""
    key = jax.random.PRNGKey(seed)
    if preset == "mlp":
        k1, k2 = jax.random.split(key)
        del k2  # final layer is zero-init: initial loss = ln(10), stabler SGD
        return [
            _he(k1, (FLAT_DIM, 64), FLAT_DIM),
            jnp.zeros((64,), jnp.float32),
            jnp.zeros((64, NUM_CLASSES), jnp.float32),
            jnp.zeros((NUM_CLASSES,), jnp.float32),
        ]
    if preset == "cnn":
        ks = jax.random.split(key, 5)
        return [
            _he(ks[0], (3, 3, 3, 16), 27),
            jnp.zeros((16,), jnp.float32),
            _he(ks[1], (3, 3, 16, 32), 144),
            jnp.zeros((32,), jnp.float32),
            _he(ks[2], (3, 3, 32, 64), 288),
            jnp.zeros((64,), jnp.float32),
            _he(ks[3], (1024, 128), 1024),
            jnp.zeros((128,), jnp.float32),
            jnp.zeros((128, NUM_CLASSES), jnp.float32),  # zero-init head
            jnp.zeros((NUM_CLASSES,), jnp.float32),
        ]
    raise ValueError(f"unknown preset {preset!r}")


def input_shape(preset: str, batch: int) -> tuple[int, ...]:
    return (batch, FLAT_DIM) if preset == "mlp" else (batch, *IMAGE_SHAPE)


def param_count(preset: str) -> int:
    return sum(int(p.size) for p in init_params(preset))


# --------------------------------------------------------------------------
# Forward passes
# --------------------------------------------------------------------------

def _maxpool2(x):
    b, h, w, c = x.shape
    return jnp.max(x.reshape(b, h // 2, 2, w // 2, 2, c), axis=(2, 4))


def _dense(x, w, b):
    return matmul(x, w) + b


def forward(preset: str, params: list[jax.Array], x: jax.Array) -> jax.Array:
    """Logits f32[B, 10]."""
    if preset == "mlp":
        w1, b1, w2, b2 = params
        h = jax.nn.relu(_dense(x, w1, b1))
        return _dense(h, w2, b2)
    return _cnn_top(params[CNN_BOTTOM_PARAMS:], _cnn_bottom(params[:CNN_BOTTOM_PARAMS], x))


def _cnn_bottom(params: list[jax.Array], x: jax.Array) -> jax.Array:
    """Device-side portion: conv1 -> pool -> conv2 -> pool (B,8,8,32)."""
    c1w, c1b, c2w, c2b = params
    h = _maxpool2(jax.nn.relu(conv2d_same(x, c1w) + c1b))
    return _maxpool2(jax.nn.relu(conv2d_same(h, c2w) + c2b))


def _cnn_top(params: list[jax.Array], a: jax.Array) -> jax.Array:
    """Gateway-side portion: conv3 -> pool -> fc1 -> fc2 logits."""
    c3w, c3b, f1w, f1b, f2w, f2b = params
    h = _maxpool2(jax.nn.relu(conv2d_same(a, c3w) + c3b))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(_dense(h, f1w, f1b))
    return _dense(h, f2w, f2b)


# --------------------------------------------------------------------------
# Loss / train / eval / gradient probe
# --------------------------------------------------------------------------

def _xent(logits: jax.Array, y: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy; y is int32[B]."""
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def loss_fn(preset: str, params: list[jax.Array], x, y) -> jax.Array:
    return _xent(forward(preset, params, x), y)


def train_step(preset: str):
    """(params..., x, y, lr) -> (params'..., loss): one SGD step."""

    def step(params: list[jax.Array], x, y, lr):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(preset, p, x, y))(params)
        new_params = [p - lr * g for p, g in zip(params, grads)]
        return tuple(new_params) + (loss,)

    return step


def train_k_steps(preset: str, k: int):
    """(params..., xs[k,B,...], ys[k,B], lr) -> (params'..., mean_loss).

    K local SGD iterations fused into ONE artifact (§Perf, L2): the rust
    coordinator calls this once per scheduled device per round instead of K
    times, removing K-1 rounds of parameter upload/download marshalling and
    letting XLA optimize across the unrolled steps.
    """

    def stepk(params: list[jax.Array], xs, ys, lr):
        loss_sum = jnp.float32(0.0)
        for i in range(k):
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(preset, p, xs[i], ys[i])
            )(params)
            params = [p - lr * g for p, g in zip(params, grads)]
            loss_sum = loss_sum + loss
        return tuple(params) + (loss_sum / k,)

    return stepk


def eval_batch(preset: str):
    """(params..., x, y) -> (sum_loss, num_correct) over one eval batch."""

    def ev(params: list[jax.Array], x, y):
        logits = forward(preset, params, x)
        logp = jax.nn.log_softmax(logits)
        sum_loss = -jnp.sum(jnp.take_along_axis(logp, y[:, None], axis=1))
        correct = jnp.sum((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
        return sum_loss, correct

    return ev


def grad_flat(preset: str):
    """(params..., x, y) -> f32[P]: flattened minibatch gradient.

    Used by the rust side to estimate the paper's sigma_n / delta_n
    (Assumptions 1-2) that feed the divergence bound Phi_m (Theorem 1).
    """

    def gf(params: list[jax.Array], x, y):
        grads = jax.grad(lambda p: loss_fn(preset, p, x, y))(params)
        return jnp.concatenate([g.ravel() for g in grads])

    return gf


# --------------------------------------------------------------------------
# Partitioned training step (paper §II-B3): device/gateway split at pool2.
# --------------------------------------------------------------------------

def bottom_fwd(bottom: list[jax.Array], x: jax.Array) -> jax.Array:
    """Device side, forward: x -> activation shipped to the gateway."""
    return _cnn_bottom(bottom, x)


def top_step(top: list[jax.Array], act: jax.Array, y: jax.Array, lr):
    """Gateway side: trains the top portion, returns the error term.

    -> (top'..., d_act, loss) where d_act is dL/d(activation), the error of
    the first gateway-side layer that the device needs for its backward pass.
    """

    def top_loss(t, a):
        return _xent(_cnn_top(t, a), y)

    (loss, (gt, ga)) = jax.value_and_grad(top_loss, argnums=(0, 1))(top, act)
    new_top = [p - lr * g for p, g in zip(top, gt)]
    return tuple(new_top) + (ga, loss)


def bottom_bwd(bottom: list[jax.Array], x: jax.Array, d_act: jax.Array, lr):
    """Device side, backward: propagate the gateway error, SGD-update."""
    _, vjp = jax.vjp(lambda b: _cnn_bottom(b, x), bottom)
    (gb,) = vjp(d_act)
    return tuple(p - lr * g for p, g in zip(bottom, gb))
