//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Phase 1 (default): DDSRA-scheduled federated training of the MLP preset
//! over the synthetic SVHN-like corpus for 150 communication rounds,
//! STREAMING the loss curve to results/e2e_loss.csv while the run is in
//! flight (CsvSink) and buffering a copy for the closing summary
//! (MemorySink). This is the run recorded in EXPERIMENTS.md.
//!
//! Phase 2: a short VGG-mini (cnn preset) leg — 2 rounds on a reduced
//! topology — proving the conv path composes with the FL stack (the cnn
//! train step is ~300x more FLOPs, so the long run uses the MLP). The cnn
//! preset runs NATIVELY on the layer-graph engine (rayon-parallel conv
//! fwd/bwd), so phase 2 needs no artifacts; with `--features pjrt` and
//! compiled artifacts it runs through the PJRT engine instead.
//!
//! Run: `cargo run --release --example e2e_train [--rounds 150] [--skip-cnn]`

use std::path::Path;

use iiot_fl::cli::Args;
use iiot_fl::config::SimConfig;
use iiot_fl::fl::{RoundObserver, SchedulerSpec, Session};
use iiot_fl::metrics::{CsvSink, MemorySink};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    args.expect_known(&["rounds", "skip-cnn"])?;
    let rounds = args.parse_num::<usize>("rounds")?.unwrap_or(150);

    // ---------------- phase 1: long MLP run -----------------------------
    let mut cfg = SimConfig::default();
    cfg.exec_model = "mlp".into();
    cfg.cost_model = "vgg11".into();
    cfg.dataset = "svhn".into();
    let session = Session::builder(cfg).rounds(rounds).eval_every(10).build()?;
    eprintln!("[e2e] phase 1: {rounds} rounds of ddsra on svhn (mlp preset)");
    let t0 = std::time::Instant::now();
    let mut mem = MemorySink::new();
    let mut csv = CsvSink::create(Path::new("results/e2e_loss.csv"))?;
    {
        let mut observers: Vec<&mut dyn RoundObserver> = vec![&mut mem, &mut csv];
        session.run_with(&SchedulerSpec::ddsra(), &mut observers)?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let log = mem.into_log();
    println!("\n[e2e] loss curve (every 10 rounds):");
    println!("round  cum_sim_delay(s)  train_loss  test_acc");
    for r in log.records.iter().filter(|r| r.test_acc.is_some()) {
        println!(
            "{:>5}  {:>16.1}  {:>10.4}  {:>7.2}%",
            r.round,
            r.cum_delay,
            r.train_loss.unwrap_or(f64::NAN),
            r.test_acc.unwrap() * 100.0
        );
    }
    println!(
        "[e2e] final accuracy {:.2}% | simulated FL latency {:.0}s | wall {:.0}s | participation {:?}",
        log.final_accuracy().unwrap_or(0.0) * 100.0,
        log.total_delay(),
        wall,
        log.participation
    );

    // ---------------- phase 2: short CNN leg -----------------------------
    if !args.has("skip-cnn") {
        let mut cfg = SimConfig::default();
        cfg.exec_model = "cnn".into();
        cfg.cost_model = "cnn".into(); // cost model matches the executable net
        cfg.num_gateways = 2;
        cfg.num_devices = 2;
        cfg.num_channels = 1;
        cfg.dataset_max = 400; // small shards -> small train batches
        cfg.test_size = 256;
        let session = Session::builder(cfg).rounds(2).eval_every(1).build()?;
        eprintln!("[e2e] phase 2: 2 rounds of VGG-mini through the native conv engine");
        let log = session.run(&SchedulerSpec::ddsra())?;
        for r in &log.records {
            println!(
                "[e2e/cnn] round {} loss {:.4} acc {:.2}%",
                r.round,
                r.train_loss.unwrap_or(f64::NAN),
                r.test_acc.unwrap_or(0.0) * 100.0
            );
        }
        let l0 = log.records.first().and_then(|r| r.train_loss).unwrap_or(f64::NAN);
        println!("[e2e/cnn] initial loss {l0:.3} (ln 10 = 2.303) — conv path OK");
    }
    Ok(())
}
