//! Quickstart: the whole system in ~30 lines, on the Session API.
//!
//! Builds the paper's default IIoT deployment (6 shop floors, 12 devices,
//! 3 channels), derives the device-specific participation rates Γ_m from
//! gradient probes (§IV), runs 10 communication rounds of DDSRA with real
//! training of the MLP preset, and prints the learning curve.
//!
//! Needs NO artifacts: the pure-Rust layer-graph NativeBackend trains the
//! MLP out of the box — swap `exec_model` to "cnn" for native VGG-mini
//! conv training. (With `--features pjrt` and `make artifacts`, the same
//! run executes through the PJRT engine instead.)
//!
//! Run: `cargo run --release --example quickstart`

use iiot_fl::config::SimConfig;
use iiot_fl::fl::{SchedulerSpec, Session};

fn main() -> anyhow::Result<()> {
    let mut cfg = SimConfig::default();
    cfg.exec_model = "mlp".into(); // fast executable preset
    cfg.cost_model = "vgg11".into(); // paper-scale DNN for the scheduler

    // One typed builder instead of Experiment + make_scheduler + RunOpts;
    // add .until_accuracy(0.5) to stop at the Fig. 4 convergence target,
    // or stream sinks during the run via session.run_with(...).
    let session = Session::builder(cfg).rounds(10).eval_every(2).build()?;
    let log = session.run(&SchedulerSpec::ddsra())?;
    println!("scheduler: {}", log.scheme);

    println!("\nround  delay(s)  train_loss  test_acc");
    for r in &log.records {
        println!(
            "{:>5}  {:>8.1}  {:>10}  {:>8}",
            r.round,
            r.delay,
            r.train_loss.map_or("-".into(), |v| format!("{v:.4}")),
            r.test_acc.map_or("-".into(), |v| format!("{:.1}%", v * 100.0)),
        );
    }
    println!("\nper-gateway participation: {:?}", log.participation);
    println!("total FL latency: {:.1}s (simulated)", log.total_delay());
    Ok(())
}
