use iiot_fl::runtime::{Backend, Engine};
fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/statm").unwrap();
    let pages: f64 = s.split_whitespace().nth(1).unwrap().parse().unwrap();
    pages * 4096.0 / 1e6
}
fn main() -> anyhow::Result<()> {
    let engine = Engine::load(std::path::Path::new("artifacts"), "mlp")?;
    let meta = engine.meta.clone();
    let x = vec![0.1f32; meta.train_batch * meta.sample_dim()];
    let y: Vec<i32> = (0..meta.train_batch as i32).map(|i| i % 10).collect();
    let mut p = engine.init_params()?;
    println!("start rss = {:.0} MB", rss_mb());
    for i in 0..300 {
        let (np, _) = engine.train_step(&p, &x, &y, 0.01)?;
        p = np;
        if i % 100 == 99 { println!("after {} steps rss = {:.0} MB", i+1, rss_mb()); }
    }
    // also probe eval + grad paths
    let xe = vec![0.1f32; meta.eval_batch * meta.sample_dim()];
    let ye: Vec<i32> = (0..meta.eval_batch as i32).map(|i| i % 10).collect();
    for i in 0..100 { engine.eval_full(&p, &xe, &ye)?; if i%50==49 { println!("after {} eval_full rss = {:.0} MB", i+1, rss_mb()); } }
    for i in 0..100 { engine.grad(&p, &x, &y)?; if i%50==49 { println!("after {} grad rss = {:.0} MB", i+1, rss_mb()); } }
    Ok(())
}
