//! §Perf measurement probe: times every engine entry point on the request
//! path, including the fused-K local-training artifact vs K single steps.
//!
//! Run: `make artifacts && cargo run --release --example perf_probe`

use std::time::Instant;

use iiot_fl::rng::Rng;
use iiot_fl::runtime::{Backend, Engine};

fn main() -> anyhow::Result<()> {
    let engine = Engine::load(std::path::Path::new("artifacts"), "mlp")?;
    let meta = engine.meta.clone();
    let mut rng = Rng::new(1);
    let dim = meta.sample_dim();
    let x: Vec<f32> = (0..meta.train_batch * dim).map(|_| rng.normal() as f32).collect();
    let y: Vec<i32> = (0..meta.train_batch).map(|_| rng.below(10) as i32).collect();
    let mut p = engine.init_params()?;

    for _ in 0..5 {
        let (np, _) = engine.train_step(&p, &x, &y, 0.01)?;
        p = np;
    }
    let n = 100;
    let t0 = Instant::now();
    for _ in 0..n {
        let (np, _) = engine.train_step(&p, &x, &y, 0.01)?;
        p = np;
    }
    let single = t0.elapsed().as_secs_f64() / n as f64 * 1e3;
    println!("train_step (1 step):            {single:.2} ms");

    if let Some(k) = engine.fused_k() {
        let xs: Vec<f32> = (0..k * meta.train_batch * dim).map(|_| rng.normal() as f32).collect();
        let ys: Vec<i32> = (0..k * meta.train_batch).map(|_| rng.below(10) as i32).collect();
        for _ in 0..3 {
            engine.train_k_steps(&p, &xs, &ys, 0.01)?;
        }
        let t0 = Instant::now();
        let m = 30;
        for _ in 0..m {
            let (np, _) = engine.train_k_steps(&p, &xs, &ys, 0.01)?;
            p = np;
        }
        let fused = t0.elapsed().as_secs_f64() / m as f64 * 1e3;
        println!(
            "local training K={k}:            fused {fused:.2} ms vs {k}x single {:.2} ms  ({:.2}x)",
            single * k as f64,
            single * k as f64 / fused
        );
    } else {
        println!("(fused train_k artifact not built — run `make artifacts`)");
    }

    let xe: Vec<f32> = (0..meta.eval_batch * dim).map(|_| rng.normal() as f32).collect();
    let ye: Vec<i32> = (0..meta.eval_batch).map(|_| rng.below(10) as i32).collect();
    for _ in 0..3 {
        engine.eval_batch(&p, &xe, &ye)?;
    }
    let t0 = Instant::now();
    for _ in 0..30 {
        engine.eval_batch(&p, &xe, &ye)?;
    }
    println!("eval_batch (literal args):      {:.2} ms", t0.elapsed().as_secs_f64() / 30.0 * 1e3);

    // eval_full reuses device-resident parameter buffers across chunks.
    let chunks = 8;
    let xf: Vec<f32> = (0..chunks * meta.eval_batch * dim).map(|_| rng.normal() as f32).collect();
    let yf: Vec<i32> = (0..chunks * meta.eval_batch).map(|_| rng.below(10) as i32).collect();
    engine.eval_full(&p, &xf, &yf)?;
    let t0 = Instant::now();
    for _ in 0..10 {
        engine.eval_full(&p, &xf, &yf)?;
    }
    println!(
        "eval_full ({chunks} chunks, buffered): {:.2} ms/chunk",
        t0.elapsed().as_secs_f64() / 10.0 / chunks as f64 * 1e3
    );

    let t0 = Instant::now();
    for _ in 0..30 {
        engine.grad(&p, &x, &y)?;
    }
    println!("grad:                           {:.2} ms", t0.elapsed().as_secs_f64() / 30.0 * 1e3);
    Ok(())
}
