//! End-to-end validation of the paper's DNN-partition mechanism (§II-B3):
//! the composed device/gateway step
//!
//!   bottom_fwd (device) → top_step (gateway) → bottom_bwd (device)
//!
//! executed through three separate AOT artifacts must produce the SAME
//! updated parameters and loss as the fused train-step artifact. This is
//! the contract that lets the orchestrator run the fused step while the
//! cost model simulates the split placement (DESIGN.md
//! §Scheduling-vs-numerics contract).
//!
//! Run: `make artifacts && cargo run --release --example partitioned_step`

use std::path::Path;

use anyhow::Result;
use iiot_fl::rng::Rng;
use iiot_fl::runtime::engine::{lit_f32, lit_i32, run_tuple};
use iiot_fl::runtime::{Backend, Engine};

// Mirrors python/compile/model.py CNN_BOTTOM_PARAMS / CNN_CUT_ACT_SHAPE.
const BOTTOM_PARAMS: usize = 4;
const ACT_SHAPE: [usize; 4] = [64, 8, 8, 32];

fn main() -> Result<()> {
    let engine = Engine::load(Path::new("artifacts"), "cnn")?;
    let bottom_fwd = engine.compile_extra("cnn_bottom_fwd")?;
    let top_step = engine.compile_extra("cnn_top_step")?;
    let bottom_bwd = engine.compile_extra("cnn_bottom_bwd")?;

    // Random batch.
    let meta = &engine.meta;
    let mut rng = Rng::new(7);
    let xs: Vec<f32> = (0..meta.train_batch * meta.sample_dim())
        .map(|_| rng.normal() as f32)
        .collect();
    let ys: Vec<i32> = (0..meta.train_batch).map(|_| rng.below(10) as i32).collect();
    let lr = 0.01f32;

    let params = engine.init_params()?;
    let (fused, fused_loss) = engine.train_step(&params, &xs, &ys, lr)?;

    // --- partitioned execution --------------------------------------
    let lit_params = |range: std::ops::Range<usize>| -> Result<Vec<xla::Literal>> {
        range
            .map(|i| lit_f32(&params[i], &meta.param_shapes[i]))
            .collect()
    };
    // Device: bottom forward.
    let mut args = lit_params(0..BOTTOM_PARAMS)?;
    args.push(lit_f32(&xs, &meta.input_train)?);
    let act = run_tuple(&bottom_fwd, &args)?.remove(0);

    // Gateway: top training step, returns (top'..., d_act, loss).
    let mut args = lit_params(BOTTOM_PARAMS..params.len())?;
    args.push(act);
    args.push(lit_i32(&ys, meta.train_batch)?);
    args.push(xla::Literal::scalar(lr));
    let mut top_out = run_tuple(&top_step, &args)?;
    let loss_lit = top_out.pop().unwrap();
    let d_act = top_out.pop().unwrap();
    let part_loss = loss_lit.get_first_element::<f32>()?;
    let new_top: Vec<Vec<f32>> =
        top_out.iter().map(|l| l.to_vec::<f32>()).collect::<xla::Result<_>>()?;

    // Device: bottom backward with the gateway's error term.
    let mut args = lit_params(0..BOTTOM_PARAMS)?;
    args.push(lit_f32(&xs, &meta.input_train)?);
    args.push(d_act);
    args.push(xla::Literal::scalar(lr));
    let bottom_out = run_tuple(&bottom_bwd, &args)?;
    let new_bottom: Vec<Vec<f32>> =
        bottom_out.iter().map(|l| l.to_vec::<f32>()).collect::<xla::Result<_>>()?;

    // --- compare ------------------------------------------------------
    let partitioned: Vec<Vec<f32>> = new_bottom.into_iter().chain(new_top).collect();
    let mut max_diff = 0.0f32;
    for (a, b) in partitioned.iter().zip(&fused) {
        for (&x, &y) in a.iter().zip(b) {
            max_diff = max_diff.max((x - y).abs());
        }
    }
    println!("activation shape at cut: {ACT_SHAPE:?}");
    println!("fused loss       = {fused_loss:.6}");
    println!("partitioned loss = {part_loss:.6}");
    println!("max |param diff| = {max_diff:.3e}");
    anyhow::ensure!((fused_loss - part_loss).abs() < 1e-5, "loss mismatch");
    anyhow::ensure!(max_diff < 1e-5, "parameter mismatch {max_diff}");
    println!("OK: device/gateway partitioned step == fused step");
    Ok(())
}
