//! End-to-end validation of the paper's DNN-partition mechanism (§II-B):
//! the composed device/gateway step
//!
//!   device fwd → smashed activation ⇡ → gateway fwd+loss+bwd
//!             → cut gradient ⇣ → device bwd
//!
//! executed through the REAL split-execution runtime
//! (`runtime::PartitionedBackend`) must produce byte-identical updated
//! parameters, losses, eval metrics and gradients to the fused
//! layer-graph engine — at EVERY legal cut point of the chosen preset.
//! Exits non-zero on any mismatch, so it doubles as a smoke check in
//! scripts.
//!
//! Run: `cargo run --release --example partitioned_step -- [--preset cnn]`
//!      (default preset: mlp; no artifacts, no `pjrt` feature needed)

use anyhow::{ensure, Result};
use iiot_fl::cli::Args;
use iiot_fl::dnn::models;
use iiot_fl::rng::Rng;
use iiot_fl::runtime::{Backend, NativeBackend, PartitionedBackend};

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    args.expect_known(&["preset"])?;
    let preset = args.get_or("preset", "mlp");
    let fused: NativeBackend = match preset {
        "mlp" => NativeBackend::mlp(),
        "cnn" => NativeBackend::cnn(),
        other => anyhow::bail!("unknown executable preset {other:?} (mlp|cnn)"),
    };
    let depth = models::by_name(preset).expect("executable presets are in the zoo").depth();
    let meta = fused.meta().clone();

    // One deterministic batch + the fused reference step.
    let mut rng = Rng::new(7);
    let xs: Vec<f32> = (0..meta.train_batch * meta.sample_dim())
        .map(|_| rng.normal() as f32)
        .collect();
    let ys: Vec<i32> = (0..meta.train_batch).map(|_| rng.below(10) as i32).collect();
    let lr = 0.01f32;
    let params = fused.init_params()?;
    let (fused_next, fused_loss) = fused.train_step(&params, &xs, &ys, lr)?;

    println!("preset {preset}: L = {depth} layers, {} params", meta.param_total);
    println!("fused loss = {fused_loss:.6}\n");
    println!("{:>4} {:>12} {:>14} {:>10}", "cut", "act@cut", "split loss", "match");

    for cut in 0..=depth {
        let split = PartitionedBackend::preset(preset, cut)?;
        ensure!(
            split.init_params()? == params,
            "cut {cut}: split init diverged from fused init"
        );
        let (split_next, split_loss) = split.train_step(&params, &xs, &ys, lr)?;
        ensure!(
            split_loss.to_bits() == fused_loss.to_bits(),
            "cut {cut}: loss {split_loss} != fused {fused_loss}"
        );
        for (t, (a, b)) in split_next.iter().zip(&fused_next).enumerate() {
            for (i, (va, vb)) in a.iter().zip(b).enumerate() {
                ensure!(
                    va.to_bits() == vb.to_bits(),
                    "cut {cut}: param tensor {t} idx {i}: {va} != {vb}"
                );
            }
        }
        println!(
            "{:>4} {:>9} KiB {:>14.6} {:>10}",
            cut,
            split.cut_activation_elems() * 4 * meta.train_batch / 1024,
            split_loss,
            "bit-exact"
        );
    }
    println!(
        "\nOK: device/gateway split step == fused step at every cut of {preset} \
         (params, loss byte-identical)"
    );
    Ok(())
}
