//! Partition-point explorer: Table II applied to the paper-scale VGG-11,
//! cross-checked against the split-execution runtime.
//!
//! For a representative device/gateway pair, sweeps the DNN partition point
//! l ∈ 0..=L and prints the per-layer cost model outputs the DDSRA
//! scheduler optimises over: device/gateway training time, energies and
//! memory footprints (Eq. 1–5). Shows why the optimum moves with the
//! device's CPU frequency and harvested energy.
//!
//! The `act@cut` column is MEASURED, not modeled: each cut point is
//! compiled into the real split-execution runtime
//! (`runtime::PartitionedBackend`) and the column reports the size of the
//! smashed-activation tensor the device half actually emits for one
//! training batch — the communication payload the paper's uplink terms
//! assume. (The cut gradient flowing back is the same size.)
//!
//! Run: `cargo run --release --example partition_explorer -- [--cost-model vgg11]`

use iiot_fl::cli::Args;
use iiot_fl::config::SimConfig;
use iiot_fl::dnn::models;
use iiot_fl::energy;
use iiot_fl::metrics::print_table;
use iiot_fl::rng::Rng;
use iiot_fl::runtime::PartitionedBackend;
use iiot_fl::topo::Topology;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    args.expect_known(&["cost-model"])?;
    let cfg = SimConfig::default();
    let name = args.get_or("cost-model", "vgg11");
    let model = models::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown cost model {name:?}"))?;
    let topo = Topology::generate(&cfg, &mut Rng::new(cfg.seed));
    let dev = &topo.devices[0];
    let gw = &topo.gateways[dev.gateway];
    let k = cfg.local_iters;
    let f_share = gw.freq_max / gw.members.len() as f64;

    println!(
        "model {}: L = {} layers, {} params, gamma = {:.0} Mbit",
        model.name,
        model.depth(),
        model.params,
        model.gamma_bits() / 1e6
    );
    println!(
        "device 0: f = {:.2} GHz, batch = {}, mem = {:.1} GB | gateway share f = {:.2} GHz",
        dev.freq / 1e9,
        dev.train_batch,
        dev.mem / 1e9,
        f_share / 1e9
    );

    let mut rows = Vec::new();
    let mut best = (0usize, f64::INFINITY);
    for l in 0..=model.depth() {
        let t_dev = energy::device_train_time(dev, &model, l, k);
        let t_gw = energy::gateway_train_time(gw, dev, &model, l, k, f_share);
        let e_dev = energy::device_train_energy(dev, &model, l, k);
        let e_gw = energy::gateway_train_energy(gw, dev, &model, l, k, f_share);
        let m_dev = model.bottom_mem(l, dev.train_batch as u64);
        let m_gw = model.top_mem(l, dev.train_batch as u64);
        // Measured at the executable cut: bytes of the per-batch smashed
        // activation the compiled device half really produces.
        let act_mb = match PartitionedBackend::from_spec(&model, l, 0) {
            Ok(split) => {
                let bytes = split.cut_activation_elems() * 4 * dev.train_batch;
                format!("{:.2}", bytes as f64 / 1e6)
            }
            Err(_) => "n/a".into(), // spec not natively executable
        };
        let total = t_dev + t_gw;
        let dev_ok = m_dev <= dev.mem && e_dev <= dev.energy_max;
        if dev_ok && total < best.1 {
            best = (l, total);
        }
        rows.push(vec![
            l.to_string(),
            format!("{t_dev:.2}"),
            format!("{t_gw:.2}"),
            format!("{total:.2}"),
            format!("{e_dev:.2}"),
            format!("{e_gw:.2}"),
            format!("{:.0}", m_dev / 1e6),
            format!("{:.0}", m_gw / 1e6),
            act_mb,
            if dev_ok { "yes".into() } else { "NO".into() },
        ]);
    }
    print_table(
        &format!("partition sweep (K = {k} local iterations)"),
        &[
            "l",
            "t_dev(s)",
            "t_gw(s)",
            "total(s)",
            "e_dev(J)",
            "e_gw(J)",
            "memD(MB)",
            "memG(MB)",
            "act@cut(MB)",
            "dev-feasible",
        ],
        &rows,
    );
    println!(
        "\noptimal feasible partition for this pair: l = {} ({:.2}s training / round)",
        best.0, best.1
    );
    Ok(())
}
