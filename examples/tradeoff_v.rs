//! Theorem 2: the [O(1/V), O(√V)] trade-off between FL latency
//! minimisation and participation-rate satisfaction.
//!
//! Sweeps the Lyapunov control parameter V over six orders of magnitude,
//! runs the DDSRA scheduler (scheduling-only — no PJRT training needed for
//! this result) for T rounds, and reports for each V:
//!   * the time-average round delay (should DECREASE with V), and
//!   * the participation-rate deficit Σ_m max(Γ_m − rate_m, 0)
//!     (should INCREASE with V).
//!
//! Run: `cargo run --release --example tradeoff_v [--rounds 300]`
//! (no artifacts needed — scheduling-only, Γ from the native backend)

use iiot_fl::cli::Args;
use iiot_fl::config::SimConfig;
use iiot_fl::fl::{Experiment, RunOpts};
use iiot_fl::metrics::print_table;
use iiot_fl::sched::Ddsra;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let rounds = args.parse_num::<usize>("rounds")?.unwrap_or(300);

    let cfg = SimConfig::default();
    let exp = Experiment::new(cfg)?;
    // Γ_m from gradient probes, shared across the sweep.
    let stats = exp.estimate_grad_stats(4)?;
    let (_, gamma) = iiot_fl::fl::gamma_rates(
        &exp.topo,
        &stats,
        exp.cfg.num_channels,
        exp.cfg.lr,
        exp.cfg.local_iters,
    );
    println!("gamma = {gamma:?}");

    let opts = RunOpts { rounds, eval_every: 0, track_divergence: false, train: false };
    let mut rows = Vec::new();
    for &v in &[0.01, 1.0, 100.0, 1e4, 1e6] {
        let mut sched = Ddsra::new(v, gamma.clone());
        let log = exp.run(&mut sched, &opts)?;
        let avg_delay = log.total_delay() / rounds as f64;
        let deficit: f64 = gamma
            .iter()
            .zip(&log.participation)
            .map(|(&g, &p)| (g - p).max(0.0))
            .sum();
        rows.push(vec![
            format!("{v:.0e}"),
            format!("{avg_delay:.2}"),
            format!("{deficit:.3}"),
            log.participation.iter().map(|p| format!("{p:.2}")).collect::<Vec<_>>().join(" "),
        ]);
    }
    print_table(
        &format!("Theorem 2 trade-off over {rounds} rounds"),
        &["V", "avg delay (s)", "rate deficit", "participation per gateway"],
        &rows,
    );
    println!("\nexpected shape: delay falls with V; deficit grows with V (O(1/V) vs O(sqrt V)).");
    Ok(())
}
