//! Theorem 2: the [O(1/V), O(√V)] trade-off between FL latency
//! minimisation and participation-rate satisfaction.
//!
//! Sweeps the Lyapunov control parameter V over six orders of magnitude
//! with ONE paired-run call (shared experiment, shared Γ estimation,
//! byte-identical environment streams per round — scheduling-only, so no
//! backend training runs), and reports for each V:
//!   * the time-average round delay (should DECREASE with V), and
//!   * the participation-rate deficit Σ_m max(Γ_m − rate_m, 0)
//!     (should INCREASE with V).
//!
//! Run: `cargo run --release --example tradeoff_v [--rounds 300]`
//! (no artifacts needed — scheduling-only, Γ from the native backend)

use iiot_fl::cli::Args;
use iiot_fl::config::SimConfig;
use iiot_fl::fl::{SchedulerSpec, Session};
use iiot_fl::metrics::print_table;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    args.expect_known(&["rounds"])?;
    let rounds = args.parse_num::<usize>("rounds")?.unwrap_or(300);

    let session = Session::builder(SimConfig::default())
        .rounds(rounds)
        .eval_every(0)
        .schedule_only()
        .build()?;
    // Γ_m from gradient probes — estimated once, shared across the sweep.
    let gamma = session.gamma()?.to_vec();
    println!("gamma = {gamma:?}");

    let specs: Vec<SchedulerSpec> =
        [0.01, 1.0, 100.0, 1e4, 1e6].iter().map(|&v| SchedulerSpec::ddsra_with_v(v)).collect();
    let mut rows = Vec::new();
    for (run, &v) in session.run_paired(&specs)?.iter().zip(&[0.01, 1.0, 100.0, 1e4, 1e6]) {
        let log = &run.log;
        let avg_delay = log.total_delay() / rounds as f64;
        let deficit: f64 = gamma
            .iter()
            .zip(&log.participation)
            .map(|(&g, &p)| (g - p).max(0.0))
            .sum();
        rows.push(vec![
            format!("{v:.0e}"),
            format!("{avg_delay:.2}"),
            format!("{deficit:.3}"),
            log.participation.iter().map(|p| format!("{p:.2}")).collect::<Vec<_>>().join(" "),
        ]);
    }
    print_table(
        &format!("Theorem 2 trade-off over {rounds} rounds"),
        &["V", "avg delay (s)", "rate deficit", "participation per gateway"],
        &rows,
    );
    println!("\nexpected shape: delay falls with V; deficit grows with V (O(1/V) vs O(sqrt V)).");
    Ok(())
}
